//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The build environment for this repo is hermetic (no crates.io
//! network), and `anyhow` is the only external dependency the workspace
//! ever had — so this vendored micro-implementation provides exactly the
//! API surface the codebase uses:
//!
//! * [`Error`] — a message + optional boxed cause chain,
//! * [`Result<T>`] — alias with `Error` as the default error type,
//! * `anyhow!` / `bail!` / `ensure!` macros,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * blanket `From<E: std::error::Error>` so `?` converts std errors,
//! * `{e}` prints the outermost message, `{e:#}` the full `a: b: c`
//!   chain, `{e:?}` an anyhow-style "Caused by:" block.
//!
//! Semantics intentionally mirror the real crate for these paths; code
//! written against this stub keeps working if the real `anyhow` is ever
//! swapped back in. Not implemented (unused in this repo): downcasting,
//! backtraces, `Error::new` adoption of non-`Display` payloads.

use std::fmt;

/// Error type: an outermost message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut stack = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            stack.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        stack.into_iter()
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below
// coherent next to core's reflexive `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Flatten the std source chain into ours.
        fn build(e: &(dyn std::error::Error + 'static)) -> Error {
            Error {
                msg: e.to_string(),
                source: e.source().map(|s| Box::new(build(s))),
            }
        }
        build(&e)
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T>: Sized {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a formatted message, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_chain() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        assert!(format!("{e:?}").contains("Caused by:"));
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "middle", "inner"]);
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn question_mark_from_std_error() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading table").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading table: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "gpt4")).unwrap_err();
        assert_eq!(format!("{e}"), "missing gpt4");
        assert_eq!(Some(7).context("x").unwrap(), 7);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("base {}", 42));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: base 42");
    }

    #[test]
    fn macros() {
        let x = 3;
        let e = anyhow!("got {x} of {}", 5);
        assert_eq!(format!("{e}"), "got 3 of 5");
        fn b() -> Result<()> {
            bail!("nope: {}", 9)
        }
        assert_eq!(format!("{}", b().unwrap_err()), "nope: 9");
        fn en(v: u32) -> Result<u32> {
            ensure!(v < 10, "v too big: {v}");
            Ok(v)
        }
        assert!(en(3).is_ok());
        assert_eq!(format!("{}", en(30).unwrap_err()), "v too big: 30");
    }
}
