# Convenience targets. Tier-1 gate = `make tier1` (ROADMAP.md).

.PHONY: tier1 ci test bench bench-optimizer port-check

tier1:
	scripts/tier1.sh

# What GitHub Actions runs (tier1 + optimizer bench smoke on a tiny grid).
ci:
	scripts/ci.sh

test:
	cargo test -q

# Full bench sweep (human-readable reports on stdout).
bench:
	cargo bench --bench optimizer
	cargo bench --bench cache
	cargo bench --bench scorer
	cargo bench --bench batcher
	cargo bench --bench cascade_e2e

# Regenerate the committed optimizer perf trajectory (machine-readable).
bench-optimizer:
	cargo bench --bench optimizer -- --json BENCH_optimizer.json

# Algorithm-equivalence + speedup harness (pure python; no toolchain).
port-check:
	python3 scripts/check_optimizer_port.py
