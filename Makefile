# Convenience targets. Tier-1 gate = `make tier1` (ROADMAP.md).

.PHONY: tier1 ci test bench bench-optimizer bench-serve bench-front-door port-check doc

# API docs (rustdoc). The crate sets #![warn(missing_docs)] and tier1's
# clippy -D warnings promotes that to an error, so public items cannot
# ship undocumented. CI uploads target/doc as a per-PR artifact.
doc:
	cargo doc --no-deps

tier1:
	scripts/tier1.sh

# What GitHub Actions runs on every push/PR (optimizer-parity harness +
# tier1 + bench smoke on a tiny grid). The nightly `bench` workflow
# additionally runs the full `make bench-optimizer` and commits the
# refreshed BENCH_optimizer.json.
ci:
	scripts/ci.sh

test:
	cargo test -q

# Full bench sweep (human-readable reports on stdout).
bench:
	cargo bench --bench optimizer
	cargo bench --bench cache
	cargo bench --bench scorer
	cargo bench --bench batcher
	cargo bench --bench cascade_e2e
	cargo bench --bench serve_hot_path

# Regenerate the committed optimizer perf trajectory (machine-readable).
# Absolute path: cargo runs bench binaries with cwd = the package root
# (rust/), so a relative path would silently write rust/BENCH_optimizer.json
# and orphan the committed file (and its history) at the repo root.
bench-optimizer:
	cargo bench --bench optimizer -- --json $(CURDIR)/BENCH_optimizer.json

# Regenerate the committed serve-path contention trajectory (sharded
# cache + wait-free snapshots vs the shard1/RwLock baseline). Same
# absolute-path caveat as bench-optimizer.
bench-serve:
	cargo bench --bench serve_hot_path -- --json $(CURDIR)/BENCH_serve.json

# Regenerate the committed front-door trajectory: frugald (sim
# marketplace, ephemeral loopback port) driven by loadgen's closed- and
# open-loop sweeps over real TCP. The script builds both binaries,
# supervises the daemon, and drains it with /shutdown.
bench-front-door:
	scripts/bench_front_door.sh $(CURDIR)/BENCH_front_door.json --bench

# Algorithm-equivalence + speedup harness (pure python; no toolchain).
# CI runs it with --quick (all correctness gates, no wall-clock timing).
port-check:
	python3 scripts/check_optimizer_port.py
