"""Validation of the built artifact tree (skipped until `make artifacts`).

These mirror the rust-side integration tests from the python side: the
manifest, datasets, response tables and HLO files must be mutually
consistent, and a sampled model artifact must reproduce the response
table's predictions when recompiled by JAX itself.
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_covers_all_datasets_and_models(manifest):
    names = {d["dataset"] for d in manifest["datasets"]}
    assert names == {"headlines", "overruling", "coqa"}
    for d in manifest["datasets"]:
        assert len(d["models"]) == 12
        for m in d["models"]:
            for b in map(str, manifest["batch_sizes"]):
                path = os.path.join(ART, m["artifacts"][b])
                assert os.path.exists(path), path
                assert os.path.getsize(path) > 10_000
        assert 0.3 < d["models"][0]["test_acc"] <= 1.0


def test_quality_tiers_preserved_in_aggregate(manifest):
    # The simulated marketplace should preserve the paper's quality tiers
    # in aggregate: the top of the capacity ladder (gpt4/chatgpt/gpt_j,
    # the heavily-trained models) must dominate the weak tier (gpt_curie,
    # fairseq, cohere). Which *specific* model tops each dataset is noisy —
    # the paper itself has GPT-3 beat GPT-4 on COQA.
    avg = {}
    for d in manifest["datasets"]:
        for m in d["models"]:
            avg.setdefault(m["name"], []).append(m["test_acc"])
    means = {k: float(np.mean(v)) for k, v in avg.items()}
    strong = max(means[k] for k in ("gpt4", "chatgpt", "gpt_j"))
    weak = np.mean([means[k] for k in ("gpt_curie", "fairseq_gpt", "cohere_xlarge")])
    assert strong > weak + 0.05, means
    ranked = sorted(means, key=means.get, reverse=True)
    assert {"gpt4", "chatgpt"} & set(ranked[:3]), ranked


def test_response_tables_consistent(manifest):
    for d in manifest["datasets"]:
        with open(os.path.join(ART, "responses", f"{d['dataset']}.json")) as f:
            table = json.load(f)
        with open(os.path.join(ART, "data", d["dataset"], "test.json")) as f:
            test = json.load(f)
        split = table["splits"]["test"]
        assert split["labels"] == test["labels"]
        for m in d["models"]:
            entry = split["models"][m["name"]]
            preds = np.asarray(entry["pred"])
            labels = np.asarray(split["labels"])
            acc = float((preds == labels).mean())
            assert abs(acc - m["test_acc"]) < 1e-6
            assert np.asarray(entry["correct"]).tolist() == (preds == labels).astype(int).tolist()
            scores = np.asarray(entry["score"])
            assert ((scores >= 0) & (scores <= 1)).all()


def test_scorer_scores_are_informative(manifest):
    # Pooled over models, correct answers should score higher on average —
    # the property the cascade relies on.
    for d in manifest["datasets"]:
        with open(os.path.join(ART, "responses", f"{d['dataset']}.json")) as f:
            table = json.load(f)
        split = table["splits"]["test"]
        sc, si = [], []
        for m in d["models"]:
            entry = split["models"][m["name"]]
            s = np.asarray(entry["score"])
            c = np.asarray(entry["correct"]).astype(bool)
            sc.append(s[c])
            si.append(s[~c])
        sep = np.concatenate(sc).mean() - np.concatenate(si).mean()
        assert sep > 0.05, f"{d['dataset']}: scorer separation {sep}"


def test_hlo_artifacts_structurally_sound(manifest):
    """Every exported HLO declares the right entry signature and carries its
    constants un-elided. (Numeric HLO↔python agreement is asserted through
    the actual serving runtime by the rust integration test
    `pjrt_execution_matches_response_table` and `frugalgpt verify`.)"""
    for d in manifest["datasets"]:
        for m in d["models"][:3] + [d["models"][-1]]:
            for b in ("1", "8"):
                path = os.path.join(ART, m["artifacts"][b])
                text = open(path).read()
                assert "{...}" not in text, f"{path}: elided constants"
                assert f"s32[{b},{manifest['seq']}]" in text, path
                assert f"f32[{b},{d['n_classes']}]" in text, path
        sc = d["scorer"]["artifacts"]
        text = open(os.path.join(ART, sc["1"])).read()
        assert f"s32[1,{d['scorer_seq']}]" in text
        assert "f32[1,1]" in text
