"""Dataset generator invariants: layout, label rules, split statistics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data


@pytest.fixture(scope="module")
def small_specs():
    return {
        name: data.dataclasses.replace(spec, size=400)
        for name, spec in data.SPECS.items()
    }


def test_specs_match_paper_table2():
    assert data.SPECS["headlines"].size == 10000
    assert data.SPECS["overruling"].size == 2400
    assert data.SPECS["coqa"].size == 7982
    assert data.SPECS["headlines"].n_examples == 8
    assert data.SPECS["overruling"].n_examples == 5
    assert data.SPECS["coqa"].n_examples == 2
    assert data.SPECS["headlines"].n_classes == 4
    assert data.SPECS["overruling"].n_classes == 2


def test_layout_fixed_positions(small_specs):
    for spec in small_specs.values():
        ds = data.generate(spec)
        toks = ds["tokens"]
        assert toks.shape == (spec.size, data.SEQ)
        # example blocks
        for j in range(spec.n_examples):
            assert (toks[:, j * spec.block_len] == data.SEP_EX).all()
            labels = toks[:, j * spec.block_len + 2]
            assert ((labels >= data.LABEL_BASE)
                    & (labels < data.LABEL_BASE + spec.n_classes)).all()
        # query segment
        assert (toks[:, spec.q_offset] == data.CLS).all()
        assert (toks[:, spec.q_offset + 1 + spec.qlen] == data.QSEP).all()
        # padding after used_len
        assert (toks[:, spec.used_len:] == data.PAD).all()


def test_label_balance_and_tiers(small_specs):
    for spec in small_specs.values():
        ds = data.generate(spec)
        counts = np.bincount(ds["labels"], minlength=spec.n_classes)
        assert counts.min() > 0
        tier_frac = np.bincount(ds["tiers"], minlength=3) / spec.size
        for t in range(3):
            assert abs(tier_frac[t] - spec.tier_probs[t]) < 0.12, (spec.name, t)


def test_episodic_items_marked_and_covered(small_specs):
    for spec in small_specs.values():
        ds = data.generate(spec)
        epi = ds["episodic"].astype(bool)
        if not epi.any():
            continue
        toks = ds["tokens"][epi]
        q = toks[:, spec.q_offset + 1: spec.q_offset + 1 + spec.qlen]
        # every episodic query carries the marker
        assert (q == data.EPI_MARK).any(axis=1).all()
        # episodic items are tier 0
        assert (ds["tiers"][epi] == 0).all()


def test_split_disjoint_and_complete(small_specs):
    spec = small_specs["headlines"]
    ds = data.generate(spec)
    tr, te = set(ds["train_idx"].tolist()), set(ds["test_idx"].tolist())
    assert not (tr & te)
    assert len(tr) + len(te) == spec.size
    assert len(tr) == int(spec.size * spec.train_frac)


def test_generation_is_deterministic(small_specs):
    spec = small_specs["overruling"]
    a = data.generate(spec)
    b = data.generate(spec)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])


@settings(max_examples=10, deadline=None)
@given(keep=st.integers(0, 8))
def test_truncate_examples_layout(keep):
    spec = data.dataclasses.replace(data.SPECS["headlines"], size=50)
    ds = data.generate(spec)
    keep_arr = np.full(50, keep)
    out = data.truncate_examples(ds["tokens"], spec, keep_arr)
    k = min(keep, spec.n_examples)
    # kept blocks identical, dropped blocks zero, query untouched
    assert np.array_equal(out[:, : k * spec.block_len],
                          ds["tokens"][:, : k * spec.block_len])
    assert (out[:, k * spec.block_len: spec.q_offset] == data.PAD).all()
    assert np.array_equal(out[:, spec.q_offset:], ds["tokens"][:, spec.q_offset:])


def test_scorer_input_layout():
    spec = data.dataclasses.replace(data.SPECS["coqa"], size=30)
    ds = data.generate(spec)
    answers = np.arange(30, dtype=np.int32) % spec.n_classes
    s = data.scorer_input(ds["tokens"], spec, answers)
    assert s.shape == (30, spec.scorer_seq)
    assert (s[:, 0] == data.CLS).all()
    assert (s[:, spec.qlen + 1] == data.QSEP).all()
    assert np.array_equal(s[:, spec.qlen + 2], data.LABEL_BASE + answers)
    assert (s[:, spec.qlen + 3:] == data.PAD).all()


def test_token_map_has_no_collisions():
    # signal token ranges must be disjoint
    kw = range(data.KW_BASE, data.KW_BASE + 12 * data.NK)
    a = range(data.A_BASE, data.A_BASE + data.NPAIR)
    b = range(data.B_BASE, data.B_BASE + data.NPAIR)
    d = range(data.DIR_BASE, data.DIR_BASE + 12)
    n = range(data.NOISE_BASE, data.VOCAB)
    ranges = [kw, a, b, d, n]
    for i, r1 in enumerate(ranges):
        for r2 in ranges[i + 1:]:
            assert not (set(r1) & set(r2)), (r1, r2)
    assert data.LABEL_BASE + 12 <= data.EPI_MARK
    assert max(data.DIR_BASE + 11, data.B_BASE + data.NPAIR - 1) < data.VOCAB


def test_tier1_all_labels_realizable():
    # regression test for the NPAIR < n_classes crash
    spec = data.dataclasses.replace(data.SPECS["coqa"], size=200)
    ds = data.generate(spec)  # would raise if (i, label) unrealizable
    assert (ds["tiers"] == 1).any()
