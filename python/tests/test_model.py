"""Model (L2) tests: shapes, pallas/ref equivalence, training smoke, and
the AOT export round-trip (HLO text with baked constants)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, model, train


def _cfg(d=16, layers=1, n_out=4, seq=data.SEQ, pool_pos=0):
    return model.ModelConfig(vocab=data.VOCAB, seq=seq, d_model=d,
                             n_layers=layers, n_heads=d // 8, n_out=n_out,
                             pool_pos=pool_pos)


@pytest.fixture(scope="module")
def tiny():
    cfg = _cfg()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, data.VOCAB, size=(4, cfg.seq), dtype=np.int32))
    return cfg, params, toks


def test_output_shape_and_finite(tiny):
    cfg, params, toks = tiny
    out = model.apply(params, toks, cfg)
    assert out.shape == (4, cfg.n_out)
    assert np.isfinite(np.asarray(out)).all()


def test_pallas_and_ref_paths_agree(tiny):
    cfg, params, toks = tiny
    a = np.asarray(model.apply(params, toks, cfg, use_pallas=False))
    b = np.asarray(model.apply(params, toks, cfg, use_pallas=True))
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)


def test_pad_variation_changes_little_but_batch_independent(tiny):
    cfg, params, toks = tiny
    # same row in different batch positions must give the same output
    row = toks[:1]
    batch = jnp.concatenate([row, toks[1:]], axis=0)
    single = np.asarray(model.apply(params, row, cfg))
    inbatch = np.asarray(model.apply(params, batch, cfg))[:1]
    np.testing.assert_allclose(single, inbatch, atol=1e-5, rtol=1e-5)


def test_num_params_counts():
    cfg = _cfg(d=16, layers=2)
    params = model.init_params(jax.random.PRNGKey(1), cfg)
    n = model.num_params(params)
    # embedding (160*16) + pos (64*16) dominate; sanity bounds
    assert 5_000 < n < 100_000


def test_training_learns_the_easy_tier():
    spec = data.dataclasses.replace(data.SPECS["overruling"], size=600)
    ds = data.generate(spec)
    cfg = _cfg(d=24, layers=2, n_out=spec.n_classes, pool_pos=spec.q_offset)
    tcfg = train.TrainConfig(steps=150, batch=48, lr=8e-3, seed=3)
    params, metrics = train.train_classifier(spec, ds, cfg, tcfg)
    # binary task, 150 steps: must be clearly above chance on train
    assert metrics["train_acc"] > 0.6, metrics


def test_scorer_training_separates():
    spec = data.dataclasses.replace(data.SPECS["overruling"], size=400)
    ds = data.generate(spec)
    # synthetic scorer rows: answer == label is correct
    rng = np.random.default_rng(0)
    answers = np.where(rng.random(400) < 0.5, ds["labels"],
                       (ds["labels"] + 1) % spec.n_classes).astype(np.int32)
    rows = data.scorer_input(ds["tokens"], spec, answers)
    correct = (answers == ds["labels"]).astype(np.int32)
    cfg = _cfg(d=16, layers=1, n_out=1, seq=spec.scorer_seq)
    tcfg = train.TrainConfig(steps=200, batch=48, lr=8e-3, seed=4)
    params, m = train.train_scorer(spec, rows, correct, cfg, tcfg)
    assert m["score_sep"] > 0.1, m  # correct answers score higher


def test_predict_handles_ragged_tail():
    cfg = _cfg()
    params = model.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, data.VOCAB, size=(19, cfg.seq), dtype=np.int32)
    preds = train.predict(params, toks, cfg, batch=8)
    assert preds.shape == (19,)
    assert (preds < cfg.n_out).all()


def test_aot_export_roundtrip(tmp_path):
    """Export → HLO text with baked constants, no elisions, one s32 param."""
    cfg = _cfg(d=16, layers=1)
    params = model.init_params(jax.random.PRNGKey(5), cfg)
    out = os.path.join(tmp_path, "m.hlo.txt")
    n = aot.export_model(params, cfg, cfg.seq, out, batch=2)
    text = open(out).read()
    assert n == len(text) > 10_000
    assert "{...}" not in text, "constants must not be elided"
    assert "s32[2,64]" in text, "entry must take (batch=2, seq) tokens"
    assert "ENTRY" in text


def test_export_batches_agree_with_apply(tmp_path):
    """The lowered fn (pallas path) equals direct apply numerics."""
    cfg = _cfg(d=16, layers=1)
    params = model.init_params(jax.random.PRNGKey(6), cfg)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, data.VOCAB, size=(2, cfg.seq), dtype=np.int32))

    def fn(t):
        return model.apply(params, t, cfg, use_pallas=True)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, cfg.seq), jnp.int32))
    compiled = lowered.compile()
    np.testing.assert_allclose(
        np.asarray(compiled(toks)),
        np.asarray(model.apply(params, toks, cfg)),
        atol=2e-5, rtol=1e-4,
    )
