"""Kernel correctness: Pallas (interpret) vs pure-jnp oracles.

This is the CORE L1 correctness signal — hypothesis sweeps shapes and
value distributions; assert_allclose against ref.py at float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.layernorm import layernorm
from compile.kernels.ref import attention_ref, layernorm_ref

ATOL = 2e-5


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 6, 8]),
    seq=st.sampled_from([32, 64, 96, 128]),
    d=st.sampled_from([4, 8, 16]),
    scale=st.sampled_from([0.1, 1.0, 4.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bh, seq, d, scale, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, bh, seq, d, scale=scale)
    k = _rand(rng, bh, seq, d, scale=scale)
    v = _rand(rng, bh, seq, d, scale=scale)
    np.testing.assert_allclose(
        np.asarray(attention(q, k, v)),
        np.asarray(attention_ref(q, k, v)),
        atol=ATOL, rtol=1e-4,
    )


def test_attention_block_sizes_equivalent():
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, 4, 64, 8) for _ in range(3))
    base = np.asarray(attention(q, k, v))
    for bq, bk in [(16, 16), (16, 32), (32, 16), (64, 64)]:
        out = np.asarray(attention(q, k, v, block_q=bq, block_k=bk))
        np.testing.assert_allclose(out, base, atol=ATOL, rtol=1e-4,
                                   err_msg=f"block_q={bq}, block_k={bk}")


def test_attention_large_logits_stable():
    # online-softmax must not overflow with large score magnitudes
    rng = np.random.default_rng(1)
    q, k, v = (_rand(rng, 2, 64, 8, scale=30.0) for _ in range(3))
    out = np.asarray(attention(q, k, v))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, np.asarray(attention_ref(q, k, v)),
                               atol=1e-3, rtol=1e-3)


def test_attention_rejects_indivisible_seq():
    rng = np.random.default_rng(2)
    q, k, v = (_rand(rng, 1, 48, 8) for _ in range(3))
    with pytest.raises(ValueError):
        attention(q, k, v)  # 48 not divisible by default 32


def test_attention_uniform_when_keys_identical():
    # identical keys → softmax uniform → output = mean of values
    rng = np.random.default_rng(3)
    q = _rand(rng, 1, 32, 8)
    k = jnp.ones((1, 32, 8), jnp.float32)
    v = _rand(rng, 1, 32, 8)
    out = np.asarray(attention(q, k, v))
    expect = np.repeat(np.asarray(v).mean(axis=1, keepdims=True), 32, axis=1)
    np.testing.assert_allclose(out, expect, atol=ATOL, rtol=1e-4)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([32, 64, 256]),
    d=st.sampled_from([8, 24, 64]),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(rows, d, scale, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, rows, d, scale=scale)
    g = _rand(rng, d)
    b = _rand(rng, d)
    np.testing.assert_allclose(
        np.asarray(layernorm(x, g, b)),
        np.asarray(layernorm_ref(x, g, b)),
        atol=1e-4, rtol=1e-4,
    )


def test_layernorm_output_is_normalized():
    rng = np.random.default_rng(4)
    x = _rand(rng, 64, 32, scale=5.0)
    out = np.asarray(layernorm(x, jnp.ones(32), jnp.zeros(32)))
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-3)


def test_layernorm_rejects_indivisible_rows():
    with pytest.raises(ValueError):
        layernorm(jnp.zeros((33, 8)), jnp.ones(8), jnp.zeros(8))


# ---------------------------------------------------------------------------
# kernels inside jit / grad contexts (as the models use them)
# ---------------------------------------------------------------------------

def test_attention_composes_with_jit():
    rng = np.random.default_rng(5)
    q, k, v = (_rand(rng, 2, 32, 8) for _ in range(3))

    @jax.jit
    def f(q, k, v):
        return attention(q, k, v).sum()

    assert np.isfinite(float(f(q, k, v)))
