"""AOT build orchestrator: data → training → HLO text artifacts.

This is the ONLY entry point that runs Python; after `make artifacts`
completes, the Rust binary is self-contained. For every dataset it:

1. generates the synthetic dataset and writes ``artifacts/data/``,
2. trains the 12 simulated LLM APIs (capacity/seed/noise per the roster
   below) and the DistilBERT-analog reliability scorer,
3. computes the full train+test *response table* (every model's prediction
   and scorer score for every item) → ``artifacts/responses/`` — the Rust
   cascade optimizer consumes this offline table; the Rust runtime
   independently re-verifies a sample of it through PJRT (integration
   test), proving HLO == python numerics,
4. lowers each model (weights baked as constants, Pallas kernels enabled)
   to **HLO text** at batch sizes {1, 8, 32} → ``artifacts/models/``,
5. writes ``artifacts/manifest.json`` describing everything.

HLO *text*, not ``.serialize()``: jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod

BATCH_SIZES = (1, 8, 32)


@dataclasses.dataclass(frozen=True)
class ApiSpec:
    """One simulated commercial LLM API.

    Pricing is the paper's Table 1, in USD: per-10M input tokens, per-10M
    output tokens, and a fixed per-request fee. ``size_b`` is the paper's
    reported parameter count (billions) — used only for reporting.
    Capacity/steps/noise/seed shape the simulated model's skill profile.
    """

    name: str
    provider: str
    size_b: float           # billions of params per paper Table 1 (NA → 0)
    usd_per_10m_input: float
    usd_per_10m_output: float
    usd_per_request: float
    d_model: int
    n_layers: int
    steps: int
    label_noise: float
    seed: int
    # Synthetic latency model for serving experiments (ms): per-request
    # base + per-1k-token component, loosely scaled with model size.
    lat_base_ms: float
    lat_per_1k_tok_ms: float


# The 12 APIs of paper Table 1. Capacities are chosen so accuracy roughly
# tracks the paper's quality tiers while keeping per-model diversity
# (GPT-J is deliberately well-trained: the paper's HEADLINES cascade leans
# on it as the cheap first stage).
APIS: List[ApiSpec] = [
    ApiSpec("gpt_curie", "openai", 6.7, 2.0, 2.0, 0.0, 24, 2, 500, 0.05, 101, 35, 35),
    ApiSpec("chatgpt", "openai", 0.0, 2.0, 2.0, 0.0, 48, 2, 1000, 0.02, 102, 40, 40),
    ApiSpec("gpt3", "openai", 175.0, 20.0, 20.0, 0.0, 48, 3, 700, 0.02, 103, 90, 80),
    ApiSpec("gpt4", "openai", 0.0, 30.0, 60.0, 0.0, 64, 3, 1000, 0.0, 104, 150, 120),
    ApiSpec("j1_large", "ai21", 7.5, 0.0, 30.0, 0.0003, 24, 2, 600, 0.04, 105, 40, 40),
    ApiSpec("j1_grande", "ai21", 17.0, 0.0, 80.0, 0.0008, 32, 2, 600, 0.04, 106, 55, 50),
    ApiSpec("j1_jumbo", "ai21", 178.0, 0.0, 250.0, 0.005, 48, 3, 700, 0.03, 107, 100, 90),
    ApiSpec("cohere_xlarge", "cohere", 52.0, 10.0, 10.0, 0.0, 40, 2, 600, 0.03, 108, 70, 60),
    ApiSpec("forefront_qa", "forefrontai", 16.0, 5.8, 5.8, 0.0, 32, 2, 600, 0.04, 109, 55, 50),
    ApiSpec("gpt_j", "textsynth", 6.0, 0.2, 5.0, 0.0, 32, 2, 1500, 0.02, 110, 30, 30),
    ApiSpec("fairseq_gpt", "textsynth", 13.0, 0.6, 15.0, 0.0, 24, 2, 500, 0.05, 111, 45, 40),
    ApiSpec("gpt_neox", "textsynth", 20.0, 1.4, 35.0, 0.0, 32, 2, 900, 0.03, 112, 50, 45),
]

SCORER_D, SCORER_LAYERS, SCORER_STEPS = 32, 2, 900


def to_hlo_text(lowered) -> str:
    """jax lowered → XLA HLO text (the rust-side interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True — the baked model weights ARE the payload;
    # the default elides them as `{...}` which the rust-side text parser
    # cannot reconstruct.
    return comp.as_hlo_text(True)


def export_model(params: Dict, mcfg: model_mod.ModelConfig, seq: int,
                 out_path: str, batch: int) -> int:
    """Lower apply(params, ·) with baked weights + Pallas kernels to HLO
    text for a fixed (batch, seq) int32 input. Returns file size."""
    def fn(tokens):
        return model_mod.apply(params, tokens, mcfg, use_pallas=True)

    spec = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def mcfg_for(api: ApiSpec, n_out: int, pool_pos: int) -> model_mod.ModelConfig:
    return model_mod.ModelConfig(
        vocab=data_mod.VOCAB, seq=data_mod.SEQ, d_model=api.d_model,
        n_layers=api.n_layers, n_heads=api.d_model // 8, n_out=n_out,
        pool_pos=pool_pos)


def build_dataset(spec: data_mod.DatasetSpec, out_dir: str, log) -> dict:
    """Run steps 1–4 for one dataset; returns its manifest fragment."""
    t0 = time.time()
    ds = data_mod.generate(spec)
    data_mod.write_dataset(ds, os.path.join(out_dir, "data"))
    log(f"[{spec.name}] data generated ({spec.size} items) "
        f"in {time.time() - t0:.1f}s")

    model_dir = os.path.join(out_dir, "models", spec.name)
    os.makedirs(model_dir, exist_ok=True)
    tr_idx, te_idx = ds["train_idx"], ds["test_idx"]
    n_tr = len(tr_idx)

    manifest_models = []
    responses = {"train": {}, "test": {}}
    all_scorer_rows, all_scorer_targets = [], []
    for api in APIS:
        t0 = time.time()
        mcfg = mcfg_for(api, spec.n_classes, spec.q_offset)
        # Smaller models tolerate (and need) a hotter schedule.
        lr = 8e-3 if api.d_model <= 40 else 6e-3
        tcfg = train_mod.TrainConfig(
            steps=api.steps, batch=48, lr=lr, label_noise=api.label_noise,
            subsample=0.9, seed=api.seed + spec.seed * 1000)
        params, metrics = train_mod.train_classifier(spec, ds, mcfg, tcfg)
        preds_tr = train_mod.predict(params, ds["tokens"][tr_idx], mcfg)
        preds_te = train_mod.predict(params, ds["tokens"][te_idx], mcfg)
        responses["train"][api.name] = preds_tr
        responses["test"][api.name] = preds_te
        # Scorer training rows: (query, this model's answer) → correct?
        all_scorer_rows.append(data_mod.scorer_input(
            ds["tokens"][tr_idx], spec, preds_tr))
        all_scorer_targets.append(
            (preds_tr == ds["labels"][tr_idx]).astype(np.int32))

        paths = {}
        for b in BATCH_SIZES:
            p = os.path.join(model_dir, f"{api.name}.b{b}.hlo.txt")
            export_model(params, mcfg, data_mod.SEQ, p, b)
            paths[str(b)] = os.path.relpath(p, out_dir)
        manifest_models.append({
            "name": api.name, "provider": api.provider, "size_b": api.size_b,
            "pricing": {
                "usd_per_10m_input": api.usd_per_10m_input,
                "usd_per_10m_output": api.usd_per_10m_output,
                "usd_per_request": api.usd_per_request,
            },
            "latency_ms": {"base": api.lat_base_ms,
                           "per_1k_tokens": api.lat_per_1k_tok_ms},
            "d_model": api.d_model, "n_layers": api.n_layers,
            "train_acc": metrics["train_acc"], "test_acc": metrics["test_acc"],
            "artifacts": paths,
        })
        log(f"[{spec.name}] {api.name:>14} trained {api.steps} steps "
            f"({time.time() - t0:.1f}s) train_acc={metrics['train_acc']:.3f} "
            f"test_acc={metrics['test_acc']:.3f}")

    # ---- scorer ----
    t0 = time.time()
    scorer_tokens = np.concatenate(all_scorer_rows)
    scorer_targets = np.concatenate(all_scorer_targets)
    # Subsample for training speed; evaluation uses everything.
    rng = np.random.default_rng(spec.seed)
    sub = rng.permutation(len(scorer_tokens))[: min(60000, len(scorer_tokens))]
    scfg = model_mod.ModelConfig(
        vocab=data_mod.VOCAB, seq=spec.scorer_seq, d_model=SCORER_D,
        n_layers=SCORER_LAYERS, n_heads=SCORER_D // 8, n_out=1)
    stcfg = train_mod.TrainConfig(steps=SCORER_STEPS, batch=64, lr=6e-3,
                                  seed=spec.seed + 7)
    sparams, smetrics = train_mod.train_scorer(
        spec, scorer_tokens[sub], scorer_targets[sub], scfg, stcfg)
    log(f"[{spec.name}] scorer trained ({time.time() - t0:.1f}s) "
        f"sep={smetrics['score_sep']:.3f} acc={smetrics['score_acc']:.3f}")

    scorer_paths = {}
    for b in BATCH_SIZES:
        p = os.path.join(model_dir, f"scorer.b{b}.hlo.txt")
        # Scorer logits are exported raw; rust applies the sigmoid (cheaper
        # than baking it: keeps the HLO head shared with classifiers).
        export_model(sparams, scfg, spec.scorer_seq, p, b)
        scorer_paths[str(b)] = os.path.relpath(p, out_dir)

    # ---- response tables (scored) ----
    resp_dir = os.path.join(out_dir, "responses")
    os.makedirs(resp_dir, exist_ok=True)
    table = {"dataset": spec.name, "models": [a.name for a in APIS],
             "splits": {}}
    for split, idx in (("train", tr_idx), ("test", te_idx)):
        labels = ds["labels"][idx]
        entry = {"labels": labels.tolist(), "models": {}}
        for api in APIS:
            preds = responses[split][api.name]
            srows = data_mod.scorer_input(ds["tokens"][idx], spec, preds)
            scores = train_mod.predict_scores(sparams, srows, scfg)
            entry["models"][api.name] = {
                "pred": preds.tolist(),
                "score": np.round(scores, 6).tolist(),
                "correct": (preds == labels).astype(int).tolist(),
            }
        table["splits"][split] = entry
    with open(os.path.join(resp_dir, f"{spec.name}.json"), "w") as f:
        json.dump(table, f)

    return {
        "dataset": spec.name, "domain": spec.domain, "size": spec.size,
        "n_classes": spec.n_classes, "n_examples": spec.n_examples,
        "seq": data_mod.SEQ, "qlen": spec.qlen,
        "block_len": spec.block_len, "q_offset": spec.q_offset,
        "scorer_seq": spec.scorer_seq,
        "answer_lens": [spec.answer_len(c) for c in range(spec.n_classes)],
        "n_train": int(n_tr), "n_test": int(len(te_idx)),
        "models": manifest_models,
        "scorer": {"d_model": SCORER_D, "n_layers": SCORER_LAYERS,
                   "artifacts": scorer_paths,
                   "score_sep": smetrics["score_sep"],
                   "score_acc": smetrics["score_acc"]},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--datasets", nargs="*", default=list(data_mod.SPECS),
                    help="subset of datasets to build (default: all)")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    def log(msg: str) -> None:
        print(msg, flush=True)

    t0 = time.time()
    manifest = {"version": 1, "seq": data_mod.SEQ, "vocab": data_mod.VOCAB,
                "batch_sizes": list(BATCH_SIZES), "datasets": []}
    for name in args.datasets:
        # Per-dataset fragments make the (long) build resumable: a crash in
        # dataset N does not retrain datasets 1..N-1.
        frag_path = os.path.join(out_dir, f"manifest.{name}.json")
        if os.path.exists(frag_path) and not getattr(args, "force", False):
            with open(frag_path) as f:
                frag = json.load(f)
            log(f"[{name}] reusing existing fragment {frag_path}")
        else:
            frag = build_dataset(data_mod.SPECS[name], out_dir, log)
            with open(frag_path, "w") as f:
                json.dump(frag, f)
        manifest["datasets"].append(frag)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"artifacts complete in {time.time() - t0:.1f}s → {out_dir}")


if __name__ == "__main__":
    main()
