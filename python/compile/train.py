"""Build-time training loops for the simulated LLM APIs and the scorer.

Hand-rolled Adam (no optax in this image), jitted train steps, pure-jnp
attention (the Pallas kernel is only swapped in for the AOT export — see
model.py). Each simulated API trains on its own bootstrap subsample with
its own label-noise level and seed: capacity, data view and noise together
produce the decorrelated error patterns the cascade exploits.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 600
    batch: int = 32
    lr: float = 3e-3
    label_noise: float = 0.0
    subsample: float = 0.9   # bootstrap fraction of the train split
    seed: int = 0
    weight_decay: float = 1e-4


def _adam_init(params):
    return {"m": jax.tree_util.tree_map(jnp.zeros_like, params),
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def _adam_update(params, grads, state, lr, wd, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    sc = jnp.sqrt(1 - b2 ** tf) / (1 - b1 ** tf)
    params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (sc * m / (jnp.sqrt(v) + eps) + wd * p),
        params, m, v)
    return params, {"m": m, "v": v, "t": t}


def _cosine_lr(base: float, step: jnp.ndarray, total: int,
               warmup: int = 40) -> jnp.ndarray:
    """Linear warmup then cosine decay (lets tiny models take lr ≈ 6e-3)."""
    frac = step.astype(jnp.float32) / max(total, 1)
    cos = base * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    wu = base * (step.astype(jnp.float32) + 1.0) / max(warmup, 1)
    return jnp.minimum(cos, wu)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "steps", "batch", "base_lr", "wd", "regression", "block_len",
    "q_offset", "n_examples"))
def _train_loop(params, tokens, targets, key, *, cfg, steps, batch, base_lr,
                wd, regression, block_len, q_offset, n_examples):
    """The entire training run as ONE jitted fori_loop.

    Per-step python dispatch dominates wall-clock at this model scale
    (~40 models to train at build time), so the loop lives in-graph:
    minibatch sampling, variable-k prompt truncation, fwd/bwd and Adam all
    happen inside the XLA program.
    """
    n = tokens.shape[0]
    seq = tokens.shape[1]
    # block id per position (positions past q_offset never truncated).
    pos = jnp.arange(seq)
    block_id = jnp.where(pos < q_offset, pos // max(block_len, 1), -1)

    def loss_fn(p, btok, btgt):
        logits = model_mod.apply(p, btok, cfg, use_pallas=False)
        if regression:
            logit = logits[:, 0]
            y = btgt.astype(jnp.float32)
            return jnp.mean(jnp.maximum(logit, 0) - logit * y
                            + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, btgt[:, None], axis=1))

    def body(step, carry):
        params, opt, key, loss_acc = carry
        key, k1, k2, k3 = jax.random.split(key, 4)
        idx = jax.random.randint(k1, (batch,), 0, n)
        btok = tokens[idx]
        btgt = targets[idx]
        if n_examples > 0:
            # Variable-k truncation: with p=0.5 keep a random prefix of the
            # in-context example blocks (graceful prompt-adaptation).
            coin = jax.random.bernoulli(k2, 0.5, (batch,))
            keep = jax.random.randint(k3, (batch,), 0, n_examples + 1)
            keep = jnp.where(coin, keep, n_examples)
            drop = block_id[None, :] >= keep[:, None]
            btok = jnp.where(drop & (block_id[None, :] >= 0), 0, btok)
        loss, grads = jax.value_and_grad(loss_fn)(params, btok, btgt)
        lr = _cosine_lr(base_lr, jnp.asarray(step), steps)
        params, opt = _adam_update(params, grads, opt, lr, wd)
        return params, opt, key, 0.98 * loss_acc + 0.02 * loss

    opt = _adam_init(params)
    params, opt, _, loss = jax.lax.fori_loop(
        0, steps, body, (params, opt, key, jnp.asarray(0.0)))
    return params, loss


@functools.partial(jax.jit, static_argnames=("cfg",))
def _predict_logits(params, tokens, *, cfg):
    return model_mod.apply(params, tokens, cfg, use_pallas=False)


def predict(params, tokens: np.ndarray, cfg: model_mod.ModelConfig,
            batch: int = 256) -> np.ndarray:
    """Batched argmax predictions (classifier) over a numpy token array."""
    outs = []
    for i in range(0, tokens.shape[0], batch):
        chunk = tokens[i: i + batch]
        pad = (-len(chunk)) % batch
        if pad:
            chunk = np.concatenate([chunk, np.zeros((pad, chunk.shape[1]), chunk.dtype)])
        logits = np.asarray(_predict_logits(params, jnp.asarray(chunk), cfg=cfg))
        outs.append(logits[: len(tokens[i: i + batch])])
    return np.concatenate(outs).argmax(axis=-1).astype(np.int32)


def predict_scores(params, tokens: np.ndarray, cfg: model_mod.ModelConfig,
                   batch: int = 256) -> np.ndarray:
    """Batched sigmoid scores for the reliability scorer."""
    outs = []
    for i in range(0, tokens.shape[0], batch):
        chunk = tokens[i: i + batch]
        pad = (-len(chunk)) % batch
        if pad:
            chunk = np.concatenate([chunk, np.zeros((pad, chunk.shape[1]), chunk.dtype)])
        logits = np.asarray(_predict_logits(params, jnp.asarray(chunk), cfg=cfg))
        outs.append(logits[: len(tokens[i: i + batch]), 0])
    return 1.0 / (1.0 + np.exp(-np.concatenate(outs)))


def _variable_k_truncation(rng: np.random.Generator, tokens: np.ndarray,
                           spec: data_mod.DatasetSpec) -> np.ndarray:
    """With p=0.5 per row, keep only a uniform-random prefix of the example
    blocks — trains each model to degrade gracefully under prompt
    adaptation (smaller k) instead of falling off a cliff."""
    n = tokens.shape[0]
    keep = np.full(n, spec.n_examples, dtype=np.int64)
    tr = rng.random(n) < 0.5
    keep[tr] = rng.integers(0, spec.n_examples + 1, size=tr.sum())
    return data_mod.truncate_examples(tokens, spec, keep)


def train_classifier(spec: data_mod.DatasetSpec, ds: dict,
                     mcfg: model_mod.ModelConfig, tcfg: TrainConfig,
                     log: Optional[callable] = None) -> Tuple[Dict, dict]:
    """Train one simulated LLM API on its bootstrap view of the train split.

    Returns (params, metrics) with train/test accuracy in metrics.
    """
    rng = np.random.default_rng(tcfg.seed)
    tr_idx = ds["train_idx"]
    n_sub = max(tcfg.batch, int(len(tr_idx) * tcfg.subsample))
    view = rng.choice(tr_idx, size=n_sub, replace=False)
    tokens = ds["tokens"][view]
    labels = ds["labels"][view].copy()
    # Per-model label noise (decorrelates errors between APIs).
    if tcfg.label_noise > 0:
        flip = rng.random(len(labels)) < tcfg.label_noise
        labels[flip] = rng.integers(0, spec.n_classes, size=flip.sum())

    params = model_mod.init_params(jax.random.PRNGKey(tcfg.seed), mcfg)
    params, loss = _train_loop(
        params, jnp.asarray(tokens), jnp.asarray(labels),
        jax.random.PRNGKey(tcfg.seed + 1000), cfg=mcfg, steps=tcfg.steps,
        batch=tcfg.batch, base_lr=tcfg.lr, wd=tcfg.weight_decay,
        regression=False, block_len=spec.block_len, q_offset=spec.q_offset,
        n_examples=spec.n_examples)
    if log:
        log(f"    final ema loss {float(loss):.4f}")

    m = {}
    for split, idx in (("train", ds["train_idx"]), ("test", ds["test_idx"])):
        preds = predict(params, ds["tokens"][idx], mcfg)
        m[f"{split}_acc"] = float((preds == ds["labels"][idx]).mean())
    return params, m


def train_scorer(spec: data_mod.DatasetSpec, scorer_tokens: np.ndarray,
                 correct: np.ndarray, mcfg: model_mod.ModelConfig,
                 tcfg: TrainConfig, log: Optional[callable] = None
                 ) -> Tuple[Dict, dict]:
    """Train the reliability scorer g(q, a) on (scorer-input, correct) rows
    pooled across all simulated APIs' train-split answers."""
    params = model_mod.init_params(jax.random.PRNGKey(tcfg.seed + 1), mcfg)
    params, loss = _train_loop(
        params, jnp.asarray(scorer_tokens),
        jnp.asarray(correct.astype(np.int32)),
        jax.random.PRNGKey(tcfg.seed + 2000), cfg=mcfg, steps=tcfg.steps,
        batch=tcfg.batch, base_lr=tcfg.lr, wd=tcfg.weight_decay,
        regression=True, block_len=1, q_offset=0, n_examples=0)
    if log:
        log(f"    scorer final ema loss {float(loss):.4f}")
    scores = predict_scores(params, scorer_tokens, mcfg)
    # AUC-ish sanity metric: mean score on correct minus on incorrect rows.
    sep = float(scores[correct > 0].mean() - scores[correct == 0].mean()) \
        if 0 < correct.sum() < len(correct) else 0.0
    acc = float(((scores > 0.5).astype(np.int32) == correct).mean())
    return params, {"score_sep": sep, "score_acc": acc}
