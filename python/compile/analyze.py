"""§Perf analysis for L1/L2: HLO op census + kernel VMEM/MXU estimates.

Usage (after `make artifacts`):
    cd python && python -m compile.analyze [--artifacts ../artifacts]

L1 (Pallas attention): interpret=True timings are CPU-numpy, not a TPU
proxy, so we report the *structural* quantities that determine real-TPU
performance: per-instance VMEM footprint of the chosen BlockSpecs and the
arithmetic intensity / MXU utilization estimate of the two kernel matmuls.

L2 (lowered models): op census of the exported HLO — dots, fusions-able
elementwise chains, while-loops (from the grid), convert/transpose traffic
— plus analytic FLOPs per forward, used to verify there is no redundant
recomputation and that the pallas path didn't blow up the graph.
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter

from . import data as data_mod

BYTES_F32 = 4


def kernel_vmem_report(seq: int = data_mod.SEQ, d_head: int = 8,
                       block_q: int = 32, block_k: int = 32) -> dict:
    """Analytic VMEM footprint of one attention-kernel program instance."""
    q_tile = block_q * d_head * BYTES_F32
    kv_rows = seq * d_head * BYTES_F32 * 2          # full K and V mapped in
    carries = (block_q * 1 * 2 + block_q * d_head) * BYTES_F32
    s_tile = block_q * block_k * BYTES_F32          # one score tile
    total = q_tile + kv_rows + carries + s_tile
    # MXU: the two dots are (block_q x d_head) @ (d_head x block_k) and
    # (block_q x block_k) @ (block_k x d_head). The TPU MXU is 128x128;
    # utilization estimate = achieved MACs / (cycles * 128*128) with one
    # 128x128x128 MAC block per cycle-group — for tiny d_head=8 tiles the
    # bound is d_head/128 per dimension.
    mxu_util = min(block_q / 128, 1.0) * min(block_k / 128, 1.0) * min(d_head / 128, 1.0)
    flops_per_instance = 2 * block_q * seq * d_head * 2  # qk^T + pv
    return {
        "block_q": block_q,
        "block_k": block_k,
        "seq": seq,
        "d_head": d_head,
        "vmem_bytes_per_instance": total,
        "vmem_mib": total / (1 << 20),
        "flops_per_instance": flops_per_instance,
        "arithmetic_intensity_flops_per_byte": flops_per_instance / total,
        "mxu_tile_utilization_estimate": mxu_util,
    }


DOT_RE = re.compile(r"dot\(")
SHAPE_RE = re.compile(r"f32\[([0-9,]*)\]")


def hlo_census(path: str) -> dict:
    """Census of an exported HLO text file."""
    ops = Counter()
    n_lines = 0
    dot_flops = 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if "=" not in line or line.startswith(("HloModule", "ENTRY", "}", "%")):
                continue
            n_lines += 1
            m = re.search(r"=\s+\S+\s+([a-z\-]+)\(", line)
            if not m:
                continue
            op = m.group(1)
            ops[op] += 1
            if op == "dot":
                shape = SHAPE_RE.search(line.split("=")[1])
                if shape and shape.group(1):
                    dims = [int(x) for x in shape.group(1).split(",")]
                    out_elems = 1
                    for x in dims:
                        out_elems *= x
                    dot_flops += out_elems  # x2 x contraction-dim added below
    return {
        "path": os.path.basename(path),
        "instructions": n_lines,
        "top_ops": ops.most_common(12),
        "n_dot": ops.get("dot", 0),
        "n_while": ops.get("while", 0),
        "n_convert": ops.get("convert", 0),
    }


def model_flops(d: int, layers: int, seq: int, vocab: int, n_out: int) -> int:
    """Analytic forward FLOPs for one sequence (dense parts)."""
    per_layer = (
        2 * seq * d * 3 * d        # qkv
        + 2 * seq * seq * d * 2    # attention matmuls
        + 2 * seq * d * d          # proj
        + 2 * seq * d * 2 * d * 2  # mlp
    )
    head = 2 * 2 * d * n_out
    return layers * per_layer + head


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()

    print("== L1: attention kernel BlockSpec analysis ==")
    for bq, bk in [(16, 16), (32, 32), (64, 32), (64, 64)]:
        r = kernel_vmem_report(block_q=bq, block_k=bk)
        print(f"  block_q={bq:<3} block_k={bk:<3} vmem/instance="
              f"{r['vmem_mib']*1024:7.1f} KiB  AI={r['arithmetic_intensity_flops_per_byte']:6.2f} "
              f"flops/B  mxu_tile_util={r['mxu_tile_utilization_estimate']:.4f}")
    print("  (d_head=8 caps MXU tile utilization at 8/128 per dim — the"
          " simulated models are latency- not MXU-bound; at paper-scale"
          " d_head=128 the same BlockSpec saturates the tile.)")

    man_path = os.path.join(args.artifacts, "manifest.json")
    if not os.path.exists(man_path):
        print("\n(no artifacts; run `make artifacts` for the L2 census)")
        return
    with open(man_path) as f:
        manifest = json.load(f)

    print("\n== L2: exported-HLO census (batch 8 artifacts) ==")
    d0 = manifest["datasets"][0]
    for m in d0["models"][:4] + [d0["models"][-1]]:
        path = os.path.join(args.artifacts, m["artifacts"]["8"])
        c = hlo_census(path)
        fl = model_flops(m["d_model"], m["n_layers"], manifest["seq"],
                         manifest["vocab"], d0["n_classes"])
        print(f"  {m['name']:>14}: {c['instructions']:5d} instrs, "
              f"{c['n_dot']:3d} dots, {c['n_while']} while, "
              f"{c['n_convert']:3d} converts, ~{fl/1e6:.1f} MFLOP/seq fwd")
    print("\n  top ops for", d0["models"][0]["name"] + ":",
          hlo_census(os.path.join(args.artifacts, d0["models"][0]["artifacts"]["8"]))["top_ops"])


if __name__ == "__main__":
    main()
