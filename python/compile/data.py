"""Synthetic analogs of the FrugalGPT evaluation datasets.

The paper evaluates on HEADLINES (finance, 4-way), OVERRULING (law, binary)
and COQA (reading comprehension, adapted to direct QA). None are bundled
here, so we generate *structural* analogs with the same sizes, class counts
and few-shot prompt lengths (paper Table 2), built so that query difficulty
is graded — which is the property the LLM cascade exploits.

Every item is an *episode*: ``k`` in-context examples followed by a query,
laid out at fixed token positions so the Rust side can slice segments
without a tokenizer. Examples are compressed 3-token digests (keyword →
label), which keeps the model sequence length at 64 so that ~40 build-time
training runs stay fast on CPU:

    [ example block ] * k  [CLS] query-body [QSEP] [PAD...]
    block = [SEP_EX] [keyword] [label]

Labels are produced by one of three rules (difficulty tiers):

* tier 0 — *keyword*: a class-keyword token appears somewhere in the body.
  A fraction of tier-0 items are **episodic**: the keyword→class mapping is
  permuted per-item and only recoverable by reading the in-context examples
  (real in-context learning; items carry an EPI marker token). Models that
  never learn induction can't answer these, and *nobody* can answer them
  when prompt adaptation drops the examples — making prompt selection a
  genuine accuracy/cost trade-off.
* tier 1 — *pair*: two feature tokens A_i, B_j with ``(i + j) mod C = y``;
  requires composition.
* tier 2 — *long-range*: a direction token early in the body, optionally
  flipped by a NEG token near the end; requires long-range attention.

Capacity-limited models learn the tiers in order, which yields the
heterogeneous, partially-complementary error patterns of the real LLM
marketplace (paper Fig. 4).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

import numpy as np

VOCAB = 160

# Token map (fixed, shared across datasets; mirrored in rust/src/data).
PAD = 0
SEP_EX = 1
LABEL_MARK = 2
NEG = 3
CLS = 4
QSEP = 5
LABEL_BASE = 6      # label tokens: LABEL_BASE + class, class < 12
EPI_MARK = 19       # present in episodic queries
KW_BASE = 20        # keyword tokens: KW_BASE + base_class * NK + variant
NK = 4              # keyword variants per class
A_BASE = 68         # pair-feature A tokens (NPAIR)
B_BASE = 84         # pair-feature B tokens (NPAIR)
NPAIR = 16          # >= max n_classes so every (i, label) pair is realizable
DIR_BASE = 100      # long-range direction tokens (12)
NOISE_BASE = 114    # everything >= NOISE_BASE is filler

SEQ = 64            # model input length, all datasets (multiple of 32)


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Static description of one synthetic dataset."""

    name: str
    domain: str
    n_classes: int
    size: int               # total items (paper Table 2)
    n_examples: int         # few-shot examples in the prompt (paper Table 2)
    qlen: int               # query body length in tokens
    tier_probs: tuple       # P(tier 0), P(tier 1), P(tier 2)
    episodic_frac: float    # fraction of tier-0 items that are episodic
    train_frac: float = 0.8
    seed: int = 0

    @property
    def block_len(self) -> int:
        return 3  # [SEP_EX] [keyword] [label] digest

    @property
    def q_offset(self) -> int:
        return self.n_examples * self.block_len

    @property
    def query_len(self) -> int:
        return self.qlen + 2  # CLS + body + QSEP

    @property
    def used_len(self) -> int:
        return self.q_offset + self.query_len

    @property
    def scorer_seq(self) -> int:
        # [CLS] body [QSEP] [answer] padded to a multiple of 32.
        n = self.qlen + 3
        return ((n + 31) // 32) * 32

    def answer_len(self, cls: int) -> int:
        """Deterministic per-class completion length in tokens (for output
        cost metering; COQA-style answers are longer)."""
        if self.name == "coqa":
            return 4 + (cls % 7)
        return 1 + (cls % 2)


SPECS: Dict[str, DatasetSpec] = {
    "headlines": DatasetSpec(
        name="headlines", domain="Finance", n_classes=4, size=10000,
        n_examples=8, qlen=16, tier_probs=(0.60, 0.25, 0.15),
        episodic_frac=0.30, seed=11),
    "overruling": DatasetSpec(
        name="overruling", domain="Law", n_classes=2, size=2400,
        n_examples=5, qlen=20, tier_probs=(0.55, 0.25, 0.20),
        episodic_frac=0.25, seed=22),
    "coqa": DatasetSpec(
        name="coqa", domain="Passage Reading", n_classes=12, size=7982,
        n_examples=2, qlen=40, tier_probs=(0.55, 0.30, 0.15),
        episodic_frac=0.08, seed=33),
}

for _s in SPECS.values():
    assert _s.used_len <= SEQ, (_s.name, _s.used_len)
    assert _s.n_classes <= 12


def _fill_noise(rng: np.random.Generator, n: int) -> np.ndarray:
    return rng.integers(NOISE_BASE, VOCAB, size=n, dtype=np.int32)


def _make_body(rng: np.random.Generator, spec: DatasetSpec, label: int,
               tier: int, episodic: bool, perm: np.ndarray) -> np.ndarray:
    """Generate one query/example body of ``spec.qlen`` tokens."""
    c = spec.n_classes
    body = _fill_noise(rng, spec.qlen)
    if tier == 0:
        # effective class of keyword slot b under this episode is perm[b]
        if episodic:
            base = int(np.where(perm == label)[0][0])
        else:
            base = label
        kw = KW_BASE + base * NK + int(rng.integers(NK))
        pos = int(rng.integers(spec.qlen))
        body[pos] = kw
        if episodic:
            epos = int(rng.integers(spec.qlen))
            while epos == pos:
                epos = int(rng.integers(spec.qlen))
            body[epos] = EPI_MARK
    elif tier == 1:
        i = int(rng.integers(NPAIR))
        j0 = (label - i) % c
        choices = np.arange(j0, NPAIR, c)
        j = int(rng.choice(choices))
        p1, p2 = rng.choice(spec.qlen, size=2, replace=False)
        body[p1] = A_BASE + i
        body[p2] = B_BASE + j
    else:
        off = max(1, c // 2)
        negate = bool(rng.integers(2))
        yprime = (label - off) % c if negate else label
        third = max(1, spec.qlen // 3)
        p1 = int(rng.integers(third))
        body[p1] = DIR_BASE + yprime
        if negate:
            p2 = spec.qlen - 1 - int(rng.integers(third))
            body[p2] = NEG
    return body


def _make_item(rng: np.random.Generator, spec: DatasetSpec) -> dict:
    c = spec.n_classes
    tier = int(rng.choice(3, p=np.asarray(spec.tier_probs)))
    episodic = bool(tier == 0 and rng.random() < spec.episodic_frac)
    label = int(rng.integers(c))
    perm = rng.permutation(c) if episodic else np.arange(c)

    tokens = np.zeros(SEQ, dtype=np.int32)
    # In-context example blocks: tier-0 items under this episode's perm.
    # Coverage: make sure the query's keyword class appears among examples.
    ex_classes = list(rng.permutation(c)[:spec.n_examples])
    while len(ex_classes) < spec.n_examples:
        ex_classes.append(int(rng.integers(c)))
    if episodic:
        qbase = int(np.where(perm == label)[0][0])
        if qbase not in [int(x) for x in ex_classes]:
            ex_classes[int(rng.integers(spec.n_examples))] = qbase
    for j, base in enumerate(ex_classes):
        base = int(base)
        ex_label = int(perm[base])
        blk = spec.block_len * j
        tokens[blk] = SEP_EX
        tokens[blk + 1] = KW_BASE + base * NK + int(rng.integers(NK))
        tokens[blk + 2] = LABEL_BASE + ex_label

    body = _make_body(rng, spec, label, tier, episodic, perm)
    qo = spec.q_offset
    tokens[qo] = CLS
    tokens[qo + 1: qo + 1 + spec.qlen] = body
    tokens[qo + 1 + spec.qlen] = QSEP
    return {
        "tokens": tokens,
        "label": label,
        "tier": tier,
        "episodic": episodic,
    }


def generate(spec: DatasetSpec) -> dict:
    """Generate the full dataset as dense numpy arrays + a train/test split."""
    rng = np.random.default_rng(spec.seed)
    items = [_make_item(rng, spec) for _ in range(spec.size)]
    tokens = np.stack([it["tokens"] for it in items])
    labels = np.asarray([it["label"] for it in items], dtype=np.int32)
    tiers = np.asarray([it["tier"] for it in items], dtype=np.int32)
    episodic = np.asarray([it["episodic"] for it in items], dtype=np.int32)
    n_train = int(spec.size * spec.train_frac)
    perm = rng.permutation(spec.size)
    tr, te = perm[:n_train], perm[n_train:]
    return {
        "spec": spec,
        "tokens": tokens, "labels": labels, "tiers": tiers,
        "episodic": episodic, "train_idx": tr, "test_idx": te,
    }


def truncate_examples(tokens: np.ndarray, spec: DatasetSpec,
                      keep: np.ndarray) -> np.ndarray:
    """Zero (PAD) all example blocks with index >= keep[i] for each row.

    Used for variable-k training augmentation and by tests mirroring the
    Rust prompt-adaptation strategy.
    """
    out = tokens.copy()
    for j in range(spec.n_examples):
        blk = slice(j * spec.block_len, (j + 1) * spec.block_len)
        mask = keep <= j
        out[mask, blk] = PAD
    return out


def scorer_input(tokens: np.ndarray, spec: DatasetSpec,
                 answers: np.ndarray) -> np.ndarray:
    """Build scorer inputs ``[CLS] body [QSEP] [answer]`` from item tokens.

    ``tokens``: (N, SEQ) item tokens; ``answers``: (N,) predicted classes.
    Returns (N, spec.scorer_seq) int32.
    """
    n = tokens.shape[0]
    out = np.zeros((n, spec.scorer_seq), dtype=np.int32)
    qo = spec.q_offset
    out[:, : spec.qlen + 2] = tokens[:, qo: qo + spec.qlen + 2]
    out[:, spec.qlen + 2] = LABEL_BASE + answers
    return out


def dataset_to_json(ds: dict, split: str) -> dict:
    spec: DatasetSpec = ds["spec"]
    idx = ds["train_idx"] if split == "train" else ds["test_idx"]
    return {
        "dataset": spec.name,
        "split": split,
        "seq": SEQ,
        "n_classes": spec.n_classes,
        "n_examples": spec.n_examples,
        "qlen": spec.qlen,
        "block_len": spec.block_len,
        "q_offset": spec.q_offset,
        "scorer_seq": spec.scorer_seq,
        "answer_lens": [spec.answer_len(c) for c in range(spec.n_classes)],
        "tokens": ds["tokens"][idx].tolist(),
        "labels": ds["labels"][idx].tolist(),
        "tiers": ds["tiers"][idx].tolist(),
        "episodic": ds["episodic"][idx].tolist(),
    }


def write_dataset(ds: dict, out_dir: str) -> List[str]:
    spec: DatasetSpec = ds["spec"]
    d = os.path.join(out_dir, spec.name)
    os.makedirs(d, exist_ok=True)
    paths = []
    for split in ("train", "test"):
        p = os.path.join(d, f"{split}.json")
        with open(p, "w") as f:
            json.dump(dataset_to_json(ds, split), f)
        paths.append(p)
    return paths
