"""L2: the JAX compute graphs — the simulated-LLM transformer family.

Each "commercial LLM API" in the simulated marketplace is an instance of
this tiny transformer classifier, and the FrugalGPT reliability scorer
``g(q, a)`` is the same architecture with a 1-dim regression head. The
attention / layernorm cores call the L1 Pallas kernels when
``use_pallas=True`` (the AOT-export path) and the pure-jnp oracles when
``False`` (the training path); the two are numerically equivalent
(python/tests asserts it), so the swap is sound.

Everything is pure functions over a params pytree — no framework.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import layernorm as ln_kernel
from .kernels import ref

PAD_ID = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for one simulated LLM / scorer."""

    vocab: int
    seq: int
    d_model: int
    n_layers: int
    n_heads: int
    n_out: int              # classes, or 1 for the regression scorer
    mlp_mult: int = 2
    # Position of the query's [CLS] token (dataset q_offset). The pooled
    # representation concatenates masked-mean and this position's hidden
    # state — the CLS read-out speeds up learning markedly at this scale.
    pool_pos: int = 0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def init_params(rng: jax.Array, cfg: ModelConfig) -> Dict:
    """Initialize a params pytree (scaled-normal inits, zero biases)."""
    keys = jax.random.split(rng, 4 + 6 * cfg.n_layers)
    d = cfg.d_model

    def dense(key, fan_in, fan_out):
        w = jax.random.normal(key, (fan_in, fan_out), jnp.float32)
        return {"w": w / math.sqrt(fan_in), "b": jnp.zeros((fan_out,), jnp.float32)}

    params = {
        "tok_emb": jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * 0.02,
        "pos_emb": jax.random.normal(keys[1], (cfg.seq, d), jnp.float32) * 0.02,
        "head": dense(keys[2], 2 * d, cfg.n_out),
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        k = keys[4 + 6 * i: 4 + 6 * (i + 1)]
        params["blocks"].append({
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "qkv": dense(k[0], d, 3 * d),
            "proj": dense(k[1], d, d),
            "mlp1": dense(k[2], d, cfg.mlp_mult * d),
            "mlp2": dense(k[3], cfg.mlp_mult * d, d),
        })
    return params


def _layernorm(x: jnp.ndarray, p: Dict, use_pallas: bool) -> jnp.ndarray:
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    if use_pallas:
        y = ln_kernel.layernorm(flat, p["g"], p["b"])
    else:
        y = ref.layernorm_ref(flat, p["g"], p["b"])
    return y.reshape(b, s, d)


def _attention(x: jnp.ndarray, blk: Dict, cfg: ModelConfig,
               use_pallas: bool) -> jnp.ndarray:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x @ blk["qkv"]["w"] + blk["qkv"]["b"]          # (b, s, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def split_heads(t):  # (b, s, d) -> (b*h, s, hd)
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3).reshape(b * h, s, hd)

    q, k, v = split_heads(q), split_heads(k), split_heads(v)
    if use_pallas:
        o = attn_kernel.attention(q, k, v)
    else:
        o = ref.attention_ref(q, k, v)
    o = o.reshape(b, h, s, hd).transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ blk["proj"]["w"] + blk["proj"]["b"]


def apply(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig,
          use_pallas: bool = False) -> jnp.ndarray:
    """Forward pass.

    Args:
      tokens: ``(B, seq)`` int32 token ids (0 = PAD).

    Returns:
      ``(B, n_out)`` float32 logits (classifier) or score logits (scorer).
    """
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :, :]
    for blk in params["blocks"]:
        x = x + _attention(_layernorm(x, blk["ln1"], use_pallas), blk, cfg, use_pallas)
        hmid = _layernorm(x, blk["ln2"], use_pallas)
        hmid = jax.nn.gelu(hmid @ blk["mlp1"]["w"] + blk["mlp1"]["b"])
        x = x + (hmid @ blk["mlp2"]["w"] + blk["mlp2"]["b"])
    x = _layernorm(x, params["ln_f"], use_pallas)
    # Masked mean-pool over non-PAD positions, concatenated with the hidden
    # state at the query's [CLS] position (fast-learning read-out).
    mask = (tokens != PAD_ID).astype(jnp.float32)[:, :, None]
    pooled = jnp.sum(x * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    pooled = jnp.concatenate([pooled, x[:, cfg.pool_pos, :]], axis=-1)
    return pooled @ params["head"]["w"] + params["head"]["b"]


def num_params(params: Dict) -> int:
    return sum(p.size for p in jax.tree_util.tree_leaves(params))
