"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle to float32 tolerance on all shapes the models use
(pytest + hypothesis sweep them). The oracles are also what the models use
during *training* — the Pallas kernels (interpret=True) are swapped in only
for the AOT-exported inference graphs, so training stays fast while the
exported HLO exercises the kernel path. The swap is sound because the two
implementations compute identical math (asserted by python/tests).
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Reference scaled-dot-product attention.

    Args:
      q, k, v: ``(BH, S, D)`` — batch*heads leading dim, full (non-causal)
        attention, no masking (PAD embeddings are trainable, models learn to
        down-weight them).

    Returns:
      ``(BH, S, D)`` attention output, same dtype as ``q``.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=jnp.float32))
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def layernorm_ref(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """Reference layer norm over the last axis.

    Args:
      x: ``(N, D)`` rows to normalize.
      gamma, beta: ``(D,)`` scale/shift.
    """
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * gamma + beta).astype(x.dtype)
