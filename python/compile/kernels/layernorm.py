"""L1 Pallas kernel: fused row-wise layer normalization.

One grid step normalizes a block of rows held in VMEM; mean/variance/scale
are fused into a single pass so the rows are read once (on TPU this saves an
HBM round-trip vs. the unfused mean→var→normalize chain). ``interpret=True``
for the same reason as the attention kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 32


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...] + b_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def layernorm(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
              block_rows: int = BLOCK_ROWS, eps: float = 1e-5) -> jnp.ndarray:
    """Fused layer norm over the last axis of ``(N, D)`` rows.

    ``N`` must be divisible by ``block_rows`` (model code guarantees this:
    N = batch * seq with seq a multiple of 32).
    """
    n, d = x.shape
    if n % block_rows:
        raise ValueError(f"rows={n} must be divisible by block_rows={block_rows}")
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)
