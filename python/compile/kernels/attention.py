"""L1 Pallas kernel: fused flash-style multi-head attention.

TPU-oriented design (see DESIGN.md §Hardware-Adaptation):

* the grid iterates over ``(batch*heads, q-blocks)``; each program instance
  holds one ``(BLOCK_Q, D)`` query tile in VMEM,
* keys/values are streamed ``BLOCK_K`` rows at a time with an online
  (running max / running sum) softmax, so the working set per instance is
  ``O(BLOCK_Q * BLOCK_K + BLOCK_Q * D)`` — the TPU analog of the
  shared-memory tiling a CUDA flash kernel would do with threadblocks,
* the two matmuls (``q·kᵀ`` and ``p·v``) are expressed as ``jnp.dot`` with
  ``preferred_element_type=float32`` so a real-TPU lowering would hit the
  MXU; under ``interpret=True`` they lower to plain HLO dots the CPU PJRT
  client executes natively.

``interpret=True`` is REQUIRED here: a real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot run. The interpret path lowers
the kernel into ordinary HLO, which is what ``aot.py`` bakes into
``artifacts/*.hlo.txt`` for the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. BLOCK_Q rows of queries are resident per program
# instance; keys/values stream through in BLOCK_K-row chunks. 32 divides all
# model sequence lengths used in this repo (128, 160) and keeps the VMEM
# footprint estimate well under 1 MiB (see DESIGN.md §Perf).
BLOCK_Q = 32
BLOCK_K = 32

_NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """One grid step: full online-softmax attention for one query tile."""
    q = q_ref[0].astype(jnp.float32)  # (BLOCK_Q, D)
    seq_k = k_ref.shape[1]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))

    block_q = q.shape[0]
    # Online softmax carries: running max m, running sum l, accumulator acc.
    m0 = jnp.full((block_q, 1), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        start = i * block_k
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[0], start, block_k, axis=0)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[0], start, block_k, axis=0)
        s = jnp.dot(q, k_blk.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32) * scale
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v_blk.astype(jnp.float32),
                                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    n_blocks = seq_k // block_k
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              block_q: int = BLOCK_Q, block_k: int = BLOCK_K) -> jnp.ndarray:
    """Fused flash-style attention via Pallas (interpret mode).

    Args:
      q, k, v: ``(BH, S, D)``. ``S`` must be divisible by both ``block_q``
        and ``block_k`` (the model code pads sequences to multiples of 32).

    Returns:
      ``(BH, S, D)`` — numerically equal to :func:`ref.attention_ref` to
      float32 tolerance.
    """
    bh, seq, d = q.shape
    if seq % block_q or seq % block_k:
        raise ValueError(
            f"seq={seq} must be divisible by block_q={block_q} and block_k={block_k}")
    grid = (bh, seq // block_q)
    kernel = functools.partial(_attention_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # One query tile per instance ...
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            # ... with the full K/V rows for this (batch, head) mapped in;
            # the kernel streams them block_k rows at a time.
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(q, k, v)
