#!/usr/bin/env bash
# CI entry point: the tier-1 gate (release build + tests + clippy -D
# warnings when available) followed by a bench smoke on a tiny grid, so
# no PR can ship rust that does not compile, pass tests, or run the
# optimizer sweep end-to-end (PR 1 shipped uncompiled — never again).
set -euo pipefail
cd "$(dirname "$0")/.."

scripts/tier1.sh

# Bench smoke: exercises the full frontier sweep + the JSON suite writer
# on a small synthetic table. Writes to a scratch path — the committed
# BENCH_optimizer.json trajectory is only ever refreshed by a deliberate
# `make bench-optimizer` on a benchmarking host.
SMOKE_JSON="$(mktemp -t bench_smoke_XXXXXX.json)"
trap 'rm -f "$SMOKE_JSON"' EXIT
cargo bench --bench optimizer -- --smoke --json "$SMOKE_JSON"
if command -v python3 >/dev/null 2>&1; then
    python3 - "$SMOKE_JSON" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["suite"] == "optimizer" and doc["results"], "smoke bench wrote no results"
print(f"bench smoke OK: {len(doc['results'])} results")
EOF
else
    echo "NOTE: python3 not installed; skipping smoke JSON validation" >&2
fi

echo "ci.sh: all gates passed"
