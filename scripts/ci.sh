#!/usr/bin/env bash
# CI entry point: the optimizer-parity harness, the tier-1 gate (release
# build + tests + clippy -D warnings when available) and a bench smoke on
# a tiny grid — so no PR can ship rust that does not compile, pass tests,
# run the sweep end-to-end, or silently drift from the python reference
# algorithm (PR 1 shipped uncompiled — never again).
set -euo pipefail
cd "$(dirname "$0")/.."

# python3 is REQUIRED: the parity harness is the only executable spec of
# the optimizer algorithms (weighted included), and skipping it would let
# the rust and its reference drift apart unnoticed.
if ! command -v python3 >/dev/null 2>&1; then
    echo "error: python3 is required for scripts/check_optimizer_port.py" >&2
    echo "       (the optimizer-parity gate must not be skipped)" >&2
    exit 1
fi

# Optimizer parity: seed == flat == packed == brute-force reference,
# packed bitset exactly equal to the byte/f64 arena (tail words included),
# weighted search uniform-bitwise + replay-consistent +
# budget-query-equivalent, plus the referee-vote shadow-label gate
# (pair selection + vote-label rule + strictly-less reference spend).
# --quick skips only the slow pure-python wall-clock measurement.
python3 scripts/check_optimizer_port.py --quick

scripts/tier1.sh

# The documented entry points must build AND run: each example's --sim
# mode drives the public API (optimizer → plan → pipeline service /
# live cascade) over the hermetic synthetic marketplace, so an API
# redesign that breaks `examples/` fails here instead of on a user's
# machine.
cargo build --release --examples
cargo run --release --example quickstart -- --sim
cargo run --release --example strategies_demo -- --sim --queries 120
cargo run --release --example serve_workload -- --sim --queries 200 --clients 2 --zipf

# Fault tolerance: the hermetic scripted-timeline suite (429 storm with
# zero client-facing errors, terminal outage + breaker recovery, price
# step → reoptimizer swap — all on a query-indexed clock, no wall-clock),
# then a live smoke of the same machinery: a storm scenario against the
# serving workload, where every client thread propagates Errs, so one
# surfaced fault fails the run.
cargo test --release --test fault_scenarios
cargo run --release --example serve_workload -- \
    --sim --queries 200 --clients 2 --scenario storm

# The contextual meta-router + the drift story: the heterogeneous-world
# router suite (trained router splits traffic by difficulty at lower
# spend; router swap storm keeps every answer on one RouterBundle) and
# the end-to-end SilentDrift → shadow detection → swap → recovery →
# `report swaps` rendering test, then a live smoke of the routed
# pipeline spec through the real serving example.
cargo test --release --test router_pipeline --test drift_story
cargo run --release --example serve_workload -- \
    --sim --queries 200 --clients 2 --pipeline cache,router,cascade --router

# Speculative agreement serving: the service-level pinning suite (accept
# path, seeded escalation billed exactly once, stale-plan abstention —
# every test wired so the terminal model errors if consulted) plus the
# referee-vote shadow loop (same swap decision as single-reference at
# strictly less reference spend), then a live smoke of the speculative
# pipeline through the real serving example.
cargo test --release --test speculate_pipeline --test shadow_loop
cargo run --release --example serve_workload -- \
    --sim --queries 200 --clients 2 --speculate

# Bench smoke: exercises the full frontier sweep + the JSON suite writer
# on a small synthetic table. Writes to a scratch path — the committed
# BENCH_optimizer.json trajectory is only ever refreshed by the nightly
# bench workflow (or a deliberate `make bench-optimizer` on a
# benchmarking host). The gate is strict: an empty results array or a
# result missing name/iters/mean_ns fails the build (an empty `[]`
# shipped unnoticed for three PRs).
SMOKE_JSON="$(mktemp -t bench_smoke_XXXXXX.json)"
trap 'rm -f "$SMOKE_JSON"' EXIT
cargo bench --bench optimizer -- --smoke --json "$SMOKE_JSON"
python3 - "$SMOKE_JSON" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc.get("suite") == "optimizer", f"wrong suite: {doc.get('suite')!r}"
results = doc.get("results")
assert isinstance(results, list) and results, \
    "smoke bench wrote an empty results array"
for r in results:
    assert isinstance(r.get("name"), str) and r["name"], f"result missing name: {r}"
    assert isinstance(r.get("iters"), int) and r["iters"] > 0, f"bad iters: {r}"
    assert isinstance(r.get("mean_ns"), (int, float)) and r["mean_ns"] > 0, \
        f"bad mean_ns: {r}"
print(f"bench smoke OK: {len(results)} schema-valid results")
EOF

# Serve-path contention bench smoke: the closed-loop multi-thread sweep
# (sharded vs shard1_rwlock over all three mixes) must run end-to-end and
# emit one schema-valid result per variant. Scratch path only — the
# committed BENCH_serve.json is refreshed by the nightly bench workflow.
SERVE_SMOKE_JSON="$(mktemp -t bench_serve_smoke_XXXXXX.json)"
trap 'rm -f "$SMOKE_JSON" "$SERVE_SMOKE_JSON"' EXIT
cargo bench --bench serve_hot_path -- --smoke --json "$SERVE_SMOKE_JSON"
python3 - "$SERVE_SMOKE_JSON" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc.get("suite") == "serve_hot_path", f"wrong suite: {doc.get('suite')!r}"
results = doc.get("results")
assert isinstance(results, list) and results, \
    "serve smoke bench wrote an empty results array"
names = set()
for r in results:
    assert isinstance(r.get("name"), str) and r["name"], f"result missing name: {r}"
    assert isinstance(r.get("iters"), int) and r["iters"] > 0, f"bad iters: {r}"
    assert isinstance(r.get("mean_ns"), (int, float)) and r["mean_ns"] > 0, \
        f"bad mean_ns: {r}"
    assert isinstance(r.get("p99_ns"), (int, float)) and r["p99_ns"] > 0, \
        f"bad p99_ns: {r}"
    names.add(r["name"])
# Both configurations of every mix must be present — the whole point of
# the suite is the sharded-vs-baseline comparison.
for mix in ("hit_heavy", "cascade", "swap_storm"):
    for cfg in ("sharded", "shard1_rwlock"):
        want = f"serve/{mix}/{cfg}/t4"
        assert want in names, f"missing variant {want}"
print(f"serve bench smoke OK: {len(results)} schema-valid results")
EOF

# Front-door smoke: a live frugald daemon (sim marketplace) on loopback,
# driven closed-loop by loadgen over >=2 real TCP connections. loadgen
# exits non-zero on ANY protocol error, so the script's exit code already
# gates wire correctness; the python check pins the suite document —
# schema-valid percentiles and the c2 scenario completing >=200 queries.
FRONT_SMOKE_JSON="$(mktemp -t bench_front_smoke_XXXXXX.json)"
trap 'rm -f "$SMOKE_JSON" "$SERVE_SMOKE_JSON" "$FRONT_SMOKE_JSON"' EXIT
scripts/bench_front_door.sh "$FRONT_SMOKE_JSON" --smoke
python3 - "$FRONT_SMOKE_JSON" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc.get("suite") == "front_door", f"wrong suite: {doc.get('suite')!r}"
results = doc.get("results")
assert isinstance(results, list) and results, \
    "front-door smoke wrote an empty results array"
names = set()
for r in results:
    assert isinstance(r.get("name"), str) and r["name"], f"result missing name: {r}"
    assert isinstance(r.get("iters"), int) and r["iters"] > 0, f"bad iters: {r}"
    for key in ("mean_ns", "p50_ns", "p95_ns", "p99_ns"):
        assert isinstance(r.get(key), (int, float)) and r[key] > 0, \
            f"bad {key}: {r}"
    assert isinstance(r.get("per_sec"), (int, float)) and r["per_sec"] > 0, \
        f"bad per_sec: {r}"
    names.add(r["name"])
assert "front_door/closed/c2" in names, f"missing c2 scenario: {sorted(names)}"
c2 = next(r for r in results if r["name"] == "front_door/closed/c2")
assert c2["iters"] >= 200, f"c2 smoke completed too few queries: {c2['iters']}"
print(f"front-door smoke OK: {len(results)} schema-valid results, "
      f"c2 completed {c2['iters']} queries")
EOF

# The committed perf trajectories must stay populated: results non-empty
# (real measurements — the nightly workflow refreshes them) and the
# cross-PR history preserved.
for BENCH_DOC in BENCH_optimizer.json BENCH_serve.json BENCH_front_door.json; do
python3 - "$BENCH_DOC" <<'EOF'
import json, sys
path = sys.argv[1]
doc = json.load(open(path))
assert doc.get("results"), f"committed {path} has an empty results array"
assert doc.get("history"), f"committed {path} lost its history"
print(f"committed {path} OK: {len(doc['results'])} results, "
      f"{len(doc['history'])} history entries")
EOF
done

echo "ci.sh: all gates passed"
