#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build + full test suite,
# plus clippy -D warnings on the workspace crates when clippy is
# installed (the hermetic build container may not ship it).
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "NOTE: cargo-clippy not installed; skipping lint gate" >&2
fi
