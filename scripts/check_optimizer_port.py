#!/usr/bin/env python3
"""Executable spec + measurement harness for the cascade-optimizer rewrite.

This is a line-for-line Python port of FOUR implementations of the §3
cascade search (joint (L, tau) optimization over the response table):

  * ``SeedOptimizer`` — the pre-PR-1 algorithm: per-grid-point O(N) mask
    rebuilds in the triple sweep, O(N) disagreement / mean-cost / accuracy
    recomputation inside the candidate-list loops.
  * ``FlatOptimizer`` — the PR-1 algorithm: precomputed disagreement
    matrix + per-model aggregates, incremental tau_a walk with a
    doubly-linked "escalated items in score_b order" list, raw-tuple local
    Pareto pruning. Since PR 3 it also ports the *weighted* search
    (``weights=`` — decay-weighted serving windows): weight-scaled cost and
    correctness arenas, weighted disagreement, and f64 accumulator updates
    with the identical incremental structure. Unweighted, it is exactly
    the rust ``CorrStore::Weighted`` path at uniform weight 1.0 — the
    "byte/f64 arena" baseline the packed path must reproduce.
  * ``PackedOptimizer`` — the PR-4 unweighted fast path (rust
    ``CorrStore::Packed``): correctness as 64-items-per-word bitsets with
    popcount totals, the K×K disagreement matrix word-at-a-time over
    bit-sliced prediction planes, and exact *integer* sweep accumulators
    (converted to float only at point emission, after the full sum — the
    conversion is exact below 2^53, which is the bit-for-bit argument).
  * ``reference_frontier`` — naive brute force: enumerate every candidate
    (plan, thresholds) combination and score each one with an independent
    (weighted) replay; the ground truth every optimizer must reproduce.

Running it (``python3 scripts/check_optimizer_port.py [--quick]``):

  1. proves SeedOptimizer == FlatOptimizer == PackedOptimizer == reference
     on a batch of random tables (the same property
     rust/tests/properties.rs asserts in-tree),
  2. proves the packed bitset path EXACTLY matches the f64-arena path —
     frontier plans identical and every accuracy/cost float equal with
     ``==`` (bit-for-bit, python floats are f64), per-model accuracy and
     pairwise disagreement equal to scalar recounts — on tables whose N
     covers exact word multiples AND ragged tail words (the
     ``prop_packed_bitset_matches_byte_arena`` gate),
  3. proves the weighted search is sound: uniform power-of-two weights
     reproduce the unweighted frontier BIT-FOR-BIT (plans included), and
     under random non-uniform weights the flat frontier's metrics
     replay-match and its budget queries agree with the brute-force
     reference (tolerance 1e-9 — summation order differs), and
  4. proves the referee-vote shadow labeling rule (``--shadow-referee``):
     the python port of ``shadow::referee_pair`` (two priciest
     non-reference models, ties to the lower index) matches a brute-force
     selection, the vote label equals the single-reference label on every
     escalated item (disagreement ⇒ reference tie-break, so the two loops
     can only differ where the referees agree), and the metered reference
     spend is strictly less whenever at least one agreement occurs, and
  5. measures speedups — wall clock at a reduced workload plus an exact
     inner-loop-operation model at the benches/optimizer.rs workload
     (K=12, N=8000, grid=24), now including the packed-vs-byte op and
     working-set deltas — feeding the numbers recorded in
     BENCH_optimizer.json. (``--quick``, used by CI, skips the slow
     wall-clock measurement but keeps every correctness gate.)

It exists because correctness of the Rust rewrite must be checkable even
where no Rust toolchain is installed; keep it in sync with
rust/src/coordinator/optimizer.rs when the algorithm changes.
"""

import bisect
import json
import math
import sys
import time

MASK = (1 << 64) - 1


class Rng:
    """Port of rust/src/util/rng.rs (splitmix64 -> xoshiro256**)."""

    def __init__(self, seed):
        s = []
        sm = seed & MASK
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        r = (s[1] * 5) & MASK
        r = ((r << 7) | (r >> 57)) & MASK
        r = (r * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & MASK
        return r

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def bool(self, p):
        return self.f64() < p


def synthetic_table(n_models, n_items, n_classes, calibration, seed):
    """Port of coordinator::responses::synthetic_table (scores as f64)."""
    rng = Rng(seed)
    labels = [rng.below(n_classes) for _ in range(n_items)]
    preds, scores, correct = [], [], []
    for m in range(n_models):
        acc = 0.5 + 0.45 * (m / (max(n_models, 2) - 1))
        p, s, c = [], [], []
        for i in range(n_items):
            ok = rng.bool(acc)
            if ok:
                pred = labels[i]
            else:
                pred = (labels[i] + 1 + rng.below(max(n_classes, 2) - 1)) % n_classes
            base = rng.f64()
            if ok:
                score = calibration * (0.5 + 0.5 * base) + (1.0 - calibration) * base
            else:
                score = calibration * 0.5 * base + (1.0 - calibration) * base
            p.append(pred)
            s.append(score)
            c.append(ok)
        preds.append(p)
        scores.append(s)
        correct.append(c)
    return {
        "n": n_items,
        "k": n_models,
        "labels": labels,
        "preds": preds,
        "scores": scores,
        "correct": correct,
    }


# (input_10m, output_10m, per_request) — marketplace::TABLE1.
TABLE1 = [
    (2.0, 2.0, 0.0),
    (2.0, 2.0, 0.0),
    (20.0, 20.0, 0.0),
    (30.0, 60.0, 0.0),
    (0.0, 30.0, 0.0003),
    (0.0, 80.0, 0.0008),
    (0.0, 250.0, 0.005),
    (10.0, 10.0, 0.0),
    (5.8, 5.8, 0.0),
    (0.2, 5.0, 0.0),
    (0.6, 15.0, 0.0),
    (1.4, 35.0, 0.0),
]
ANSWER_LENS = [1, 1, 2, 1]


def call_cost(m, input_tokens, answer):
    inp, out, req = TABLE1[m]
    out_tokens = ANSWER_LENS[answer] if answer < len(ANSWER_LENS) else 1
    return inp * input_tokens / 1e7 + out * out_tokens / 1e7 + req


def replay(plan, table, toks, weights=None):
    """Port of cascade::replay::replay — ground-truth (weighted) plan
    metrics: acc = sum(w_i * correct_i) / W, cost = sum(w_i * cost_i) / W,
    accumulated per item exactly like the rust replay."""
    n = table["n"]
    w_correct = 0.0
    total_cost = 0.0
    total_w = 0.0
    last = len(plan) - 1
    for i in range(n):
        w = 1.0 if weights is None else weights[i]
        total_w += w
        item_cost = 0.0
        for s, (m, tau) in enumerate(plan):
            item_cost += call_cost(m, toks[i], table["preds"][m][i])
            if s == last or table["scores"][m][i] > tau:
                if table["correct"][m][i]:
                    w_correct += w
                break
        total_cost += w * item_cost
    denom = float(n) if weights is None else total_w
    return w_correct / denom, total_cost / denom


def prev_midpoint(hi, lo):
    if hi == float("inf"):
        return lo + 1.0
    return (hi + lo) * 0.5


def prune_pareto(pts):
    """pts: list of (plan, acc, cost). Port of optimizer::prune_pareto."""
    pts = sorted(pts, key=lambda p: (p[2], -p[1]))
    out = []
    best = float("-inf")
    for p in pts:
        if p[1] > best + 1e-12:
            best = p[1]
            out.append(p)
    return out


OPS = {"n": 0}  # inner-loop item visits, for the op-count model cross-check


class SeedOptimizer:
    """The pre-PR-1 search, ported verbatim from the seed optimizer.rs."""

    def __init__(self, table, toks, grid=24, max_len=3, min_disagreement=0.02):
        self.t = table
        self.toks = toks
        self.grid = grid
        self.max_len = max_len
        self.eps = min_disagreement
        n, k = table["n"], table["k"]
        self.cost = [
            [call_cost(m, toks[i], table["preds"][m][i]) for i in range(n)]
            for m in range(k)
        ]
        self.order = []
        self.quantiles = []
        for m in range(k):
            sc = table["scores"][m]
            idx = sorted(range(n), key=lambda i: -sc[i])
            qs = []
            for g in range(grid):
                pos = min(((g + 1) * n) // (grid + 1), n - 1)
                qs.append(sc[idx[pos]])
            # Vec::dedup — consecutive duplicates only.
            dq = [q for j, q in enumerate(qs) if j == 0 or q != qs[j - 1]]
            self.order.append(idx)
            self.quantiles.append(dq)

    def disagreement(self, a, b):
        t = self.t
        n = t["n"]
        OPS["n"] += n
        pa, pb = t["preds"][a], t["preds"][b]
        return sum(pa[i] != pb[i] for i in range(n)) / max(n, 1)

    def model_cost(self, m):
        OPS["n"] += self.t["n"]
        return sum(self.cost[m]) / max(self.t["n"], 1)

    def accuracy(self, m):
        OPS["n"] += self.t["n"]
        return sum(self.t["correct"][m]) / max(self.t["n"], 1)

    def candidate_lists(self):
        k = self.t["k"]
        lists = [[m] for m in range(k)]
        if self.max_len >= 2:
            for a in range(k):
                for b in range(k):
                    if a == b or self.disagreement(a, b) < self.eps:
                        continue
                    if self.model_cost(a) > self.model_cost(b) and self.accuracy(
                        a
                    ) < self.accuracy(b):
                        continue
                    lists.append([a, b])
        if self.max_len >= 3:
            pairs = [(l[0], l[1]) for l in lists if len(l) == 2]
            for a, b in pairs:
                for c in range(k):
                    if c == a or c == b or self.disagreement(b, c) < self.eps:
                        continue
                    if self.model_cost(b) > self.model_cost(c) and self.accuracy(
                        b
                    ) < self.accuracy(c):
                        continue
                    lists.append([a, b, c])
        return lists

    def sweep_pair(self, a, b, out):
        t = self.t
        n = t["n"]
        order = self.order[a]
        scores = t["scores"][a]
        OPS["n"] += n  # totals pass
        total_cost_a = sum(self.cost[a])
        total_cost_b = sum(self.cost[b])
        total_corr_b = sum(t["correct"][b])
        acc_corr_a = 0
        acc_corr_b = total_corr_b
        esc_cost_b = total_cost_b
        inv_n = 1.0 / n
        pts = []
        prev = float("inf")
        OPS["n"] += n
        for i in order:
            s = scores[i]
            if s < prev:
                tau = prev_midpoint(prev, s)
                pts.append(
                    (
                        ((a, tau), (b, 0.0)),
                        (acc_corr_a + acc_corr_b) * inv_n,
                        (total_cost_a + esc_cost_b) * inv_n,
                    )
                )
            acc_corr_a += t["correct"][a][i]
            acc_corr_b -= t["correct"][b][i]
            esc_cost_b -= self.cost[b][i]
            prev = s
        pts.append((((a, -1.0), (b, 0.0)), acc_corr_a * inv_n, total_cost_a * inv_n))
        out.extend(prune_pareto(pts))

    def sweep_triple_fixed_first(self, a, tau_a, b, c, out):
        t = self.t
        n = t["n"]
        scores_a, scores_b = t["scores"][a], t["scores"][b]
        corr_a, corr_b, corr_c = t["correct"][a], t["correct"][b], t["correct"][c]
        cost_a, cost_b, cost_c = self.cost[a], self.cost[b], self.cost[c]

        mask = [False] * n
        acc_corr_a = 0
        base_cost = 0.0
        n_esc = 0
        OPS["n"] += n  # mask build
        for i in range(n):
            base_cost += cost_a[i]
            if scores_a[i] > tau_a:
                acc_corr_a += corr_a[i]
            else:
                mask[i] = True
                n_esc += 1
        if n_esc == 0:
            return

        esc_cost_b = 0.0
        esc_corr_c = 0
        esc_cost_c = 0.0
        OPS["n"] += n  # aggregate rescan
        for i in range(n):
            if mask[i]:
                esc_cost_b += cost_b[i]
                esc_corr_c += corr_c[i]
                esc_cost_c += cost_c[i]

        inv_n = 1.0 / n
        corr_b_acc = 0
        rem_corr_c = esc_corr_c
        rem_cost_c = esc_cost_c
        prev = float("inf")
        pts = []
        OPS["n"] += n  # full order_b walk (mask check on every item)
        for i in self.order[b]:
            if not mask[i]:
                continue
            s = scores_b[i]
            if s < prev:
                tau_b = prev_midpoint(prev, s)
                pts.append(
                    (
                        ((a, tau_a), (b, tau_b), (c, 0.0)),
                        (acc_corr_a + corr_b_acc + rem_corr_c) * inv_n,
                        (base_cost + esc_cost_b + rem_cost_c) * inv_n,
                    )
                )
            corr_b_acc += corr_b[i]
            rem_corr_c -= corr_c[i]
            rem_cost_c -= cost_c[i]
            prev = s
        pts.append(
            (
                ((a, tau_a), (b, -1.0), (c, 0.0)),
                (acc_corr_a + corr_b_acc) * inv_n,
                (base_cost + esc_cost_b) * inv_n,
            )
        )
        out.extend(prune_pareto(pts))

    def frontier(self):
        out = []
        for lst in self.candidate_lists():
            if len(lst) == 1:
                m = lst[0]
                out.append((((m, 0.0),), self.accuracy(m), self.model_cost(m)))
            elif len(lst) == 2:
                self.sweep_pair(lst[0], lst[1], out)
            else:
                a, b, c = lst
                for tau_a in self.quantiles[a]:
                    self.sweep_triple_fixed_first(a, tau_a, b, c, out)
        return prune_pareto(out)


def quantile_grid(scores, idx, grid, weights=None, total_weight=None):
    """The τ_a grid of one model (rust ``optimizer::quantile_grid``):
    positional quantiles over the score-descending ``idx`` order for
    unweighted tables, *weighted* quantiles (grid point g sits where the
    cumulative observation mass first exceeds ``(g+1)/(grid+1)`` of the
    total) when per-item weights are present — so under heavy decay the
    grid concentrates where the mass actually is. For uniform weights the
    walk reproduces the positional grid exactly: with w ≡ c the stop
    condition ``cum + c <= target`` compares exact multiples of c against
    ``(g+1)·n·c/(grid+1)``, which floors to the positional index (the
    power-of-two-scaling argument of the §Weights bit-parity property).
    Consecutive duplicates are deduped, exactly like the rust."""
    n = len(idx)
    qs = []
    if weights is None:
        for g in range(grid):
            pos = min(((g + 1) * n) // (grid + 1), n - 1)
            qs.append(scores[idx[pos]])
    else:
        cum = 0.0
        pos = 0
        for g in range(grid):
            target = (g + 1) * total_weight / (grid + 1)
            while pos + 1 < n and cum + weights[idx[pos]] <= target:
                cum += weights[idx[pos]]
                pos += 1
            qs.append(scores[idx[pos]])
    return [q for j, q in enumerate(qs) if j == 0 or q != qs[j - 1]]


def build_cost_order_quantiles(table, toks, grid, weights=None):
    """The workspace build both optimizer ports share (rust
    Workspace::build's cost/order/quantile section): the (weight-scaled)
    per-item cost arena + index-order totals, the score-descending item
    order, and the consecutive-deduped quantile grid per model (weight-
    aware via ``quantile_grid``). Kept in ONE place so the packed and
    flat executable specs cannot silently diverge on it. For
    ``weights=None`` every cost is multiplied by exactly 1.0 —
    bit-identical to no multiply, matching the rust."""
    n, k = table["n"], table["k"]
    if weights is None:
        total_weight = float(n)
    else:
        # Index-order accumulation, matching SplitTable::with_weights.
        total_weight = 0.0
        for w in weights:
            total_weight += w
    cost, total_cost, order, quantiles = [], [], [], []
    for m in range(k):
        OPS["n"] += n  # cost arena build (f64 per item, both paths)
        row = []
        total = 0.0
        for i in range(n):
            w = 1.0 if weights is None else weights[i]
            c = call_cost(m, toks[i], table["preds"][m][i]) * w
            row.append(c)
            total += c
        cost.append(row)
        total_cost.append(total)
        sc = table["scores"][m]
        idx = sorted(range(n), key=lambda i: -sc[i])
        order.append(idx)
        quantiles.append(quantile_grid(sc, idx, grid, weights, total_weight))
    return cost, total_cost, order, quantiles


class FlatOptimizer:
    """The PR-1 search: precomputed aggregates + incremental triple sweep.
    With ``weights`` it is the PR-3 *weighted* search (a line-for-line port
    of the rust Workspace §Weights layout): per-item costs are
    weight-scaled, correctness becomes a weighted arena (w_i where correct,
    else 0.0), disagreement and every mean divide by sum(w), and the sweep
    accumulators add/subtract the scaled entries in the same order."""

    def __init__(self, table, toks, grid=24, max_len=3, min_disagreement=0.02,
                 weights=None):
        self.t = table
        self.toks = toks
        self.grid = grid
        self.max_len = max_len
        self.eps = min_disagreement
        n, k = table["n"], table["k"]
        if weights is None:
            self.total_weight = float(n)
        else:
            assert len(weights) == n
            total = 0.0
            for w in weights:
                assert w > 0.0
                total += w
            self.total_weight = total
        (self.cost, self.total_cost, self.order, self.quantiles) = (
            build_cost_order_quantiles(table, toks, grid, weights)
        )
        self.wcorr = []
        self.total_corr = []
        for m in range(k):
            wc_row = []
            tcorr = 0.0
            corr = table["correct"][m]
            for i in range(n):
                w = 1.0 if weights is None else weights[i]
                wc = w if corr[i] else 0.0
                wc_row.append(wc)
                tcorr += wc
            self.wcorr.append(wc_row)
            self.total_corr.append(tcorr)
        self.disagree = [[0.0] * k for _ in range(k)]
        for a in range(k):
            for b in range(a + 1, k):
                OPS["n"] += n
                pa, pb = table["preds"][a], table["preds"][b]
                if weights is None:
                    d = float(sum(pa[i] != pb[i] for i in range(n)))
                else:
                    d = 0.0
                    for i in range(n):
                        if pa[i] != pb[i]:
                            d += weights[i]
                frac = d / self.total_weight
                self.disagree[a][b] = frac
                self.disagree[b][a] = frac

    def model_cost(self, m):
        return self.total_cost[m] / self.total_weight

    def accuracy(self, m):
        return self.total_corr[m] / self.total_weight

    def candidate_lists(self):
        k = self.t["k"]
        lists = [[m] for m in range(k)]
        if self.max_len >= 2:
            for a in range(k):
                for b in range(k):
                    if a == b or self.disagree[a][b] < self.eps:
                        continue
                    if self.model_cost(a) > self.model_cost(b) and self.accuracy(
                        a
                    ) < self.accuracy(b):
                        continue
                    lists.append([a, b])
        if self.max_len >= 3:
            pairs = [(l[0], l[1]) for l in lists if len(l) == 2]
            for a, b in pairs:
                for c in range(k):
                    if c == a or c == b or self.disagree[b][c] < self.eps:
                        continue
                    if self.model_cost(b) > self.model_cost(c) and self.accuracy(
                        b
                    ) < self.accuracy(c):
                        continue
                    lists.append([a, b, c])
        return lists

    def sweep_pair(self, a, b, out):
        t = self.t
        n = t["n"]
        order = self.order[a]
        scores = t["scores"][a]
        wcorr_a, wcorr_b = self.wcorr[a], self.wcorr[b]
        cost_b = self.cost[b]
        total_cost_a = self.total_cost[a]
        acc_corr_a = 0.0
        acc_corr_b = self.total_corr[b]
        esc_cost_b = self.total_cost[b]
        inv_n = 1.0 / self.total_weight
        raw = []
        prev = float("inf")
        OPS["n"] += n
        for i in order:
            s = scores[i]
            if s < prev:
                raw.append(
                    (
                        prev_midpoint(prev, s),
                        (acc_corr_a + acc_corr_b) * inv_n,
                        (total_cost_a + esc_cost_b) * inv_n,
                    )
                )
            acc_corr_a += wcorr_a[i]
            acc_corr_b -= wcorr_b[i]
            esc_cost_b -= cost_b[i]
            prev = s
        raw.append((-1.0, acc_corr_a * inv_n, total_cost_a * inv_n))
        out.extend(
            (((a, tau), (b, 0.0)), acc, cost)
            for tau, acc, cost in prune_pareto_raw(raw)
        )

    def sweep_triple(self, a, b, c, out):
        t = self.t
        n = t["n"]
        sent = n
        scores_a, scores_b = t["scores"][a], t["scores"][b]
        wcorr_a, wcorr_b, wcorr_c = self.wcorr[a], self.wcorr[b], self.wcorr[c]
        cost_b, cost_c = self.cost[b], self.cost[c]
        order_a, order_b = self.order[a], self.order[b]

        OPS["n"] += 2 * n  # rank + linked-list init
        rank = [0] * n
        for r, i in enumerate(order_b):
            rank[i] = r
        nxt = list(range(1, n + 1)) + [0]
        nxt[n] = 0
        prv = [sent] + list(range(n))

        base_cost = self.total_cost[a]
        acc_corr_a = 0.0
        n_esc = n
        esc_cost_b = self.total_cost[b]
        esc_corr_c = self.total_corr[c]
        esc_cost_c = self.total_cost[c]

        inv_n = 1.0 / self.total_weight
        accepted = 0
        for tau_a in self.quantiles[a]:
            while accepted < n:
                i = order_a[accepted]
                if scores_a[i] <= tau_a:
                    break
                OPS["n"] += 1
                acc_corr_a += wcorr_a[i]
                esc_cost_b -= cost_b[i]
                esc_corr_c -= wcorr_c[i]
                esc_cost_c -= cost_c[i]
                r = rank[i]
                p, nx = prv[r], nxt[r]
                nxt[p] = nx
                prv[nx] = p
                n_esc -= 1
                accepted += 1
            if n_esc == 0:
                break

            raw = []
            corr_b_acc = 0.0
            rem_corr_c = esc_corr_c
            rem_cost_c = esc_cost_c
            prev = float("inf")
            r = nxt[sent]
            OPS["n"] += n_esc
            while r != sent:
                i = order_b[r]
                s = scores_b[i]
                if s < prev:
                    raw.append(
                        (
                            prev_midpoint(prev, s),
                            (acc_corr_a + corr_b_acc + rem_corr_c) * inv_n,
                            (base_cost + esc_cost_b + rem_cost_c) * inv_n,
                        )
                    )
                corr_b_acc += wcorr_b[i]
                rem_corr_c -= wcorr_c[i]
                rem_cost_c -= cost_c[i]
                prev = s
                r = nxt[r]
            raw.append(
                (
                    -1.0,
                    (acc_corr_a + corr_b_acc) * inv_n,
                    (base_cost + esc_cost_b) * inv_n,
                )
            )
            out.extend(
                (((a, tau_a), (b, tau_b), (c, 0.0)), acc, cost)
                for tau_b, acc, cost in prune_pareto_raw(raw)
            )

    def frontier(self):
        out = []
        for lst in self.candidate_lists():
            if len(lst) == 1:
                m = lst[0]
                out.append((((m, 0.0),), self.accuracy(m), self.model_cost(m)))
            elif len(lst) == 2:
                self.sweep_pair(lst[0], lst[1], out)
            else:
                self.sweep_triple(lst[0], lst[1], lst[2], out)
        return prune_pareto(out)


def prune_pareto_raw(raw):
    """raw: list of (tau, acc, cost) — port of optimizer::prune_pareto_raw."""
    raw = sorted(raw, key=lambda p: (p[2], -p[1]))
    out = []
    best = float("-inf")
    for p in raw:
        if p[1] > best + 1e-12:
            best = p[1]
            out.append(p)
    return out


def popcount(x):
    return bin(x).count("1")


def pack_bools(bools):
    """Port of responses::pack_bools: bit i%64 of word i//64, tail zero."""
    words = [0] * ((len(bools) + 63) // 64)
    for i, b in enumerate(bools):
        if b:
            words[i >> 6] |= 1 << (i & 63)
    return words


class PackedOptimizer(FlatOptimizer):
    """Port of the PR-4 packed-bitset unweighted fast path (rust
    ``CorrStore::Packed`` + the ``PackedCorr`` sweeps): correctness lives
    in 64-items-per-word bitset rows (tail bits zero), per-model totals
    are popcounts, the disagreement matrix runs word-at-a-time over
    bit-sliced prediction planes, and every sweep accumulator is an exact
    python int (== rust u64 at these ranges). Floats appear only at point
    emission, converting the *summed* count — exactly like the rust
    ``CorrAcc::to_f64`` — so results must equal FlatOptimizer's (without
    weights) with ``==``, not a tolerance.

    Inherits candidate_lists/accuracy/model_cost/frontier from
    FlatOptimizer (they only read the aggregates built here); __init__ and
    both sweeps are full overrides and deliberately do NOT call super() —
    the packed path never materializes the f64 correctness arena.
    """

    # pylint: disable=super-init-not-called
    def __init__(self, table, toks, grid=24, max_len=3, min_disagreement=0.02):
        self.t = table
        self.toks = toks
        self.grid = grid
        self.max_len = max_len
        self.eps = min_disagreement
        n, k = table["n"], table["k"]
        self.total_weight = float(n)
        words = (n + 63) // 64
        self.words = words
        (self.cost, self.total_cost, self.order, self.quantiles) = (
            build_cost_order_quantiles(table, toks, grid)
        )
        self.corr_words = []
        self.total_corr = []
        for m in range(k):
            cw = pack_bools(table["correct"][m])
            OPS["n"] += words  # popcount totals: word ops, not item visits
            self.corr_words.append(cw)
            self.total_corr.append(sum(popcount(w) for w in cw))
        # Bit-sliced prediction planes: plane p of model m packs bit p of
        # every pred, so pa[i] != pb[i] == "any plane XOR has bit i".
        max_pred = max((p for m in range(k) for p in table["preds"][m]), default=0)
        n_planes = max(max_pred.bit_length(), 1)
        self.n_planes = n_planes
        planes = [[[0] * words for _ in range(n_planes)] for _ in range(k)]
        for m in range(k):
            OPS["n"] += n  # plane build: one visit per item
            for i, p in enumerate(table["preds"][m]):
                w, b = i >> 6, i & 63
                for pl in range(n_planes):
                    if (p >> pl) & 1:
                        planes[m][pl][w] |= 1 << b
        self.disagree = [[0.0] * k for _ in range(k)]
        for a in range(k):
            for b in range(a + 1, k):
                OPS["n"] += words * (n_planes + 1)  # XOR/OR + popcount words
                d = 0
                for w in range(words):
                    diff = 0
                    for pl in range(n_planes):
                        diff |= planes[a][pl][w] ^ planes[b][pl][w]
                    d += popcount(diff)
                frac = d / self.total_weight
                self.disagree[a][b] = frac
                self.disagree[b][a] = frac

    def sweep_pair(self, a, b, out):
        t = self.t
        n = t["n"]
        order = self.order[a]
        scores = t["scores"][a]
        words_a, words_b = self.corr_words[a], self.corr_words[b]
        cost_b = self.cost[b]
        total_cost_a = self.total_cost[a]
        acc_corr_a = 0
        acc_corr_b = self.total_corr[b]
        esc_cost_b = self.total_cost[b]
        inv_n = 1.0 / self.total_weight
        raw = []
        prev = float("inf")
        OPS["n"] += n
        for i in order:
            s = scores[i]
            if s < prev:
                raw.append(
                    (
                        prev_midpoint(prev, s),
                        (acc_corr_a + acc_corr_b) * inv_n,
                        (total_cost_a + esc_cost_b) * inv_n,
                    )
                )
            acc_corr_a += (words_a[i >> 6] >> (i & 63)) & 1
            acc_corr_b -= (words_b[i >> 6] >> (i & 63)) & 1
            esc_cost_b -= cost_b[i]
            prev = s
        raw.append((-1.0, acc_corr_a * inv_n, total_cost_a * inv_n))
        out.extend(
            (((a, tau), (b, 0.0)), acc, cost)
            for tau, acc, cost in prune_pareto_raw(raw)
        )

    def sweep_triple(self, a, b, c, out):
        t = self.t
        n = t["n"]
        sent = n
        scores_a, scores_b = t["scores"][a], t["scores"][b]
        words_a, words_b, words_c = (
            self.corr_words[a],
            self.corr_words[b],
            self.corr_words[c],
        )
        cost_b, cost_c = self.cost[b], self.cost[c]
        order_a, order_b = self.order[a], self.order[b]

        OPS["n"] += 2 * n  # rank + linked-list init
        rank = [0] * n
        for r, i in enumerate(order_b):
            rank[i] = r
        nxt = list(range(1, n + 1)) + [0]
        nxt[n] = 0
        prv = [sent] + list(range(n))

        base_cost = self.total_cost[a]
        acc_corr_a = 0
        n_esc = n
        esc_cost_b = self.total_cost[b]
        esc_corr_c = self.total_corr[c]
        esc_cost_c = self.total_cost[c]

        inv_n = 1.0 / self.total_weight
        accepted = 0
        for tau_a in self.quantiles[a]:
            while accepted < n:
                i = order_a[accepted]
                if scores_a[i] <= tau_a:
                    break
                OPS["n"] += 1
                acc_corr_a += (words_a[i >> 6] >> (i & 63)) & 1
                esc_cost_b -= cost_b[i]
                esc_corr_c -= (words_c[i >> 6] >> (i & 63)) & 1
                esc_cost_c -= cost_c[i]
                r = rank[i]
                p, nx = prv[r], nxt[r]
                nxt[p] = nx
                prv[nx] = p
                n_esc -= 1
                accepted += 1
            if n_esc == 0:
                break

            raw = []
            corr_b_acc = 0
            rem_corr_c = esc_corr_c
            rem_cost_c = esc_cost_c
            prev = float("inf")
            r = nxt[sent]
            OPS["n"] += n_esc
            while r != sent:
                i = order_b[r]
                s = scores_b[i]
                if s < prev:
                    raw.append(
                        (
                            prev_midpoint(prev, s),
                            (acc_corr_a + corr_b_acc + rem_corr_c) * inv_n,
                            (base_cost + esc_cost_b + rem_cost_c) * inv_n,
                        )
                    )
                corr_b_acc += (words_b[i >> 6] >> (i & 63)) & 1
                rem_corr_c -= (words_c[i >> 6] >> (i & 63)) & 1
                rem_cost_c -= cost_c[i]
                prev = s
                r = nxt[r]
            raw.append(
                (
                    -1.0,
                    (acc_corr_a + corr_b_acc) * inv_n,
                    (base_cost + esc_cost_b) * inv_n,
                )
            )
            out.extend(
                (((a, tau_a), (b, tau_b), (c, 0.0)), acc, cost)
                for tau_b, acc, cost in prune_pareto_raw(raw)
            )


def reference_frontier(table, toks, grid=24, max_len=3, min_disagreement=0.02,
                       weights=None):
    """Brute force: enumerate candidate (plan, tau) combos independently of
    either optimizer and score each with (weighted) replay()."""
    n, k = table["n"], table["k"]

    def wt(i):
        return 1.0 if weights is None else weights[i]

    total_w = float(n) if weights is None else sum(weights)

    def disagreement(a, b):
        pa, pb = table["preds"][a], table["preds"][b]
        return sum(wt(i) for i in range(n) if pa[i] != pb[i]) / total_w

    def model_cost(m):
        return (
            sum(wt(i) * call_cost(m, toks[i], table["preds"][m][i]) for i in range(n))
            / total_w
        )

    def accuracy(m):
        return sum(wt(i) for i in range(n) if table["correct"][m][i]) / total_w

    def cut_taus(scores, items):
        """Thresholds the exact sweeps can emit over `items`: one above the
        max score, midpoints between adjacent distinct scores, and -1."""
        ss = sorted({scores[i] for i in items}, reverse=True)
        taus = [ss[0] + 1.0]
        for hi, lo in zip(ss, ss[1:]):
            taus.append((hi + lo) * 0.5)
        taus.append(-1.0)
        return taus

    def quantile_taus(m):
        # Same weight-aware grid as the searches under test: the τ_a grid
        # determines WHICH triples exist, so the reference must place its
        # grid points identically or the frontier sets diverge by design.
        sc = table["scores"][m]
        idx = sorted(range(n), key=lambda i: -sc[i])
        return quantile_grid(sc, idx, grid, weights, total_w)

    eps = min_disagreement
    plans = [((m, 0.0),) for m in range(k)]
    pairs = []
    if max_len >= 2:
        for a in range(k):
            for b in range(k):
                if a == b or disagreement(a, b) < eps:
                    continue
                if model_cost(a) > model_cost(b) and accuracy(a) < accuracy(b):
                    continue
                pairs.append((a, b))
                for tau in cut_taus(table["scores"][a], range(n)):
                    plans.append(((a, tau), (b, 0.0)))
    if max_len >= 3:
        for a, b in pairs:
            for c in range(k):
                if c == a or c == b or disagreement(b, c) < eps:
                    continue
                if model_cost(b) > model_cost(c) and accuracy(b) < accuracy(c):
                    continue
                for tau_a in quantile_taus(a):
                    esc = [i for i in range(n) if table["scores"][a][i] <= tau_a]
                    if not esc:
                        continue
                    for tau_b in cut_taus(table["scores"][b], esc):
                        plans.append(((a, tau_a), (b, tau_b), (c, 0.0)))
    pts = []
    for plan in plans:
        acc, cost = replay(plan, table, toks, weights=weights)
        pts.append((plan, acc, cost))
    return prune_pareto(pts)


def frontiers_match(fa, fb, tol=1e-12, plans_too=False):
    if len(fa) != len(fb):
        return False, f"lengths differ: {len(fa)} vs {len(fb)}"
    for j, (pa, pb) in enumerate(zip(fa, fb)):
        if abs(pa[1] - pb[1]) > tol:
            return False, f"point {j}: acc {pa[1]} vs {pb[1]}"
        if abs(pa[2] - pb[2]) > tol:
            return False, f"point {j}: cost {pa[2]} vs {pb[2]}"
        if plans_too and pa[0] != pb[0]:
            return False, f"point {j}: plan {pa[0]} vs {pb[0]}"
    return True, ""


def best_within(frontier, budget_per_query):
    """Port of optimizer::best_within (per-query budget form)."""
    fits = [p for p in frontier if p[2] <= budget_per_query + 1e-15]
    if not fits:
        return None
    return max(fits, key=lambda p: (p[1], -p[2]))


def check_packed(cases=12):
    """PR-4 packed-bitset gate (the python side of
    rust/tests/properties.rs::prop_packed_bitset_matches_byte_arena):
    on tables covering exact word multiples AND ragged tail words,
    (a) per-model accuracy and pairwise disagreement from the packed
        popcount/bit-plane paths EXACTLY equal scalar recounts and the
        f64-arena (flat) values, and
    (b) the packed frontier equals the flat frontier point-for-point —
        plans identical, accuracy/cost floats equal with ``==`` (python
        floats are f64, so this is the bit-for-bit claim executed)."""
    print(f"[2/7] packed bitset vs byte arena on {cases} tables ...")
    rng = Rng(0xB175)
    # The first cases pin N to word-boundary edges; the rest are random.
    fixed_ns = [64, 65, 127, 128, 129, 100]
    for case in range(cases):
        k = 3 + rng.below(3)
        n = fixed_ns[case] if case < len(fixed_ns) else 20 + rng.below(230)
        classes = 2 + rng.below(4)
        grid = 4 + rng.below(4)
        table = synthetic_table(k, n, classes, 0.5 + 0.5 * rng.f64(), rng.next_u64())
        toks = [40 + rng.below(100)] * n

        flat = FlatOptimizer(table, toks, grid=grid)
        packed = PackedOptimizer(table, toks, grid=grid)
        # tail bits of every packed row are zero
        tail = n & 63
        if tail:
            for m in range(k):
                assert packed.corr_words[m][-1] >> tail == 0, f"case {case} m={m}"
        for m in range(k):
            scalar = sum(table["correct"][m]) / n
            assert packed.accuracy(m) == scalar == flat.accuracy(m), (
                f"case {case} model {m}: packed {packed.accuracy(m)} "
                f"scalar {scalar} flat {flat.accuracy(m)}"
            )
        for a in range(k):
            for b in range(k):
                if a == b:
                    continue
                scalar = (
                    sum(
                        table["preds"][a][i] != table["preds"][b][i]
                        for i in range(n)
                    )
                    / n
                )
                assert packed.disagree[a][b] == scalar == flat.disagree[a][b], (
                    f"case {case} disagree({a},{b})"
                )
        f_flat = flat.frontier()
        f_packed = packed.frontier()
        assert len(f_flat) == len(f_packed), (
            f"case {case} (n={n}): {len(f_packed)} packed pts vs {len(f_flat)}"
        )
        for j, (p, q) in enumerate(zip(f_flat, f_packed)):
            assert p[0] == q[0], f"case {case} pt {j}: plan {q[0]} vs {p[0]}"
            assert p[1] == q[1], f"case {case} pt {j}: acc {q[1]} != {p[1]}"
            assert p[2] == q[2], f"case {case} pt {j}: cost {q[2]} != {p[2]}"
        print(
            f"  case {case:2d}: k={k} n={n:3d} grid={grid} "
            f"frontier={len(f_packed):2d} pts ... packed == byte EXACT "
            f"({'tail word' if tail else 'word-aligned'})"
        )
    print("  packed bitset PASSED")


def check_weighted(cases=10):
    """PR-3 weighted-search gates:
    (a) uniform power-of-two weights reproduce the unweighted frontier
        bit-for-bit, plans included (the rust property test's claim);
    (b) under random non-uniform weights every flat frontier point
        replay-matches to 1e-9 (summation order is the only difference),
        the frontier is sorted/strictly-improving, and
    (c) budget queries against the weighted brute-force reference agree
        to 1e-9 (exact frontier-set comparison would be brittle at Pareto
        near-ties, so equivalence is checked at the query interface the
        serving stack actually uses), and
    (d) the weight-aware τ_a grid: uniform power-of-two weights reproduce
        the positional grid bit-for-bit, and under arbitrary weights the
        incremental walk matches an independent prefix-sum definition
        (grid point g = score of the first order position whose cumulative
        mass exceeds (g+1)/(grid+1) of the total)."""
    print(f"[3/7] weighted search on {cases} random tables ...")
    rng = Rng(0xBEEF)
    for case in range(cases):
        k = 3 + rng.below(3)
        n = 30 + rng.below(170)
        classes = 2 + rng.below(4)
        grid = 4 + rng.below(4)
        table = synthetic_table(k, n, classes, 0.5 + 0.5 * rng.f64(), rng.next_u64())
        toks = [40 + rng.below(100)] * n

        # (a) uniform power-of-two weights: bit-for-bit identical.
        f_plain = FlatOptimizer(table, toks, grid=grid).frontier()
        for u in (1.0, 0.5, 2.0):
            f_u = FlatOptimizer(table, toks, grid=grid, weights=[u] * n).frontier()
            assert len(f_u) == len(f_plain), (
                f"case {case} w={u}: {len(f_u)} pts vs {len(f_plain)}"
            )
            for j, (p, q) in enumerate(zip(f_plain, f_u)):
                assert p[0] == q[0], f"case {case} w={u} pt {j}: plan {p[0]} vs {q[0]}"
                assert p[1] == q[1], f"case {case} w={u} pt {j}: acc {p[1]} vs {q[1]}"
                assert p[2] == q[2], f"case {case} w={u} pt {j}: cost {p[2]} vs {q[2]}"

        # (d) the weight-aware grid itself, independent of the sweeps.
        grid_weights = [0.25 + 3.75 * rng.f64() for _ in range(n)]
        gw_total = 0.0
        for w in grid_weights:
            gw_total += w
        for m in range(k):
            sc = table["scores"][m]
            idx = sorted(range(n), key=lambda i: -sc[i])
            pos_grid = quantile_grid(sc, idx, grid)
            for u in (1.0, 0.5, 2.0):
                ut = 0.0
                for _ in range(n):
                    ut += u
                wg = quantile_grid(sc, idx, grid, [u] * n, ut)
                assert wg == pos_grid, (
                    f"case {case} m={m} w={u}: uniform grid {wg} != "
                    f"positional {pos_grid}"
                )
            # prefix-sum reference: first position whose cumulative mass
            # exceeds the target, capped at the last item.
            prefix = [0.0]
            for p in range(n):
                prefix.append(prefix[-1] + grid_weights[idx[p]])
            want = []
            for g in range(grid):
                target = (g + 1) * gw_total / (grid + 1)
                pos = n - 1
                for p in range(n):
                    if prefix[p + 1] > target:
                        pos = p
                        break
                want.append(sc[idx[pos]])
            want = [q for j, q in enumerate(want) if j == 0 or q != want[j - 1]]
            got = quantile_grid(sc, idx, grid, grid_weights, gw_total)
            assert got == want, (
                f"case {case} m={m}: weighted grid {got} != prefix-sum "
                f"reference {want}"
            )

        # (b) non-uniform weights: internal consistency via weighted replay.
        weights = [0.25 + 3.75 * rng.f64() for _ in range(n)]
        f_w = FlatOptimizer(table, toks, grid=grid, weights=weights).frontier()
        assert f_w, "weighted frontier must not be empty"
        for j in range(1, len(f_w)):
            assert f_w[j - 1][2] <= f_w[j][2] and f_w[j - 1][1] < f_w[j][1]
        for plan, acc, cost in f_w:
            racc, rcost = replay(plan, table, toks, weights=weights)
            assert abs(racc - acc) < 1e-9 and abs(rcost - cost) < 1e-9, (
                f"case {case}: weighted plan {plan} reports ({acc}, {cost}) "
                f"but replays to ({racc}, {rcost})"
            )

        # (c) budget-query equivalence against the weighted brute force.
        f_ref = reference_frontier(table, toks, grid=grid, weights=weights)
        assert abs(f_w[-1][1] - f_ref[-1][1]) < 1e-9, (
            f"case {case}: top weighted accuracy {f_w[-1][1]} vs reference "
            f"{f_ref[-1][1]}"
        )
        lo = min(f_ref[0][2], f_w[0][2])
        hi = max(f_ref[-1][2], f_w[-1][2])
        for frac in (0.0, 0.1, 0.3, 0.6, 1.0):
            budget = lo + frac * (hi - lo)
            got = best_within(f_w, budget)
            want = best_within(f_ref, budget)
            assert (got is None) == (want is None), (
                f"case {case} budget {budget}: feasibility disagrees"
            )
            if got is not None:
                assert abs(got[1] - want[1]) < 1e-9, (
                    f"case {case} budget {budget}: acc {got[1]} vs {want[1]}"
                )
        print(
            f"  case {case:2d}: k={k} n={n:3d} grid={grid} "
            f"weighted={len(f_w):2d} pts ... uniform-bitwise + replay + budget OK"
        )
    print("  weighted search PASSED")


def route_plans_py(global_plan, frontier, grid):
    """Port of strategies::router::route_plans: route 0 is the global
    plan verbatim, routes 1..L-1 are its prefix-skips, then an even
    subsample of the frontier (deduplicated)."""
    out = [(list(global_plan), 0)]
    for j in range(1, len(global_plan)):
        out.append((list(global_plan[j:]), j))
    if grid > 0 and frontier:
        picks = min(grid, len(frontier))
        for k in range(picks):
            idx = 0 if picks == 1 else k * (len(frontier) - 1) // (picks - 1)
            plan = list(frontier[idx][0])
            if any(p == plan for p, _ in out):
                continue
            out.append((plan, 0))
    return out


def routed_replay_py(weights, routes, table, toks):
    """Port of server::router_train::evaluate_router at uniform weight:
    per item, score the features (bias + log-length; probe and cache are
    0.0 offline), argmax with ties to the lowest index, then walk the
    chosen route's plan exactly like replay()."""
    n = table["n"]
    w_correct = 0.0
    total_cost = 0.0
    for i in range(n):
        feats = [1.0, math.log(1.0 + toks[i]) / 8.0, 0.0, 0.0]
        best_r, best_s = 0, None
        for r, wrow in enumerate(weights):
            s = sum(w * f for w, f in zip(wrow, feats))
            if best_s is None or s > best_s:
                best_r, best_s = r, s
        plan, _skip = routes[best_r]
        item_cost = 0.0
        last = len(plan) - 1
        for s_idx, (m, tau) in enumerate(plan):
            item_cost += call_cost(m, toks[i], table["preds"][m][i])
            if s_idx == last or table["scores"][m][i] > tau:
                if table["correct"][m][i]:
                    w_correct += 1.0
                break
        total_cost += item_cost
    return w_correct / float(n), total_cost / float(n)


def check_degenerate_router(cases=12):
    """PR-9 router gate (the python side of
    properties.rs::prop_degenerate_router_reproduces_global_plan_bitwise):
    the all-zero ("degenerate") router model must decide route 0 for
    every query, and its routed replay must equal the global plan's
    replay EXACTLY (same floats, not approximately) — for every frontier
    point taken as the global plan."""
    print(f"[4/7] degenerate router vs global frontier on {cases} tables ...")
    rng = Rng(0xA0F7E5)
    for case in range(cases):
        k = 3 + rng.below(3)
        n = 30 + rng.below(200)
        classes = 2 + rng.below(4)
        seed = rng.next_u64()
        grid = 4 + rng.below(5)
        table = synthetic_table(k, n, classes, 0.5 + 0.5 * rng.f64(), seed)
        toks = [40 + rng.below(100) for _ in range(n)]
        frontier = FlatOptimizer(table, toks, grid=grid).frontier()
        checked = 0
        for plan, acc, cost in frontier:
            routes = route_plans_py(plan, frontier, grid=4)
            assert routes[0] == (list(plan), 0), "route 0 must be the global plan"
            for j in range(1, len(plan)):
                assert routes[j] == (list(plan[j:]), j), f"route {j} must skip {j} stages"
            degenerate = [[0.0] * 4 for _ in routes]
            racc, rcost = routed_replay_py(degenerate, routes, table, toks)
            gacc, gcost = replay(plan, table, toks)
            assert racc == gacc and rcost == gcost, (
                f"case {case}: degenerate router diverged from its global plan "
                f"{plan}: ({racc}, {rcost}) vs ({gacc}, {gcost})"
            )
            checked += 1
        print(
            f"  case {case:2d}: k={k} n={n:3d} "
            f"{checked:2d} frontier plans ... degenerate == global OK"
        )
    print("  degenerate router PASSED")


def rank_cost(m):
    """Port of shadow::referee_pair's ranking price: ``pricing.cost(256, 2)``
    — a fixed 256-input / 2-output probe shape, independent of any query."""
    inp, out, req = TABLE1[m]
    return inp * 256 / 1e7 + out * 2 / 1e7 + req


def referee_pair_py(k, reference):
    """Port of server::shadow::referee_pair: the two priciest non-reference
    models by rank_cost, descending, ties broken toward the lower index."""
    ranked = sorted(
        (m for m in range(k) if m != reference),
        key=lambda m: (-rank_cost(m), m),
    )
    if len(ranked) < 2:
        return None
    return ranked[0], ranked[1]


def check_referee_vote(cases=12):
    """Speculation-PR referee-vote gate (the python side of the shadow.rs
    referee unit tests and shadow_loop.rs's vote-vs-single-reference loop):
    on random tables,
    (a) ``referee_pair_py`` matches an independent two-pass max selection
        (including the models-0/1 equal-price tie, broken low),
    (b) the vote label rule — ``label[i] = preds[a][i]`` when the referees
        agree, else ``preds[reference][i]`` — equals the single-reference
        label on EVERY escalated item (tie-breaks are reference calls, so
        the loops can only diverge where the referees agree), and
    (c) the metered reference spend is ``escalations × per_call`` — never
        more than the single-reference loop's ``n × per_call`` and
        strictly less whenever at least one agreement occurred."""
    print(f"[5/7] referee-vote shadow labels on {cases} random tables ...")
    rng = Rng(0x5AD0E5)
    for case in range(cases):
        k = 3 + rng.below(3)
        n = 30 + rng.below(200)
        classes = 2 + rng.below(4)
        table = synthetic_table(k, n, classes, 0.5 + 0.5 * rng.f64(), rng.next_u64())
        reference = rng.below(k)

        # (a) pair selection vs an independent brute-force max scan.
        pair = referee_pair_py(k, reference)
        assert pair is not None, f"case {case}: k={k} leaves >= 2 referees"
        a, b = pair
        pool = [m for m in range(k) if m != reference]
        first = max(pool, key=lambda m: (rank_cost(m), -m))
        rest = [m for m in pool if m != first]
        second = max(rest, key=lambda m: (rank_cost(m), -m))
        assert (a, b) == (first, second), (
            f"case {case}: referee_pair {pair} vs brute force {(first, second)}"
        )
        assert a != reference and b != reference and a != b
        # models 0 and 1 share a price in TABLE1: when both are candidates
        # and tied at the top, the lower index must come first.
        if reference > 1 and {a, b} == {0, 1}:
            assert (a, b) == (0, 1), f"case {case}: tie must break low, got {pair}"

        # (b) + (c) the label rule and its spend, item by item.
        preds = table["preds"]
        agreements = 0
        escalations = 0
        for i in range(n):
            pa, pb = preds[a][i], preds[b][i]
            single = preds[reference][i]
            if pa == pb:
                vote = pa
                agreements += 1
                # The loops may only diverge here, and only when the agreed
                # answer differs from what the reference would have said.
                if vote != single:
                    assert pa == pb, "divergence requires referee agreement"
            else:
                vote = single
                escalations += 1
                assert vote == single, (
                    f"case {case} item {i}: an escalated vote label must be "
                    f"the reference tie-break"
                )
        assert agreements + escalations == n
        per_call = rank_cost(reference)
        vote_spend = escalations * per_call
        single_spend = n * per_call
        assert vote_spend <= single_spend
        if agreements > 0 and per_call > 0.0:
            assert vote_spend < single_spend, (
                f"case {case}: {agreements} agreements must save reference spend"
            )
        print(
            f"  case {case:2d}: k={k} n={n:3d} ref={reference} pair=({a},{b}) "
            f"agree={agreements:3d} escalate={escalations:3d} "
            f"... vote == single on escalations, spend {vote_spend:.6f} <= "
            f"{single_spend:.6f} OK"
        )
    print("  referee vote PASSED")


def check_equivalence(cases=25):
    print(f"[1/7] equivalence on {cases} random tables ...")
    rng = Rng(0xF00D)
    for case in range(cases):
        k = 3 + rng.below(3)
        n = 20 + rng.below(280)
        classes = 2 + rng.below(4)
        cal = 0.5 + 0.5 * rng.f64()
        seed = rng.next_u64()
        grid = 4 + rng.below(5)
        table = synthetic_table(k, n, classes, cal, seed)
        toks = [40 + rng.below(100)] * n
        f_seed = SeedOptimizer(table, toks, grid=grid).frontier()
        f_flat = FlatOptimizer(table, toks, grid=grid).frontier()
        f_packed = PackedOptimizer(table, toks, grid=grid).frontier()
        # Metrics must agree point-for-point. Plan identity may differ on
        # exact (acc, cost) ties (e.g. a triple with tau_b = -1 is
        # metrically the same cascade as its pair prefix), so each side's
        # plans are instead validated against replay() ground truth below.
        ok, why = frontiers_match(f_seed, f_flat)
        assert ok, f"case {case} (k={k} n={n} grid={grid}): seed vs flat: {why}"
        # packed vs flat is the strict gate: plans AND exact floats.
        ok, why = frontiers_match(f_flat, f_packed, tol=0.0, plans_too=True)
        assert ok, f"case {case} (k={k} n={n} grid={grid}): flat vs packed: {why}"
        f_ref = reference_frontier(table, toks, grid=grid)
        ok, why = frontiers_match(f_flat, f_ref)
        assert ok, f"case {case} (k={k} n={n} grid={grid}): flat vs reference: {why}"
        # Every flat frontier point's reported metrics are real: replaying
        # its plan from scratch reproduces them.
        for plan, acc, cost in f_flat:
            racc, rcost = replay(plan, table, toks)
            assert abs(racc - acc) < 1e-12 and abs(rcost - cost) < 1e-12, (
                f"case {case}: plan {plan} reports ({acc}, {cost}) "
                f"but replays to ({racc}, {rcost})"
            )
        print(
            f"  case {case:2d}: k={k} n={n:3d} grid={grid} "
            f"frontier={len(f_flat):2d} pts ... seed==flat==packed==reference OK"
        )
    print("  equivalence PASSED")


def measure_wall(k=12, n=1200, grid=24, seed=99):
    print(f"[6/7] wall-clock at reduced workload (K={k}, N={n}, grid={grid}) ...")
    table = synthetic_table(k, n, 4, 0.9, seed)
    toks = [45] * n
    t0 = time.perf_counter()
    f_seed = SeedOptimizer(table, toks, grid=grid).frontier()
    t_seed = time.perf_counter() - t0
    t0 = time.perf_counter()
    f_flat = FlatOptimizer(table, toks, grid=grid).frontier()
    t_flat = time.perf_counter() - t0
    t0 = time.perf_counter()
    f_packed = PackedOptimizer(table, toks, grid=grid).frontier()
    t_packed = time.perf_counter() - t0
    ok, why = frontiers_match(f_seed, f_flat)
    assert ok, f"reduced workload: {why}"
    ok, why = frontiers_match(f_flat, f_packed, tol=0.0, plans_too=True)
    assert ok, f"reduced workload packed: {why}"
    print(
        f"  seed {t_seed:8.2f}s   flat {t_flat:8.2f}s   packed {t_packed:8.2f}s   "
        f"({len(f_flat)} frontier pts, identical; python constant factors "
        f"mask the rust arena-layout gains)"
    )
    return t_seed, t_flat, t_packed


def count_ops(k=12, n=8000, grid=24, seed=99):
    """Exact inner-loop item-visit counts for the algorithms at the
    benches/optimizer.rs workload, without running the seed sweep (the
    counts follow from the candidate structure + per-grid escalation
    sizes, which bisecting each model's sorted scores gives directly).
    The packed model replaces the byte path's correctness item visits
    with word ops (totals popcounts, bit-plane disagreement) and also
    reports the correctness working-set shrink — the sweeps' per-item
    visit counts are identical, the win there is 64x less memory traffic
    per correctness read."""
    print(f"[7/7] op-count model at bench workload (K={k}, N={n}, grid={grid}) ...")
    table = synthetic_table(k, n, 4, 0.9, seed)
    toks = [45] * n
    flat = FlatOptimizer(table, toks, grid=grid)
    lists = flat.candidate_lists()
    n_pairs = sum(1 for l in lists if len(l) == 2)
    n_triples = sum(1 for l in lists if len(l) == 3)

    # Seed candidate_lists cost: every disagreement / model_cost / accuracy
    # call is an O(N) scan. Replicate the exact call pattern.
    seed_candidates = 0
    eps = 0.02

    def d(a, b):
        return flat.disagree[a][b]

    pair_list = []
    for a in range(k):
        for b in range(k):
            if a == b:
                continue
            seed_candidates += n  # disagreement(a, b)
            if d(a, b) < eps:
                continue
            seed_candidates += 2 * n  # model_cost(a), model_cost(b)
            if flat.model_cost(a) > flat.model_cost(b):
                seed_candidates += 2 * n  # accuracy(a), accuracy(b)
                if flat.accuracy(a) < flat.accuracy(b):
                    continue
            pair_list.append((a, b))
    for a, b in pair_list:
        for c in range(k):
            if c == a or c == b:
                continue
            seed_candidates += n
            if d(b, c) < eps:
                continue
            seed_candidates += 2 * n
            if flat.model_cost(b) > flat.model_cost(c):
                seed_candidates += 2 * n
                if flat.accuracy(b) < flat.accuracy(c):
                    continue

    # Flat candidate_lists cost: the K(K-1)/2 disagreement matrix, once.
    flat_candidates = (k * (k - 1) // 2) * n

    # Shared (identical) work: workspace cost build + sorts + pair sweeps.
    shared = k * n + 2 * k * n + n_pairs * 2 * n  # costs, sort-ish, pairs

    # Triple sweeps. Escalation size per grid point from sorted scores.
    seed_triples = 0
    flat_triples = 0
    by_ab = {}
    for l in lists:
        if len(l) == 3:
            by_ab.setdefault(l[0], []).append(l)
    for a, tri in by_ab.items():
        asc = sorted(table["scores"][a])
        per_a_seed = 0
        per_a_flat = 2 * n  # rank + link init
        accepted_total = 0
        for tau_a in flat.quantiles[a]:
            # items with score > tau_a are accepted at stage a
            accepted = n - bisect.bisect_right(asc, tau_a)
            n_esc = n - accepted
            per_a_seed += n  # mask build happens before the early return
            if n_esc == 0:
                continue
            per_a_seed += 2 * n  # aggregate rescan + full order_b walk
            per_a_flat += n_esc  # linked-list walk
            accepted_total = accepted
        per_a_flat += accepted_total  # each accepted item unlinks once
        seed_triples += per_a_seed * len(tri)
        flat_triples += per_a_flat * len(tri)

    ops_seed = seed_candidates + shared + seed_triples
    ops_flat = flat_candidates + shared + flat_triples

    # Packed path: same sweep item visits, but the correctness aggregates
    # become word ops. words = ceil(n/64); planes = bits of max pred.
    words = (n + 63) // 64
    max_pred = max(p for m in range(k) for p in table["preds"][m])
    n_planes = max(max_pred.bit_length(), 1)
    pairs_kk = k * (k - 1) // 2
    # byte path: K(K-1)/2 item scans for disagreement + k*n wcorr build.
    byte_corr_ops = pairs_kk * n + k * n
    # packed: plane build (k*n item visits) + per-pair word XOR/OR+popcount
    # + per-model popcount totals.
    packed_corr_ops = k * n + pairs_kk * words * (n_planes + 1) + k * words
    ops_packed = ops_flat - byte_corr_ops + packed_corr_ops

    # Correctness working set of the search (bytes): the byte/f64 path
    # carries an f64 per (model, item) in the workspace arena; the packed
    # path carries one bit (u64 words) in both the table and workspace.
    byte_corr_bytes = k * n * 8
    packed_corr_bytes = k * words * 8

    print(f"  candidate lists: {len(lists)} ({n_pairs} pairs, {n_triples} triples)")
    print(f"  seed ops:   {ops_seed:,} (candidates {seed_candidates:,}, triples {seed_triples:,})")
    print(f"  flat ops:   {ops_flat:,} (candidates {flat_candidates:,}, triples {flat_triples:,})")
    print(
        f"  packed ops: {ops_packed:,} (corr aggregates {byte_corr_ops:,} item-ops "
        f"-> {packed_corr_ops:,} word-ops; sweeps unchanged)"
    )
    print(f"  single-thread algorithmic speedup (seed->flat): {ops_seed / ops_flat:.2f}x")
    print(f"  flat->packed op delta: {ops_flat / ops_packed:.3f}x fewer ops")
    print(
        f"  correctness working set: {byte_corr_bytes:,} B (f64 arena) -> "
        f"{packed_corr_bytes:,} B (bitset) = {byte_corr_bytes // packed_corr_bytes}x smaller"
    )
    return {
        "seed": ops_seed,
        "flat": ops_flat,
        "packed": ops_packed,
        "byte_corr_ops": byte_corr_ops,
        "packed_corr_ops": packed_corr_ops,
        "byte_corr_bytes": byte_corr_bytes,
        "packed_corr_bytes": packed_corr_bytes,
        "lists": len(lists),
        "pairs": n_pairs,
        "triples": n_triples,
    }


def ops_summary(ops):
    return {
        "seed": ops["seed"],
        "flat": ops["flat"],
        "packed": ops["packed"],
        "seed_to_flat_speedup": round(ops["seed"] / ops["flat"], 2),
        "flat_to_packed_op_ratio": round(ops["flat"] / ops["packed"], 3),
        "corr_working_set_bytes": {
            "byte_f64_arena": ops["byte_corr_bytes"],
            "packed_bitset": ops["packed_corr_bytes"],
        },
    }


if __name__ == "__main__":
    quick = "--quick" in sys.argv[1:]
    check_equivalence()
    check_packed()
    check_weighted()
    check_degenerate_router()
    check_referee_vote()
    if quick:
        # CI mode: every correctness gate above ran; skip only the slow
        # wall-clock measurement (minutes of pure python).
        ops = count_ops()
        print(
            json.dumps(
                {
                    "mode": "quick (wall-clock measurement skipped)",
                    "ops_full_workload": ops_summary(ops),
                    "lists": {"total": ops["lists"], "pairs": ops["pairs"],
                              "triples": ops["triples"]},
                },
                indent=2,
            )
        )
        sys.exit(0)
    t_seed, t_flat, t_packed = measure_wall()
    ops = count_ops()
    print(
        json.dumps(
            {
                "wall_reduced": {"seed_s": round(t_seed, 3), "flat_s": round(t_flat, 3),
                                 "packed_s": round(t_packed, 3),
                                 "seed_to_flat_speedup": round(t_seed / t_flat, 2)},
                "ops_full_workload": ops_summary(ops),
                "lists": {"total": ops["lists"], "pairs": ops["pairs"],
                          "triples": ops["triples"]},
            },
            indent=2,
        )
    )
