#!/usr/bin/env bash
# Run the front-door load harness against a live frugald daemon (sim
# marketplace) and write the suite JSON to $1.
#
#   scripts/bench_front_door.sh OUT.json --smoke   # the ci.sh gate
#   scripts/bench_front_door.sh OUT.json --bench   # the committed sweep
#
# Everything is loopback and hermetic: frugald binds an ephemeral port
# (written to a temp port file), loadgen drives it over real TCP, then
# drains it with /shutdown. The OUT path is taken verbatim — pass an
# absolute path (the Makefile does) so the committed trajectory at the
# repo root is the file that gets refreshed.
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:?usage: bench_front_door.sh OUT.json [--smoke|--bench ...]}"
shift
MODE_ARGS=("$@")
if [ ${#MODE_ARGS[@]} -eq 0 ]; then
  MODE_ARGS=(--bench)
fi

cargo build --release --bin frugald --bin loadgen
BIN=target/release

PORT_FILE="$(mktemp)"
DAEMON_LOG="$(mktemp)"
: > "$PORT_FILE"
DAEMON_PID=""
cleanup() {
  [ -n "$DAEMON_PID" ] && kill "$DAEMON_PID" 2>/dev/null || true
  rm -f "$PORT_FILE" "$DAEMON_LOG"
}
trap cleanup EXIT

# Daemon: sim marketplace, ephemeral port. `--sim` last so the Args
# parser keeps it a switch.
"$BIN/frugald" --listen 127.0.0.1:0 --port-file "$PORT_FILE" --sim \
  >"$DAEMON_LOG" 2>&1 &
DAEMON_PID=$!

# Wait for the bound address (up to 10s), failing fast if the daemon died.
ADDR=""
for _ in $(seq 1 100); do
  if ! kill -0 "$DAEMON_PID" 2>/dev/null; then
    echo "frugald exited before binding; log:" >&2
    cat "$DAEMON_LOG" >&2
    exit 1
  fi
  ADDR="$(head -n1 "$PORT_FILE" 2>/dev/null || true)"
  [ -n "$ADDR" ] && break
  sleep 0.1
done
if [ -z "$ADDR" ]; then
  echo "frugald never wrote its port file; log:" >&2
  cat "$DAEMON_LOG" >&2
  exit 1
fi
echo "frugald up at $ADDR"

# Harness: the selected sweep, then /metrics + /shutdown. Exit code is
# the gate (any protocol error fails the run).
if ! "$BIN/loadgen" --connect "$ADDR" --json "$OUT" "${MODE_ARGS[@]}" --shutdown; then
  echo "loadgen failed; daemon log:" >&2
  cat "$DAEMON_LOG" >&2
  exit 1
fi

wait "$DAEMON_PID" || {
  echo "frugald exited non-zero after drain; log:" >&2
  cat "$DAEMON_LOG" >&2
  exit 1
}
DAEMON_PID=""
tail -n 3 "$DAEMON_LOG"
echo "front-door bench complete: $OUT"
