//! Dataset + artifact loading and the fixed token layout.
//!
//! The Python build path (`python/compile/data.py`) writes datasets with a
//! *fixed positional layout* so this side can slice prompt / query segments
//! without a tokenizer:
//!
//! ```text
//! [ example block ] * k   [CLS] query-body [QSEP] [PAD...]
//! block = [SEP_EX] body(qlen) [LABEL_MARK] [label]
//! ```
//!
//! The constants below mirror `data.py`'s token map exactly; an integration
//! test cross-checks them against the manifest.

/// The fixed token map shared with `python/compile/data.py`.
pub mod layout {
    /// Padding token (never billable).
    pub const PAD: i32 = 0;
    /// Separator opening an in-context example block.
    pub const SEP_EX: i32 = 1;
    /// Marker before an example block's label token.
    pub const LABEL_MARK: i32 = 2;
    /// Negation marker token.
    pub const NEG: i32 = 3;
    /// Start-of-query marker.
    pub const CLS: i32 = 4;
    /// End-of-query separator.
    pub const QSEP: i32 = 5;
    /// Label tokens: `LABEL_BASE + class`.
    pub const LABEL_BASE: i32 = 6;
    /// Marker present in episodic (in-context-learning) queries.
    pub const EPI_MARK: i32 = 19;
    /// Vocabulary size every simulated model shares.
    pub const VOCAB: i32 = 512;
}

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

fn req<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    match v.get(key) {
        Value::Null => Err(anyhow!("missing key `{key}`")),
        other => Ok(other),
    }
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    req(v, key)?
        .as_usize()
        .with_context(|| format!("key `{key}` is not a number"))
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    Ok(req(v, key)?
        .as_str()
        .with_context(|| format!("key `{key}` is not a string"))?
        .to_string())
}

fn u32_vec(v: &Value, key: &str) -> Result<Vec<u32>> {
    Ok(req(v, key)?
        .as_arr()
        .with_context(|| format!("key `{key}` is not an array"))?
        .iter()
        .map(|x| x.as_u32().unwrap_or(0))
        .collect())
}

/// Geometry of a dataset's token layout (shared by both splits).
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetMeta {
    /// Dataset name.
    pub name: String,
    /// Full token-row length.
    pub seq: usize,
    /// Number of answer classes.
    pub n_classes: usize,
    /// In-context example blocks per prompt.
    pub n_examples: usize,
    /// Query body length (tokens).
    pub qlen: usize,
    /// Length of one example block (tokens).
    pub block_len: usize,
    /// Offset of the query segment in the row.
    pub q_offset: usize,
    /// Scorer-artifact input row length.
    pub scorer_seq: usize,
    /// Deterministic completion length per class (output-cost metering).
    pub answer_lens: Vec<u32>,
}

impl DatasetMeta {
    /// Length of the `[CLS] body [QSEP]` query segment.
    pub fn query_len(&self) -> usize {
        self.qlen + 2
    }
}

/// One loaded dataset split, token rows in a dense row-major buffer.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Shared geometry of every row.
    pub meta: DatasetMeta,
    /// Which split this is ("train" / "test").
    pub split: String,
    tokens: Vec<i32>, // n * seq
    /// Ground-truth class per item.
    pub labels: Vec<u32>,
    /// Difficulty tier per item (workload generators).
    pub tiers: Vec<u8>,
    /// Whether each item is episodic (needs in-context examples).
    pub episodic: Vec<u8>,
}

impl Dataset {
    /// Read + parse one split file (`artifacts/data/<ds>/<split>.json`).
    pub fn from_file(path: &Path) -> Result<Self> {
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        Self::from_json(&raw).with_context(|| format!("parsing dataset {}", path.display()))
    }

    /// Parse a split document.
    pub fn from_json(raw: &str) -> Result<Self> {
        let v = Value::parse(raw).map_err(|e| anyhow!("{e}"))?;
        let name = req_str(&v, "dataset")?;
        let seq = req_usize(&v, "seq")?;
        let rows = req(&v, "tokens")?.as_arr().context("tokens not an array")?;
        let n = rows.len();
        let mut tokens = Vec::with_capacity(n * seq);
        for row in rows {
            let row = row.as_arr().context("token row not an array")?;
            if row.len() != seq {
                bail!("dataset {name}: row len {} != seq {seq}", row.len());
            }
            for t in row {
                tokens.push(t.as_f64().context("token not a number")? as i32);
            }
        }
        let labels = u32_vec(&v, "labels")?;
        let tiers: Vec<u8> = u32_vec(&v, "tiers")?.iter().map(|&x| x as u8).collect();
        let episodic: Vec<u8> = u32_vec(&v, "episodic")?.iter().map(|&x| x as u8).collect();
        if labels.len() != n || tiers.len() != n || episodic.len() != n {
            bail!("dataset {name}: ragged arrays");
        }
        Ok(Dataset {
            meta: DatasetMeta {
                name,
                seq,
                n_classes: req_usize(&v, "n_classes")?,
                n_examples: req_usize(&v, "n_examples")?,
                qlen: req_usize(&v, "qlen")?,
                block_len: req_usize(&v, "block_len")?,
                q_offset: req_usize(&v, "q_offset")?,
                scorer_seq: req_usize(&v, "scorer_seq")?,
                answer_lens: u32_vec(&v, "answer_lens")?,
            },
            split: req_str(&v, "split")?,
            tokens,
            labels,
            tiers,
            episodic,
        })
    }

    /// Items in the split.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether the split holds no items.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Full token row for item `i`.
    pub fn tokens(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.meta.seq..(i + 1) * self.meta.seq]
    }
}

/// Prompt/query manipulation over the fixed layout. These mirror
/// `python/compile/data.py` (`truncate_examples`, `scorer_input`) and are
/// cross-validated in integration tests.
pub mod prompt {
    use super::{layout, DatasetMeta};

    /// Keep only the first `keep` in-context example blocks, PAD the rest.
    /// This is the *prompt selection* cost-reduction strategy (paper Fig 2a).
    pub fn truncate_examples(tokens: &[i32], meta: &DatasetMeta, keep: usize) -> Vec<i32> {
        let mut out = tokens.to_vec();
        let keep = keep.min(meta.n_examples);
        out[keep * meta.block_len..meta.q_offset]
            .iter_mut()
            .for_each(|t| *t = layout::PAD);
        out
    }

    /// Slice the `[CLS] body [QSEP]` query segment.
    pub fn query_segment<'a>(tokens: &'a [i32], meta: &DatasetMeta) -> &'a [i32] {
        &tokens[meta.q_offset..meta.q_offset + meta.query_len()]
    }

    /// Build the scorer input `[CLS] body [QSEP] [answer] PAD...`.
    pub fn scorer_input(tokens: &[i32], meta: &DatasetMeta, answer: u32) -> Vec<i32> {
        let mut out = vec![layout::PAD; meta.scorer_seq];
        let q = query_segment(tokens, meta);
        out[..q.len()].copy_from_slice(q);
        out[meta.qlen + 2] = layout::LABEL_BASE + answer as i32;
        out
    }

    /// Number of billable (non-PAD) input tokens.
    pub fn input_tokens(tokens: &[i32]) -> u32 {
        tokens.iter().filter(|&&t| t != layout::PAD).count() as u32
    }

    /// Whether the query is episodic (needs in-context examples to decode).
    pub fn is_episodic(tokens: &[i32], meta: &DatasetMeta) -> bool {
        query_segment(tokens, meta).contains(&layout::EPI_MARK)
    }
}

// ---------------------------------------------------------------------------
// Manifest (artifacts/manifest.json)
// ---------------------------------------------------------------------------

/// The parsed `artifacts/manifest.json`: everything the build path
/// exported, per dataset.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest format version.
    pub version: u32,
    /// Token-row length shared by all model artifacts.
    pub seq: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Batch sizes the artifacts were AOT-compiled for.
    pub batch_sizes: Vec<usize>,
    /// One entry per exported dataset.
    pub datasets: Vec<ManifestDataset>,
}

/// One dataset's manifest entry: geometry, splits, models, scorer.
#[derive(Debug, Clone)]
pub struct ManifestDataset {
    /// Dataset name.
    pub dataset: String,
    /// Task domain label (reports).
    pub domain: String,
    /// Total items across splits.
    pub size: usize,
    /// Number of answer classes.
    pub n_classes: usize,
    /// In-context example blocks per prompt.
    pub n_examples: usize,
    /// Token-row length.
    pub seq: usize,
    /// Query body length (tokens).
    pub qlen: usize,
    /// Example-block length (tokens).
    pub block_len: usize,
    /// Offset of the query segment.
    pub q_offset: usize,
    /// Scorer input row length.
    pub scorer_seq: usize,
    /// Completion length per answer class.
    pub answer_lens: Vec<u32>,
    /// Train-split size.
    pub n_train: usize,
    /// Test-split size.
    pub n_test: usize,
    /// The simulated marketplace models.
    pub models: Vec<ManifestModel>,
    /// The reliability scorer's entry.
    pub scorer: ManifestScorer,
}

/// One simulated API's manifest entry.
#[derive(Debug, Clone)]
pub struct ManifestModel {
    /// API name (Table 1).
    pub name: String,
    /// Provider name (Table 1).
    pub provider: String,
    /// Nominal parameter count (billions; 0 = undisclosed).
    pub size_b: f64,
    /// Table-1 pricing components.
    pub pricing: ManifestPricing,
    /// Simulated API latency parameters.
    pub latency_ms: ManifestLatency,
    /// Simulator transformer width.
    pub d_model: usize,
    /// Simulator transformer depth.
    pub n_layers: usize,
    /// Train-split accuracy measured at build time.
    pub train_acc: f64,
    /// Test-split accuracy measured at build time.
    pub test_acc: f64,
    /// batch-size (as string key) → HLO text path relative to artifacts/.
    pub artifacts: HashMap<String, String>,
}

/// Raw pricing components from the manifest (mirrors `marketplace::Pricing`).
#[derive(Debug, Clone, Copy)]
pub struct ManifestPricing {
    /// USD per 10M input tokens.
    pub usd_per_10m_input: f64,
    /// USD per 10M output tokens.
    pub usd_per_10m_output: f64,
    /// Fixed USD per request.
    pub usd_per_request: f64,
}

/// Raw latency parameters from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct ManifestLatency {
    /// Fixed round-trip floor (ms).
    pub base: f64,
    /// Additional ms per 1k tokens.
    pub per_1k_tokens: f64,
}

/// The reliability scorer's manifest entry.
#[derive(Debug, Clone)]
pub struct ManifestScorer {
    /// Scorer transformer width.
    pub d_model: usize,
    /// Scorer transformer depth.
    pub n_layers: usize,
    /// batch-size (string key) → HLO text path relative to artifacts/.
    pub artifacts: HashMap<String, String>,
    /// Mean score separation (correct vs wrong) at build time.
    pub score_sep: f64,
    /// Scorer classification accuracy at build time.
    pub score_acc: f64,
}

impl Manifest {
    /// Parse `manifest.json`.
    pub fn from_json(raw: &str) -> Result<Self> {
        let v = Value::parse(raw).map_err(|e| anyhow!("{e}"))?;
        let mut datasets = Vec::new();
        for d in req(&v, "datasets")?.as_arr().context("datasets not array")? {
            datasets.push(ManifestDataset::from_value(d)?);
        }
        Ok(Manifest {
            version: req_usize(&v, "version")? as u32,
            seq: req_usize(&v, "seq")?,
            vocab: req_usize(&v, "vocab")?,
            batch_sizes: u32_vec(&v, "batch_sizes")?
                .iter()
                .map(|&b| b as usize)
                .collect(),
            datasets,
        })
    }
}

fn artifact_map(v: &Value) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    for (k, val) in v.as_obj().context("artifacts not an object")? {
        out.insert(
            k.clone(),
            val.as_str().context("artifact path not a string")?.to_string(),
        );
    }
    Ok(out)
}

impl ManifestDataset {
    fn from_value(v: &Value) -> Result<Self> {
        let mut models = Vec::new();
        for m in req(v, "models")?.as_arr().context("models not array")? {
            let pr = req(m, "pricing")?;
            let lat = req(m, "latency_ms")?;
            models.push(ManifestModel {
                name: req_str(m, "name")?,
                provider: req_str(m, "provider")?,
                size_b: req(m, "size_b")?.as_f64().unwrap_or(0.0),
                pricing: ManifestPricing {
                    usd_per_10m_input: req(pr, "usd_per_10m_input")?
                        .as_f64()
                        .context("bad pricing")?,
                    usd_per_10m_output: req(pr, "usd_per_10m_output")?
                        .as_f64()
                        .context("bad pricing")?,
                    usd_per_request: req(pr, "usd_per_request")?
                        .as_f64()
                        .context("bad pricing")?,
                },
                latency_ms: ManifestLatency {
                    base: req(lat, "base")?.as_f64().context("bad latency")?,
                    per_1k_tokens: req(lat, "per_1k_tokens")?
                        .as_f64()
                        .context("bad latency")?,
                },
                d_model: req_usize(m, "d_model")?,
                n_layers: req_usize(m, "n_layers")?,
                train_acc: req(m, "train_acc")?.as_f64().unwrap_or(0.0),
                test_acc: req(m, "test_acc")?.as_f64().unwrap_or(0.0),
                artifacts: artifact_map(req(m, "artifacts")?)?,
            });
        }
        let sc = req(v, "scorer")?;
        Ok(ManifestDataset {
            dataset: req_str(v, "dataset")?,
            domain: req_str(v, "domain")?,
            size: req_usize(v, "size")?,
            n_classes: req_usize(v, "n_classes")?,
            n_examples: req_usize(v, "n_examples")?,
            seq: req_usize(v, "seq")?,
            qlen: req_usize(v, "qlen")?,
            block_len: req_usize(v, "block_len")?,
            q_offset: req_usize(v, "q_offset")?,
            scorer_seq: req_usize(v, "scorer_seq")?,
            answer_lens: u32_vec(v, "answer_lens")?,
            n_train: req_usize(v, "n_train")?,
            n_test: req_usize(v, "n_test")?,
            models,
            scorer: ManifestScorer {
                d_model: req_usize(sc, "d_model")?,
                n_layers: req_usize(sc, "n_layers")?,
                artifacts: artifact_map(req(sc, "artifacts")?)?,
                score_sep: req(sc, "score_sep")?.as_f64().unwrap_or(0.0),
                score_acc: req(sc, "score_acc")?.as_f64().unwrap_or(0.0),
            },
        })
    }
}

impl ManifestDataset {
    /// The geometry view shared with loaded splits.
    pub fn meta(&self) -> DatasetMeta {
        DatasetMeta {
            name: self.dataset.clone(),
            seq: self.seq,
            n_classes: self.n_classes,
            n_examples: self.n_examples,
            qlen: self.qlen,
            block_len: self.block_len,
            q_offset: self.q_offset,
            scorer_seq: self.scorer_seq,
            answer_lens: self.answer_lens.clone(),
        }
    }

    /// A model's entry by name.
    pub fn model(&self, name: &str) -> Option<&ManifestModel> {
        self.models.iter().find(|m| m.name == name)
    }
}

/// Root handle over the `artifacts/` directory.
#[derive(Debug, Clone)]
pub struct Artifacts {
    /// The artifacts directory.
    pub root: PathBuf,
    /// Its parsed manifest.
    pub manifest: Manifest,
}

impl Artifacts {
    /// Open an artifacts directory (reads + parses its manifest).
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let raw = std::fs::read_to_string(&mpath).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                mpath.display()
            )
        })?;
        let manifest = Manifest::from_json(&raw)?;
        Ok(Artifacts { root, manifest })
    }

    /// The manifest entry of one dataset.
    pub fn dataset_manifest(&self, name: &str) -> Result<&ManifestDataset> {
        self.manifest
            .datasets
            .iter()
            .find(|d| d.dataset == name)
            .with_context(|| format!("dataset {name} not in manifest"))
    }

    /// Load one token split of a dataset.
    pub fn dataset(&self, name: &str, split: &str) -> Result<Dataset> {
        Dataset::from_file(&self.root.join("data").join(name).join(format!("{split}.json")))
    }

    /// Load a dataset's offline response table.
    pub fn responses(&self, name: &str) -> Result<crate::coordinator::responses::ResponseTable> {
        crate::coordinator::responses::ResponseTable::from_file(
            &self.root.join("responses").join(format!("{name}.json")),
        )
    }

    /// Load everything a report/driver needs for one dataset in one call.
    pub fn context(&self, name: &str) -> Result<DatasetContext> {
        let table = self.responses(name)?;
        let costs = crate::marketplace::CostModel::from_manifest(&self.manifest, name)?;
        let train = self.dataset(name, "train")?;
        let test = self.dataset(name, "test")?;
        let train_tokens =
            (0..train.len()).map(|i| prompt::input_tokens(train.tokens(i))).collect();
        let test_tokens =
            (0..test.len()).map(|i| prompt::input_tokens(test.tokens(i))).collect();
        let meta = train.meta.clone();
        Ok(DatasetContext { table, costs, train, test, train_tokens, test_tokens, meta })
    }

    /// Path of one AOT artifact (`model` may be `"scorer"`).
    pub fn model_path(&self, ds: &str, model: &str, batch: usize) -> Result<PathBuf> {
        let dm = self.dataset_manifest(ds)?;
        let m = if model == "scorer" {
            &dm.scorer.artifacts
        } else {
            &dm.model(model)
                .with_context(|| format!("model {model} not in manifest for {ds}"))?
                .artifacts
        };
        let rel = m
            .get(&batch.to_string())
            .with_context(|| format!("no batch-{batch} artifact for {ds}/{model}"))?;
        Ok(self.root.join(rel))
    }
}

/// Everything needed to optimize/evaluate on one dataset, loaded once.
pub struct DatasetContext {
    /// The offline response tables (train + test).
    pub table: crate::coordinator::responses::ResponseTable,
    /// The marketplace cost model.
    pub costs: crate::marketplace::CostModel,
    /// The train token split.
    pub train: Dataset,
    /// The test token split.
    pub test: Dataset,
    /// Billable input tokens per train item.
    pub train_tokens: Vec<u32>,
    /// Billable input tokens per test item.
    pub test_tokens: Vec<u32>,
    /// The dataset geometry.
    pub meta: DatasetMeta,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> DatasetMeta {
        DatasetMeta {
            name: "t".into(),
            seq: 32,
            n_classes: 4,
            n_examples: 2,
            qlen: 5,
            block_len: 8,
            q_offset: 16,
            scorer_seq: 32,
            answer_lens: vec![1, 2, 1, 2],
        }
    }

    fn row(meta: &DatasetMeta) -> Vec<i32> {
        let mut t = vec![layout::PAD; meta.seq];
        // two example blocks
        for j in 0..meta.n_examples {
            let b = j * meta.block_len;
            t[b] = layout::SEP_EX;
            for p in 1..=meta.qlen {
                t[b + p] = 300 + p as i32;
            }
            t[b + 1 + meta.qlen] = layout::LABEL_MARK;
            t[b + 2 + meta.qlen] = layout::LABEL_BASE + 1;
        }
        let qo = meta.q_offset;
        t[qo] = layout::CLS;
        for p in 0..meta.qlen {
            t[qo + 1 + p] = 400 + p as i32;
        }
        t[qo + 1 + meta.qlen] = layout::QSEP;
        t
    }

    #[test]
    fn truncate_zeroes_dropped_blocks_only() {
        let m = meta();
        let t = row(&m);
        let out = prompt::truncate_examples(&t, &m, 1);
        assert_eq!(&out[..m.block_len], &t[..m.block_len]);
        assert!(out[m.block_len..m.q_offset].iter().all(|&x| x == layout::PAD));
        assert_eq!(&out[m.q_offset..], &t[m.q_offset..]);
        // keep >= n_examples is a no-op
        assert_eq!(prompt::truncate_examples(&t, &m, 5), t);
    }

    #[test]
    fn scorer_input_layout() {
        let m = meta();
        let t = row(&m);
        let s = prompt::scorer_input(&t, &m, 3);
        assert_eq!(s.len(), m.scorer_seq);
        assert_eq!(s[0], layout::CLS);
        assert_eq!(s[m.qlen + 1], layout::QSEP);
        assert_eq!(s[m.qlen + 2], layout::LABEL_BASE + 3);
        assert!(s[m.qlen + 3..].iter().all(|&x| x == layout::PAD));
    }

    #[test]
    fn input_tokens_counts_non_pad() {
        let m = meta();
        let t = row(&m);
        let full = prompt::input_tokens(&t);
        assert_eq!(full as usize, m.n_examples * m.block_len + m.query_len());
        let trunc = prompt::truncate_examples(&t, &m, 0);
        assert_eq!(prompt::input_tokens(&trunc) as usize, m.query_len());
    }
}
