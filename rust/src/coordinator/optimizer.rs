//! The cascade optimizer: joint search over API lists `L ∈ [K]^m` and
//! threshold vectors `τ` under a budget constraint (paper §3).
//!
//! The paper formulates this as a mixed-integer program and solves it with
//! a specialized optimizer that (i) *prunes* the list search space by
//! ignoring lists whose members show small answer disagreement, and
//! (ii) *approximates* the objective by interpolating it within a few
//! samples. This module implements both ideas:
//!
//! * **Pruning** — a list survives only if every later stage disagrees
//!   with the stage before it on ≥ `min_disagreement` of training queries
//!   (no headroom → the longer list cannot beat its prefix), and only if
//!   its non-final stages are not strictly dominated.
//! * **Sampled objective** — the coarse sweep can run on a training
//!   subsample (`coarse_subsample`); surviving candidates are re-scored on
//!   the full table (the "interpolation within a few samples" analog).
//! * **Threshold search** — thresholds are swept over *score quantiles*
//!   with prefix-sum accumulators, so a full 1-D threshold sweep is O(N)
//!   after one sort per model (done once, reused across all lists).
//!
//! The search yields the complete accuracy–cost *frontier* (paper Fig. 5)
//! as a byproduct; `optimize(budget)` just picks the best frontier point
//! within budget.

use anyhow::{bail, Result};

use super::cascade::{replay, CascadePlan, Stage};
use super::responses::SplitTable;
use crate::marketplace::CostModel;

/// Tuning knobs for the search. Defaults reproduce the paper's setup
/// (cascade length 3).
#[derive(Debug, Clone)]
pub struct OptimizerOptions {
    /// Maximum cascade length m (paper uses 3).
    pub max_len: usize,
    /// Quantile grid size for the *first* stage threshold of a triple.
    /// Second-stage thresholds always get a full O(N) sweep.
    pub grid: usize,
    /// Prune lists whose adjacent stages disagree on fewer than this
    /// fraction of training queries.
    pub min_disagreement: f64,
    /// If set, run the coarse sweep on only this many training items and
    /// re-score the surviving candidates on the full table.
    pub coarse_subsample: Option<usize>,
    /// Number of top candidates re-scored on the full table when
    /// `coarse_subsample` is active.
    pub rescore_top: usize,
}

impl Default for OptimizerOptions {
    fn default() -> Self {
        OptimizerOptions {
            max_len: 3,
            grid: 24,
            min_disagreement: 0.02,
            coarse_subsample: None,
            rescore_top: 64,
        }
    }
}

/// One point of the accuracy–cost frontier.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    pub plan: CascadePlan,
    /// Training accuracy of the plan.
    pub accuracy: f64,
    /// Average training cost per query (USD).
    pub avg_cost: f64,
}

/// The outcome of `optimize`: the chosen plan plus its train metrics.
#[derive(Debug, Clone)]
pub struct OptimizedPlan {
    pub plan: CascadePlan,
    pub train_accuracy: f64,
    pub train_avg_cost: f64,
    /// USD per 10k queries (the budget unit).
    pub train_cost_per_10k: f64,
}

/// Precomputed per-item call costs and per-model score orderings.
struct Workspace {
    /// `cost[m][i]` — USD of calling model m on item i.
    cost: Vec<Vec<f64>>,
    /// `order[m]` — item indices sorted by model-m score, descending.
    order: Vec<Vec<u32>>,
    /// `quantiles[m]` — score thresholds at the option grid.
    quantiles: Vec<Vec<f32>>,
}

impl Workspace {
    fn build(table: &SplitTable, costs: &CostModel, input_tokens: &[u32], grid: usize) -> Self {
        let n = table.len();
        let k = table.n_models();
        let mut cost = Vec::with_capacity(k);
        let mut order = Vec::with_capacity(k);
        let mut quantiles = Vec::with_capacity(k);
        for m in 0..k {
            let mut c = Vec::with_capacity(n);
            for i in 0..n {
                c.push(costs.call_cost(m, input_tokens[i], table.preds[m][i]));
            }
            cost.push(c);
            let mut idx: Vec<u32> = (0..n as u32).collect();
            idx.sort_by(|&a, &b| {
                table.scores[m][b as usize]
                    .partial_cmp(&table.scores[m][a as usize])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            let mut qs = Vec::with_capacity(grid);
            for g in 0..grid {
                let pos = ((g + 1) * n) / (grid + 1);
                let pos = pos.min(n.saturating_sub(1));
                qs.push(table.scores[m][idx[pos] as usize]);
            }
            qs.dedup();
            order.push(idx);
            quantiles.push(qs);
        }
        Workspace { cost, order, quantiles }
    }
}

/// The cascade optimizer. Borrows a training table + cost model and owns
/// the precomputed workspace.
pub struct CascadeOptimizer<'a> {
    table: &'a SplitTable,
    costs: &'a CostModel,
    input_tokens: Vec<u32>,
    pub options: OptimizerOptions,
    ws: Workspace,
    /// Memoized frontier — §Perf: `optimize()` used to recompute the full
    /// sweep (~seconds at K=12, N=8000) on every budget query; the sweep
    /// is a pure function of (table, costs, options), so cache it.
    frontier_cache: std::sync::OnceLock<Vec<FrontierPoint>>,
}

impl<'a> CascadeOptimizer<'a> {
    /// `input_tokens[i]`: billable prompt tokens of train item i. Use
    /// [`uniform_tokens`] when all prompts have the same size.
    pub fn new(
        table: &'a SplitTable,
        costs: &'a CostModel,
        input_tokens: Vec<u32>,
        options: OptimizerOptions,
    ) -> Result<Self> {
        if table.is_empty() {
            bail!("empty training table");
        }
        if input_tokens.len() != table.len() {
            bail!("input_tokens length mismatch");
        }
        if table.n_models() != costs.n_models() {
            bail!(
                "table has {} models but cost model has {}",
                table.n_models(),
                costs.n_models()
            );
        }
        let ws = Workspace::build(table, costs, &input_tokens, options.grid);
        Ok(CascadeOptimizer {
            table,
            costs,
            input_tokens,
            options,
            ws,
            frontier_cache: std::sync::OnceLock::new(),
        })
    }

    /// Disagreement P[pred_a != pred_b] between two models.
    pub fn disagreement(&self, a: usize, b: usize) -> f64 {
        let n = self.table.len();
        let mut d = 0usize;
        for i in 0..n {
            d += (self.table.preds[a][i] != self.table.preds[b][i]) as usize;
        }
        d as f64 / n.max(1) as f64
    }

    /// Mean cost of always calling model m (USD per query).
    fn model_cost(&self, m: usize) -> f64 {
        let n = self.table.len();
        self.ws.cost[m].iter().sum::<f64>() / n.max(1) as f64
    }

    /// Enumerate candidate lists of length 1..=max_len with pruning.
    fn candidate_lists(&self) -> Vec<Vec<usize>> {
        let k = self.table.n_models();
        let eps = self.options.min_disagreement;
        let mut lists: Vec<Vec<usize>> = (0..k).map(|m| vec![m]).collect();
        if self.options.max_len >= 2 {
            for a in 0..k {
                for b in 0..k {
                    if a == b || self.disagreement(a, b) < eps {
                        continue;
                    }
                    // A cheaper model behind a more expensive one can still
                    // pay off only if the front stage is cheaper; prune
                    // front stages that are both pricier and weaker.
                    if self.model_cost(a) > self.model_cost(b)
                        && self.table.accuracy(a) < self.table.accuracy(b)
                    {
                        continue;
                    }
                    lists.push(vec![a, b]);
                }
            }
        }
        if self.options.max_len >= 3 {
            let pairs: Vec<(usize, usize)> = lists
                .iter()
                .filter(|l| l.len() == 2)
                .map(|l| (l[0], l[1]))
                .collect();
            for &(a, b) in &pairs {
                for c in 0..k {
                    if c == a || c == b || self.disagreement(b, c) < eps {
                        continue;
                    }
                    if self.model_cost(b) > self.model_cost(c)
                        && self.table.accuracy(b) < self.table.accuracy(c)
                    {
                        continue;
                    }
                    lists.push(vec![a, b, c]);
                }
            }
        }
        lists
    }

    /// Sweep all thresholds of `list` and push non-dominated (cost, acc)
    /// points to `out`. Exact for length ≤ 2 (full O(N) sweep); for
    /// triples the first threshold runs on the quantile grid and the
    /// second gets a full sweep conditioned on it.
    fn sweep_list(&self, list: &[usize], out: &mut Vec<FrontierPoint>) {
        let n = self.table.len();
        match list.len() {
            1 => {
                let m = list[0];
                out.push(FrontierPoint {
                    plan: CascadePlan::single(m),
                    accuracy: self.table.accuracy(m),
                    avg_cost: self.model_cost(m),
                });
            }
            2 => {
                let (a, b) = (list[0], list[1]);
                self.sweep_pair(a, b, None, n, out);
            }
            3 => {
                let (a, b, c) = (list[0], list[1], list[2]);
                // Grid over τ_a; for each, a full conditional sweep of τ_b.
                for &tau_a in &self.ws.quantiles[a] {
                    self.sweep_triple_fixed_first(a, tau_a, b, c, out);
                }
            }
            _ => unreachable!("lists are length 1..=3"),
        }
    }

    /// Exact sweep of a 2-stage cascade `[a(τ) → b]`, optionally restricted
    /// to items where `mask[i]` (used by the triple sweep).
    fn sweep_pair(
        &self,
        a: usize,
        b: usize,
        mask: Option<&[bool]>,
        _n: usize,
        out: &mut Vec<FrontierPoint>,
    ) {
        // Walk items in descending score_a order. Cutting after the j-th
        // item means: top-j accepted at stage a, the rest escalate to b.
        let order = &self.ws.order[a];
        let scores = &self.table.scores[a];

        let mut total_cost_a = 0.0;
        let mut total_cost_b = 0.0;
        let mut total_corr_b = 0usize;
        let mut n_eff = 0usize;
        for &iu in order.iter() {
            let i = iu as usize;
            if mask.map_or(false, |m| !m[i]) {
                continue;
            }
            n_eff += 1;
            total_cost_a += self.ws.cost[a][i];
            total_cost_b += self.ws.cost[b][i];
            total_corr_b += self.table.correct[b][i] as usize;
        }
        if n_eff == 0 {
            return;
        }

        let mut acc_corr_a = 0usize; // correct among accepted (top-j)
        let mut acc_corr_b = total_corr_b;
        let mut esc_cost_b = total_cost_b;
        let mut best_for_cut: Vec<FrontierPoint> = Vec::new();
        let mut j = 0usize;
        let mut prev_score = f32::INFINITY;
        let inv_n = 1.0 / n_eff as f64;
        for &iu in order.iter() {
            let i = iu as usize;
            if mask.map_or(false, |m| !m[i]) {
                continue;
            }
            let s = scores[i];
            // A valid threshold separates distinct score values; emit the
            // point for the cut *before* item i when the score drops.
            if s < prev_score {
                let tau = prev_midpoint(prev_score, s);
                let acc = (acc_corr_a + acc_corr_b) as f64 * inv_n;
                let cost = (total_cost_a + esc_cost_b) * inv_n;
                best_for_cut.push(FrontierPoint {
                    plan: CascadePlan::new(vec![
                        Stage { model: a, threshold: tau },
                        Stage { model: b, threshold: 0.0 },
                    ]),
                    accuracy: acc,
                    avg_cost: cost,
                });
            }
            // accept item i at stage a:
            acc_corr_a += self.table.correct[a][i] as usize;
            acc_corr_b -= self.table.correct[b][i] as usize;
            esc_cost_b -= self.ws.cost[b][i];
            prev_score = s;
            j += 1;
        }
        let _ = j;
        // Cut after everything = stage a alone never escalates; τ below min.
        best_for_cut.push(FrontierPoint {
            plan: CascadePlan::new(vec![
                Stage { model: a, threshold: -1.0 },
                Stage { model: b, threshold: 0.0 },
            ]),
            accuracy: acc_corr_a as f64 * inv_n,
            avg_cost: total_cost_a * inv_n,
        });
        out.extend(prune_pareto(best_for_cut));
    }

    /// Triple sweep with the first threshold fixed: items with
    /// `score_a > tau_a` stop at `a`; the rest replay `[b(τ_b) → c]`.
    fn sweep_triple_fixed_first(
        &self,
        a: usize,
        tau_a: f32,
        b: usize,
        c: usize,
        out: &mut Vec<FrontierPoint>,
    ) {
        let n = self.table.len();
        // §Perf: hoist all row slices out of the hot loops — indexing
        // `Vec<Vec<_>>[m][i]` repeatedly defeats bounds-check elimination
        // and costs ~2x on this, the optimizer's dominant inner loop.
        let scores_a = &self.table.scores[a][..n];
        let scores_b = &self.table.scores[b][..n];
        let corr_a = &self.table.correct[a][..n];
        let corr_b = &self.table.correct[b][..n];
        let corr_c = &self.table.correct[c][..n];
        let cost_a = &self.ws.cost[a][..n];
        let cost_b = &self.ws.cost[b][..n];
        let cost_c = &self.ws.cost[c][..n];

        let mut mask = vec![false; n]; // true = escalated past stage a
        let mut acc_corr_a = 0usize;
        let mut base_cost = 0.0; // everyone pays stage a
        let mut n_esc = 0usize;
        for i in 0..n {
            base_cost += cost_a[i];
            if scores_a[i] > tau_a {
                acc_corr_a += corr_a[i] as usize;
            } else {
                mask[i] = true;
                n_esc += 1;
            }
        }
        if n_esc == 0 {
            return; // degenerates to the single [a]; covered elsewhere.
        }

        // Conditional sweep of τ_b over escalated items, in score_b order.
        let order_b = &self.ws.order[b];
        let mut esc_cost_b_total = 0.0;
        let mut esc_corr_c = 0usize;
        let mut esc_cost_c = 0.0;
        for i in 0..n {
            if mask[i] {
                esc_cost_b_total += cost_b[i];
                esc_corr_c += corr_c[i] as usize;
                esc_cost_c += cost_c[i];
            }
        }
        let inv_n = 1.0 / n as f64;
        let mut corr_b_acc = 0usize;
        let mut rem_corr_c = esc_corr_c;
        let mut rem_cost_c = esc_cost_c;
        let mut prev_score = f32::INFINITY;
        let mut pts = Vec::new();
        for &iu in order_b.iter() {
            let i = iu as usize;
            if !mask[i] {
                continue;
            }
            let s = scores_b[i];
            if s < prev_score {
                let tau_b = prev_midpoint(prev_score, s);
                let acc = (acc_corr_a + corr_b_acc + rem_corr_c) as f64 * inv_n;
                let cost = (base_cost + esc_cost_b_total + rem_cost_c) * inv_n;
                pts.push(FrontierPoint {
                    plan: CascadePlan::new(vec![
                        Stage { model: a, threshold: tau_a },
                        Stage { model: b, threshold: tau_b },
                        Stage { model: c, threshold: 0.0 },
                    ]),
                    accuracy: acc,
                    avg_cost: cost,
                });
            }
            corr_b_acc += corr_b[i] as usize;
            rem_corr_c -= corr_c[i] as usize;
            rem_cost_c -= cost_c[i];
            prev_score = s;
        }
        // τ_b below min: b answers every escalated item.
        pts.push(FrontierPoint {
            plan: CascadePlan::new(vec![
                Stage { model: a, threshold: tau_a },
                Stage { model: b, threshold: -1.0 },
                Stage { model: c, threshold: 0.0 },
            ]),
            accuracy: (acc_corr_a + corr_b_acc) as f64 * inv_n,
            avg_cost: (base_cost + esc_cost_b_total) * inv_n,
        });
        out.extend(prune_pareto(pts));
    }

    /// Compute the global accuracy–cost frontier over all candidate plans.
    ///
    /// With `options.coarse_subsample = Some(n)` the sweep runs on the
    /// first `n` training items only (the paper's "approximate the
    /// objective by interpolating it within a few samples"), and the
    /// surviving `rescore_top` candidates are re-evaluated exactly on the
    /// full table before the final Pareto prune.
    pub fn frontier(&self) -> Vec<FrontierPoint> {
        self.frontier_cache.get_or_init(|| self.compute_frontier()).clone()
    }

    fn compute_frontier(&self) -> Vec<FrontierPoint> {
        match self.options.coarse_subsample {
            Some(n) if n < self.table.len() => {
                let sub = self.table.head(n);
                let sub_tokens = self.input_tokens[..n].to_vec();
                let sub_opt = CascadeOptimizer::new(
                    &sub,
                    self.costs,
                    sub_tokens,
                    OptimizerOptions {
                        coarse_subsample: None,
                        ..self.options.clone()
                    },
                )
                .expect("subsample optimizer");
                let mut coarse = Vec::new();
                for list in sub_opt.candidate_lists() {
                    sub_opt.sweep_list(&list, &mut coarse);
                }
                let coarse = prune_pareto(coarse);
                // Re-score the best candidates exactly on the full table.
                let take = self.options.rescore_top.max(1);
                let start = coarse.len().saturating_sub(take);
                let rescored = coarse[start..]
                    .iter()
                    .map(|p| {
                        let r = replay::replay(
                            &p.plan,
                            self.table,
                            self.costs,
                            &self.input_tokens,
                        );
                        FrontierPoint {
                            plan: p.plan.clone(),
                            accuracy: r.accuracy,
                            avg_cost: r.avg_cost,
                        }
                    })
                    .collect();
                prune_pareto(rescored)
            }
            _ => {
                let mut pts = Vec::new();
                for list in self.candidate_lists() {
                    self.sweep_list(&list, &mut pts);
                }
                prune_pareto(pts)
            }
        }
    }

    /// Best plan whose average train cost ≤ `budget_usd_per_10k / 10_000`.
    pub fn optimize(&self, budget_usd_per_10k: f64) -> Result<OptimizedPlan> {
        let per_query = budget_usd_per_10k / 10_000.0;
        let frontier = self.frontier();
        let best = frontier
            .iter()
            .filter(|p| p.avg_cost <= per_query + 1e-15)
            .max_by(|x, y| {
                x.accuracy
                    .partial_cmp(&y.accuracy)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(y.avg_cost.partial_cmp(&x.avg_cost).unwrap_or(std::cmp::Ordering::Equal))
            });
        match best {
            Some(p) => Ok(OptimizedPlan {
                plan: p.plan.clone(),
                train_accuracy: p.accuracy,
                train_avg_cost: p.avg_cost,
                train_cost_per_10k: p.avg_cost * 10_000.0,
            }),
            None => bail!(
                "no cascade fits budget ${budget_usd_per_10k:.4} per 10k queries \
                 (cheapest frontier point: ${:.4})",
                frontier
                    .first()
                    .map(|p| p.avg_cost * 10_000.0)
                    .unwrap_or(f64::NAN)
            ),
        }
    }

    /// Replay a plan on an arbitrary split with this optimizer's cost model
    /// (convenience for reports: train-optimize → test-evaluate).
    pub fn replay_on(
        &self,
        plan: &CascadePlan,
        table: &SplitTable,
        input_tokens: &[u32],
    ) -> replay::ReplaySummary {
        replay::replay(plan, table, self.costs, input_tokens)
    }
}

/// `input_tokens` helper when every item has the same billable size.
pub fn uniform_tokens(n: usize, tokens: u32) -> Vec<u32> {
    vec![tokens; n]
}

/// Midpoint threshold strictly between two adjacent scores.
fn prev_midpoint(hi: f32, lo: f32) -> f32 {
    if hi.is_infinite() {
        // Above the max score: stage never accepts.
        lo + 1.0
    } else {
        (hi + lo) * 0.5
    }
}

/// Keep only Pareto-optimal points (no other point has ≤ cost and ≥ acc),
/// sorted by ascending cost.
pub fn prune_pareto(mut pts: Vec<FrontierPoint>) -> Vec<FrontierPoint> {
    pts.sort_by(|a, b| {
        a.avg_cost
            .partial_cmp(&b.avg_cost)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.accuracy.partial_cmp(&a.accuracy).unwrap_or(std::cmp::Ordering::Equal))
    });
    let mut out: Vec<FrontierPoint> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for p in pts {
        if p.accuracy > best_acc + 1e-12 {
            best_acc = p.accuracy;
            out.push(p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::responses::synthetic_table;

    fn setup() -> (SplitTable, CostModel) {
        // 8 models / 600 items keeps the exhaustive sweep fast in debug
        // builds; the full 12-model search is exercised by the release-mode
        // integration tests and benches.
        let t = synthetic_table(8, 600, 4, 0.9, 7);
        let full = CostModel::from_table1("synthetic", vec![1, 1, 2, 1]);
        let cm = CostModel {
            dataset: full.dataset.clone(),
            model_names: t.model_names.clone(),
            pricing: full.pricing[..8].to_vec(),
            latency: full.latency[..8].to_vec(),
            answer_lens: full.answer_lens.clone(),
        };
        (t, cm)
    }

    fn optimizer<'a>(t: &'a SplitTable, cm: &'a CostModel) -> CascadeOptimizer<'a> {
        let toks = uniform_tokens(t.len(), 125);
        CascadeOptimizer::new(t, cm, toks, OptimizerOptions::default()).unwrap()
    }

    #[test]
    fn frontier_is_sorted_and_pareto() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        assert!(f.len() > 3, "frontier should have multiple points");
        for w in f.windows(2) {
            assert!(w[0].avg_cost <= w[1].avg_cost);
            assert!(w[0].accuracy < w[1].accuracy);
        }
    }

    #[test]
    fn optimize_respects_budget() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let mid_budget = f[f.len() / 2].avg_cost * 10_000.0;
        let plan = opt.optimize(mid_budget).unwrap();
        assert!(plan.train_cost_per_10k <= mid_budget + 1e-9);
        // Verify by replay: the plan's reported train metrics are real.
        let toks = uniform_tokens(t.len(), 125);
        let r = replay::replay(&plan.plan, &t, &cm, &toks);
        assert!((r.accuracy - plan.train_accuracy).abs() < 1e-9);
        assert!((r.avg_cost - plan.train_avg_cost).abs() < 1e-9);
    }

    #[test]
    fn bigger_budget_never_hurts() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let mut prev = 0.0;
        for mult in [0.25, 0.5, 1.0, 2.0] {
            let b = f.last().unwrap().avg_cost * 10_000.0 * mult;
            if let Ok(p) = opt.optimize(b) {
                assert!(p.train_accuracy >= prev - 1e-12);
                prev = p.train_accuracy;
            }
        }
    }

    #[test]
    fn cascade_beats_best_individual_with_calibrated_scorer() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let best_single = (0..t.n_models())
            .map(|m| t.accuracy(m))
            .fold(f64::MIN, f64::max);
        let best = f.last().unwrap();
        // With a well-calibrated synthetic scorer the cascade should match
        // or beat the best individual API on the train split.
        assert!(
            best.accuracy >= best_single - 1e-9,
            "frontier top {} vs best single {}",
            best.accuracy,
            best_single
        );
    }

    #[test]
    fn cheap_budget_selects_cheap_models() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let cheapest = &f[0];
        let plan = opt.optimize(cheapest.avg_cost * 10_000.0 * 1.01).unwrap();
        // the selected plan must cost no more than the cheapest+1%.
        assert!(plan.train_avg_cost <= cheapest.avg_cost * 1.011);
    }

    #[test]
    fn impossible_budget_errors() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        assert!(opt.optimize(0.0).is_err());
    }

    #[test]
    fn disagreement_pruning_symmetric_sanity() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let d = opt.disagreement(0, 7);
        assert!(d > 0.05, "weak vs strong models should disagree, d={d}");
        assert_eq!(opt.disagreement(3, 3), 0.0);
    }

    #[test]
    fn coarse_subsample_approximates_full_search() {
        let (t, cm) = setup();
        let toks = uniform_tokens(t.len(), 125);
        let full = CascadeOptimizer::new(&t, &cm, toks.clone(), OptimizerOptions::default())
            .unwrap()
            .frontier();
        let coarse = CascadeOptimizer::new(
            &t,
            &cm,
            toks,
            OptimizerOptions {
                coarse_subsample: Some(200),
                rescore_top: 48,
                ..Default::default()
            },
        )
        .unwrap()
        .frontier();
        assert!(!coarse.is_empty());
        // The coarse frontier's best accuracy should be close to exact.
        let fa = full.last().unwrap().accuracy;
        let ca = coarse.last().unwrap().accuracy;
        assert!(ca > fa - 0.05, "coarse {ca} vs full {fa}");
        // And every coarse point's metrics are exact (re-scored) values.
        for p in &coarse {
            assert!((0.0..=1.0).contains(&p.accuracy));
        }
    }

    #[test]
    fn pareto_prune_removes_dominated() {
        let mk = |c: f64, a: f64| FrontierPoint {
            plan: CascadePlan::single(0),
            accuracy: a,
            avg_cost: c,
        };
        let pts = vec![mk(1.0, 0.5), mk(2.0, 0.4), mk(3.0, 0.9), mk(0.5, 0.45)];
        let f = prune_pareto(pts);
        assert_eq!(f.len(), 3);
        assert_eq!(f[0].avg_cost, 0.5);
        assert_eq!(f[1].avg_cost, 1.0);
        assert_eq!(f[2].avg_cost, 3.0);
    }
}
