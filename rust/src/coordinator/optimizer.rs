//! The cascade optimizer: joint search over API lists `L ∈ [K]^m` and
//! threshold vectors `τ` under a budget constraint (paper §3).
//!
//! The paper formulates this as a mixed-integer program and solves it with
//! a specialized optimizer that (i) *prunes* the list search space by
//! ignoring lists whose members show small answer disagreement, and
//! (ii) *approximates* the objective by interpolating it within a few
//! samples. This module implements both ideas:
//!
//! * **Pruning** — a list survives only if every later stage disagrees
//!   with the stage before it on ≥ `min_disagreement` of training queries
//!   (no headroom → the longer list cannot beat its prefix), and only if
//!   its non-final stages are not strictly dominated.
//! * **Sampled objective** — the coarse sweep can run on a training
//!   subsample (`coarse_subsample`); surviving candidates are re-scored on
//!   the full table (the "interpolation within a few samples" analog).
//! * **Threshold search** — thresholds are swept over *score quantiles*
//!   with prefix-sum accumulators, so a full 1-D threshold sweep is O(N)
//!   after one sort per model (done once, reused across all lists).
//!
//! The search yields the complete accuracy–cost *frontier* (paper Fig. 5)
//! as a byproduct; `optimize(budget)` just picks the best frontier point
//! within budget.
//!
//! §Perf — the frontier sweep is the repo's single most expensive
//! computation (the paper's one-time cascade-training cost), so the hot
//! path is organized for throughput:
//!
//! * the [`Workspace`] holds *flat model-major arenas* (cost, score
//!   orderings) plus the K×K disagreement matrix, per-model cost totals
//!   and correct counts, all computed **once** — `candidate_lists` does no
//!   O(N) work per pair/triple;
//! * on the **unweighted fast path** correctness never materializes as
//!   bytes or floats: the workspace reuses the table's *word-packed
//!   bitset* (`responses.rs` §Bitset), per-model correct totals are row
//!   popcounts, the K×K disagreement matrix is computed word-at-a-time
//!   over *bit-sliced prediction planes* (one XOR/OR/popcount per 64
//!   items per plane instead of 64 `u32` compares), and the sweep
//!   accumulators are exact `u64` counts fed by single-bit reads — an
//!   ~8x smaller working set than one byte per (model, item) and 64x
//!   smaller than the weighted path's f64 arena;
//! * the triple sweep is *incremental*: τ_a walks down the pre-sorted
//!   `order[a]` while the escalated set, its cost/correct aggregates, and
//!   a doubly-linked "escalated items in score_b order" list are updated
//!   by O(1) deltas per accepted item — no per-grid-point O(N) mask
//!   rebuilds or rescans;
//! * threshold sweeps emit raw `(τ, accuracy, cost)` tuples and build
//!   [`CascadePlan`]s only for locally Pareto-optimal survivors, removing
//!   ~grid×N heap allocations per triple;
//! * candidate lists are swept in parallel by `std::thread::scope` workers
//!   (pure reads of the shared workspace) whose per-worker frontier
//!   buffers are merged back in deterministic list order.
//!
//! The result is the same frontier as the straightforward implementation
//! up to float summation order (last-ulp differences in `avg_cost`; the
//! accuracy counts are exact) — `rust/tests/properties.rs` proves
//! equivalence to 1e-12 against a brute-force reference via
//! `replay::replay`. The parallel and sequential sweep paths of *this*
//! implementation are bit-identical to each other (unit-tested).
//!
//! §Weights — when the training table carries per-item observation
//! weights (decay-weighted serving windows, see `responses.rs` §Weights),
//! every aggregate becomes weighted: the workspace stores *weight-scaled*
//! per-item costs (`wᵢ·cᵢ`) and a weighted-correctness arena (`wᵢ` where
//! correct, else 0), disagreement fractions and accuracies divide by
//! `Σ wᵢ`, the τ_a grid places its points at *weighted* score quantiles
//! (see [`quantile_grid`] — uniform weights reproduce the positional grid
//! bit-for-bit), and the incremental sweeps add/subtract the scaled
//! entries with the exact same update structure as the unweighted search.
//!
//! The two correctness representations live behind one dispatch
//! (`CorrStore` selects the packed-`u64` fast path when weights are
//! uniform-absent, the f64 `wcorr` arena otherwise) and the sweeps are
//! generic over the `CorrRead` view, so both paths share the identical update
//! structure. Bit-for-bit equivalence holds in both directions: the
//! packed path's integer counts convert to the exact same f64 values the
//! old per-item 1.0-sums produced (sums of small integers are exact in
//! f64), and uniform power-of-two weights reproduce the packed frontier
//! bit-for-bit too (property-tested in
//! `rust/tests/properties.rs::prop_packed_bitset_matches_byte_arena` and
//! executed in `scripts/check_optimizer_port.py`; scaling every term and
//! the denominator by the same power of two commutes with every f64
//! rounding step).

use anyhow::{bail, Context, Result};

use super::cascade::{replay, CascadePlan};
use super::responses::SplitTable;
use crate::marketplace::CostModel;
use crate::util::json::Value;

/// Tuning knobs for the search. Defaults reproduce the paper's setup
/// (cascade length 3).
#[derive(Debug, Clone)]
pub struct OptimizerOptions {
    /// Maximum cascade length m (paper uses 3).
    pub max_len: usize,
    /// Quantile grid size for the *first* stage threshold of a triple.
    /// Second-stage thresholds always get a full O(N) sweep.
    pub grid: usize,
    /// Prune lists whose adjacent stages disagree on fewer than this
    /// fraction of training queries.
    pub min_disagreement: f64,
    /// If set, run the coarse sweep on only this many training items and
    /// re-score the surviving candidates on the full table.
    pub coarse_subsample: Option<usize>,
    /// Number of top candidates re-scored on the full table when
    /// `coarse_subsample` is active.
    pub rescore_top: usize,
    /// Worker threads for the candidate sweep. `None` = all available
    /// cores (`FRUGALGPT_SWEEP_THREADS` overrides); `Some(1)` forces the
    /// sequential path. The frontier is identical either way.
    pub threads: Option<usize>,
}

impl Default for OptimizerOptions {
    fn default() -> Self {
        OptimizerOptions {
            max_len: 3,
            grid: 24,
            min_disagreement: 0.02,
            coarse_subsample: None,
            rescore_top: 64,
            threads: None,
        }
    }
}

/// One point of the accuracy–cost frontier.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    /// The cascade achieving this (accuracy, cost) trade-off.
    pub plan: CascadePlan,
    /// Training accuracy of the plan.
    pub accuracy: f64,
    /// Average training cost per query (USD).
    pub avg_cost: f64,
}

impl FrontierPoint {
    /// JSON form via `util::json`. f64 metrics serialize through Rust's
    /// shortest-roundtrip float formatting, so the trip is bit-lossless.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("plan".to_string(), self.plan.to_value());
        m.insert("accuracy".to_string(), Value::Num(self.accuracy));
        m.insert("avg_cost".to_string(), Value::Num(self.avg_cost));
        Value::Obj(m)
    }

    /// Parse a point serialized by [`FrontierPoint::to_value`].
    pub fn from_value(v: &Value) -> Result<FrontierPoint> {
        Ok(FrontierPoint {
            plan: CascadePlan::from_value(v.get("plan"))
                .context("frontier point plan")?,
            accuracy: v.get("accuracy").as_f64().context("point missing `accuracy`")?,
            avg_cost: v.get("avg_cost").as_f64().context("point missing `avg_cost`")?,
        })
    }
}

/// The outcome of `optimize`: the chosen plan plus its train metrics.
#[derive(Debug, Clone)]
pub struct OptimizedPlan {
    /// The selected cascade.
    pub plan: CascadePlan,
    /// Its (weighted) training accuracy.
    pub train_accuracy: f64,
    /// Its (weighted) average training cost per query (USD).
    pub train_avg_cost: f64,
    /// USD per 10k queries (the budget unit).
    pub train_cost_per_10k: f64,
}

impl OptimizedPlan {
    /// JSON form (bit-lossless floats, like [`FrontierPoint::to_value`]).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("plan".to_string(), self.plan.to_value());
        m.insert("train_accuracy".to_string(), Value::Num(self.train_accuracy));
        m.insert("train_avg_cost".to_string(), Value::Num(self.train_avg_cost));
        m.insert(
            "train_cost_per_10k".to_string(),
            Value::Num(self.train_cost_per_10k),
        );
        Value::Obj(m)
    }

    /// Parse a plan serialized by [`OptimizedPlan::to_value`].
    pub fn from_value(v: &Value) -> Result<OptimizedPlan> {
        Ok(OptimizedPlan {
            plan: CascadePlan::from_value(v.get("plan")).context("optimized plan")?,
            train_accuracy: v
                .get("train_accuracy")
                .as_f64()
                .context("missing `train_accuracy`")?,
            train_avg_cost: v
                .get("train_avg_cost")
                .as_f64()
                .context("missing `train_avg_cost`")?,
            train_cost_per_10k: v
                .get("train_cost_per_10k")
                .as_f64()
                .context("missing `train_cost_per_10k`")?,
        })
    }
}

/// The workspace's correctness representation — the §Weights dispatch.
/// Built once per search; the sweeps pick their [`CorrRead`] view (and
/// with it their accumulator type) from this.
enum CorrStore {
    /// Unweighted fast path: the table's word-packed bitset (stride
    /// `words` `u64`s per model, tail bits zero) plus per-model popcount
    /// totals. All sweep accumulators are exact `u64` counts.
    Packed {
        words: usize,
        bits: Vec<u64>,
        totals: Vec<u64>,
    },
    /// Weighted path: `wcorr[m * n + i]` = `wᵢ` if model m answers item i
    /// correctly, else 0.0, plus per-model totals in index order.
    Weighted {
        wcorr: Vec<f64>,
        totals: Vec<f64>,
    },
}

/// Accumulator of the sweeps' correctness aggregates: exact `u64` counts
/// on the packed fast path, f64 weighted mass on the weighted path. Both
/// use the same add/sub update structure; `to_f64` happens only at point
/// emission, after the full sum — for counts < 2^53 that conversion is
/// exact, which is what makes the two paths bit-identical on unweighted
/// tables.
trait CorrAcc: Copy {
    /// The additive identity.
    fn zero() -> Self;
    /// Exact conversion of an accumulated sum for point emission.
    fn to_f64(self) -> f64;
    /// `self + o`.
    fn add(self, o: Self) -> Self;
    /// `self - o` (never called below zero: every subtracted item was
    /// previously part of the total).
    fn sub(self, o: Self) -> Self;
}

impl CorrAcc for u64 {
    #[inline(always)]
    fn zero() -> Self {
        0
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        self + o
    }
    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        self - o
    }
}

impl CorrAcc for f64 {
    #[inline(always)]
    fn zero() -> Self {
        0.0
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn add(self, o: Self) -> Self {
        self + o
    }
    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        self - o
    }
}

/// Read-only view of one [`CorrStore`] variant, `Copy` so the generic
/// sweeps can pass it around freely.
trait CorrRead: Copy {
    /// The matching accumulator type.
    type Acc: CorrAcc;
    /// Correctness contribution of item `i` under model `m` (1/0 on the
    /// packed path, `wᵢ`/0.0 on the weighted path).
    fn at(self, m: usize, i: usize) -> Self::Acc;
    /// `Σᵢ at(m, i)`, precomputed at workspace build.
    fn total(self, m: usize) -> Self::Acc;
}

/// [`CorrRead`] over the packed bitset: one shift + mask per item read.
#[derive(Clone, Copy)]
struct PackedCorr<'a> {
    bits: &'a [u64],
    words: usize,
    totals: &'a [u64],
}

impl CorrRead for PackedCorr<'_> {
    type Acc = u64;
    #[inline(always)]
    fn at(self, m: usize, i: usize) -> u64 {
        (self.bits[m * self.words + (i >> 6)] >> (i & 63)) & 1
    }
    #[inline(always)]
    fn total(self, m: usize) -> u64 {
        self.totals[m]
    }
}

/// [`CorrRead`] over the weighted f64 arena.
#[derive(Clone, Copy)]
struct WeightedCorr<'a> {
    wcorr: &'a [f64],
    n: usize,
    totals: &'a [f64],
}

impl CorrRead for WeightedCorr<'_> {
    type Acc = f64;
    #[inline(always)]
    fn at(self, m: usize, i: usize) -> f64 {
        self.wcorr[m * self.n + i]
    }
    #[inline(always)]
    fn total(self, m: usize) -> f64 {
        self.totals[m]
    }
}

/// Precomputed, read-only search state shared by every sweep worker. All
/// per-(model, item) arrays are flat model-major arenas with stride `n`
/// (the packed correctness store uses stride `words = n.div_ceil(64)`).
/// Per-item cost entries are *weight-scaled* (§Weights): for an
/// unweighted table every weight is 1.0 and the arena holds plain USD.
struct Workspace {
    n: usize,
    k: usize,
    /// `cost[m * n + i]` — `wᵢ ·` USD of calling model m on item i.
    cost: Vec<f64>,
    /// `Σ_i cost[m][i]` (index order, so it matches a fresh rescan).
    total_cost: Vec<f64>,
    /// `order[m * n + j]` — item indices sorted by model-m score, desc.
    order: Vec<u32>,
    /// `quantiles[m]` — score thresholds at the option grid (deduped, so
    /// ragged; kept per-model).
    quantiles: Vec<Vec<f32>>,
    /// `disagree[a * k + b]` — weighted P[pred_a != pred_b], symmetric,
    /// 0 diagonal.
    disagree: Vec<f64>,
    /// Correctness store: packed bitset (unweighted) or f64 arena.
    corr: CorrStore,
    /// `Σ_i wᵢ` (`n` as f64 for unweighted tables).
    total_weight: f64,
}

impl Workspace {
    fn build(table: &SplitTable, costs: &CostModel, input_tokens: &[u32], grid: usize) -> Self {
        let n = table.len();
        let k = table.n_models();
        let weights = table.weights();
        let total_weight = table.total_weight();
        let mut cost = Vec::with_capacity(k * n);
        let mut total_cost = Vec::with_capacity(k);
        let mut order = Vec::with_capacity(k * n);
        let mut quantiles = Vec::with_capacity(k);
        for m in 0..k {
            let preds = table.preds_row(m);
            let scores = table.scores_row(m);
            let mut total = 0.0;
            for i in 0..n {
                let w = weights.map_or(1.0, |w| w[i]);
                let c = costs.call_cost(m, input_tokens[i], preds[i]) * w;
                cost.push(c);
                total += c;
            }
            total_cost.push(total);
            let mut idx: Vec<u32> = (0..n as u32).collect();
            idx.sort_by(|&a, &b| {
                scores[b as usize]
                    .partial_cmp(&scores[a as usize])
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            quantiles.push(quantile_grid(scores, &idx, weights, total_weight, grid));
            order.extend_from_slice(&idx);
        }

        // Correctness store: borrow the table's packed rows (one memcpy
        // per model + a popcount pass) on the unweighted fast path, or
        // scale weights into the f64 arena otherwise. The weighted totals
        // accumulate in index order, exactly like a fresh rescan.
        let corr = match weights {
            None => {
                let words = table.words_per_row();
                let mut bits = Vec::with_capacity(k * words);
                let mut totals = Vec::with_capacity(k);
                for m in 0..k {
                    let row = table.correct_words_row(m);
                    bits.extend_from_slice(row);
                    totals.push(row.iter().map(|w| u64::from(w.count_ones())).sum());
                }
                CorrStore::Packed { words, bits, totals }
            }
            Some(w) => {
                let mut wcorr = Vec::with_capacity(k * n);
                let mut totals = Vec::with_capacity(k);
                for m in 0..k {
                    let mut tcorr = 0.0;
                    for (i, &wi) in w.iter().enumerate() {
                        let wc = if table.is_correct(m, i) { wi } else { 0.0 };
                        wcorr.push(wc);
                        tcorr += wc;
                    }
                    totals.push(tcorr);
                }
                CorrStore::Weighted { wcorr, totals }
            }
        };

        // K×K disagreement, once — the candidate enumeration used to
        // recompute these inside its nested loops. Unweighted tables run
        // word-at-a-time over bit-sliced prediction planes: plane p of
        // model m packs bit p of every prediction, so `pa[i] != pb[i]`
        // reduces to "any plane XOR has bit i set" and each 64-item word
        // costs `planes` XOR/ORs + one popcount instead of 64 compares.
        let mut disagree = vec![0.0; k * k];
        match weights {
            None => {
                let words = table.words_per_row();
                let max_pred = (0..k)
                    .flat_map(|m| table.preds_row(m).iter().copied())
                    .max()
                    .unwrap_or(0);
                let n_planes = (32 - max_pred.leading_zeros()).max(1) as usize;
                let mut planes = vec![0u64; k * n_planes * words];
                for m in 0..k {
                    for (i, &p) in table.preds_row(m).iter().enumerate() {
                        let (wi, bi) = (i >> 6, i & 63);
                        for pl in 0..n_planes {
                            if (p >> pl) & 1 == 1 {
                                planes[(m * n_planes + pl) * words + wi] |= 1u64 << bi;
                            }
                        }
                    }
                }
                for a in 0..k {
                    for b in (a + 1)..k {
                        let mut d = 0u64;
                        for wi in 0..words {
                            let mut diff = 0u64;
                            for pl in 0..n_planes {
                                diff |= planes[(a * n_planes + pl) * words + wi]
                                    ^ planes[(b * n_planes + pl) * words + wi];
                            }
                            d += u64::from(diff.count_ones());
                        }
                        // `total_weight` > 0: the optimizer rejects empty
                        // tables before building a workspace.
                        let frac = d as f64 / total_weight;
                        disagree[a * k + b] = frac;
                        disagree[b * k + a] = frac;
                    }
                }
            }
            Some(w) => {
                for a in 0..k {
                    let pa = table.preds_row(a);
                    for b in (a + 1)..k {
                        let pb = table.preds_row(b);
                        let mut s = 0.0;
                        for i in 0..n {
                            if pa[i] != pb[i] {
                                s += w[i];
                            }
                        }
                        // Weights are validated strictly positive, so
                        // `total_weight` > 0 here too.
                        let frac = s / total_weight;
                        disagree[a * k + b] = frac;
                        disagree[b * k + a] = frac;
                    }
                }
            }
        }
        Workspace {
            n,
            k,
            cost,
            total_cost,
            order,
            quantiles,
            disagree,
            corr,
            total_weight,
        }
    }

    #[inline]
    fn cost_row(&self, m: usize) -> &[f64] {
        &self.cost[m * self.n..(m + 1) * self.n]
    }

    #[inline]
    fn order_row(&self, m: usize) -> &[u32] {
        &self.order[m * self.n..(m + 1) * self.n]
    }

    #[inline]
    fn mean_cost(&self, m: usize) -> f64 {
        self.total_cost[m] / self.total_weight
    }

    #[inline]
    fn accuracy(&self, m: usize) -> f64 {
        match &self.corr {
            CorrStore::Packed { totals, .. } => totals[m] as f64 / self.total_weight,
            CorrStore::Weighted { totals, .. } => totals[m] / self.total_weight,
        }
    }
}

/// Reusable per-worker buffers for the threshold sweeps, so the hot loop
/// never allocates proportionally to N per candidate list.
struct SweepScratch {
    /// `rank[i]` — position of item i in `order[b]` (rebuilt per triple).
    rank: Vec<u32>,
    /// Doubly-linked list over `order[b]` ranks of still-escalated items;
    /// index `n` is the circular sentinel.
    prev: Vec<u32>,
    next: Vec<u32>,
    /// Raw `(τ, accuracy, avg_cost)` candidates of one local sweep.
    raw: Vec<(f32, f64, f64)>,
}

impl SweepScratch {
    fn new(n: usize) -> Self {
        SweepScratch {
            rank: vec![0; n],
            prev: vec![0; n + 1],
            next: vec![0; n + 1],
            raw: Vec::new(),
        }
    }
}

/// The cascade optimizer. Borrows a training table + cost model and owns
/// the precomputed workspace.
pub struct CascadeOptimizer<'a> {
    table: &'a SplitTable,
    costs: &'a CostModel,
    input_tokens: Vec<u32>,
    /// The search knobs this optimizer was built with.
    pub options: OptimizerOptions,
    ws: Workspace,
    /// Memoized frontier — §Perf: `optimize()` used to recompute the full
    /// sweep (~seconds at K=12, N=8000) on every budget query; the sweep
    /// is a pure function of (table, costs, options), so cache it.
    frontier_cache: std::sync::OnceLock<Vec<FrontierPoint>>,
}

impl<'a> CascadeOptimizer<'a> {
    /// `input_tokens[i]`: billable prompt tokens of train item i. Use
    /// [`uniform_tokens`] when all prompts have the same size.
    pub fn new(
        table: &'a SplitTable,
        costs: &'a CostModel,
        input_tokens: Vec<u32>,
        options: OptimizerOptions,
    ) -> Result<Self> {
        if table.is_empty() {
            bail!("empty training table");
        }
        if input_tokens.len() != table.len() {
            bail!("input_tokens length mismatch");
        }
        if table.n_models() != costs.n_models() {
            bail!(
                "table has {} models but cost model has {}",
                table.n_models(),
                costs.n_models()
            );
        }
        let ws = Workspace::build(table, costs, &input_tokens, options.grid);
        Ok(CascadeOptimizer {
            table,
            costs,
            input_tokens,
            options,
            ws,
            frontier_cache: std::sync::OnceLock::new(),
        })
    }

    /// Disagreement P[pred_a != pred_b] between two models (precomputed).
    pub fn disagreement(&self, a: usize, b: usize) -> f64 {
        self.ws.disagree[a * self.ws.k + b]
    }

    /// Mean cost of always calling model m (USD per query).
    fn model_cost(&self, m: usize) -> f64 {
        self.ws.mean_cost(m)
    }

    /// Enumerate candidate lists of length 1..=max_len with pruning. Pure
    /// table-driven lookups against the precomputed workspace — no O(N)
    /// work inside the nested loops.
    pub fn candidate_lists(&self) -> Vec<Vec<usize>> {
        let k = self.ws.k;
        let eps = self.options.min_disagreement;
        let mut lists: Vec<Vec<usize>> = (0..k).map(|m| vec![m]).collect();
        if self.options.max_len >= 2 {
            for a in 0..k {
                for b in 0..k {
                    if a == b || self.disagreement(a, b) < eps {
                        continue;
                    }
                    // A cheaper model behind a more expensive one can still
                    // pay off only if the front stage is cheaper; prune
                    // front stages that are both pricier and weaker.
                    if self.model_cost(a) > self.model_cost(b)
                        && self.ws.accuracy(a) < self.ws.accuracy(b)
                    {
                        continue;
                    }
                    lists.push(vec![a, b]);
                }
            }
        }
        if self.options.max_len >= 3 {
            let pairs: Vec<(usize, usize)> = lists
                .iter()
                .filter(|l| l.len() == 2)
                .map(|l| (l[0], l[1]))
                .collect();
            for &(a, b) in &pairs {
                for c in 0..k {
                    if c == a || c == b || self.disagreement(b, c) < eps {
                        continue;
                    }
                    if self.model_cost(b) > self.model_cost(c)
                        && self.ws.accuracy(b) < self.ws.accuracy(c)
                    {
                        continue;
                    }
                    lists.push(vec![a, b, c]);
                }
            }
        }
        lists
    }

    /// Sweep all thresholds of `list` and push non-dominated (cost, acc)
    /// points to `out`. Exact for length ≤ 2 (full O(N) sweep); for
    /// triples the first threshold runs on the quantile grid and the
    /// second gets a full sweep conditioned on it. This is the one
    /// §Weights dispatch point: the generic pair/triple sweeps run with
    /// `u64` popcount-backed accumulators on the packed store and f64
    /// accumulators on the weighted arena.
    fn sweep_list(&self, list: &[usize], scratch: &mut SweepScratch, out: &mut Vec<FrontierPoint>) {
        match list.len() {
            1 => {
                let m = list[0];
                out.push(FrontierPoint {
                    plan: CascadePlan::single(m),
                    accuracy: self.ws.accuracy(m),
                    avg_cost: self.model_cost(m),
                });
            }
            2 => match &self.ws.corr {
                CorrStore::Packed { words, bits, totals } => self.sweep_pair(
                    PackedCorr { bits, words: *words, totals },
                    list[0],
                    list[1],
                    scratch,
                    out,
                ),
                CorrStore::Weighted { wcorr, totals } => self.sweep_pair(
                    WeightedCorr { wcorr, n: self.ws.n, totals },
                    list[0],
                    list[1],
                    scratch,
                    out,
                ),
            },
            3 => match &self.ws.corr {
                CorrStore::Packed { words, bits, totals } => self.sweep_triple(
                    PackedCorr { bits, words: *words, totals },
                    list[0],
                    list[1],
                    list[2],
                    scratch,
                    out,
                ),
                CorrStore::Weighted { wcorr, totals } => self.sweep_triple(
                    WeightedCorr { wcorr, n: self.ws.n, totals },
                    list[0],
                    list[1],
                    list[2],
                    scratch,
                    out,
                ),
            },
            _ => unreachable!("lists are length 1..=3"),
        }
    }

    /// Exact sweep of a 2-stage cascade `[a(τ) → b]`: walk items in
    /// descending score_a order; cutting after the j-th item means top-j
    /// accepted at stage a, the rest escalate to b. Generic over the
    /// correctness view (§Weights): both instantiations share this exact
    /// update structure.
    fn sweep_pair<C: CorrRead>(
        &self,
        corr: C,
        a: usize,
        b: usize,
        scratch: &mut SweepScratch,
        out: &mut Vec<FrontierPoint>,
    ) {
        let order = self.ws.order_row(a);
        let scores = self.table.scores_row(a);
        let cost_b = self.ws.cost_row(b);

        let total_cost_a = self.ws.total_cost[a];
        let mut acc_corr_a = C::Acc::zero(); // correct mass among accepted (top-j)
        let mut acc_corr_b = corr.total(b);
        let mut esc_cost_b = self.ws.total_cost[b];
        let inv_n = 1.0 / self.ws.total_weight;
        let raw = &mut scratch.raw;
        raw.clear();
        let mut prev_score = f32::INFINITY;
        for &iu in order {
            let i = iu as usize;
            let s = scores[i];
            // A valid threshold separates distinct score values; emit the
            // point for the cut *before* item i when the score drops.
            if s < prev_score {
                raw.push((
                    prev_midpoint(prev_score, s),
                    acc_corr_a.add(acc_corr_b).to_f64() * inv_n,
                    (total_cost_a + esc_cost_b) * inv_n,
                ));
            }
            // accept item i at stage a:
            acc_corr_a = acc_corr_a.add(corr.at(a, i));
            acc_corr_b = acc_corr_b.sub(corr.at(b, i));
            esc_cost_b -= cost_b[i];
            prev_score = s;
        }
        // Cut after everything = stage a alone never escalates; τ below min.
        raw.push((-1.0, acc_corr_a.to_f64() * inv_n, total_cost_a * inv_n));
        prune_pareto_raw(raw);
        out.extend(raw.iter().map(|&(tau, accuracy, avg_cost)| FrontierPoint {
            plan: CascadePlan::pair(a, tau, b),
            accuracy,
            avg_cost,
        }));
    }

    /// Full τ_a-grid sweep of the 3-stage cascade `[a(τ_a) → b(τ_b) → c]`,
    /// incremental in τ_a: items with `score_a > τ_a` stop at `a`; the
    /// rest replay `[b(τ_b) → c]` with a full conditional τ_b sweep.
    ///
    /// τ_a only ever *decreases* along the quantile grid, so the escalated
    /// set only shrinks: each item is accepted at stage a exactly once,
    /// updating the escalation aggregates and unlinking itself from the
    /// score_b-ordered list in O(1). Per grid point the conditional sweep
    /// then costs O(|escalated|), not O(N) — and nothing is rebuilt.
    fn sweep_triple<C: CorrRead>(
        &self,
        corr: C,
        a: usize,
        b: usize,
        c: usize,
        scratch: &mut SweepScratch,
        out: &mut Vec<FrontierPoint>,
    ) {
        let n = self.ws.n;
        let sentinel = n;
        let scores_a = self.table.scores_row(a);
        let scores_b = self.table.scores_row(b);
        let cost_b = self.ws.cost_row(b);
        let cost_c = self.ws.cost_row(c);
        let order_a = self.ws.order_row(a);
        let order_b = self.ws.order_row(b);

        let SweepScratch { rank, prev, next, raw } = scratch;
        // rank[i] = position of item i in order_b; the linked list chains
        // all ranks (everything starts escalated under τ_a = +∞).
        for (r, &iu) in order_b.iter().enumerate() {
            rank[iu as usize] = r as u32;
        }
        for r in 0..=n {
            next[r] = if r == n { 0 } else { (r + 1) as u32 };
            prev[r] = if r == 0 { sentinel as u32 } else { (r - 1) as u32 };
        }

        let base_cost = self.ws.total_cost[a]; // everyone pays stage a
        let mut acc_corr_a = C::Acc::zero(); // correct mass among items accepted at a
        let mut n_esc = n;
        let mut esc_cost_b = self.ws.total_cost[b];
        let mut esc_corr_c = corr.total(c);
        let mut esc_cost_c = self.ws.total_cost[c];

        let inv_n = 1.0 / self.ws.total_weight;
        let mut accepted = 0usize; // prefix of order_a accepted at stage a
        for &tau_a in &self.ws.quantiles[a] {
            // Delta-accept every item whose score_a clears the new τ_a.
            while accepted < n {
                let i = order_a[accepted] as usize;
                if scores_a[i] <= tau_a {
                    break;
                }
                acc_corr_a = acc_corr_a.add(corr.at(a, i));
                esc_cost_b -= cost_b[i];
                esc_corr_c = esc_corr_c.sub(corr.at(c, i));
                esc_cost_c -= cost_c[i];
                let r = rank[i] as usize;
                let (p, nx) = (prev[r] as usize, next[r] as usize);
                next[p] = nx as u32;
                prev[nx] = p as u32;
                n_esc -= 1;
                accepted += 1;
            }
            if n_esc == 0 {
                // Degenerates to the single [a] for this and every lower
                // τ_a (the escalated set only shrinks); covered elsewhere.
                break;
            }

            // Conditional sweep of τ_b over escalated items, in score_b
            // order (the linked list), with suffix aggregates peeled off.
            raw.clear();
            let mut corr_b_acc = C::Acc::zero();
            let mut rem_corr_c = esc_corr_c;
            let mut rem_cost_c = esc_cost_c;
            let mut prev_score = f32::INFINITY;
            let mut r = next[sentinel] as usize;
            while r != sentinel {
                let i = order_b[r] as usize;
                let s = scores_b[i];
                if s < prev_score {
                    raw.push((
                        prev_midpoint(prev_score, s),
                        acc_corr_a.add(corr_b_acc).add(rem_corr_c).to_f64() * inv_n,
                        (base_cost + esc_cost_b + rem_cost_c) * inv_n,
                    ));
                }
                corr_b_acc = corr_b_acc.add(corr.at(b, i));
                rem_corr_c = rem_corr_c.sub(corr.at(c, i));
                rem_cost_c -= cost_c[i];
                prev_score = s;
                r = next[r] as usize;
            }
            // τ_b below min: b answers every escalated item.
            raw.push((
                -1.0,
                acc_corr_a.add(corr_b_acc).to_f64() * inv_n,
                (base_cost + esc_cost_b) * inv_n,
            ));
            prune_pareto_raw(raw);
            out.extend(raw.iter().map(|&(tau_b, accuracy, avg_cost)| FrontierPoint {
                plan: CascadePlan::triple(a, tau_a, b, tau_b, c),
                accuracy,
                avg_cost,
            }));
        }
    }

    /// Sweep every candidate list, fanning the (read-only) work across
    /// scoped worker threads. Workers take lists round-robin and their
    /// buffers are merged back in list order, so the combined point stream
    /// — and therefore the final pruned frontier — is identical to the
    /// sequential sweep.
    fn sweep_all(&self, lists: &[Vec<usize>]) -> Vec<FrontierPoint> {
        let n_workers = self
            .options
            .threads
            .or_else(|| {
                std::env::var("FRUGALGPT_SWEEP_THREADS").ok().and_then(|v| v.parse().ok())
            })
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
            })
            .clamp(1, lists.len().max(1));
        if n_workers == 1 {
            let mut scratch = SweepScratch::new(self.ws.n);
            let mut out = Vec::new();
            for list in lists {
                self.sweep_list(list, &mut scratch, &mut out);
            }
            return out;
        }
        let per_worker: Vec<Vec<(usize, Vec<FrontierPoint>)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_workers)
                .map(|w| {
                    s.spawn(move || {
                        let mut scratch = SweepScratch::new(self.ws.n);
                        let mut done = Vec::new();
                        let mut idx = w;
                        while idx < lists.len() {
                            let mut pts = Vec::new();
                            self.sweep_list(&lists[idx], &mut scratch, &mut pts);
                            done.push((idx, pts));
                            idx += n_workers;
                        }
                        done
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("sweep worker panicked"))
                .collect()
        });
        let mut slots: Vec<Vec<FrontierPoint>> = (0..lists.len()).map(|_| Vec::new()).collect();
        for chunk in per_worker {
            for (idx, pts) in chunk {
                slots[idx] = pts;
            }
        }
        let mut out = Vec::new();
        for pts in slots {
            out.extend(pts);
        }
        out
    }

    /// Compute the global accuracy–cost frontier over all candidate plans.
    ///
    /// With `options.coarse_subsample = Some(n)` the sweep runs on the
    /// first `n` training items only (the paper's "approximate the
    /// objective by interpolating it within a few samples"), and the
    /// surviving `rescore_top` candidates are re-evaluated exactly on the
    /// full table before the final Pareto prune.
    pub fn frontier(&self) -> Vec<FrontierPoint> {
        self.frontier_cache.get_or_init(|| self.compute_frontier()).clone()
    }

    fn compute_frontier(&self) -> Vec<FrontierPoint> {
        match self.options.coarse_subsample {
            Some(n) if n < self.table.len() => {
                // Weighted tables (decay windows) are ordered oldest →
                // newest: coarse-sample the newest suffix, not the stale
                // near-zero-weight head the decay exists to de-emphasize.
                let (sub, sub_tokens) = if self.table.is_weighted() {
                    let start = self.table.len() - n;
                    (self.table.tail(n), self.input_tokens[start..].to_vec())
                } else {
                    (self.table.head(n), self.input_tokens[..n].to_vec())
                };
                let sub_opt = CascadeOptimizer::new(
                    &sub,
                    self.costs,
                    sub_tokens,
                    OptimizerOptions {
                        coarse_subsample: None,
                        ..self.options.clone()
                    },
                )
                .expect("subsample optimizer");
                let coarse = prune_pareto(sub_opt.sweep_all(&sub_opt.candidate_lists()));
                // Re-score the best candidates exactly on the full table.
                let take = self.options.rescore_top.max(1);
                let start = coarse.len().saturating_sub(take);
                let rescored = coarse[start..]
                    .iter()
                    .map(|p| {
                        let r = replay::replay(
                            &p.plan,
                            self.table,
                            self.costs,
                            &self.input_tokens,
                        );
                        FrontierPoint {
                            plan: p.plan.clone(),
                            accuracy: r.accuracy,
                            avg_cost: r.avg_cost,
                        }
                    })
                    .collect();
                prune_pareto(rescored)
            }
            _ => prune_pareto(self.sweep_all(&self.candidate_lists())),
        }
    }

    /// Best plan whose average train cost ≤ `budget_usd_per_10k / 10_000`.
    pub fn optimize(&self, budget_usd_per_10k: f64) -> Result<OptimizedPlan> {
        best_within(&self.frontier(), budget_usd_per_10k)
    }

    /// Replay a plan on an arbitrary split with this optimizer's cost model
    /// (convenience for reports: train-optimize → test-evaluate).
    pub fn replay_on(
        &self,
        plan: &CascadePlan,
        table: &SplitTable,
        input_tokens: &[u32],
    ) -> replay::ReplaySummary {
        replay::replay(plan, table, self.costs, input_tokens)
    }
}

/// `input_tokens` helper when every item has the same billable size.
pub fn uniform_tokens(n: usize, tokens: u32) -> Vec<u32> {
    vec![tokens; n]
}

/// The τ_a grid of one model: `grid` score thresholds over the
/// score-descending `order`, consecutive duplicates deduped.
///
/// Unweighted tables get *positional* quantiles (grid point g sits at
/// order index `⌊(g+1)·n/(grid+1)⌋`). With per-item observation weights
/// (decay windows) the grid is *weight-aware*: point g sits at the first
/// order position whose cumulative observation mass exceeds
/// `(g+1)/(grid+1)` of the total, so under heavy decay the grid
/// concentrates where the mass actually is instead of spending most
/// points on near-zero-weight stale rows.
///
/// For uniform weights the cumulative walk reproduces the positional grid
/// **bit-for-bit**: with w ≡ c the stop condition `cum + c <= target`
/// compares exact multiples of c against `(g+1)·n·c/(grid+1)`, which
/// floors to exactly the positional index (the same power-of-two-scaling
/// argument as the §Weights frontier bit-parity property; pinned by
/// `weighted_grid_uniform_matches_positional_bitwise` and executed by
/// `scripts/check_optimizer_port.py` gate \[3/5\](d)).
pub fn quantile_grid(
    scores: &[f32],
    order: &[u32],
    weights: Option<&[f64]>,
    total_weight: f64,
    grid: usize,
) -> Vec<f32> {
    let n = order.len();
    if n == 0 {
        return Vec::new();
    }
    let mut qs = Vec::with_capacity(grid);
    match weights {
        None => {
            for g in 0..grid {
                let pos = (((g + 1) * n) / (grid + 1)).min(n - 1);
                qs.push(scores[order[pos] as usize]);
            }
        }
        Some(w) => {
            // One monotone walk: targets increase with g, so `pos` only
            // ever advances — O(n + grid) total, like the positional path.
            let mut cum = 0.0f64;
            let mut pos = 0usize;
            for g in 0..grid {
                let target = (g + 1) as f64 * total_weight / (grid + 1) as f64;
                while pos + 1 < n && cum + w[order[pos] as usize] <= target {
                    cum += w[order[pos] as usize];
                    pos += 1;
                }
                qs.push(scores[order[pos] as usize]);
            }
        }
    }
    qs.dedup();
    qs
}

/// Best plan on a frontier whose average cost fits
/// `budget_usd_per_10k / 10_000` — the budget query of paper §3, factored
/// out of [`CascadeOptimizer::optimize`] so frontiers restored from disk
/// ([`super::frontier::SavedFrontier`]) and the online reoptimizer answer
/// it identically. Ties on accuracy prefer the cheaper plan.
pub fn best_within(
    frontier: &[FrontierPoint],
    budget_usd_per_10k: f64,
) -> Result<OptimizedPlan> {
    let per_query = budget_usd_per_10k / 10_000.0;
    let best = frontier
        .iter()
        .filter(|p| p.avg_cost <= per_query + 1e-15)
        .max_by(|x, y| {
            x.accuracy
                .partial_cmp(&y.accuracy)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(y.avg_cost.partial_cmp(&x.avg_cost).unwrap_or(std::cmp::Ordering::Equal))
        });
    match best {
        Some(p) => Ok(OptimizedPlan {
            plan: p.plan.clone(),
            train_accuracy: p.accuracy,
            train_avg_cost: p.avg_cost,
            train_cost_per_10k: p.avg_cost * 10_000.0,
        }),
        None => bail!(
            "no cascade fits budget ${budget_usd_per_10k:.4} per 10k queries \
             (cheapest frontier point: ${:.4})",
            frontier
                .first()
                .map(|p| p.avg_cost * 10_000.0)
                .unwrap_or(f64::NAN)
        ),
    }
}

/// Midpoint threshold strictly between two adjacent scores.
fn prev_midpoint(hi: f32, lo: f32) -> f32 {
    if hi.is_infinite() {
        // Above the max score: stage never accepts.
        lo + 1.0
    } else {
        (hi + lo) * 0.5
    }
}

/// In-place Pareto prune over raw `(τ, accuracy, cost)` sweep tuples —
/// same ordering and tie rules as [`prune_pareto`], applied *before* any
/// `CascadePlan` is allocated (the dominated majority never materializes).
fn prune_pareto_raw(pts: &mut Vec<(f32, f64, f64)>) {
    pts.sort_by(|a, b| {
        a.2.partial_cmp(&b.2)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal))
    });
    let mut best_acc = f64::NEG_INFINITY;
    pts.retain(|&(_, acc, _)| {
        if acc > best_acc + 1e-12 {
            best_acc = acc;
            true
        } else {
            false
        }
    });
}

/// Keep only Pareto-optimal points (no other point has ≤ cost and ≥ acc),
/// sorted by ascending cost.
pub fn prune_pareto(mut pts: Vec<FrontierPoint>) -> Vec<FrontierPoint> {
    pts.sort_by(|a, b| {
        a.avg_cost
            .partial_cmp(&b.avg_cost)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.accuracy.partial_cmp(&a.accuracy).unwrap_or(std::cmp::Ordering::Equal))
    });
    let mut out: Vec<FrontierPoint> = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for p in pts {
        if p.accuracy > best_acc + 1e-12 {
            best_acc = p.accuracy;
            out.push(p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::responses::synthetic_table;

    fn setup() -> (SplitTable, CostModel) {
        // 8 models / 600 items keeps the exhaustive sweep fast in debug
        // builds; the full 12-model search is exercised by the release-mode
        // integration tests and benches.
        let t = synthetic_table(8, 600, 4, 0.9, 7);
        let cm = CostModel::from_table1("synthetic", vec![1, 1, 2, 1])
            .truncated(t.model_names.clone());
        (t, cm)
    }

    fn optimizer<'a>(t: &'a SplitTable, cm: &'a CostModel) -> CascadeOptimizer<'a> {
        let toks = uniform_tokens(t.len(), 125);
        CascadeOptimizer::new(t, cm, toks, OptimizerOptions::default()).unwrap()
    }

    #[test]
    fn frontier_is_sorted_and_pareto() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        assert!(f.len() > 3, "frontier should have multiple points");
        for w in f.windows(2) {
            assert!(w[0].avg_cost <= w[1].avg_cost);
            assert!(w[0].accuracy < w[1].accuracy);
        }
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree() {
        let (t, cm) = setup();
        let toks = uniform_tokens(t.len(), 125);
        let seq = CascadeOptimizer::new(
            &t,
            &cm,
            toks.clone(),
            OptimizerOptions { threads: Some(1), ..Default::default() },
        )
        .unwrap()
        .frontier();
        let par = CascadeOptimizer::new(
            &t,
            &cm,
            toks,
            OptimizerOptions { threads: Some(4), ..Default::default() },
        )
        .unwrap()
        .frontier();
        assert_eq!(seq.len(), par.len());
        for (x, y) in seq.iter().zip(&par) {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits());
            assert_eq!(x.avg_cost.to_bits(), y.avg_cost.to_bits());
        }
    }

    #[test]
    fn optimize_respects_budget() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let mid_budget = f[f.len() / 2].avg_cost * 10_000.0;
        let plan = opt.optimize(mid_budget).unwrap();
        assert!(plan.train_cost_per_10k <= mid_budget + 1e-9);
        // Verify by replay: the plan's reported train metrics are real.
        let toks = uniform_tokens(t.len(), 125);
        let r = replay::replay(&plan.plan, &t, &cm, &toks);
        assert!((r.accuracy - plan.train_accuracy).abs() < 1e-9);
        assert!((r.avg_cost - plan.train_avg_cost).abs() < 1e-9);
    }

    #[test]
    fn bigger_budget_never_hurts() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let mut prev = 0.0;
        for mult in [0.25, 0.5, 1.0, 2.0] {
            let b = f.last().unwrap().avg_cost * 10_000.0 * mult;
            if let Ok(p) = opt.optimize(b) {
                assert!(p.train_accuracy >= prev - 1e-12);
                prev = p.train_accuracy;
            }
        }
    }

    #[test]
    fn cascade_beats_best_individual_with_calibrated_scorer() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let best_single = (0..t.n_models())
            .map(|m| t.accuracy(m))
            .fold(f64::MIN, f64::max);
        let best = f.last().unwrap();
        // With a well-calibrated synthetic scorer the cascade should match
        // or beat the best individual API on the train split.
        assert!(
            best.accuracy >= best_single - 1e-9,
            "frontier top {} vs best single {}",
            best.accuracy,
            best_single
        );
    }

    #[test]
    fn cheap_budget_selects_cheap_models() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let cheapest = &f[0];
        let plan = opt.optimize(cheapest.avg_cost * 10_000.0 * 1.01).unwrap();
        // the selected plan must cost no more than the cheapest+1%.
        assert!(plan.train_avg_cost <= cheapest.avg_cost * 1.011);
    }

    #[test]
    fn impossible_budget_errors() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        assert!(opt.optimize(0.0).is_err());
    }

    #[test]
    fn disagreement_pruning_symmetric_sanity() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let d = opt.disagreement(0, 7);
        assert!(d > 0.05, "weak vs strong models should disagree, d={d}");
        assert_eq!(opt.disagreement(3, 3), 0.0);
        // precomputed matrix must match a direct recount
        let direct = t
            .preds_row(0)
            .iter()
            .zip(t.preds_row(7))
            .filter(|&(x, y)| x != y)
            .count() as f64
            / t.len() as f64;
        assert!((d - direct).abs() < 1e-15);
    }

    #[test]
    fn coarse_subsample_approximates_full_search() {
        let (t, cm) = setup();
        let toks = uniform_tokens(t.len(), 125);
        let full = CascadeOptimizer::new(&t, &cm, toks.clone(), OptimizerOptions::default())
            .unwrap()
            .frontier();
        let coarse = CascadeOptimizer::new(
            &t,
            &cm,
            toks,
            OptimizerOptions {
                coarse_subsample: Some(200),
                rescore_top: 48,
                ..Default::default()
            },
        )
        .unwrap()
        .frontier();
        assert!(!coarse.is_empty());
        // The coarse frontier's best accuracy should be close to exact.
        let fa = full.last().unwrap().accuracy;
        let ca = coarse.last().unwrap().accuracy;
        assert!(ca > fa - 0.05, "coarse {ca} vs full {fa}");
        // And every coarse point's metrics are exact (re-scored) values.
        for p in &coarse {
            assert!((0.0..=1.0).contains(&p.accuracy));
        }
    }

    #[test]
    fn optimized_plan_json_roundtrip_is_bit_exact() {
        let (t, cm) = setup();
        let opt = optimizer(&t, &cm);
        let f = opt.frontier();
        let plan = opt.optimize(f[f.len() / 2].avg_cost * 10_000.0).unwrap();
        let json = plan.to_value().to_json();
        let back = OptimizedPlan::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.plan, plan.plan);
        assert_eq!(back.train_accuracy.to_bits(), plan.train_accuracy.to_bits());
        assert_eq!(back.train_avg_cost.to_bits(), plan.train_avg_cost.to_bits());
        assert_eq!(
            back.train_cost_per_10k.to_bits(),
            plan.train_cost_per_10k.to_bits()
        );
        // and the point round-trip used by SavedFrontier
        let p = &f[0];
        let pb = FrontierPoint::from_value(&Value::parse(&p.to_value().to_json()).unwrap())
            .unwrap();
        assert_eq!(pb.plan, p.plan);
        assert_eq!(pb.accuracy.to_bits(), p.accuracy.to_bits());
        assert_eq!(pb.avg_cost.to_bits(), p.avg_cost.to_bits());
    }

    /// Score-descending order + the positional grid, computed naively —
    /// the independent reference for the quantile-grid tests.
    fn sorted_order(scores: &[f32]) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        idx
    }

    #[test]
    fn weighted_grid_uniform_matches_positional_bitwise() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x9A1D);
        for n in [1usize, 7, 64, 201] {
            let scores: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
            let order = sorted_order(&scores);
            for grid in [4usize, 8, 24] {
                let positional = quantile_grid(&scores, &order, None, n as f64, grid);
                for c in [1.0f64, 0.5, 2.0, 0.25] {
                    let w = vec![c; n];
                    let mut total = 0.0;
                    for &wi in &w {
                        total += wi;
                    }
                    let weighted = quantile_grid(&scores, &order, Some(&w), total, grid);
                    assert_eq!(positional.len(), weighted.len(), "n={n} grid={grid} c={c}");
                    for (p, q) in positional.iter().zip(&weighted) {
                        assert_eq!(p.to_bits(), q.to_bits(), "n={n} grid={grid} c={c}");
                    }
                }
            }
        }
    }

    #[test]
    fn weighted_grid_matches_prefix_sum_reference() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF00D);
        for _ in 0..20 {
            let n = 2 + rng.below(120) as usize;
            let grid = 3 + rng.below(8) as usize;
            let scores: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
            let w: Vec<f64> = (0..n).map(|_| 0.25 + 3.75 * rng.f64()).collect();
            let order = sorted_order(&scores);
            let mut total = 0.0;
            for &wi in &w {
                total += wi;
            }
            // Independent definition: grid point g = score of the first
            // order position whose cumulative mass exceeds the target.
            let mut prefix = vec![0.0f64; n + 1];
            for (p, &iu) in order.iter().enumerate() {
                prefix[p + 1] = prefix[p] + w[iu as usize];
            }
            let mut want = Vec::new();
            for g in 0..grid {
                let target = (g + 1) as f64 * total / (grid + 1) as f64;
                let pos =
                    (0..n).find(|&p| prefix[p + 1] > target).unwrap_or(n - 1);
                want.push(scores[order[pos] as usize]);
            }
            want.dedup();
            let got = quantile_grid(&scores, &order, Some(&w), total, grid);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn decayed_weights_pull_grid_into_the_mass() {
        // All observation mass on the 4 highest-scoring items: every grid
        // point must come from that top slice, while the positional grid
        // still spreads across the stale tail.
        let n = 64usize;
        let scores: Vec<f32> = (0..n).map(|i| 1.0 - i as f32 / n as f32).collect();
        let order = sorted_order(&scores);
        let mut w = vec![1e-9f64; n];
        for &iu in order.iter().take(4) {
            w[iu as usize] = 1.0;
        }
        let mut total = 0.0;
        for &wi in &w {
            total += wi;
        }
        let weighted = quantile_grid(&scores, &order, Some(&w), total, 8);
        let top: Vec<f32> =
            order.iter().take(4).map(|&i| scores[i as usize]).collect();
        for q in &weighted {
            assert!(top.contains(q), "grid point {q} outside the mass-carrying top slice");
        }
        let positional = quantile_grid(&scores, &order, None, n as f64, 8);
        assert!(
            positional.iter().any(|q| !top.contains(q)),
            "positional grid should spread into the zero-mass tail"
        );
    }

    #[test]
    fn pareto_prune_removes_dominated() {
        let mk = |c: f64, a: f64| FrontierPoint {
            plan: CascadePlan::single(0),
            accuracy: a,
            avg_cost: c,
        };
        let pts = vec![mk(1.0, 0.5), mk(2.0, 0.4), mk(3.0, 0.9), mk(0.5, 0.45)];
        let f = prune_pareto(pts);
        assert_eq!(f.len(), 3);
        assert_eq!(f[0].avg_cost, 0.5);
        assert_eq!(f[1].avg_cost, 1.0);
        assert_eq!(f[2].avg_cost, 3.0);
    }

    #[test]
    fn raw_prune_matches_plan_prune() {
        // prune_pareto_raw must select exactly the tuples whose (acc, cost)
        // survive prune_pareto on equivalent FrontierPoints.
        let tuples = vec![
            (0.9f32, 0.50, 1.0),
            (0.8, 0.40, 2.0),
            (0.7, 0.90, 3.0),
            (0.6, 0.45, 0.5),
            (0.5, 0.50, 1.0), // exact duplicate of #0 in (acc, cost)
        ];
        let pts: Vec<FrontierPoint> = tuples
            .iter()
            .map(|&(_, a, c)| FrontierPoint {
                plan: CascadePlan::single(0),
                accuracy: a,
                avg_cost: c,
            })
            .collect();
        let via_plans = prune_pareto(pts);
        let mut raw = tuples.clone();
        prune_pareto_raw(&mut raw);
        assert_eq!(via_plans.len(), raw.len());
        for (p, &(_, a, c)) in via_plans.iter().zip(&raw) {
            assert_eq!(p.accuracy.to_bits(), a.to_bits());
            assert_eq!(p.avg_cost.to_bits(), c.to_bits());
        }
    }
}
