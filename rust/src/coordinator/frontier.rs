//! Frontier persistence: save a learned accuracy–cost frontier next to the
//! response tables and restore it without re-running the train-time sweep.
//!
//! The frontier is a pure function of (train table, cost model, optimizer
//! options), and the sweep that produces it is the repo's single most
//! expensive computation — so `optimize --save-frontier` writes the result
//! to `artifacts/frontiers/<dataset>.json` and `serve --frontier <path>`
//! boots straight from it. The file stores every Pareto point with its
//! full `(L, τ)` plan and exact train metrics; floats round-trip
//! bit-losslessly through `util::json` (Rust's shortest-roundtrip float
//! formatting), which `rust/tests/properties.rs::prop_frontier_json_roundtrip`
//! asserts point-for-point.
//!
//! A saved frontier names the dataset and the marketplace model list it
//! was learned against; [`SavedFrontier::validate_for`] rejects a
//! plan/marketplace mismatch before any stage index is dereferenced.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::optimizer::{best_within, FrontierPoint, OptimizedPlan};
use crate::util::json::Value;

/// Format tag written into every frontier file (bump on layout changes).
pub const FORMAT: &str = "frugalgpt-frontier/v1";

/// A persisted accuracy–cost frontier for one dataset.
#[derive(Debug, Clone)]
pub struct SavedFrontier {
    /// Dataset the frontier was learned on.
    pub dataset: String,
    /// Marketplace model list the plans' stage indices refer to.
    pub model_names: Vec<String>,
    /// Pareto points, ascending cost / ascending accuracy (as produced by
    /// `CascadeOptimizer::frontier`).
    pub points: Vec<FrontierPoint>,
}

impl SavedFrontier {
    /// Wrap learned points for persistence.
    pub fn new(
        dataset: impl Into<String>,
        model_names: Vec<String>,
        points: Vec<FrontierPoint>,
    ) -> Self {
        SavedFrontier { dataset: dataset.into(), model_names, points }
    }

    /// Canonical on-disk location: `<artifacts>/frontiers/<dataset>.json`.
    pub fn default_path(artifacts_root: &Path, dataset: &str) -> PathBuf {
        artifacts_root.join("frontiers").join(format!("{dataset}.json"))
    }

    /// JSON document form (format-tagged, see [`FORMAT`]).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("format".to_string(), Value::Str(FORMAT.to_string()));
        m.insert("dataset".to_string(), Value::Str(self.dataset.clone()));
        m.insert(
            "models".to_string(),
            Value::Arr(self.model_names.iter().map(|n| Value::Str(n.clone())).collect()),
        );
        m.insert(
            "points".to_string(),
            Value::Arr(self.points.iter().map(FrontierPoint::to_value).collect()),
        );
        Value::Obj(m)
    }

    /// Parse + validate a document written by [`SavedFrontier::to_value`]
    /// (format tag, stage indices in range).
    pub fn from_value(v: &Value) -> Result<SavedFrontier> {
        match v.get("format").as_str() {
            Some(FORMAT) => {}
            Some(other) => bail!("unsupported frontier format `{other}` (want {FORMAT})"),
            None => bail!("not a frontier file (missing `format`)"),
        }
        let dataset = v.get("dataset").as_str().context("missing `dataset`")?.to_string();
        let model_names: Vec<String> = v
            .get("models")
            .as_arr()
            .context("missing `models`")?
            .iter()
            .map(|x| x.as_str().map(str::to_string).context("model name not a string"))
            .collect::<Result<_>>()?;
        let points: Vec<FrontierPoint> = v
            .get("points")
            .as_arr()
            .context("missing `points`")?
            .iter()
            .map(FrontierPoint::from_value)
            .collect::<Result<_>>()?;
        for (j, p) in points.iter().enumerate() {
            for s in &p.plan.stages {
                if s.model >= model_names.len() {
                    bail!(
                        "frontier point {j}: stage model index {} out of range \
                         (file lists {} models)",
                        s.model,
                        model_names.len()
                    );
                }
            }
        }
        Ok(SavedFrontier { dataset, model_names, points })
    }

    /// Serialized document (bit-lossless floats).
    pub fn to_json(&self) -> String {
        self.to_value().to_json()
    }

    /// Parse a serialized frontier document.
    pub fn from_json(raw: &str) -> Result<SavedFrontier> {
        Self::from_value(&Value::parse(raw).map_err(|e| anyhow!("{e}"))?)
    }

    /// Write to `path`, creating parent directories as needed.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing frontier {}", path.display()))
    }

    /// Read + parse a frontier file.
    pub fn load(path: &Path) -> Result<SavedFrontier> {
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("reading frontier {}", path.display()))?;
        Self::from_json(&raw)
            .with_context(|| format!("parsing frontier {}", path.display()))
    }

    /// Reject serving this frontier against a mismatched dataset or
    /// marketplace (stage indices would silently point at wrong models).
    pub fn validate_for(&self, dataset: &str, model_names: &[String]) -> Result<()> {
        if self.dataset != dataset {
            bail!("frontier was learned on `{}`, not `{dataset}`", self.dataset);
        }
        if self.model_names != model_names {
            bail!(
                "frontier model list {:?} does not match the marketplace {:?}",
                self.model_names,
                model_names
            );
        }
        Ok(())
    }

    /// Budget query over the restored points — identical semantics to
    /// `CascadeOptimizer::optimize`.
    pub fn best_within(&self, budget_usd_per_10k: f64) -> Result<OptimizedPlan> {
        best_within(&self.points, budget_usd_per_10k)
    }

    /// The highest-accuracy plan (unbounded budget).
    pub fn top(&self) -> Result<OptimizedPlan> {
        self.best_within(f64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::{uniform_tokens, CascadeOptimizer, OptimizerOptions};
    use crate::coordinator::responses::synthetic_table;
    use crate::marketplace::CostModel;

    fn learned() -> (SavedFrontier, Vec<FrontierPoint>) {
        let t = synthetic_table(6, 400, 4, 0.9, 11);
        let cm = CostModel::from_table1("synthetic", vec![1, 1, 2, 1])
            .truncated(t.model_names.clone());
        let toks = uniform_tokens(t.len(), 125);
        let opt =
            CascadeOptimizer::new(&t, &cm, toks, OptimizerOptions::default()).unwrap();
        let points = opt.frontier();
        (
            SavedFrontier::new("synthetic", t.model_names.clone(), points.clone()),
            points,
        )
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let (sf, points) = learned();
        let back = SavedFrontier::from_json(&sf.to_json()).unwrap();
        assert_eq!(back.dataset, "synthetic");
        assert_eq!(back.model_names, sf.model_names);
        assert_eq!(back.points.len(), points.len());
        for (a, b) in points.iter().zip(&back.points) {
            assert_eq!(a.plan, b.plan);
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            assert_eq!(a.avg_cost.to_bits(), b.avg_cost.to_bits());
        }
    }

    #[test]
    fn save_load_and_budget_query_agree_with_live_optimizer() {
        let (sf, points) = learned();
        let dir = std::env::temp_dir().join("frugalgpt_frontier_test");
        let path = SavedFrontier::default_path(&dir, "synthetic");
        sf.save(&path).unwrap();
        let loaded = SavedFrontier::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let budget = points[points.len() / 2].avg_cost * 1e4;
        let from_file = loaded.best_within(budget).unwrap();
        let live = best_within(&points, budget).unwrap();
        assert_eq!(from_file.plan, live.plan);
        assert_eq!(from_file.train_accuracy.to_bits(), live.train_accuracy.to_bits());
        let top = loaded.top().unwrap();
        assert_eq!(top.plan, points.last().unwrap().plan);
    }

    #[test]
    fn rejects_mismatch_and_bad_files() {
        let (sf, _) = learned();
        assert!(sf.validate_for("synthetic", &sf.model_names).is_ok());
        assert!(sf.validate_for("other", &sf.model_names).is_err());
        let short = sf.model_names[..3].to_vec();
        assert!(sf.validate_for("synthetic", &short).is_err());

        assert!(SavedFrontier::from_json("{}").is_err());
        assert!(SavedFrontier::from_json("not json").is_err());
        // stage index out of range for the declared model list
        let mut doc = sf.to_value();
        if let Value::Obj(m) = &mut doc {
            m.insert("models".into(), Value::Arr(vec![Value::Str("only_one".into())]));
        }
        assert!(SavedFrontier::from_value(&doc).is_err());
    }

    /// Each way a frontier file can be broken on disk must surface as a
    /// distinct, situating error — the serve path prints these verbatim,
    /// so "something failed somewhere" is not acceptable.
    #[test]
    fn corrupt_files_fail_with_situating_errors() {
        let dir = std::env::temp_dir().join("frugalgpt_frontier_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();

        // 1. Missing file: the error names the path and the read phase.
        let missing = SavedFrontier::default_path(&dir, "no_such_dataset");
        let err = format!("{:#}", SavedFrontier::load(&missing).unwrap_err());
        assert!(err.contains("reading frontier"), "got: {err}");
        assert!(err.contains("no_such_dataset"), "got: {err}");

        // 2. Truncated JSON (a write that died mid-file): parse phase.
        let (sf, _) = learned();
        let truncated_path = dir.join("truncated.json");
        let mut raw = sf.to_json();
        raw.truncate(raw.len() / 2);
        std::fs::write(&truncated_path, raw).unwrap();
        let err = format!("{:#}", SavedFrontier::load(&truncated_path).unwrap_err());
        assert!(err.contains("parsing frontier"), "got: {err}");
        std::fs::remove_file(&truncated_path).ok();

        // 3. Wrong schema version: valid JSON, wrong format tag.
        let stale_path = dir.join("stale.json");
        let mut doc = sf.to_value();
        if let Value::Obj(m) = &mut doc {
            m.insert("format".into(), Value::Str("frugalgpt-frontier/v0".into()));
        }
        std::fs::write(&stale_path, doc.to_json()).unwrap();
        let err = format!("{:#}", SavedFrontier::load(&stale_path).unwrap_err());
        assert!(err.contains("unsupported frontier format"), "got: {err}");
        assert!(err.contains(FORMAT), "error should name the wanted format: {err}");
        std::fs::remove_file(&stale_path).ok();
    }
}
