//! The paper's system contribution: the budget-aware LLM cascade.
//!
//! * [`responses`] — offline response tables (every API's answer + scorer
//!   score for every train/test item), the substrate the optimizer works on.
//! * [`optimizer`] — the joint search over API lists `L` and threshold
//!   vectors `τ` under a budget constraint (paper §3, "LLM cascade").
//! * [`cascade`] — the runtime executor: sequential API invocation with
//!   reliability-score gating, both *offline* (replay from a table) and
//!   *live* (PJRT model execution through [`crate::runtime`]).
//! * [`frontier`] — persistence for learned frontiers
//!   (`artifacts/frontiers/<dataset>.json`), so serving can skip the
//!   train-time sweep entirely.
//! * [`scorer`] — the generation scoring function `g(q, a)`.
//! * [`budget`] — serving-time spend tracking.

pub mod budget;
pub mod cascade;
pub mod frontier;
pub mod optimizer;
pub mod responses;
pub mod scorer;
