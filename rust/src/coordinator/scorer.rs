//! The generation scoring function `g(q, a) → [0, 1]` (paper §3).
//!
//! The paper trains a DistilBERT regression head; ours is the same idea at
//! simulation scale: a small transformer regression model trained at build
//! time on `(query, answer, correct?)` triples pooled over all 12 APIs'
//! train-split answers, AOT-exported like every other model, and executed
//! here through PJRT. The artifact outputs a raw logit; the sigmoid lives
//! on this side (one less HLO variant to export).

use anyhow::Result;

use crate::data::{prompt, DatasetMeta};
use crate::runtime::EngineHandle;

/// Live reliability scorer bound to one dataset's artifact.
pub struct Scorer {
    engine: EngineHandle,
    meta: DatasetMeta,
}

impl Scorer {
    /// Bind the scorer artifact of `meta`'s dataset to an engine.
    pub fn new(engine: EngineHandle, meta: DatasetMeta) -> Self {
        Scorer { engine, meta }
    }

    /// Dataset geometry the scorer input rows are built for.
    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    /// Score one (query, answer) pair. `tokens` is the full item row; the
    /// scorer sees only the query segment plus the answer token.
    pub fn score(&self, tokens: &[i32], answer: u32) -> Result<f32> {
        let logits =
            self.engine
                .execute(&self.meta.name, "scorer", self.input(tokens, answer))?;
        Ok(sigmoid(logits[0]))
    }

    /// The scorer-artifact input row for one (query, answer) pair —
    /// exposed so callers that route scorer executions through their own
    /// channel (e.g. `server::shadow`'s batched fan-out) build exactly the
    /// row `score`/`score_batch` would; apply [`sigmoid`] to the returned
    /// logit to recover the score.
    pub fn input(&self, tokens: &[i32], answer: u32) -> Vec<i32> {
        prompt::scorer_input(tokens, &self.meta, answer)
    }

    /// Score a batch of (query, answer) pairs in one PJRT execution.
    pub fn score_batch(&self, items: &[(&[i32], u32)]) -> Result<Vec<f32>> {
        let mut inputs = Vec::with_capacity(items.len());
        for (tokens, answer) in items {
            inputs.push(prompt::scorer_input(tokens, &self.meta, *answer));
        }
        let logits = self
            .engine
            .execute_batch(&self.meta.name, "scorer", inputs)?;
        Ok(logits.iter().map(|row| sigmoid(row[0])).collect())
    }
}

/// Numerically stable logistic function.
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::sigmoid;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
        // symmetric
        assert!((sigmoid(1.3) + sigmoid(-1.3) - 1.0).abs() < 1e-6);
        // extremes don't overflow
        assert_eq!(sigmoid(1000.0), 1.0);
        assert_eq!(sigmoid(-1000.0), 0.0);
    }
}
