//! The LLM cascade executor (paper §3, Strategy 3 / Fig. 2e).
//!
//! A cascade is an ordered list of APIs with per-stage acceptance
//! thresholds. A query walks the list: each stage's answer is scored by the
//! reliability function `g(q, a)`; if the score clears the stage threshold
//! the answer is returned, otherwise the next (more expensive) API is
//! invoked. The final stage always answers.
//!
//! Two execution modes share the same plan type:
//! * [`replay`] — offline evaluation against a [`SplitTable`] (used by the
//!   optimizer and all paper-figure reports; zero PJRT work), and
//! * [`Cascade`] — live serving: every stage runs the real AOT-compiled
//!   model + scorer through the PJRT engine, with metered cost.

use anyhow::{bail, Context, Result};

use super::responses::SplitTable;
use super::scorer::Scorer;
use crate::data::{prompt, DatasetMeta};
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::util::json::Value;

/// One stage of a cascade: an API index plus its acceptance threshold.
/// The threshold of the last stage is ignored (it always answers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stage {
    /// Marketplace index of the API this stage invokes.
    pub model: usize,
    /// Acceptance threshold on the reliability score g(q, a).
    pub threshold: f32,
}

impl Stage {
    /// JSON form via `util::json`. The f32 threshold is stored as its
    /// exact f64 widening, so `from_value(to_value())` is bit-lossless.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("model".to_string(), Value::Num(self.model as f64));
        m.insert("threshold".to_string(), Value::Num(f64::from(self.threshold)));
        Value::Obj(m)
    }

    /// Parse a stage serialized by [`Stage::to_value`].
    pub fn from_value(v: &Value) -> Result<Stage> {
        let model = v.get("model").as_usize().context("stage missing `model`")?;
        let threshold =
            v.get("threshold").as_f64().context("stage missing `threshold`")? as f32;
        Ok(Stage { model, threshold })
    }
}

/// Inline capacity of [`StageVec`]: one more than the paper's cascade
/// length 3, so every plan the optimizer can emit lives on the stack.
const STAGE_INLINE: usize = 4;

/// Padding value for unused inline slots (never observable through the
/// slice view).
const PAD_STAGE: Stage = Stage { model: 0, threshold: 0.0 };

/// Small-vec stage storage for [`CascadePlan`]: up to `STAGE_INLINE` (4)
/// stages inline (zero heap allocations — §Perf: the frontier sweeps
/// construct a plan for every surviving Pareto point, and with inline
/// storage those survivors stop allocating per list), spilling to a `Vec`
/// only for longer plans (reachable via deserialization). Dereferences to
/// `&[Stage]`, so all slice-style reads (`iter`, indexing, `last`, `len`)
/// work unchanged.
#[derive(Clone)]
pub struct StageVec {
    /// Stages used in `inline` (meaningful only when `spill` is empty).
    len: u8,
    inline: [Stage; STAGE_INLINE],
    /// Non-empty iff the plan has more than [`STAGE_INLINE`] stages; then
    /// it holds *all* stages and `inline` is ignored.
    spill: Vec<Stage>,
}

impl StageVec {
    /// Build from a slice: inline when it fits, heap spill otherwise.
    pub fn from_slice(stages: &[Stage]) -> StageVec {
        if stages.len() <= STAGE_INLINE {
            let mut inline = [PAD_STAGE; STAGE_INLINE];
            inline[..stages.len()].copy_from_slice(stages);
            StageVec { len: stages.len() as u8, inline, spill: Vec::new() }
        } else {
            StageVec { len: 0, inline: [PAD_STAGE; STAGE_INLINE], spill: stages.to_vec() }
        }
    }

    /// The stages as a slice (the only read path; hides the inline/spill
    /// split).
    #[inline]
    pub fn as_slice(&self) -> &[Stage] {
        if self.spill.is_empty() {
            &self.inline[..self.len as usize]
        } else {
            &self.spill
        }
    }
}

impl std::ops::Deref for StageVec {
    type Target = [Stage];
    #[inline]
    fn deref(&self) -> &[Stage] {
        self.as_slice()
    }
}

impl PartialEq for StageVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for StageVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self.as_slice())
    }
}

impl From<Vec<Stage>> for StageVec {
    fn from(stages: Vec<Stage>) -> StageVec {
        StageVec::from_slice(&stages)
    }
}

impl FromIterator<Stage> for StageVec {
    fn from_iter<I: IntoIterator<Item = Stage>>(iter: I) -> StageVec {
        StageVec::from_slice(&iter.into_iter().collect::<Vec<_>>())
    }
}

impl<'a> IntoIterator for &'a StageVec {
    type Item = &'a Stage;
    type IntoIter = std::slice::Iter<'a, Stage>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

/// A learned cascade configuration `(L, τ)`.
#[derive(Debug, Clone, PartialEq)]
pub struct CascadePlan {
    /// The ordered stages; executes front to back, last stage always
    /// answers.
    pub stages: StageVec,
}

impl CascadePlan {
    /// Plan from an explicit stage list (converted to inline storage when
    /// it fits; the dedicated [`CascadePlan::single`] /
    /// [`CascadePlan::pair`] / [`CascadePlan::triple`] constructors never
    /// touch the heap at all).
    pub fn new(stages: Vec<Stage>) -> Self {
        CascadePlan { stages: StageVec::from(stages) }
    }

    /// The one-stage plan `[model]`.
    pub fn single(model: usize) -> Self {
        CascadePlan {
            stages: StageVec::from_slice(&[Stage { model, threshold: 0.0 }]),
        }
    }

    /// The two-stage plan `[a(τ) → b]` (allocation-free).
    pub fn pair(a: usize, tau: f32, b: usize) -> Self {
        CascadePlan {
            stages: StageVec::from_slice(&[
                Stage { model: a, threshold: tau },
                Stage { model: b, threshold: 0.0 },
            ]),
        }
    }

    /// The three-stage plan `[a(τ_a) → b(τ_b) → c]` (allocation-free).
    pub fn triple(a: usize, tau_a: f32, b: usize, tau_b: f32, c: usize) -> Self {
        CascadePlan {
            stages: StageVec::from_slice(&[
                Stage { model: a, threshold: tau_a },
                Stage { model: b, threshold: tau_b },
                Stage { model: c, threshold: 0.0 },
            ]),
        }
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// Whether the plan has no stages (constructors uphold non-emptiness;
    /// only a hand-built plan can be empty).
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// JSON form via `util::json` (frontier persistence, swap logs).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert(
            "stages".to_string(),
            Value::Arr(self.stages.iter().map(Stage::to_value).collect()),
        );
        Value::Obj(m)
    }

    /// Parse a plan serialized by [`CascadePlan::to_value`]. Rejects empty
    /// stage lists (every constructor path upholds non-emptiness).
    pub fn from_value(v: &Value) -> Result<CascadePlan> {
        let stages: Vec<Stage> = v
            .get("stages")
            .as_arr()
            .context("plan missing `stages`")?
            .iter()
            .map(Stage::from_value)
            .collect::<Result<_>>()?;
        if stages.is_empty() {
            bail!("serialized cascade plan has no stages");
        }
        Ok(CascadePlan::new(stages))
    }

    /// Human-readable form, e.g. `gpt_j(τ=0.96) → j1_large(τ=0.37) → gpt4`.
    pub fn describe(&self, names: &[String]) -> String {
        let mut parts = Vec::new();
        for (i, s) in self.stages.iter().enumerate() {
            let name = names.get(s.model).map(|s| s.as_str()).unwrap_or("?");
            if i + 1 == self.stages.len() {
                parts.push(name.to_string());
            } else {
                parts.push(format!("{name}(τ={:.2})", s.threshold));
            }
        }
        parts.join(" → ")
    }
}

/// Offline evaluation of a plan over a response table.
pub mod replay {
    use super::*;

    /// Outcome of replaying one item through the cascade.
    #[derive(Debug, Clone, Copy)]
    pub struct ItemOutcome {
        /// The accepted answer class.
        pub answer: u32,
        /// Whether the accepted answer matches the item's label.
        pub correct: bool,
        /// Stage index that answered (0-based).
        pub stopped_at: usize,
        /// USD spent on this item (all invoked stages).
        pub cost: f64,
    }

    /// Aggregate result of a replay. For a weighted table (decay windows,
    /// `responses.rs` §Weights) `accuracy` and `avg_cost` are the weighted
    /// means `Σ wᵢ·xᵢ / Σ wᵢ` — the same aggregates the optimizer's sweeps
    /// report — while `stop_frac`/`invoke_frac` stay raw query fractions
    /// (they describe traffic routing, not the learning objective).
    #[derive(Debug, Clone)]
    pub struct ReplaySummary {
        /// (Weighted) fraction of items answered correctly.
        pub accuracy: f64,
        /// (Weighted) average USD per query.
        pub avg_cost: f64,
        /// Fraction of queries answered at each stage.
        pub stop_frac: Vec<f64>,
        /// Fraction of queries for which each stage was *invoked*.
        pub invoke_frac: Vec<f64>,
    }

    /// Replay item `i` of `table` through `plan`. `input_tokens[i]` is the
    /// billable prompt size of item `i` (same for every model by layout).
    pub fn replay_item(
        plan: &CascadePlan,
        table: &SplitTable,
        costs: &CostModel,
        input_tokens: &[u32],
        i: usize,
    ) -> ItemOutcome {
        let mut cost = 0.0;
        let last = plan.stages.len() - 1;
        for (s, stage) in plan.stages.iter().enumerate() {
            let m = stage.model;
            let answer = table.pred(m, i);
            cost += costs.call_cost(m, input_tokens[i], answer);
            if s == last || table.score(m, i) > stage.threshold {
                return ItemOutcome {
                    answer,
                    correct: table.is_correct(m, i),
                    stopped_at: s,
                    cost,
                };
            }
        }
        unreachable!("cascade plans are non-empty");
    }

    /// Replay the whole table; the workhorse behind every offline report.
    pub fn replay(
        plan: &CascadePlan,
        table: &SplitTable,
        costs: &CostModel,
        input_tokens: &[u32],
    ) -> ReplaySummary {
        assert!(!plan.is_empty(), "empty cascade plan");
        assert_eq!(input_tokens.len(), table.len());
        let n = table.len();
        let mut w_correct = 0.0f64;
        let mut total_cost = 0.0f64;
        let mut stops = vec![0usize; plan.stages.len()];
        for i in 0..n {
            let o = replay_item(plan, table, costs, input_tokens, i);
            let w = table.weight(i);
            if o.correct {
                w_correct += w;
            }
            total_cost += w * o.cost;
            stops[o.stopped_at] += 1;
        }
        let mut invoked = vec![0usize; plan.stages.len()];
        let mut carried = n;
        for (s, &st) in stops.iter().enumerate() {
            invoked[s] = carried;
            carried -= st;
        }
        // total_weight() is n for unweighted tables and > 0 whenever the
        // table is non-empty (weights are validated strictly positive).
        let denom = if n == 0 { 1.0 } else { table.total_weight() };
        ReplaySummary {
            accuracy: w_correct / denom,
            avg_cost: total_cost / denom,
            stop_frac: stops.iter().map(|&s| s as f64 / n.max(1) as f64).collect(),
            invoke_frac: invoked.iter().map(|&s| s as f64 / n.max(1) as f64).collect(),
        }
    }
}

/// Result of answering one live query.
#[derive(Debug, Clone)]
pub struct CascadeAnswer {
    /// The accepted answer class.
    pub answer: u32,
    /// Stage that produced the accepted answer.
    pub stopped_at: usize,
    /// Reliability score of the accepted answer (1.0 if last stage).
    pub score: f32,
    /// Metered USD across all invoked stages.
    pub cost: f64,
    /// USD per invoked stage (`stage_costs[s]` = stage s alone;
    /// `stage_costs.iter().sum() == cost`). Lets the serving metrics
    /// attribute spend to each model window exactly.
    pub stage_costs: Vec<f64>,
    /// Billable input tokens of the query prompt.
    pub input_tokens: u32,
    /// Per-stage simulated API latency (ms), for serving reports.
    pub simulated_latency_ms: f64,
}

/// Live cascade: executes the learned plan against real AOT artifacts.
pub struct Cascade {
    plan: CascadePlan,
    engine: EngineHandle,
    scorer: Scorer,
    costs: CostModel,
    meta: DatasetMeta,
    dataset: String,
}

impl Cascade {
    /// Bind a plan to an engine + scorer + cost model (validates every
    /// stage's model index against the marketplace).
    pub fn new(
        plan: CascadePlan,
        engine: EngineHandle,
        scorer: Scorer,
        costs: CostModel,
        meta: DatasetMeta,
    ) -> Result<Self> {
        if plan.is_empty() {
            bail!("cascade plan must have at least one stage");
        }
        for s in &plan.stages {
            if s.model >= costs.n_models() {
                bail!("stage model index {} out of range", s.model);
            }
        }
        let dataset = meta.name.clone();
        Ok(Cascade { plan, engine, scorer, costs, meta, dataset })
    }

    /// The plan this cascade executes.
    pub fn plan(&self) -> &CascadePlan {
        &self.plan
    }

    /// Dataset geometry of the queries this cascade answers.
    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    /// Handle to the engine actor the stages execute on.
    pub fn engine_handle(&self) -> EngineHandle {
        self.engine.clone()
    }

    /// The cost model metering each stage invocation.
    pub fn costs(&self) -> &CostModel {
        &self.costs
    }

    /// Answer one query (a full token row in the dataset layout).
    ///
    /// Every stage performs TWO PJRT executions: the stage's LLM artifact
    /// (argmax over class logits = the "generation") and, unless it is the
    /// final stage, the scorer artifact on `[query; answer]`.
    pub fn answer(&self, tokens: &[i32]) -> Result<CascadeAnswer> {
        self.answer_billed(tokens, prompt::input_tokens(tokens))
    }

    /// [`Cascade::answer`] with an explicit billable input-token count.
    /// Execution is identical; only cost metering (and the simulated API
    /// latency model) uses `input_tokens`. This is the hook for
    /// concatenation-amortized billing (`strategies::concat`): a query
    /// that shares its few-shot prompt with a group is billed
    /// `prompt/g + query` tokens instead of the full row.
    pub fn answer_billed(&self, tokens: &[i32], input_tokens: u32) -> Result<CascadeAnswer> {
        let mut cost = 0.0;
        let mut stage_costs = Vec::with_capacity(self.plan.stages.len());
        let mut sim_lat = 0.0;
        let last = self.plan.stages.len() - 1;
        for (s, stage) in self.plan.stages.iter().enumerate() {
            let name = &self.costs.model_names[stage.model];
            let logits = self
                .engine
                .execute(&self.dataset, name, tokens.to_vec())
                .with_context(|| format!("stage {s} ({name})"))?;
            let answer = argmax(&logits) as u32;
            let stage_cost = self.costs.call_cost(stage.model, input_tokens, answer);
            cost += stage_cost;
            stage_costs.push(stage_cost);
            let out_tokens = self.costs.answer_len(answer);
            sim_lat += self.costs.latency[stage.model]
                .latency_ms(input_tokens + out_tokens);
            if s == last {
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: s,
                    score: 1.0,
                    cost,
                    stage_costs,
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
            let score = self.scorer.score(tokens, answer)?;
            if score > stage.threshold {
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: s,
                    score,
                    cost,
                    stage_costs,
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
        }
        unreachable!()
    }
}

/// Index of the maximum logit (ties → first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::responses::synthetic_table;

    fn setup() -> (SplitTable, CostModel, Vec<u32>) {
        let t = synthetic_table(12, 2000, 4, 0.9, 42);
        let cm = CostModel::from_table1("synthetic", vec![1, 1, 2, 1]);
        let toks = vec![125u32; t.len()];
        (t, cm, toks)
    }

    #[test]
    fn single_stage_replay_matches_model_accuracy() {
        let (t, cm, toks) = setup();
        for m in [0, 5, 11] {
            let plan = CascadePlan::single(m);
            let r = replay::replay(&plan, &t, &cm, &toks);
            assert!((r.accuracy - t.accuracy(m)).abs() < 1e-12);
            assert_eq!(r.stop_frac, vec![1.0]);
        }
    }

    #[test]
    fn threshold_zero_always_stops_at_first_stage_with_positive_scores() {
        let (t, cm, toks) = setup();
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.0 },
            Stage { model: 11, threshold: 0.0 },
        ]);
        let r = replay::replay(&plan, &t, &cm, &toks);
        // synthetic scores are in (0,1], so all stop at stage 0.
        assert!(r.stop_frac[0] > 0.999);
        assert!((r.accuracy - t.accuracy(0)).abs() < 0.01);
    }

    #[test]
    fn threshold_one_always_escalates() {
        let (t, cm, toks) = setup();
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 1.1 },
            Stage { model: 11, threshold: 0.0 },
        ]);
        let r = replay::replay(&plan, &t, &cm, &toks);
        assert_eq!(r.stop_frac[0], 0.0);
        assert!((r.accuracy - t.accuracy(11)).abs() < 1e-12);
        // cost includes BOTH stages for every query.
        let c0 = replay::replay(&CascadePlan::single(0), &t, &cm, &toks).avg_cost;
        let c11 = replay::replay(&CascadePlan::single(11), &t, &cm, &toks).avg_cost;
        assert!((r.avg_cost - (c0 + c11)).abs() < 1e-9);
    }

    #[test]
    fn cost_is_monotone_in_threshold() {
        let (t, cm, toks) = setup();
        let mut prev = 0.0;
        for th in [0.0f32, 0.3, 0.6, 0.9, 1.01] {
            let plan = CascadePlan::new(vec![
                Stage { model: 2, threshold: th },
                Stage { model: 11, threshold: 0.0 },
            ]);
            let r = replay::replay(&plan, &t, &cm, &toks);
            assert!(r.avg_cost >= prev - 1e-12, "cost must grow with τ");
            prev = r.avg_cost;
        }
    }

    #[test]
    fn well_calibrated_cascade_beats_first_stage_accuracy() {
        let (t, cm, toks) = setup();
        // cheap weak model 0 gated at a high threshold, strong model 11 behind.
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.75 },
            Stage { model: 11, threshold: 0.0 },
        ]);
        let r = replay::replay(&plan, &t, &cm, &toks);
        assert!(r.accuracy > t.accuracy(0) + 0.05);
    }

    #[test]
    fn describe_is_readable() {
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.96 },
            Stage { model: 1, threshold: 0.37 },
            Stage { model: 2, threshold: 0.0 },
        ]);
        let names: Vec<String> =
            ["gpt_j", "j1_large", "gpt4"].iter().map(|s| s.to_string()).collect();
        assert_eq!(plan.describe(&names), "gpt_j(τ=0.96) → j1_large(τ=0.37) → gpt4");
    }

    #[test]
    fn plan_json_roundtrip_is_bit_exact() {
        let plan = CascadePlan::new(vec![
            Stage { model: 9, threshold: 0.1 + 0.2 }, // not exactly representable
            Stage { model: 0, threshold: -1.0 },      // "never accepts" sentinel
            Stage { model: 11, threshold: 0.0 },
        ]);
        let json = plan.to_value().to_json();
        let back = CascadePlan::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.stages.len(), plan.stages.len());
        for (a, b) in plan.stages.iter().zip(&back.stages) {
            assert_eq!(a.model, b.model);
            assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
        }
    }

    #[test]
    fn plan_from_value_rejects_garbage() {
        for bad in [
            r#"{}"#,
            r#"{"stages": []}"#,
            r#"{"stages": [{"model": 1}]}"#,
            r#"{"stages": [{"threshold": 0.5}]}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(CascadePlan::from_value(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn stage_vec_inline_and_spill_behave_like_a_slice() {
        let mk = |m: usize| Stage { model: m, threshold: m as f32 * 0.1 };
        for n in [1usize, 2, 3, 4, 5, 7] {
            let stages: Vec<Stage> = (0..n).map(mk).collect();
            let sv = StageVec::from(stages.clone());
            assert_eq!(sv.len(), n);
            assert_eq!(&sv[..], &stages[..]);
            assert_eq!(sv.last(), stages.last());
            assert_eq!(sv.iter().count(), n);
            assert_eq!((&sv).into_iter().count(), n);
            // collected and converted forms agree
            let collected: StageVec = stages.iter().copied().collect();
            assert_eq!(collected, sv);
            // plans longer than the inline capacity round-trip through
            // JSON (the spill path)
            let plan = CascadePlan::new(stages.clone());
            let back =
                CascadePlan::from_value(&Value::parse(&plan.to_value().to_json()).unwrap())
                    .unwrap();
            assert_eq!(back, plan);
        }
        // the dedicated constructors match the Vec-built equivalents
        assert_eq!(
            CascadePlan::pair(1, 0.5, 2),
            CascadePlan::new(vec![
                Stage { model: 1, threshold: 0.5 },
                Stage { model: 2, threshold: 0.0 },
            ])
        );
        assert_eq!(
            CascadePlan::triple(0, 0.9, 1, 0.4, 2),
            CascadePlan::new(vec![
                Stage { model: 0, threshold: 0.9 },
                Stage { model: 1, threshold: 0.4 },
                Stage { model: 2, threshold: 0.0 },
            ])
        );
    }

    #[test]
    fn argmax_ties_and_order() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[2.0, 2.0]), 0);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
