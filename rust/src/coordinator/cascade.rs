//! The LLM cascade executor (paper §3, Strategy 3 / Fig. 2e).
//!
//! A cascade is an ordered list of APIs with per-stage acceptance
//! thresholds. A query walks the list: each stage's answer is scored by the
//! reliability function `g(q, a)`; if the score clears the stage threshold
//! the answer is returned, otherwise the next (more expensive) API is
//! invoked. The final stage always answers.
//!
//! Two execution modes share the same plan type:
//! * [`replay`] — offline evaluation against a [`SplitTable`] (used by the
//!   optimizer and all paper-figure reports; zero PJRT work), and
//! * [`Cascade`] — live serving: every stage runs the real AOT-compiled
//!   model + scorer through the PJRT engine, with metered cost.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::responses::SplitTable;
use super::scorer::Scorer;
use crate::data::{prompt, DatasetMeta};
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::util::json::Value;

/// What the health layer says about one prospective model call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gate {
    /// The model is healthy — call it.
    Allow,
    /// The breaker is half-open and this call is the recovery probe.
    Probe,
    /// The breaker is open — skip the stage, route around the model.
    Skip,
}

/// Per-model availability consulted by the live cascade. Implemented by
/// `server::health::ModelHealth`; defined here as a trait so the pure
/// `coordinator` layer never depends on the `server` runtime modules
/// (the layering rule `strategies/pipeline.rs` documents).
///
/// Decisions must be **local** to the queried model: `admit(m)` /
/// `record(m, ..)` may not read or move any other model's state.
pub trait HealthView: Send + Sync {
    /// May model `m` be called right now?
    fn admit(&self, m: usize) -> Gate;
    /// Report one call outcome against model `m`.
    fn record(&self, m: usize, ok: bool);
    /// Bounded retries allowed per engine call.
    fn max_retries(&self) -> u32;
    /// Count one retry against model `m` and return the deterministic
    /// jittered backoff to sleep before it (µs; 0 = no sleep).
    fn retry_backoff_us(&self, m: usize, attempt: u32) -> u64;
}

/// One stage of a cascade: an API index plus its acceptance threshold.
/// The threshold of the last stage is ignored (it always answers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stage {
    /// Marketplace index of the API this stage invokes.
    pub model: usize,
    /// Acceptance threshold on the reliability score g(q, a).
    pub threshold: f32,
}

impl Stage {
    /// JSON form via `util::json`. The f32 threshold is stored as its
    /// exact f64 widening, so `from_value(to_value())` is bit-lossless.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("model".to_string(), Value::Num(self.model as f64));
        m.insert("threshold".to_string(), Value::Num(f64::from(self.threshold)));
        Value::Obj(m)
    }

    /// Parse a stage serialized by [`Stage::to_value`].
    pub fn from_value(v: &Value) -> Result<Stage> {
        let model = v.get("model").as_usize().context("stage missing `model`")?;
        let threshold =
            v.get("threshold").as_f64().context("stage missing `threshold`")? as f32;
        Ok(Stage { model, threshold })
    }
}

/// Inline capacity of [`StageVec`]: one more than the paper's cascade
/// length 3, so every plan the optimizer can emit lives on the stack.
const STAGE_INLINE: usize = 4;

/// Padding value for unused inline slots (never observable through the
/// slice view).
const PAD_STAGE: Stage = Stage { model: 0, threshold: 0.0 };

/// Small-vec stage storage for [`CascadePlan`]: up to `STAGE_INLINE` (4)
/// stages inline (zero heap allocations — §Perf: the frontier sweeps
/// construct a plan for every surviving Pareto point, and with inline
/// storage those survivors stop allocating per list), spilling to a `Vec`
/// only for longer plans (reachable via deserialization). Dereferences to
/// `&[Stage]`, so all slice-style reads (`iter`, indexing, `last`, `len`)
/// work unchanged.
#[derive(Clone)]
pub struct StageVec {
    /// Stages used in `inline` (meaningful only when `spill` is empty).
    len: u8,
    inline: [Stage; STAGE_INLINE],
    /// Non-empty iff the plan has more than [`STAGE_INLINE`] stages; then
    /// it holds *all* stages and `inline` is ignored.
    spill: Vec<Stage>,
}

impl StageVec {
    /// Build from a slice: inline when it fits, heap spill otherwise.
    pub fn from_slice(stages: &[Stage]) -> StageVec {
        if stages.len() <= STAGE_INLINE {
            let mut inline = [PAD_STAGE; STAGE_INLINE];
            inline[..stages.len()].copy_from_slice(stages);
            StageVec { len: stages.len() as u8, inline, spill: Vec::new() }
        } else {
            StageVec { len: 0, inline: [PAD_STAGE; STAGE_INLINE], spill: stages.to_vec() }
        }
    }

    /// The stages as a slice (the only read path; hides the inline/spill
    /// split).
    #[inline]
    pub fn as_slice(&self) -> &[Stage] {
        if self.spill.is_empty() {
            &self.inline[..self.len as usize]
        } else {
            &self.spill
        }
    }
}

impl std::ops::Deref for StageVec {
    type Target = [Stage];
    #[inline]
    fn deref(&self) -> &[Stage] {
        self.as_slice()
    }
}

impl PartialEq for StageVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::fmt::Debug for StageVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}", self.as_slice())
    }
}

impl From<Vec<Stage>> for StageVec {
    fn from(stages: Vec<Stage>) -> StageVec {
        StageVec::from_slice(&stages)
    }
}

impl FromIterator<Stage> for StageVec {
    fn from_iter<I: IntoIterator<Item = Stage>>(iter: I) -> StageVec {
        StageVec::from_slice(&iter.into_iter().collect::<Vec<_>>())
    }
}

impl<'a> IntoIterator for &'a StageVec {
    type Item = &'a Stage;
    type IntoIter = std::slice::Iter<'a, Stage>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

/// A learned cascade configuration `(L, τ)`.
#[derive(Debug, Clone, PartialEq)]
pub struct CascadePlan {
    /// The ordered stages; executes front to back, last stage always
    /// answers.
    pub stages: StageVec,
}

impl CascadePlan {
    /// Plan from an explicit stage list (converted to inline storage when
    /// it fits; the dedicated [`CascadePlan::single`] /
    /// [`CascadePlan::pair`] / [`CascadePlan::triple`] constructors never
    /// touch the heap at all).
    pub fn new(stages: Vec<Stage>) -> Self {
        CascadePlan { stages: StageVec::from(stages) }
    }

    /// The one-stage plan `[model]`.
    pub fn single(model: usize) -> Self {
        CascadePlan {
            stages: StageVec::from_slice(&[Stage { model, threshold: 0.0 }]),
        }
    }

    /// The two-stage plan `[a(τ) → b]` (allocation-free).
    pub fn pair(a: usize, tau: f32, b: usize) -> Self {
        CascadePlan {
            stages: StageVec::from_slice(&[
                Stage { model: a, threshold: tau },
                Stage { model: b, threshold: 0.0 },
            ]),
        }
    }

    /// The three-stage plan `[a(τ_a) → b(τ_b) → c]` (allocation-free).
    pub fn triple(a: usize, tau_a: f32, b: usize, tau_b: f32, c: usize) -> Self {
        CascadePlan {
            stages: StageVec::from_slice(&[
                Stage { model: a, threshold: tau_a },
                Stage { model: b, threshold: tau_b },
                Stage { model: c, threshold: 0.0 },
            ]),
        }
    }

    /// Number of stages.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// Whether the plan has no stages (constructors uphold non-emptiness;
    /// only a hand-built plan can be empty).
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// JSON form via `util::json` (frontier persistence, swap logs).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert(
            "stages".to_string(),
            Value::Arr(self.stages.iter().map(Stage::to_value).collect()),
        );
        Value::Obj(m)
    }

    /// Parse a plan serialized by [`CascadePlan::to_value`]. Rejects empty
    /// stage lists (every constructor path upholds non-emptiness).
    pub fn from_value(v: &Value) -> Result<CascadePlan> {
        let stages: Vec<Stage> = v
            .get("stages")
            .as_arr()
            .context("plan missing `stages`")?
            .iter()
            .map(Stage::from_value)
            .collect::<Result<_>>()?;
        if stages.is_empty() {
            bail!("serialized cascade plan has no stages");
        }
        Ok(CascadePlan::new(stages))
    }

    /// Human-readable form, e.g. `gpt_j(τ=0.96) → j1_large(τ=0.37) → gpt4`.
    pub fn describe(&self, names: &[String]) -> String {
        let mut parts = Vec::new();
        for (i, s) in self.stages.iter().enumerate() {
            let name = names.get(s.model).map(|s| s.as_str()).unwrap_or("?");
            if i + 1 == self.stages.len() {
                parts.push(name.to_string());
            } else {
                parts.push(format!("{name}(τ={:.2})", s.threshold));
            }
        }
        parts.join(" → ")
    }
}

/// Offline evaluation of a plan over a response table.
pub mod replay {
    use super::*;

    /// Outcome of replaying one item through the cascade.
    #[derive(Debug, Clone, Copy)]
    pub struct ItemOutcome {
        /// The accepted answer class.
        pub answer: u32,
        /// Whether the accepted answer matches the item's label.
        pub correct: bool,
        /// Stage index that answered (0-based).
        pub stopped_at: usize,
        /// USD spent on this item (all invoked stages).
        pub cost: f64,
    }

    /// Aggregate result of a replay. For a weighted table (decay windows,
    /// `responses.rs` §Weights) `accuracy` and `avg_cost` are the weighted
    /// means `Σ wᵢ·xᵢ / Σ wᵢ` — the same aggregates the optimizer's sweeps
    /// report — while `stop_frac`/`invoke_frac` stay raw query fractions
    /// (they describe traffic routing, not the learning objective).
    #[derive(Debug, Clone)]
    pub struct ReplaySummary {
        /// (Weighted) fraction of items answered correctly.
        pub accuracy: f64,
        /// (Weighted) average USD per query.
        pub avg_cost: f64,
        /// Fraction of queries answered at each stage.
        pub stop_frac: Vec<f64>,
        /// Fraction of queries for which each stage was *invoked*.
        pub invoke_frac: Vec<f64>,
    }

    /// Replay item `i` of `table` through `plan`. `input_tokens[i]` is the
    /// billable prompt size of item `i` (same for every model by layout).
    pub fn replay_item(
        plan: &CascadePlan,
        table: &SplitTable,
        costs: &CostModel,
        input_tokens: &[u32],
        i: usize,
    ) -> ItemOutcome {
        let mut cost = 0.0;
        let last = plan.stages.len() - 1;
        for (s, stage) in plan.stages.iter().enumerate() {
            let m = stage.model;
            let answer = table.pred(m, i);
            cost += costs.call_cost(m, input_tokens[i], answer);
            if s == last || table.score(m, i) > stage.threshold {
                return ItemOutcome {
                    answer,
                    correct: table.is_correct(m, i),
                    stopped_at: s,
                    cost,
                };
            }
        }
        unreachable!("cascade plans are non-empty");
    }

    /// Replay the whole table; the workhorse behind every offline report.
    pub fn replay(
        plan: &CascadePlan,
        table: &SplitTable,
        costs: &CostModel,
        input_tokens: &[u32],
    ) -> ReplaySummary {
        assert!(!plan.is_empty(), "empty cascade plan");
        assert_eq!(input_tokens.len(), table.len());
        let n = table.len();
        let mut w_correct = 0.0f64;
        let mut total_cost = 0.0f64;
        let mut stops = vec![0usize; plan.stages.len()];
        for i in 0..n {
            let o = replay_item(plan, table, costs, input_tokens, i);
            let w = table.weight(i);
            if o.correct {
                w_correct += w;
            }
            total_cost += w * o.cost;
            stops[o.stopped_at] += 1;
        }
        let mut invoked = vec![0usize; plan.stages.len()];
        let mut carried = n;
        for (s, &st) in stops.iter().enumerate() {
            invoked[s] = carried;
            carried -= st;
        }
        // total_weight() is n for unweighted tables and > 0 whenever the
        // table is non-empty (weights are validated strictly positive).
        let denom = if n == 0 { 1.0 } else { table.total_weight() };
        ReplaySummary {
            accuracy: w_correct / denom,
            avg_cost: total_cost / denom,
            stop_frac: stops.iter().map(|&s| s as f64 / n.max(1) as f64).collect(),
            invoke_frac: invoked.iter().map(|&s| s as f64 / n.max(1) as f64).collect(),
        }
    }
}

/// One stage result computed *outside* the cascade — a speculative probe
/// (`strategies::speculate`) that already invoked, billed, and scored a
/// model before the cascade ran. Passed into
/// [`Cascade::answer_billed_seeded`], which reuses the result for the
/// matching plan stage instead of re-invoking (and re-billing) it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageSeed {
    /// Marketplace index of the model that produced the answer.
    pub model: usize,
    /// The answer class it produced.
    pub answer: u32,
    /// Reliability score `g(q, a)` already measured for it.
    pub score: f32,
    /// USD already incurred by the probe call; the seeded stage bills
    /// exactly this (once), never a fresh call.
    pub cost_usd: f64,
    /// Simulated API latency the probe already incurred (ms). Seeded
    /// stages contribute 0 to the cascade's latency sum — the probe ran
    /// concurrently with the pipeline, so the caller accounts it as a
    /// `max`, not a sum.
    pub latency_ms: f64,
}

/// Claim the first unconsumed seed for `model`, if any (each seed feeds
/// at most one plan stage, so a duplicated model bills its second stage
/// normally).
fn take_seed<'a>(
    seeds: &'a [StageSeed],
    used: &mut [bool],
    model: usize,
) -> Option<&'a StageSeed> {
    for (i, seed) in seeds.iter().enumerate() {
        if !used[i] && seed.model == model {
            used[i] = true;
            return Some(seed);
        }
    }
    None
}

/// Result of answering one live query.
#[derive(Debug, Clone)]
pub struct CascadeAnswer {
    /// The accepted answer class.
    pub answer: u32,
    /// Stage that produced the accepted answer.
    pub stopped_at: usize,
    /// Reliability score of the accepted answer (1.0 if last stage).
    pub score: f32,
    /// Whether `score` is the always-answers sentinel 1.0 rather than a
    /// scorer measurement. Depth alone can no longer tell the two apart:
    /// a degraded fallback answers terminally from a non-final stage, and
    /// raw scorer logits may legitimately exceed 1.0.
    pub sentinel_score: bool,
    /// Metered USD across all invoked stages.
    pub cost: f64,
    /// USD per invoked stage (`stage_costs[s]` = stage s alone;
    /// `stage_costs.iter().sum() == cost`). Lets the serving metrics
    /// attribute spend to each model window exactly.
    pub stage_costs: Vec<f64>,
    /// Marketplace model behind each entry of `stage_costs` (same length,
    /// same order). With health-aware skipping the invoked stages are no
    /// longer a plan prefix, so metrics must attribute spend through this
    /// list instead of indexing the plan by position.
    pub invoked_models: Vec<usize>,
    /// Plan stage indices that did NOT contribute to this answer: their
    /// breaker was open, or the call failed after bounded retries. Empty
    /// on the healthy path.
    pub skipped_stages: Vec<usize>,
    /// Billable input tokens of the query prompt.
    pub input_tokens: u32,
    /// Per-stage simulated API latency (ms), for serving reports.
    pub simulated_latency_ms: f64,
}

/// Live cascade: executes the learned plan against real AOT artifacts.
pub struct Cascade {
    plan: CascadePlan,
    engine: EngineHandle,
    scorer: Scorer,
    costs: CostModel,
    meta: DatasetMeta,
    dataset: String,
    /// Optional per-model availability layer; `None` = strict mode (an
    /// engine error bubbles out, the pre-health behavior).
    health: Option<Arc<dyn HealthView>>,
}

impl Cascade {
    /// Bind a plan to an engine + scorer + cost model (validates every
    /// stage's model index against the marketplace).
    pub fn new(
        plan: CascadePlan,
        engine: EngineHandle,
        scorer: Scorer,
        costs: CostModel,
        meta: DatasetMeta,
    ) -> Result<Self> {
        if plan.is_empty() {
            bail!("cascade plan must have at least one stage");
        }
        for s in &plan.stages {
            if s.model >= costs.n_models() {
                bail!("stage model index {} out of range", s.model);
            }
        }
        let dataset = meta.name.clone();
        Ok(Cascade { plan, engine, scorer, costs, meta, dataset, health: None })
    }

    /// Attach (or detach) a per-model health layer. With health on, the
    /// cascade *skips* stages whose breaker is open, retries transient
    /// failures with the layer's bounded backoff, and degrades to the
    /// strongest answer it can produce instead of erroring.
    pub fn with_health(mut self, health: Option<Arc<dyn HealthView>>) -> Self {
        self.health = health;
        self
    }

    /// The plan this cascade executes.
    pub fn plan(&self) -> &CascadePlan {
        &self.plan
    }

    /// Dataset geometry of the queries this cascade answers.
    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    /// Handle to the engine actor the stages execute on.
    pub fn engine_handle(&self) -> EngineHandle {
        self.engine.clone()
    }

    /// The cost model metering each stage invocation.
    pub fn costs(&self) -> &CostModel {
        &self.costs
    }

    /// Answer one query (a full token row in the dataset layout).
    ///
    /// Every stage performs TWO PJRT executions: the stage's LLM artifact
    /// (argmax over class logits = the "generation") and, unless it is the
    /// final stage, the scorer artifact on `[query; answer]`.
    pub fn answer(&self, tokens: &[i32]) -> Result<CascadeAnswer> {
        self.answer_billed(tokens, prompt::input_tokens(tokens))
    }

    /// [`Cascade::answer`] with an explicit billable input-token count.
    /// Execution is identical; only cost metering (and the simulated API
    /// latency model) uses `input_tokens`. This is the hook for
    /// concatenation-amortized billing (`strategies::concat`): a query
    /// that shares its few-shot prompt with a group is billed
    /// `prompt/g + query` tokens instead of the full row.
    pub fn answer_billed(&self, tokens: &[i32], input_tokens: u32) -> Result<CascadeAnswer> {
        self.answer_billed_seeded(tokens, input_tokens, &[])
    }

    /// [`Cascade::answer_billed`] with speculative probe results attached:
    /// a plan stage whose model has an unconsumed [`StageSeed`] reuses the
    /// seed's answer, score, and already-metered cost instead of invoking
    /// the engine again — the never-re-bill contract of the speculative
    /// stage. With `seeds` empty this is bit-identical to
    /// [`Cascade::answer_billed`].
    pub fn answer_billed_seeded(
        &self,
        tokens: &[i32],
        input_tokens: u32,
        seeds: &[StageSeed],
    ) -> Result<CascadeAnswer> {
        match &self.health {
            None => self.answer_strict(tokens, input_tokens, seeds),
            Some(h) => self.answer_resilient(h.as_ref(), tokens, input_tokens, seeds),
        }
    }

    /// The pre-health execution loop: any engine error bubbles out.
    fn answer_strict(
        &self,
        tokens: &[i32],
        input_tokens: u32,
        seeds: &[StageSeed],
    ) -> Result<CascadeAnswer> {
        let mut cost = 0.0;
        let mut stage_costs = Vec::with_capacity(self.plan.stages.len());
        let mut invoked_models = Vec::with_capacity(self.plan.stages.len());
        let mut seed_used = vec![false; seeds.len()];
        let mut sim_lat = 0.0;
        let last = self.plan.stages.len() - 1;
        for (s, stage) in self.plan.stages.iter().enumerate() {
            let (answer, stage_cost, seeded_score) =
                match take_seed(seeds, &mut seed_used, stage.model) {
                    Some(seed) => (seed.answer, seed.cost_usd, Some(seed.score)),
                    None => {
                        let name = &self.costs.model_names[stage.model];
                        let logits = self
                            .engine
                            .execute(&self.dataset, name, tokens.to_vec())
                            .with_context(|| format!("stage {s} ({name})"))?;
                        let answer = argmax(&logits) as u32;
                        let stage_cost =
                            self.costs.call_cost(stage.model, input_tokens, answer);
                        let out_tokens = self.costs.answer_len(answer);
                        sim_lat += self.costs.latency[stage.model]
                            .latency_ms(input_tokens + out_tokens);
                        (answer, stage_cost, None)
                    }
                };
            cost += stage_cost;
            stage_costs.push(stage_cost);
            invoked_models.push(stage.model);
            if s == last {
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: s,
                    score: 1.0,
                    sentinel_score: true,
                    cost,
                    stage_costs,
                    invoked_models,
                    skipped_stages: Vec::new(),
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
            let score = match seeded_score {
                Some(sc) => sc,
                None => self.scorer.score(tokens, answer)?,
            };
            if score > stage.threshold {
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: s,
                    score,
                    sentinel_score: false,
                    cost,
                    stage_costs,
                    invoked_models,
                    skipped_stages: Vec::new(),
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
        }
        unreachable!()
    }

    /// Health-aware execution: open-breaker stages are skipped, engine
    /// failures are retried (bounded) and then skipped, and when the
    /// terminal stage cannot answer the cascade degrades — strongest
    /// skipped stage that has recovered, else the best sub-threshold
    /// answer already in hand, else one breaker-bypassing attempt at the
    /// strongest stage. An `Err` escapes only when *no* stage can produce
    /// an answer at all (skip-never-error).
    fn answer_resilient(
        &self,
        health: &dyn HealthView,
        tokens: &[i32],
        input_tokens: u32,
        seeds: &[StageSeed],
    ) -> Result<CascadeAnswer> {
        let mut cost = 0.0;
        let mut stage_costs = Vec::with_capacity(self.plan.stages.len());
        let mut invoked_models = Vec::with_capacity(self.plan.stages.len());
        let mut seed_used = vec![false; seeds.len()];
        let mut skipped: Vec<usize> = Vec::new();
        let mut gate_skipped: Vec<usize> = Vec::new();
        let mut sim_lat = 0.0;
        // Strongest successful sub-threshold (answer, score, stage): the
        // degraded fallback when nothing downstream can answer.
        let mut best_effort: Option<(u32, f32, usize)> = None;
        let mut attempted_any = false;
        let last = self.plan.stages.len() - 1;

        for (s, stage) in self.plan.stages.iter().enumerate() {
            // A seeded stage needs no gate and no call: the answer is
            // already in hand (the probe's success/failure already fed
            // the breaker when it ran).
            let seed = take_seed(seeds, &mut seed_used, stage.model);
            if seed.is_none() && health.admit(stage.model) == Gate::Skip {
                skipped.push(s);
                gate_skipped.push(s);
                continue;
            }
            attempted_any = true;
            let (answer, stage_cost, seeded_score) = match seed {
                Some(seed) => (seed.answer, seed.cost_usd, Some(seed.score)),
                None => {
                    let Some(logits) = self.try_stage(health, stage.model, tokens) else {
                        // failed after bounded retries — degrade to the
                        // next stage
                        skipped.push(s);
                        continue;
                    };
                    let answer = argmax(&logits) as u32;
                    let stage_cost =
                        self.costs.call_cost(stage.model, input_tokens, answer);
                    let out_tokens = self.costs.answer_len(answer);
                    sim_lat += self.costs.latency[stage.model]
                        .latency_ms(input_tokens + out_tokens);
                    (answer, stage_cost, None)
                }
            };
            cost += stage_cost;
            stage_costs.push(stage_cost);
            invoked_models.push(stage.model);
            if s == last {
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: s,
                    score: 1.0,
                    sentinel_score: true,
                    cost,
                    stage_costs,
                    invoked_models,
                    skipped_stages: skipped,
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
            let score = match seeded_score {
                Some(sc) => sc,
                None => self.scorer.score(tokens, answer)?,
            };
            if score > stage.threshold {
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: s,
                    score,
                    sentinel_score: false,
                    cost,
                    stage_costs,
                    invoked_models,
                    skipped_stages: skipped,
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
            best_effort = Some((answer, score, s));
        }

        // The terminal stage was skipped or failed. Fall back to the
        // strongest breaker-skipped stage the health layer lets through
        // now (a half-open probe, typically); it answers terminally.
        for &s in gate_skipped.iter().rev() {
            let stage = &self.plan.stages[s];
            if health.admit(stage.model) == Gate::Skip {
                continue;
            }
            if let Some(logits) = self.try_stage(health, stage.model, tokens) {
                let answer = argmax(&logits) as u32;
                let stage_cost = self.costs.call_cost(stage.model, input_tokens, answer);
                cost += stage_cost;
                stage_costs.push(stage_cost);
                invoked_models.push(stage.model);
                let out_tokens = self.costs.answer_len(answer);
                sim_lat += self.costs.latency[stage.model]
                    .latency_ms(input_tokens + out_tokens);
                skipped.retain(|&x| x != s);
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: s,
                    score: 1.0,
                    sentinel_score: true,
                    cost,
                    stage_costs,
                    invoked_models,
                    skipped_stages: skipped,
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
        }

        // Serve the best sub-threshold answer we already paid for.
        if let Some((answer, score, s)) = best_effort {
            return Ok(CascadeAnswer {
                answer,
                stopped_at: s,
                score,
                sentinel_score: false,
                cost,
                stage_costs,
                invoked_models,
                skipped_stages: skipped,
                input_tokens,
                simulated_latency_ms: sim_lat,
            });
        }

        // Every stage was breaker-skipped and nothing was even attempted:
        // one last-resort attempt at the strongest stage, bypassing the
        // breaker — a skip decision alone must never surface as an error.
        if !attempted_any {
            let stage = &self.plan.stages[last];
            if let Some(logits) = self.try_stage(health, stage.model, tokens) {
                let answer = argmax(&logits) as u32;
                let stage_cost = self.costs.call_cost(stage.model, input_tokens, answer);
                cost += stage_cost;
                stage_costs.push(stage_cost);
                invoked_models.push(stage.model);
                let out_tokens = self.costs.answer_len(answer);
                sim_lat += self.costs.latency[stage.model]
                    .latency_ms(input_tokens + out_tokens);
                skipped.retain(|&x| x != last);
                return Ok(CascadeAnswer {
                    answer,
                    stopped_at: last,
                    score: 1.0,
                    sentinel_score: true,
                    cost,
                    stage_costs,
                    invoked_models,
                    skipped_stages: skipped,
                    input_tokens,
                    simulated_latency_ms: sim_lat,
                });
            }
        }

        bail!(
            "cascade unavailable: all {} stages failed or are circuit-open",
            self.plan.stages.len()
        )
    }

    /// One health-gated engine call with bounded, deterministically
    /// jittered retry. Outcomes feed the breaker; a definitive failure
    /// returns `None` (the caller skips the stage) instead of erroring.
    fn try_stage(
        &self,
        health: &dyn HealthView,
        model: usize,
        tokens: &[i32],
    ) -> Option<Vec<f32>> {
        let name = &self.costs.model_names[model];
        let mut attempt = 0u32;
        loop {
            match self.engine.execute(&self.dataset, name, tokens.to_vec()) {
                Ok(logits) => {
                    health.record(model, true);
                    return Some(logits);
                }
                Err(_) => {
                    health.record(model, false);
                    if attempt >= health.max_retries() {
                        return None;
                    }
                    attempt += 1;
                    let backoff_us = health.retry_backoff_us(model, attempt);
                    if backoff_us > 0 {
                        std::thread::sleep(std::time::Duration::from_micros(backoff_us));
                    }
                }
            }
        }
    }
}

/// Index of the maximum logit (ties → first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::responses::synthetic_table;

    fn setup() -> (SplitTable, CostModel, Vec<u32>) {
        let t = synthetic_table(12, 2000, 4, 0.9, 42);
        let cm = CostModel::from_table1("synthetic", vec![1, 1, 2, 1]);
        let toks = vec![125u32; t.len()];
        (t, cm, toks)
    }

    #[test]
    fn single_stage_replay_matches_model_accuracy() {
        let (t, cm, toks) = setup();
        for m in [0, 5, 11] {
            let plan = CascadePlan::single(m);
            let r = replay::replay(&plan, &t, &cm, &toks);
            assert!((r.accuracy - t.accuracy(m)).abs() < 1e-12);
            assert_eq!(r.stop_frac, vec![1.0]);
        }
    }

    #[test]
    fn threshold_zero_always_stops_at_first_stage_with_positive_scores() {
        let (t, cm, toks) = setup();
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.0 },
            Stage { model: 11, threshold: 0.0 },
        ]);
        let r = replay::replay(&plan, &t, &cm, &toks);
        // synthetic scores are in (0,1], so all stop at stage 0.
        assert!(r.stop_frac[0] > 0.999);
        assert!((r.accuracy - t.accuracy(0)).abs() < 0.01);
    }

    #[test]
    fn threshold_one_always_escalates() {
        let (t, cm, toks) = setup();
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 1.1 },
            Stage { model: 11, threshold: 0.0 },
        ]);
        let r = replay::replay(&plan, &t, &cm, &toks);
        assert_eq!(r.stop_frac[0], 0.0);
        assert!((r.accuracy - t.accuracy(11)).abs() < 1e-12);
        // cost includes BOTH stages for every query.
        let c0 = replay::replay(&CascadePlan::single(0), &t, &cm, &toks).avg_cost;
        let c11 = replay::replay(&CascadePlan::single(11), &t, &cm, &toks).avg_cost;
        assert!((r.avg_cost - (c0 + c11)).abs() < 1e-9);
    }

    #[test]
    fn cost_is_monotone_in_threshold() {
        let (t, cm, toks) = setup();
        let mut prev = 0.0;
        for th in [0.0f32, 0.3, 0.6, 0.9, 1.01] {
            let plan = CascadePlan::new(vec![
                Stage { model: 2, threshold: th },
                Stage { model: 11, threshold: 0.0 },
            ]);
            let r = replay::replay(&plan, &t, &cm, &toks);
            assert!(r.avg_cost >= prev - 1e-12, "cost must grow with τ");
            prev = r.avg_cost;
        }
    }

    #[test]
    fn well_calibrated_cascade_beats_first_stage_accuracy() {
        let (t, cm, toks) = setup();
        // cheap weak model 0 gated at a high threshold, strong model 11 behind.
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.75 },
            Stage { model: 11, threshold: 0.0 },
        ]);
        let r = replay::replay(&plan, &t, &cm, &toks);
        assert!(r.accuracy > t.accuracy(0) + 0.05);
    }

    #[test]
    fn describe_is_readable() {
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.96 },
            Stage { model: 1, threshold: 0.37 },
            Stage { model: 2, threshold: 0.0 },
        ]);
        let names: Vec<String> =
            ["gpt_j", "j1_large", "gpt4"].iter().map(|s| s.to_string()).collect();
        assert_eq!(plan.describe(&names), "gpt_j(τ=0.96) → j1_large(τ=0.37) → gpt4");
    }

    #[test]
    fn plan_json_roundtrip_is_bit_exact() {
        let plan = CascadePlan::new(vec![
            Stage { model: 9, threshold: 0.1 + 0.2 }, // not exactly representable
            Stage { model: 0, threshold: -1.0 },      // "never accepts" sentinel
            Stage { model: 11, threshold: 0.0 },
        ]);
        let json = plan.to_value().to_json();
        let back = CascadePlan::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.stages.len(), plan.stages.len());
        for (a, b) in plan.stages.iter().zip(&back.stages) {
            assert_eq!(a.model, b.model);
            assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
        }
    }

    #[test]
    fn plan_from_value_rejects_garbage() {
        for bad in [
            r#"{}"#,
            r#"{"stages": []}"#,
            r#"{"stages": [{"model": 1}]}"#,
            r#"{"stages": [{"threshold": 0.5}]}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(CascadePlan::from_value(&v).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn stage_vec_inline_and_spill_behave_like_a_slice() {
        let mk = |m: usize| Stage { model: m, threshold: m as f32 * 0.1 };
        for n in [1usize, 2, 3, 4, 5, 7] {
            let stages: Vec<Stage> = (0..n).map(mk).collect();
            let sv = StageVec::from(stages.clone());
            assert_eq!(sv.len(), n);
            assert_eq!(&sv[..], &stages[..]);
            assert_eq!(sv.last(), stages.last());
            assert_eq!(sv.iter().count(), n);
            assert_eq!((&sv).into_iter().count(), n);
            // collected and converted forms agree
            let collected: StageVec = stages.iter().copied().collect();
            assert_eq!(collected, sv);
            // plans longer than the inline capacity round-trip through
            // JSON (the spill path)
            let plan = CascadePlan::new(stages.clone());
            let back =
                CascadePlan::from_value(&Value::parse(&plan.to_value().to_json()).unwrap())
                    .unwrap();
            assert_eq!(back, plan);
        }
        // the dedicated constructors match the Vec-built equivalents
        assert_eq!(
            CascadePlan::pair(1, 0.5, 2),
            CascadePlan::new(vec![
                Stage { model: 1, threshold: 0.5 },
                Stage { model: 2, threshold: 0.0 },
            ])
        );
        assert_eq!(
            CascadePlan::triple(0, 0.9, 1, 0.4, 2),
            CascadePlan::new(vec![
                Stage { model: 0, threshold: 0.9 },
                Stage { model: 1, threshold: 0.4 },
                Stage { model: 2, threshold: 0.0 },
            ])
        );
    }

    #[test]
    fn argmax_ties_and_order() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[2.0, 2.0]), 0);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    mod resilient {
        use super::*;
        use crate::data::layout;
        use crate::marketplace::{LatencyModel, Pricing};
        use crate::runtime::EngineHandle;
        use crate::server::health::{HealthConfig, ModelHealth};
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        fn meta() -> DatasetMeta {
            DatasetMeta {
                name: "sim".into(),
                seq: 8,
                n_classes: 4,
                n_examples: 0,
                qlen: 4,
                block_len: 1,
                q_offset: 0,
                scorer_seq: 8,
                answer_lens: vec![1, 1, 1, 1],
            }
        }

        fn costs() -> CostModel {
            CostModel {
                dataset: "sim".into(),
                model_names: vec!["m0".into(), "m1".into()],
                pricing: vec![Pricing::new(2.0, 2.0, 0.0), Pricing::new(30.0, 30.0, 0.0)],
                latency: vec![LatencyModel { base_ms: 1.0, per_1k_tokens_ms: 1.0 }; 2],
                answer_lens: vec![1, 1, 1, 1],
            }
        }

        fn row() -> Vec<i32> {
            vec![layout::CLS, 5, 11, 12, 13, layout::QSEP, layout::PAD, layout::PAD]
        }

        /// m0 answers class 0, m1 answers class 1 unless `down`; the
        /// scorer logit is low, so every stage-0 answer stays below any
        /// positive threshold and the cascade must escalate.
        fn engine(down_m1: Arc<AtomicBool>) -> EngineHandle {
            EngineHandle::simulated(move |_ds, model, rows| {
                rows.iter()
                    .map(|_| match model {
                        "scorer" => Ok(vec![-4.0f32]),
                        "m0" => Ok(vec![1.0, 0.0, 0.0, 0.0]),
                        "m1" => {
                            if down_m1.load(Ordering::Relaxed) {
                                anyhow::bail!("simulated outage: m1 is down")
                            }
                            Ok(vec![0.0, 1.0, 0.0, 0.0])
                        }
                        other => anyhow::bail!("unknown model {other}"),
                    })
                    .collect()
            })
        }

        fn health() -> Arc<ModelHealth> {
            Arc::new(ModelHealth::new(
                2,
                HealthConfig {
                    trip_consecutive: 2,
                    cooldown: 3,
                    max_retries: 1,
                    backoff_base_us: 0, // hermetic: no sleeping
                    ..Default::default()
                },
            ))
        }

        fn cascade(down_m1: Arc<AtomicBool>, h: Option<Arc<ModelHealth>>) -> Cascade {
            let e = engine(down_m1);
            Cascade::new(
                CascadePlan::pair(0, 2.0, 1), // τ=2.0: stage 0 never accepts
                e.clone(),
                Scorer::new(e, meta()),
                costs(),
                meta(),
            )
            .unwrap()
            .with_health(h.map(|h| h as Arc<dyn HealthView>))
        }

        #[test]
        fn strict_mode_errors_when_the_terminal_stage_is_down() {
            let c = cascade(Arc::new(AtomicBool::new(true)), None);
            let err = c.answer(&row()).unwrap_err();
            assert!(format!("{err:#}").contains("m1"), "{err:#}");
        }

        #[test]
        fn terminal_outage_degrades_to_best_effort_instead_of_erroring() {
            let c = cascade(Arc::new(AtomicBool::new(true)), Some(health()));
            let a = c.answer(&row()).expect("skip-never-error");
            // the degraded answer is stage 0's sub-threshold answer
            assert_eq!(a.answer, 0);
            assert_eq!(a.stopped_at, 0);
            assert!(a.score < 1.0, "a best-effort answer keeps its measured score");
            assert!(!a.sentinel_score, "a best-effort score is a real measurement");
            assert_eq!(a.skipped_stages, vec![1]);
            assert_eq!(a.invoked_models, vec![0]);
            assert_eq!(a.stage_costs.len(), 1);
            assert!((a.stage_costs.iter().sum::<f64>() - a.cost).abs() < 1e-12);
        }

        #[test]
        fn breaker_opens_under_outage_and_recloses_after_recovery() {
            let down = Arc::new(AtomicBool::new(true));
            let h = health();
            let c = cascade(down.clone(), Some(h.clone()));
            // Outage: every answer degrades, never errors; the m1 breaker
            // trips after trip_consecutive failures.
            for _ in 0..8 {
                let a = c.answer(&row()).expect("skip-never-error");
                assert_eq!(a.answer, 0);
                assert!(!a.skipped_stages.is_empty());
            }
            let snap = &h.snapshot()[1];
            assert!(snap.trips >= 1, "m1 breaker never tripped: {snap:?}");
            assert!(snap.skips >= 1);
            // Recovery: the next half-open probe succeeds, the breaker
            // closes, and terminal answers flow again.
            down.store(false, Ordering::Relaxed);
            let mut terminal_again = false;
            for _ in 0..16 {
                let a = c.answer(&row()).expect("answer");
                if a.stopped_at == 1 && a.skipped_stages.is_empty() {
                    terminal_again = true;
                    break;
                }
            }
            assert!(terminal_again, "cascade never returned to the terminal stage");
            assert!(h.snapshot()[1].recoveries >= 1);
            // healthy steady state: no more skips
            let a = c.answer(&row()).unwrap();
            assert_eq!(a.stopped_at, 1);
            assert_eq!(a.invoked_models, vec![0, 1]);
            assert!(a.skipped_stages.is_empty());
            assert!(a.sentinel_score, "terminal answers carry the sentinel 1.0");
        }

        #[test]
        fn all_breakers_open_still_attempts_the_strongest_stage() {
            let h = health();
            // trip BOTH breakers by hand
            for _ in 0..4 {
                use crate::coordinator::cascade::HealthView;
                h.record(0, false);
                h.record(1, false);
            }
            let c = cascade(Arc::new(AtomicBool::new(false)), Some(h));
            // both stages gate-skip, but the last-resort bypass still
            // produces the strongest stage's answer
            let a = c.answer(&row()).expect("skip-never-error");
            assert_eq!(a.answer, 1);
            assert_eq!(a.stopped_at, 1);
        }

        #[test]
        fn seeded_stage_is_reused_not_re_invoked() {
            // m1 down, no health: invoking m1 would error — but a seed
            // for stage 0 that clears τ answers before m1 is reached,
            // and the seeded stage itself must not call the engine (the
            // plan's τ=2.0 would otherwise force escalation into m1).
            let c = cascade(Arc::new(AtomicBool::new(true)), None);
            let seed = StageSeed {
                model: 0,
                answer: 3,
                score: 5.0, // clears τ=2.0
                cost_usd: 0.123,
                latency_ms: 9.0,
            };
            let a = c
                .answer_billed_seeded(&row(), 8, &[seed])
                .expect("seed answers before the outage");
            assert_eq!(a.answer, 3, "the seed's answer, not the engine's");
            assert_eq!(a.stopped_at, 0);
            assert_eq!(a.score.to_bits(), 5.0f32.to_bits());
            assert_eq!(a.cost.to_bits(), 0.123f64.to_bits(), "billed once, at probe price");
            assert_eq!(a.stage_costs, vec![0.123]);
            assert_eq!(a.invoked_models, vec![0]);
            // the probe's latency is the caller's to account (concurrent)
            assert_eq!(a.simulated_latency_ms, 0.0);
        }

        #[test]
        fn sub_threshold_seed_escalates_and_bills_each_stage_once() {
            let c = cascade(Arc::new(AtomicBool::new(false)), None);
            let seed = StageSeed {
                model: 0,
                answer: 0,
                score: 0.5, // under τ=2.0 → escalate to m1
                cost_usd: 0.2,
                latency_ms: 4.0,
            };
            let a = c.answer_billed_seeded(&row(), 8, &[seed]).unwrap();
            assert_eq!(a.answer, 1, "m1 answers terminally");
            assert_eq!(a.stopped_at, 1);
            let m1_cost = c.costs().call_cost(1, 8, 1);
            assert_eq!(a.stage_costs.len(), 2);
            assert_eq!(a.stage_costs[0].to_bits(), 0.2f64.to_bits());
            assert_eq!(a.stage_costs[1].to_bits(), m1_cost.to_bits());
            assert_eq!(a.cost.to_bits(), (0.2 + m1_cost).to_bits());
            // only m1's latency is summed — the seed ran concurrently
            let m1_lat = c.costs().latency[1].latency_ms(8 + 1);
            assert_eq!(a.simulated_latency_ms.to_bits(), m1_lat.to_bits());
        }

        #[test]
        fn empty_seeds_are_bit_identical_to_answer_billed() {
            let c = cascade(Arc::new(AtomicBool::new(false)), Some(health()));
            let a = c.answer_billed(&row(), 8).unwrap();
            let b = c.answer_billed_seeded(&row(), 8, &[]).unwrap();
            assert_eq!(a.answer, b.answer);
            assert_eq!(a.stopped_at, b.stopped_at);
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            assert_eq!(a.score.to_bits(), b.score.to_bits());
            assert_eq!(a.simulated_latency_ms.to_bits(), b.simulated_latency_ms.to_bits());
            assert_eq!(a.invoked_models, b.invoked_models);
        }

        #[test]
        fn seed_bypasses_an_open_breaker() {
            // Trip m0's breaker: without a seed the stage would gate-skip;
            // with one, the already-answered result is served.
            let h = health();
            for _ in 0..4 {
                use crate::coordinator::cascade::HealthView;
                h.record(0, false);
            }
            let c = cascade(Arc::new(AtomicBool::new(false)), Some(h));
            let seed = StageSeed {
                model: 0,
                answer: 2,
                score: 5.0,
                cost_usd: 0.05,
                latency_ms: 1.0,
            };
            let a = c.answer_billed_seeded(&row(), 8, &[seed]).unwrap();
            assert_eq!(a.answer, 2);
            assert_eq!(a.stopped_at, 0);
            assert!(a.skipped_stages.is_empty(), "a seeded stage is not a skip");
        }
    }
}
