//! Offline response tables.
//!
//! `make artifacts` computes, for every dataset item and every simulated
//! API, the API's answer and the reliability scorer's score, and writes
//! them to `artifacts/responses/<dataset>.json`. The cascade optimizer is
//! a pure function of this table plus the cost model — exactly the paper's
//! setting, where the cascade is trained once on labelled examples.
//!
//! The Rust runtime independently re-verifies a sample of the table by
//! executing the AOT artifacts through PJRT (see `rust/tests/`), proving
//! the HLO artifacts and the python training path agree.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// Responses of all APIs on one split, in model-major dense arrays.
#[derive(Debug, Clone)]
pub struct SplitTable {
    pub dataset: String,
    pub model_names: Vec<String>,
    pub labels: Vec<u32>,
    /// `preds[m][i]`: model m's answer class on item i.
    pub preds: Vec<Vec<u32>>,
    /// `scores[m][i]`: scorer reliability of (query i, model m's answer).
    pub scores: Vec<Vec<f32>>,
    /// `correct[m][i]`.
    pub correct: Vec<Vec<bool>>,
}

impl SplitTable {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn n_models(&self) -> usize {
        self.model_names.len()
    }

    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.model_names.iter().position(|n| n == name)
    }

    /// Accuracy of a single model.
    pub fn accuracy(&self, m: usize) -> f64 {
        let n = self.len().max(1);
        self.correct[m].iter().filter(|&&c| c).count() as f64 / n as f64
    }

    /// Restrict the table to the first `n` items (coarse optimizer pass).
    pub fn head(&self, n: usize) -> SplitTable {
        let n = n.min(self.len());
        SplitTable {
            dataset: self.dataset.clone(),
            model_names: self.model_names.clone(),
            labels: self.labels[..n].to_vec(),
            preds: self.preds.iter().map(|v| v[..n].to_vec()).collect(),
            scores: self.scores.iter().map(|v| v[..n].to_vec()).collect(),
            correct: self.correct.iter().map(|v| v[..n].to_vec()).collect(),
        }
    }

    fn from_value(dataset: &str, names: &[String], raw: &Value) -> Result<Self> {
        let labels: Vec<u32> = raw
            .get("labels")
            .as_arr()
            .context("labels not an array")?
            .iter()
            .map(|x| x.as_u32().unwrap_or(0))
            .collect();
        let n = labels.len();
        let models = raw.get("models");
        let mut preds = Vec::new();
        let mut scores = Vec::new();
        let mut correct = Vec::new();
        for name in names {
            let m = models.get(name);
            if m.as_obj().is_none() {
                bail!("model {name} missing from split");
            }
            let pred: Vec<u32> = m
                .get("pred")
                .as_arr()
                .context("pred not array")?
                .iter()
                .map(|x| x.as_u32().unwrap_or(0))
                .collect();
            let score: Vec<f32> = m
                .get("score")
                .as_arr()
                .context("score not array")?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                .collect();
            let corr: Vec<bool> = m
                .get("correct")
                .as_arr()
                .context("correct not array")?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) != 0.0)
                .collect();
            if pred.len() != n || score.len() != n || corr.len() != n {
                bail!("model {name}: ragged response arrays");
            }
            preds.push(pred);
            scores.push(score);
            correct.push(corr);
        }
        Ok(SplitTable {
            dataset: dataset.to_string(),
            model_names: names.to_vec(),
            labels,
            preds,
            scores,
            correct,
        })
    }
}

/// Train + test response tables for one dataset.
#[derive(Debug, Clone)]
pub struct ResponseTable {
    pub dataset: String,
    pub train: SplitTable,
    pub test: SplitTable,
}

impl ResponseTable {
    pub fn from_file(path: &Path) -> Result<Self> {
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("reading response table {}", path.display()))?;
        Self::from_json(&raw)
    }

    pub fn from_json(raw: &str) -> Result<Self> {
        let v = Value::parse(raw).map_err(|e| anyhow!("{e}"))?;
        let dataset = v
            .get("dataset")
            .as_str()
            .context("missing dataset name")?
            .to_string();
        let names: Vec<String> = v
            .get("models")
            .as_arr()
            .context("models not an array")?
            .iter()
            .map(|x| x.as_str().unwrap_or("").to_string())
            .collect();
        let splits = v.get("splits");
        let train = splits.get("train");
        let test = splits.get("test");
        if train.as_obj().is_none() || test.as_obj().is_none() {
            bail!("missing train/test split");
        }
        Ok(ResponseTable {
            dataset: dataset.clone(),
            train: SplitTable::from_value(&dataset, &names, train)?,
            test: SplitTable::from_value(&dataset, &names, test)?,
        })
    }
}

/// Deterministic synthetic table for unit tests and benches (no artifacts
/// needed): `n_models` APIs with accuracy spread and a scorer whose score
/// correlates with correctness at strength `calibration`.
pub fn synthetic_table(
    n_models: usize,
    n_items: usize,
    n_classes: u32,
    calibration: f64,
    seed: u64,
) -> SplitTable {
    let mut rng = crate::util::rng::Rng::new(seed);
    let labels: Vec<u32> =
        (0..n_items).map(|_| rng.below(n_classes as u64) as u32).collect();
    let mut preds = Vec::new();
    let mut scores = Vec::new();
    let mut correct = Vec::new();
    for m in 0..n_models {
        let acc = 0.5 + 0.45 * (m as f64 / (n_models.max(2) - 1) as f64);
        let mut p = Vec::with_capacity(n_items);
        let mut s = Vec::with_capacity(n_items);
        let mut c = Vec::with_capacity(n_items);
        for i in 0..n_items {
            let ok = rng.bool(acc);
            let pred = if ok {
                labels[i]
            } else {
                (labels[i] + 1 + rng.below(n_classes.max(2) as u64 - 1) as u32)
                    % n_classes
            };
            let base: f64 = rng.f64();
            let score = if ok {
                calibration * (0.5 + 0.5 * base) + (1.0 - calibration) * base
            } else {
                calibration * 0.5 * base + (1.0 - calibration) * base
            };
            p.push(pred);
            s.push(score as f32);
            c.push(ok);
        }
        preds.push(p);
        scores.push(s);
        correct.push(c);
    }
    SplitTable {
        dataset: "synthetic".into(),
        model_names: (0..n_models).map(|m| format!("api_{m}")).collect(),
        labels,
        preds,
        scores,
        correct,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let json = r#"{
            "dataset": "toy", "models": ["a", "b"],
            "splits": {
                "train": {"labels": [0,1], "models": {
                    "a": {"pred": [0,0], "score": [0.9,0.2], "correct": [1,0]},
                    "b": {"pred": [0,1], "score": [0.8,0.7], "correct": [1,1]}}},
                "test": {"labels": [1], "models": {
                    "a": {"pred": [1], "score": [0.5], "correct": [1]},
                    "b": {"pred": [0], "score": [0.4], "correct": [0]}}}
            }}"#;
        let t = ResponseTable::from_json(json).unwrap();
        assert_eq!(t.train.len(), 2);
        assert_eq!(t.test.len(), 1);
        assert_eq!(t.train.accuracy(0), 0.5);
        assert_eq!(t.train.accuracy(1), 1.0);
        assert_eq!(t.test.model_index("b"), Some(1));
    }

    #[test]
    fn synthetic_accuracy_is_monotone_in_model_index() {
        let t = synthetic_table(6, 4000, 4, 0.9, 1);
        for m in 1..6 {
            assert!(
                t.accuracy(m) > t.accuracy(m - 1) - 0.05,
                "model {m} should be no worse than {}",
                m - 1
            );
        }
        assert!(t.accuracy(5) > t.accuracy(0) + 0.2);
    }

    #[test]
    fn synthetic_scores_are_calibrated() {
        let t = synthetic_table(3, 4000, 4, 0.9, 2);
        for m in 0..3 {
            let (mut sc, mut nc, mut si, mut ni) = (0.0, 0, 0.0, 0);
            for i in 0..t.len() {
                if t.correct[m][i] {
                    sc += t.scores[m][i] as f64;
                    nc += 1;
                } else {
                    si += t.scores[m][i] as f64;
                    ni += 1;
                }
            }
            assert!(sc / nc as f64 > si / ni.max(1) as f64 + 0.1);
        }
    }

    #[test]
    fn head_truncates_consistently() {
        let t = synthetic_table(3, 100, 4, 0.9, 3);
        let h = t.head(10);
        assert_eq!(h.len(), 10);
        assert_eq!(h.preds[2][9], t.preds[2][9]);
        assert_eq!(h.n_models(), 3);
    }
}
