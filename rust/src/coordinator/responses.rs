//! Offline response tables.
//!
//! `make artifacts` computes, for every dataset item and every simulated
//! API, the API's answer and the reliability scorer's score, and writes
//! them to `artifacts/responses/<dataset>.json`. The cascade optimizer is
//! a pure function of this table plus the cost model — exactly the paper's
//! setting, where the cascade is trained once on labelled examples.
//!
//! The Rust runtime independently re-verifies a sample of the table by
//! executing the AOT artifacts through PJRT (see `rust/tests/`), proving
//! the HLO artifacts and the python training path agree.
//!
//! §Perf: all per-(model, item) data lives in *flat model-major arenas*
//! (one contiguous allocation per field, stride = `len()`), not
//! `Vec<Vec<_>>`. The optimizer's inner loops run over `*_row(m)` slices,
//! which the compiler can bounds-check once per loop instead of once per
//! element, and adjacent items share cache lines. Field access goes
//! through accessors so the layout can keep evolving.
//!
//! §Bitset: correctness is stored *word-packed* — 64 items per `u64`,
//! stride [`SplitTable::words_per_row`] words per model, tail bits of the
//! last word always zero. Point reads go through [`SplitTable::is_correct`]
//! (a shift + mask); whole-row consumers ([`SplitTable::accuracy`], the
//! optimizer's disagreement matrix and sweep totals, `eval::mpi`) read
//! [`SplitTable::correct_words_row`] and run word-at-a-time with
//! popcounts. At the K=12 × N=8000 bench workload this shrinks the
//! correctness arena 8x vs one byte per (model, item) — and 64x vs the
//! f64 arena the weighted path needs — so the sweep's working set stays
//! cache-resident. Packing is an implementation detail of this module:
//! ingest ([`ModelRow`], [`TableBuilder`]) still speaks `bool`s.
//!
//! §Weights: a table may carry optional *per-item observation weights*
//! ([`SplitTable::with_weights`] / [`TableBuilder::push_item_weighted`]).
//! The serving-time observation window uses them for exponential decay
//! (recent traffic counts more — cf. budget-constrained cascade policy
//! learning, Zhang et al. 2024); the optimizer and `replay` then compute
//! *weighted* accuracy `Σ wᵢ·correctᵢ / Σ wᵢ` and cost `Σ wᵢ·costᵢ / Σ wᵢ`.
//! An unweighted table behaves exactly as weight 1.0 per item: every
//! aggregate is accumulated so that uniform power-of-two weights reproduce
//! the unweighted numbers **bit-for-bit** (property-tested).

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// Responses of all APIs on one split, in flat model-major dense arenas.
#[derive(Debug, Clone)]
pub struct SplitTable {
    /// Dataset the responses were computed on.
    pub dataset: String,
    /// Marketplace model names (row order of every arena).
    pub model_names: Vec<String>,
    /// Ground-truth answer class per item.
    pub labels: Vec<u32>,
    /// Items per model (row stride of the flat arenas below).
    n: usize,
    /// `u64` words per model row of the packed `correct` arena
    /// (`n.div_ceil(64)`).
    words: usize,
    /// `preds[m * n + i]`: model m's answer class on item i.
    preds: Vec<u32>,
    /// `scores[m * n + i]`: scorer reliability of (query i, model m's answer).
    scores: Vec<f32>,
    /// Word-packed correctness: bit `i % 64` of word `m * words + i / 64`
    /// is set iff model m answers item i correctly. Tail bits (≥ `n` in
    /// the last word of each row) are always zero, so popcounts over rows
    /// need no masking.
    correct: Vec<u64>,
    /// Optional per-item observation weights (`None` = uniform 1.0).
    weights: Option<Vec<f64>>,
    /// `Σᵢ weightᵢ` in index order (`n` as f64 when uniform), cached so
    /// weighted denominators are O(1) and deterministic.
    total_weight: f64,
}

impl SplitTable {
    /// Build from per-model rows (validates that all rows have the same
    /// length as `labels`).
    pub fn from_rows(
        dataset: String,
        model_names: Vec<String>,
        labels: Vec<u32>,
        rows: Vec<ModelRow>,
    ) -> Result<Self> {
        let n = labels.len();
        if rows.len() != model_names.len() {
            bail!("{} model rows for {} model names", rows.len(), model_names.len());
        }
        let k = rows.len();
        let words = n.div_ceil(64);
        let mut preds = Vec::with_capacity(k * n);
        let mut scores = Vec::with_capacity(k * n);
        let mut correct = vec![0u64; k * words];
        for (m, (row, name)) in rows.into_iter().zip(&model_names).enumerate() {
            if row.pred.len() != n || row.score.len() != n || row.correct.len() != n {
                bail!("model {name}: ragged response arrays");
            }
            preds.extend_from_slice(&row.pred);
            scores.extend_from_slice(&row.score);
            pack_bools(&row.correct, &mut correct[m * words..(m + 1) * words]);
        }
        Ok(SplitTable {
            dataset,
            model_names,
            labels,
            n,
            words,
            preds,
            scores,
            correct,
            weights: None,
            total_weight: n as f64,
        })
    }

    /// Attach per-item observation weights (decay windows). Every weight
    /// must be finite and strictly positive — a zero weight would make an
    /// item invisible to the optimizer while still occupying a row, and
    /// negative weights break the Pareto accounting outright.
    pub fn with_weights(mut self, weights: Vec<f64>) -> Result<Self> {
        if weights.len() != self.n {
            bail!("{} weights for {} items", weights.len(), self.n);
        }
        let mut total = 0.0;
        for (i, &w) in weights.iter().enumerate() {
            if !w.is_finite() || w <= 0.0 {
                bail!("item {i}: weight {w} is not finite and positive");
            }
            total += w;
        }
        self.total_weight = total;
        self.weights = Some(weights);
        Ok(self)
    }

    /// Observation weight of item i (1.0 when the table is unweighted).
    #[inline(always)]
    pub fn weight(&self, i: usize) -> f64 {
        match &self.weights {
            Some(w) => w[i],
            None => 1.0,
        }
    }

    /// The weight row, if this table is weighted.
    #[inline]
    pub fn weights(&self) -> Option<&[f64]> {
        self.weights.as_deref()
    }

    /// Whether the table carries per-item observation weights.
    pub fn is_weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// `Σᵢ weightᵢ` (= `len()` for unweighted tables).
    #[inline]
    pub fn total_weight(&self) -> f64 {
        self.total_weight
    }

    /// Items per model.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the table holds no items.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of marketplace models covered.
    pub fn n_models(&self) -> usize {
        self.model_names.len()
    }

    /// Row index of a model by name.
    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.model_names.iter().position(|n| n == name)
    }

    /// Model m's answer class on item i.
    #[inline(always)]
    pub fn pred(&self, m: usize, i: usize) -> u32 {
        self.preds[m * self.n + i]
    }

    /// Reliability score of (query i, model m's answer).
    #[inline(always)]
    pub fn score(&self, m: usize, i: usize) -> f32 {
        self.scores[m * self.n + i]
    }

    /// Whether model m answers item i correctly (one shift + mask into the
    /// packed bitset).
    #[inline(always)]
    pub fn is_correct(&self, m: usize, i: usize) -> bool {
        debug_assert!(i < self.n);
        (self.correct[m * self.words + (i >> 6)] >> (i & 63)) & 1 == 1
    }

    /// All of model m's answer classes (len = `len()`).
    #[inline]
    pub fn preds_row(&self, m: usize) -> &[u32] {
        &self.preds[m * self.n..(m + 1) * self.n]
    }

    /// All of model m's reliability scores (len = `len()`).
    #[inline]
    pub fn scores_row(&self, m: usize) -> &[f32] {
        &self.scores[m * self.n..(m + 1) * self.n]
    }

    /// Model m's packed correctness row: [`SplitTable::words_per_row`]
    /// `u64` words, bit `i % 64` of word `i / 64` = item i, tail bits
    /// zero. The substrate for every popcount fast path (optimizer
    /// sweeps, `eval::mpi`).
    #[inline]
    pub fn correct_words_row(&self, m: usize) -> &[u64] {
        &self.correct[m * self.words..(m + 1) * self.words]
    }

    /// `u64` words per packed correctness row (`len().div_ceil(64)`).
    #[inline]
    pub fn words_per_row(&self) -> usize {
        self.words
    }

    /// Model m's correctness as a materialized `Vec<bool>` (tests and
    /// cold paths; hot paths use [`SplitTable::correct_words_row`]).
    pub fn correct_row_vec(&self, m: usize) -> Vec<bool> {
        (0..self.n).map(|i| self.is_correct(m, i)).collect()
    }

    /// (Weighted) accuracy of a single model: `Σᵢ wᵢ·correctᵢ / Σᵢ wᵢ`.
    /// Unweighted tables popcount the packed row (word-at-a-time); the
    /// count is an exact small integer, so the result is bit-identical to
    /// a per-item scan.
    pub fn accuracy(&self, m: usize) -> f64 {
        match &self.weights {
            None => {
                let n = self.n.max(1);
                let ones: u64 = self
                    .correct_words_row(m)
                    .iter()
                    .map(|w| u64::from(w.count_ones()))
                    .sum();
                ones as f64 / n as f64
            }
            Some(w) => {
                let mut s = 0.0;
                for (i, &wi) in w.iter().enumerate() {
                    if self.is_correct(m, i) {
                        s += wi;
                    }
                }
                s / self.total_weight
            }
        }
    }

    /// Restrict the table to the first `n` items (coarse optimizer pass).
    pub fn head(&self, n: usize) -> SplitTable {
        let n = n.min(self.n);
        self.slice(0, n)
    }

    /// Restrict the table to the *last* `n` items. Decay-weighted window
    /// snapshots are ordered oldest → newest, so the suffix is the
    /// highest-weight (most recent) slice — the right subsample for a
    /// coarse pass over such a table, where `head` would pick exactly the
    /// stale rows the decay de-emphasizes.
    pub fn tail(&self, n: usize) -> SplitTable {
        let n = n.min(self.n);
        self.slice(self.n - n, n)
    }

    /// Rebuild a table from the item range `start..start + n` of every
    /// arena (the one place the per-field layout is copied — keep any
    /// future layout change here). The packed correctness rows are
    /// re-based with [`extract_bit_range`], so an unaligned `start`
    /// shifts bits across word boundaries rather than re-packing per item.
    fn slice(&self, start: usize, n: usize) -> SplitTable {
        let end = start + n;
        let k = self.n_models();
        let words = n.div_ceil(64);
        let mut preds = Vec::with_capacity(k * n);
        let mut scores = Vec::with_capacity(k * n);
        let mut correct = vec![0u64; k * words];
        for m in 0..k {
            preds.extend_from_slice(&self.preds_row(m)[start..end]);
            scores.extend_from_slice(&self.scores_row(m)[start..end]);
            extract_bit_range(
                self.correct_words_row(m),
                start,
                n,
                &mut correct[m * words..(m + 1) * words],
            );
        }
        let weights = self.weights.as_ref().map(|w| w[start..end].to_vec());
        let total_weight = match &weights {
            Some(w) => w.iter().sum(),
            None => n as f64,
        };
        SplitTable {
            dataset: self.dataset.clone(),
            model_names: self.model_names.clone(),
            labels: self.labels[start..end].to_vec(),
            n,
            words,
            preds,
            scores,
            correct,
            weights,
            total_weight,
        }
    }

    fn from_value(dataset: &str, names: &[String], raw: &Value) -> Result<Self> {
        let labels: Vec<u32> = raw
            .get("labels")
            .as_arr()
            .context("labels not an array")?
            .iter()
            .map(|x| x.as_u32().unwrap_or(0))
            .collect();
        let models = raw.get("models");
        let mut rows = Vec::with_capacity(names.len());
        for name in names {
            let m = models.get(name);
            if m.as_obj().is_none() {
                bail!("model {name} missing from split");
            }
            let pred: Vec<u32> = m
                .get("pred")
                .as_arr()
                .context("pred not array")?
                .iter()
                .map(|x| x.as_u32().unwrap_or(0))
                .collect();
            let score: Vec<f32> = m
                .get("score")
                .as_arr()
                .context("score not array")?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                .collect();
            let correct: Vec<bool> = m
                .get("correct")
                .as_arr()
                .context("correct not array")?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) != 0.0)
                .collect();
            rows.push(ModelRow { pred, score, correct });
        }
        SplitTable::from_rows(dataset.to_string(), names.to_vec(), labels, rows)
    }
}

/// Pack a bool row into `u64` words (bit `i % 64` of word `i / 64`).
/// `out` must hold exactly `bools.len().div_ceil(64)` zeroed words; tail
/// bits stay zero by construction.
fn pack_bools(bools: &[bool], out: &mut [u64]) {
    debug_assert_eq!(out.len(), bools.len().div_ceil(64));
    for (i, &b) in bools.iter().enumerate() {
        if b {
            out[i >> 6] |= 1u64 << (i & 63);
        }
    }
}

/// Copy the bit range `start..start + len` of a packed row into `dst`
/// (re-based to bit 0, `len.div_ceil(64)` words, tail bits cleared).
/// Handles unaligned `start` by stitching each destination word from two
/// adjacent source words.
fn extract_bit_range(src: &[u64], start: usize, len: usize, dst: &mut [u64]) {
    let out_words = len.div_ceil(64);
    debug_assert_eq!(dst.len(), out_words);
    let w0 = start >> 6;
    let shift = start & 63;
    for (dw, d) in dst.iter_mut().enumerate() {
        let lo = src.get(w0 + dw).copied().unwrap_or(0) >> shift;
        let hi = if shift == 0 {
            0
        } else {
            // The complementary top bits of the next source word; shift is
            // in 1..=63 here, so `64 - shift` never overflows.
            src.get(w0 + dw + 1).copied().unwrap_or(0) << (64 - shift)
        };
        *d = lo | hi;
    }
    let tail = len & 63;
    if tail != 0 {
        dst[out_words - 1] &= (1u64 << tail) - 1;
    }
}

/// One model's responses over a split, used to assemble a [`SplitTable`].
#[derive(Debug, Clone, Default)]
pub struct ModelRow {
    /// Answer class per item.
    pub pred: Vec<u32>,
    /// Reliability score per item.
    pub score: Vec<f32>,
    /// Whether the answer was correct, per item.
    pub correct: Vec<bool>,
}

/// Incremental *item-major* table builder: push one labelled item at a
/// time with every model's (pred, score, correct) triple, then `finish()`.
///
/// This is the write path of the serving-time observation window
/// (`server::metrics::ObservationWindow`): traffic arrives item by item,
/// but the optimizer consumes model-major arenas — the builder does the
/// transpose so the reoptimizer can hand a fresh window slice straight to
/// `CascadeOptimizer::new`.
#[derive(Debug, Clone)]
pub struct TableBuilder {
    dataset: String,
    model_names: Vec<String>,
    labels: Vec<u32>,
    rows: Vec<ModelRow>,
    weights: Vec<f64>,
    /// Whether any push supplied an explicit weight; a builder fed only
    /// through [`TableBuilder::push_item`] finishes into an unweighted
    /// table (uniform explicit weights would behave identically anyway —
    /// that equivalence is property-tested — but `None` keeps the common
    /// path allocation-free).
    weighted: bool,
}

impl TableBuilder {
    /// An empty builder covering `model_names`.
    pub fn new(dataset: impl Into<String>, model_names: Vec<String>) -> Self {
        let k = model_names.len();
        TableBuilder {
            dataset: dataset.into(),
            model_names,
            labels: Vec::new(),
            rows: vec![ModelRow::default(); k],
            weights: Vec::new(),
            weighted: false,
        }
    }

    /// Append one item: `preds[m]`/`scores[m]`/`correct[m]` are model m's
    /// response on it. All three slices must cover every model.
    pub fn push_item(
        &mut self,
        label: u32,
        preds: &[u32],
        scores: &[f32],
        correct: &[bool],
    ) -> Result<()> {
        self.push_row(label, preds, scores, correct, 1.0)
    }

    /// [`TableBuilder::push_item`] with an explicit observation weight
    /// (finite, > 0). The finished table carries the weights and the
    /// optimizer computes weighted accuracy/cost aggregates from them.
    pub fn push_item_weighted(
        &mut self,
        label: u32,
        preds: &[u32],
        scores: &[f32],
        correct: &[bool],
        weight: f64,
    ) -> Result<()> {
        if !weight.is_finite() || weight <= 0.0 {
            bail!("observation weight {weight} is not finite and positive");
        }
        self.push_row(label, preds, scores, correct, weight)?;
        self.weighted = true;
        Ok(())
    }

    fn push_row(
        &mut self,
        label: u32,
        preds: &[u32],
        scores: &[f32],
        correct: &[bool],
        weight: f64,
    ) -> Result<()> {
        let k = self.rows.len();
        if preds.len() != k || scores.len() != k || correct.len() != k {
            bail!(
                "observation covers {}/{}/{} models, table has {k}",
                preds.len(),
                scores.len(),
                correct.len()
            );
        }
        self.labels.push(label);
        self.weights.push(weight);
        for (m, row) in self.rows.iter_mut().enumerate() {
            row.pred.push(preds[m]);
            row.score.push(scores[m]);
            row.correct.push(correct[m]);
        }
        Ok(())
    }

    /// Items pushed so far.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether nothing has been pushed yet.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Transpose the pushed items into a model-major [`SplitTable`]
    /// (weighted iff any push carried an explicit weight).
    pub fn finish(self) -> Result<SplitTable> {
        let table =
            SplitTable::from_rows(self.dataset, self.model_names, self.labels, self.rows)?;
        if self.weighted {
            table.with_weights(self.weights)
        } else {
            Ok(table)
        }
    }
}

/// Train + test response tables for one dataset.
#[derive(Debug, Clone)]
pub struct ResponseTable {
    /// Dataset name (matches both splits).
    pub dataset: String,
    /// The training split (what the optimizer learns on).
    pub train: SplitTable,
    /// The held-out test split (what reports evaluate on).
    pub test: SplitTable,
}

impl ResponseTable {
    /// Read + parse `artifacts/responses/<dataset>.json`.
    pub fn from_file(path: &Path) -> Result<Self> {
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("reading response table {}", path.display()))?;
        Self::from_json(&raw)
    }

    /// Parse the response-table JSON document.
    pub fn from_json(raw: &str) -> Result<Self> {
        let v = Value::parse(raw).map_err(|e| anyhow!("{e}"))?;
        let dataset = v
            .get("dataset")
            .as_str()
            .context("missing dataset name")?
            .to_string();
        let names: Vec<String> = v
            .get("models")
            .as_arr()
            .context("models not an array")?
            .iter()
            .map(|x| x.as_str().unwrap_or("").to_string())
            .collect();
        let splits = v.get("splits");
        let train = splits.get("train");
        let test = splits.get("test");
        if train.as_obj().is_none() || test.as_obj().is_none() {
            bail!("missing train/test split");
        }
        Ok(ResponseTable {
            dataset: dataset.clone(),
            train: SplitTable::from_value(&dataset, &names, train)?,
            test: SplitTable::from_value(&dataset, &names, test)?,
        })
    }
}

/// Deterministic synthetic table for unit tests and benches (no artifacts
/// needed): `n_models` APIs with accuracy spread and a scorer whose score
/// correlates with correctness at strength `calibration`.
pub fn synthetic_table(
    n_models: usize,
    n_items: usize,
    n_classes: u32,
    calibration: f64,
    seed: u64,
) -> SplitTable {
    let mut rng = crate::util::rng::Rng::new(seed);
    let labels: Vec<u32> =
        (0..n_items).map(|_| rng.below(n_classes as u64) as u32).collect();
    let mut rows = Vec::with_capacity(n_models);
    for m in 0..n_models {
        let acc = 0.5 + 0.45 * (m as f64 / (n_models.max(2) - 1) as f64);
        let mut row = ModelRow::default();
        for i in 0..n_items {
            let ok = rng.bool(acc);
            let pred = if ok {
                labels[i]
            } else {
                (labels[i] + 1 + rng.below(n_classes.max(2) as u64 - 1) as u32)
                    % n_classes
            };
            let base: f64 = rng.f64();
            let score = if ok {
                calibration * (0.5 + 0.5 * base) + (1.0 - calibration) * base
            } else {
                calibration * 0.5 * base + (1.0 - calibration) * base
            };
            row.pred.push(pred);
            row.score.push(score as f32);
            row.correct.push(ok);
        }
        rows.push(row);
    }
    SplitTable::from_rows(
        "synthetic".into(),
        (0..n_models).map(|m| format!("api_{m}")).collect(),
        labels,
        rows,
    )
    .expect("synthetic rows are rectangular")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let json = r#"{
            "dataset": "toy", "models": ["a", "b"],
            "splits": {
                "train": {"labels": [0,1], "models": {
                    "a": {"pred": [0,0], "score": [0.9,0.2], "correct": [1,0]},
                    "b": {"pred": [0,1], "score": [0.8,0.7], "correct": [1,1]}}},
                "test": {"labels": [1], "models": {
                    "a": {"pred": [1], "score": [0.5], "correct": [1]},
                    "b": {"pred": [0], "score": [0.4], "correct": [0]}}}
            }}"#;
        let t = ResponseTable::from_json(json).unwrap();
        assert_eq!(t.train.len(), 2);
        assert_eq!(t.test.len(), 1);
        assert_eq!(t.train.accuracy(0), 0.5);
        assert_eq!(t.train.accuracy(1), 1.0);
        assert_eq!(t.test.model_index("b"), Some(1));
        assert_eq!(t.train.pred(1, 1), 1);
        assert!((t.train.score(0, 0) - 0.9).abs() < 1e-6);
        assert!(t.train.is_correct(1, 0));
    }

    #[test]
    fn rows_and_scalars_agree() {
        let t = synthetic_table(4, 64, 4, 0.9, 9);
        for m in 0..4 {
            assert_eq!(t.preds_row(m).len(), 64);
            assert_eq!(t.correct_words_row(m).len(), 1);
            for i in (0..64).step_by(7) {
                assert_eq!(t.preds_row(m)[i], t.pred(m, i));
                assert_eq!(t.scores_row(m)[i], t.score(m, i));
                assert_eq!(t.correct_row_vec(m)[i], t.is_correct(m, i));
            }
        }
    }

    #[test]
    fn packed_bits_match_pushed_bools_including_tail_words() {
        // 100 items: the second word of each row has 36 tail bits that
        // must stay zero so popcount paths need no masking.
        for n in [1usize, 63, 64, 65, 100, 128, 129] {
            let t = synthetic_table(3, n, 4, 0.9, 17);
            assert_eq!(t.words_per_row(), n.div_ceil(64));
            for m in 0..3 {
                let row = t.correct_words_row(m);
                let naive = t.correct_row_vec(m);
                assert_eq!(naive.len(), n);
                // every bit round-trips
                for (i, &c) in naive.iter().enumerate() {
                    assert_eq!((row[i >> 6] >> (i & 63)) & 1 == 1, c);
                }
                // tail bits beyond n are zero
                let tail = n & 63;
                if tail != 0 {
                    assert_eq!(row[row.len() - 1] >> tail, 0, "n={n} m={m}");
                }
                // popcount accuracy == naive count
                let ones: u64 =
                    row.iter().map(|w| u64::from(w.count_ones())).sum();
                assert_eq!(ones as usize, naive.iter().filter(|&&c| c).count());
                assert_eq!(t.accuracy(m), ones as f64 / n as f64);
            }
        }
    }

    #[test]
    fn slice_extracts_unaligned_bit_ranges() {
        // start=90 crosses a word boundary with shift 26; every bit of the
        // sliced table must match the source, and tails must be masked.
        let t = synthetic_table(3, 200, 4, 0.9, 23);
        for (start, n) in [(90usize, 70usize), (0, 64), (64, 64), (1, 199), (190, 10)] {
            let s = t.slice(start, n);
            assert_eq!(s.len(), n);
            assert_eq!(s.words_per_row(), n.div_ceil(64));
            for m in 0..3 {
                for i in 0..n {
                    assert_eq!(
                        s.is_correct(m, i),
                        t.is_correct(m, start + i),
                        "start={start} n={n} m={m} i={i}"
                    );
                }
                let tail = n & 63;
                if tail != 0 {
                    let row = s.correct_words_row(m);
                    assert_eq!(row[row.len() - 1] >> tail, 0);
                }
            }
        }
    }

    #[test]
    fn ragged_rows_rejected() {
        let r = SplitTable::from_rows(
            "x".into(),
            vec!["a".into()],
            vec![0, 1],
            vec![ModelRow { pred: vec![0], score: vec![0.5], correct: vec![true] }],
        );
        assert!(r.is_err());
    }

    #[test]
    fn synthetic_accuracy_is_monotone_in_model_index() {
        let t = synthetic_table(6, 4000, 4, 0.9, 1);
        for m in 1..6 {
            assert!(
                t.accuracy(m) > t.accuracy(m - 1) - 0.05,
                "model {m} should be no worse than {}",
                m - 1
            );
        }
        assert!(t.accuracy(5) > t.accuracy(0) + 0.2);
    }

    #[test]
    fn synthetic_scores_are_calibrated() {
        let t = synthetic_table(3, 4000, 4, 0.9, 2);
        for m in 0..3 {
            let (mut sc, mut nc, mut si, mut ni) = (0.0, 0, 0.0, 0);
            for i in 0..t.len() {
                if t.is_correct(m, i) {
                    sc += t.score(m, i) as f64;
                    nc += 1;
                } else {
                    si += t.score(m, i) as f64;
                    ni += 1;
                }
            }
            assert!(sc / nc as f64 > si / ni.max(1) as f64 + 0.1);
        }
    }

    #[test]
    fn table_builder_transposes_item_major_pushes() {
        let t = synthetic_table(3, 20, 4, 0.9, 5);
        let mut b = TableBuilder::new("synthetic", t.model_names.clone());
        for i in 0..t.len() {
            let preds: Vec<u32> = (0..3).map(|m| t.pred(m, i)).collect();
            let scores: Vec<f32> = (0..3).map(|m| t.score(m, i)).collect();
            let correct: Vec<bool> = (0..3).map(|m| t.is_correct(m, i)).collect();
            b.push_item(t.labels[i], &preds, &scores, &correct).unwrap();
        }
        assert_eq!(b.len(), t.len());
        let built = b.finish().unwrap();
        for m in 0..3 {
            assert_eq!(built.preds_row(m), t.preds_row(m));
            assert_eq!(built.scores_row(m), t.scores_row(m));
            assert_eq!(built.correct_words_row(m), t.correct_words_row(m));
        }
        assert_eq!(built.labels, t.labels);
    }

    #[test]
    fn table_builder_rejects_short_observations() {
        let mut b = TableBuilder::new("x", vec!["a".into(), "b".into()]);
        assert!(b.push_item(0, &[1], &[0.5, 0.5], &[true, false]).is_err());
        assert!(b.is_empty());
    }

    #[test]
    fn weighted_accuracy_and_totals() {
        let t = synthetic_table(2, 4, 2, 0.9, 1);
        // Make model 0 correct on exactly items 0 and 2.
        let mut b = TableBuilder::new("w", t.model_names.clone());
        for i in 0..4 {
            let correct = [i % 2 == 0, true];
            b.push_item_weighted(
                0,
                &[0, 0],
                &[0.5, 0.5],
                &correct,
                [4.0, 1.0, 2.0, 1.0][i],
            )
            .unwrap();
        }
        let w = b.finish().unwrap();
        assert!(w.is_weighted());
        assert_eq!(w.total_weight(), 8.0);
        assert_eq!(w.weight(0), 4.0);
        // model 0: weights of correct items = 4 + 2 = 6, of 8 total.
        assert!((w.accuracy(0) - 6.0 / 8.0).abs() < 1e-15);
        assert_eq!(w.accuracy(1), 1.0);
        // head keeps the weight prefix and recomputes the total
        let h = w.head(2);
        assert_eq!(h.total_weight(), 5.0);
        assert_eq!(h.weights().unwrap(), &[4.0, 1.0]);
    }

    #[test]
    fn unweighted_builder_stays_unweighted() {
        let mut b = TableBuilder::new("x", vec!["a".into()]);
        b.push_item(0, &[0], &[0.5], &[true]).unwrap();
        let t = b.finish().unwrap();
        assert!(!t.is_weighted());
        assert_eq!(t.weight(0), 1.0);
        assert_eq!(t.total_weight(), 1.0);
        assert!(t.weights().is_none());
    }

    #[test]
    fn bad_weights_rejected() {
        let t = synthetic_table(2, 3, 2, 0.9, 1);
        assert!(t.clone().with_weights(vec![1.0, 2.0]).is_err(), "length mismatch");
        assert!(t.clone().with_weights(vec![1.0, 0.0, 1.0]).is_err(), "zero weight");
        assert!(t.clone().with_weights(vec![1.0, -1.0, 1.0]).is_err(), "negative");
        assert!(t.clone().with_weights(vec![1.0, f64::NAN, 1.0]).is_err(), "nan");
        let mut b = TableBuilder::new("x", vec!["a".into()]);
        assert!(b.push_item_weighted(0, &[0], &[0.5], &[true], 0.0).is_err());
        assert!(b.is_empty(), "rejected weight must not partially push");
    }

    #[test]
    fn head_truncates_consistently() {
        let t = synthetic_table(3, 100, 4, 0.9, 3);
        let h = t.head(10);
        assert_eq!(h.len(), 10);
        assert_eq!(h.pred(2, 9), t.pred(2, 9));
        assert_eq!(h.scores_row(1), &t.scores_row(1)[..10]);
        assert_eq!(h.n_models(), 3);
    }

    #[test]
    fn tail_keeps_newest_suffix_and_weights() {
        let t = synthetic_table(3, 100, 4, 0.9, 3);
        let weights: Vec<f64> = (0..100).map(|i| 1.0 + i as f64).collect();
        let w = t.clone().with_weights(weights.clone()).unwrap();
        let s = w.tail(10);
        assert_eq!(s.len(), 10);
        assert_eq!(s.pred(2, 0), t.pred(2, 90));
        assert_eq!(s.scores_row(1), &t.scores_row(1)[90..]);
        assert_eq!(s.labels, &t.labels[90..]);
        assert_eq!(s.weights().unwrap(), &weights[90..]);
        assert_eq!(s.total_weight(), weights[90..].iter().sum::<f64>());
        // unweighted tail stays unweighted
        let u = t.tail(10);
        assert!(!u.is_weighted());
        assert_eq!(u.total_weight(), 10.0);
        assert_eq!(u.correct_row_vec(0), &t.correct_row_vec(0)[90..]);
    }
}
