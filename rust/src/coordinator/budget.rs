//! Serving-time budget tracking.
//!
//! The optimizer enforces the budget *in expectation* at training time; at
//! serving time the coordinator meters actual spend so operators can watch
//! it and (optionally) hard-stop or degrade when a cap is reached — the
//! "budget-aware LLM API usage" problem statement of paper §2.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lock-free accumulating budget tracker (f64 spend stored as bits).
#[derive(Debug)]
pub struct BudgetTracker {
    /// Total spend in nano-dollars (u64 keeps addition atomic & exact
    /// enough: 1 nUSD granularity, 18.4B USD range).
    spent_nano_usd: AtomicU64,
    queries: AtomicU64,
    /// Optional hard cap (nano-USD); 0 = unlimited.
    cap_nano_usd: u64,
}

/// Decision returned by [`BudgetTracker::admit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Spend is within budget.
    Ok,
    /// The cap is exhausted; the caller should degrade (e.g. cheapest
    /// model only) or reject.
    CapReached,
}

impl BudgetTracker {
    /// A tracker with an optional hard spend cap (USD).
    pub fn new(cap_usd: Option<f64>) -> Self {
        BudgetTracker {
            spent_nano_usd: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            cap_nano_usd: cap_usd.map(|c| (c * 1e9) as u64).unwrap_or(0),
        }
    }

    /// Record the cost of one answered query.
    pub fn record(&self, cost_usd: f64) {
        let nano = (cost_usd * 1e9).round().max(0.0) as u64;
        self.spent_nano_usd.fetch_add(nano, Ordering::Relaxed);
        self.queries.fetch_add(1, Ordering::Relaxed);
    }

    /// Check whether new work should be admitted at full quality.
    pub fn admit(&self) -> Admission {
        if self.cap_nano_usd == 0
            || self.spent_nano_usd.load(Ordering::Relaxed) < self.cap_nano_usd
        {
            Admission::Ok
        } else {
            Admission::CapReached
        }
    }

    /// Total metered spend so far (USD).
    pub fn spent_usd(&self) -> f64 {
        self.spent_nano_usd.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Queries recorded so far.
    pub fn queries(&self) -> u64 {
        self.queries.load(Ordering::Relaxed)
    }

    /// Mean spend per recorded query (0.0 before the first record).
    pub fn avg_cost_usd(&self) -> f64 {
        let q = self.queries();
        if q == 0 {
            0.0
        } else {
            self.spent_usd() / q as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_averages() {
        let b = BudgetTracker::new(None);
        b.record(0.001);
        b.record(0.003);
        assert_eq!(b.queries(), 2);
        assert!((b.spent_usd() - 0.004).abs() < 1e-9);
        assert!((b.avg_cost_usd() - 0.002).abs() < 1e-9);
        assert_eq!(b.admit(), Admission::Ok);
    }

    #[test]
    fn cap_trips() {
        let b = BudgetTracker::new(Some(0.005));
        assert_eq!(b.admit(), Admission::Ok);
        b.record(0.004);
        assert_eq!(b.admit(), Admission::Ok);
        b.record(0.002);
        assert_eq!(b.admit(), Admission::CapReached);
    }

    #[test]
    fn concurrent_records_are_exact() {
        use std::sync::Arc;
        let b = Arc::new(BudgetTracker::new(None));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    b.record(0.000001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(b.queries(), 8000);
        assert!((b.spent_usd() - 0.008).abs() < 1e-9);
    }
}
