//! The composed FrugalGPT service: completion cache → prompt adaptation →
//! LLM cascade, with budget metering and metrics (paper Fig. 1b: all
//! three cost-reduction strategies stacked in front of the marketplace).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use std::sync::Mutex;

use crate::coordinator::budget::{Admission, BudgetTracker};
use crate::coordinator::cascade::{Cascade, CascadeAnswer, CascadePlan};
use crate::coordinator::scorer::Scorer;
use crate::data::DatasetMeta;
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::server::metrics::ServiceMetrics;
use crate::strategies::cache::{CachedAnswer, CompletionCache};
use crate::strategies::prompt::PromptPolicy;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Master switch for the completion cache (Fig. 2c). Off = every query
    /// goes through the cascade (the "cascade only" ablation).
    pub cache_enabled: bool,
    pub cache_capacity: usize,
    /// Similarity threshold for the cache's MinHash tier (≥1.0 = exact only).
    pub cache_min_similarity: f64,
    pub prompt_policy: PromptPolicy,
    /// Optional hard budget cap (USD); when reached the service degrades
    /// to the first cascade stage only.
    pub budget_cap_usd: Option<f64>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cache_enabled: true,
            cache_capacity: 4096,
            cache_min_similarity: 1.0,
            prompt_policy: PromptPolicy::Full,
            budget_cap_usd: None,
        }
    }
}

/// The answer returned to a client.
#[derive(Debug, Clone)]
pub struct ServiceAnswer {
    pub answer: u32,
    pub from_cache: bool,
    pub stopped_at: usize,
    pub cost_usd: f64,
    pub latency_us: u64,
    pub simulated_api_latency_ms: f64,
}

/// A FrugalGPT serving instance for one dataset.
pub struct FrugalService {
    cascade: Cascade,
    /// Degraded mode (budget cap reached): cheapest stage only.
    degraded: Cascade,
    cache: Mutex<CompletionCache>,
    cfg: ServiceConfig,
    pub budget: BudgetTracker,
    pub metrics: Arc<ServiceMetrics>,
    meta: DatasetMeta,
}

impl FrugalService {
    pub fn new(
        plan: CascadePlan,
        engine: EngineHandle,
        costs: CostModel,
        meta: DatasetMeta,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        let scorer = Scorer::new(engine.clone(), meta.clone());
        let degrade_plan = CascadePlan::single(plan.stages[0].model);
        let degraded = Cascade::new(
            degrade_plan,
            engine.clone(),
            Scorer::new(engine.clone(), meta.clone()),
            costs.clone(),
            meta.clone(),
        )?;
        let cascade = Cascade::new(plan, engine, scorer, costs, meta.clone())?;
        Ok(FrugalService {
            cascade,
            degraded,
            cache: Mutex::new(CompletionCache::new(
                cfg.cache_capacity.max(1),
                cfg.cache_min_similarity,
            )),
            budget: BudgetTracker::new(cfg.budget_cap_usd),
            metrics: Arc::new(ServiceMetrics::default()),
            cfg,
            meta,
        })
    }

    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    pub fn plan(&self) -> &CascadePlan {
        self.cascade.plan()
    }

    /// Answer one query (blocking; wrap in `spawn_blocking` from tokio).
    pub fn answer(&self, tokens: &[i32]) -> Result<ServiceAnswer> {
        let t0 = Instant::now();
        self.metrics
            .queries
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);

        // 1. Completion cache (paper Fig. 2c).
        if self.cfg.cache_enabled {
            if let Some(hit) = self.cache.lock().unwrap().get(tokens) {
            self.metrics
                .cache_hits
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let lat = t0.elapsed().as_micros() as u64;
            self.metrics.latency.record_us(lat);
                return Ok(ServiceAnswer {
                    answer: hit.answer,
                    from_cache: true,
                    stopped_at: 0,
                    cost_usd: 0.0,
                    latency_us: lat,
                    simulated_api_latency_ms: 0.0,
                });
            }
        }

        // 2. Prompt adaptation (paper Fig. 2a).
        let adapted = self.cfg.prompt_policy.apply(tokens, &self.meta);

        // 3. LLM cascade (paper Fig. 2e), degraded if over budget.
        self.metrics
            .cascade_invocations
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let out: CascadeAnswer = if self.budget.admit() == Admission::CapReached {
            self.degraded.answer(&adapted)?
        } else {
            self.cascade.answer(&adapted)?
        };

        self.budget.record(out.cost_usd());
        if out.stopped_at < 3 {
            self.metrics.stopped_at[out.stopped_at]
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }

        // 4. Populate the cache.
        if self.cfg.cache_enabled {
            self.cache.lock().unwrap().put(
                tokens,
                CachedAnswer { answer: out.answer, score: out.score },
            );
        }

        let lat = t0.elapsed().as_micros() as u64;
        self.metrics.latency.record_us(lat);
        Ok(ServiceAnswer {
            answer: out.answer,
            from_cache: false,
            stopped_at: out.stopped_at,
            cost_usd: out.cost_usd(),
            latency_us: lat,
            simulated_api_latency_ms: out.simulated_latency_ms,
        })
    }

    pub fn engine_handle(&self) -> EngineHandle {
        self.cascade.engine_handle()
    }

    pub fn costs(&self) -> &CostModel {
        self.cascade.costs()
    }
}

impl CascadeAnswer {
    fn cost_usd(&self) -> f64 {
        self.cost
    }
}
