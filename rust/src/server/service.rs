//! The composed FrugalGPT service: completion cache → prompt adaptation →
//! LLM cascade, with budget metering and metrics (paper Fig. 1b: all
//! three cost-reduction strategies stacked in front of the marketplace).
//!
//! §Plan lifecycle — the served cascade is no longer a constructor-frozen
//! pair: the service routes every query through a [`PlanHandle`], an
//! atomically swappable `Arc` over an immutable [`PlanBundle`]
//! (plan + live cascade + degraded cascade, all built together).
//! `answer()` grabs one snapshot up front and uses only that bundle for
//! the whole query, so a concurrent swap can never mix stages, costs, or
//! models from two plans inside one answer. Publishers
//! (`swap_plan` / the `server::reoptimizer` loop) build the new bundle
//! *outside* the lock and swap a single pointer under a write lock held
//! for nanoseconds; readers clone the `Arc` under the read lock, so they
//! never wait on plan construction. Every publish is recorded as a
//! [`SwapEvent`] for the swap-history report.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::budget::{Admission, BudgetTracker};
use crate::coordinator::cascade::{Cascade, CascadeAnswer, CascadePlan};
use crate::coordinator::scorer::Scorer;
use crate::data::DatasetMeta;
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::server::metrics::{Observation, ServiceMetrics};
use crate::server::shadow::{Shadow, ShadowConfig, ShadowSnapshot};
use crate::strategies::cache::{CachedAnswer, CompletionCache};
use crate::strategies::prompt::PromptPolicy;
use crate::util::json::Value;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Master switch for the completion cache (Fig. 2c). Off = every query
    /// goes through the cascade (the "cascade only" ablation).
    pub cache_enabled: bool,
    /// Entries the completion cache retains (LRU beyond this).
    pub cache_capacity: usize,
    /// Similarity threshold for the cache's MinHash tier (≥1.0 = exact only).
    pub cache_min_similarity: f64,
    /// Prompt-adaptation policy applied before the cascade (Fig. 2a).
    pub prompt_policy: PromptPolicy,
    /// Optional hard budget cap (USD); when reached the service degrades
    /// to the first cascade stage only.
    pub budget_cap_usd: Option<f64>,
    /// Rows kept in the labelled observation window the reoptimizer
    /// re-learns from.
    pub window_capacity: usize,
    /// Exponential-decay half-life of the observation window, in
    /// observations (`None` = hard ring). See
    /// [`crate::server::metrics::ObservationWindow::with_half_life`].
    pub window_half_life: Option<f64>,
    /// Shadow-score a sampled fraction of live traffic into the
    /// observation window (`None` = off). See [`crate::server::shadow`].
    pub shadow: Option<ShadowConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cache_enabled: true,
            cache_capacity: 4096,
            cache_min_similarity: 1.0,
            prompt_policy: PromptPolicy::Full,
            budget_cap_usd: None,
            window_capacity: 4096,
            window_half_life: None,
            shadow: None,
        }
    }
}

/// The answer returned to a client. `stopped_at`, `model`, `cost_usd` and
/// `plan_version` all come from the *same* plan snapshot.
#[derive(Debug, Clone)]
pub struct ServiceAnswer {
    /// The answer class returned to the client.
    pub answer: u32,
    /// Whether the completion cache served it (no API was invoked).
    pub from_cache: bool,
    /// Cascade stage that answered (0 for cache hits).
    pub stopped_at: usize,
    /// Marketplace index of the model whose answer was accepted
    /// (meaningless for cache hits, which skip the cascade).
    pub model: usize,
    /// Metered marketplace spend of this answer (USD).
    pub cost_usd: f64,
    /// Version of the plan bundle that served this query.
    pub plan_version: u64,
    /// Wall-clock service latency of this answer (µs).
    pub latency_us: u64,
    /// Simulated commercial-API round-trip latency (ms).
    pub simulated_api_latency_ms: f64,
}

/// One immutable served-plan generation: the learned plan plus the live
/// and degraded cascades compiled from it. Never mutated after build —
/// swaps replace the whole bundle.
pub struct PlanBundle {
    plan: CascadePlan,
    version: u64,
    cascade: Cascade,
    /// Budget-cap fallback: cheapest stage of `plan` only.
    degraded: Cascade,
}

impl PlanBundle {
    fn build(
        plan: CascadePlan,
        version: u64,
        engine: &EngineHandle,
        costs: &CostModel,
        meta: &DatasetMeta,
    ) -> Result<PlanBundle> {
        if plan.is_empty() {
            anyhow::bail!("cannot build a plan bundle from an empty cascade plan");
        }
        let degrade_plan = CascadePlan::single(plan.stages[0].model);
        let degraded = Cascade::new(
            degrade_plan,
            engine.clone(),
            Scorer::new(engine.clone(), meta.clone()),
            costs.clone(),
            meta.clone(),
        )?;
        let cascade = Cascade::new(
            plan.clone(),
            engine.clone(),
            Scorer::new(engine.clone(), meta.clone()),
            costs.clone(),
            meta.clone(),
        )?;
        Ok(PlanBundle { plan, version, cascade, degraded })
    }

    /// The learned plan this bundle serves.
    pub fn plan(&self) -> &CascadePlan {
        &self.plan
    }

    /// Monotone version assigned at publish time.
    pub fn version(&self) -> u64 {
        self.version
    }
}

/// One published plan swap, kept for the `report swaps` history.
#[derive(Debug, Clone)]
pub struct SwapEvent {
    /// Version of the bundle this publish installed.
    pub version: u64,
    /// `metrics.queries` at publish time.
    pub at_query: u64,
    /// Human-readable cause (manual swap, reoptimizer window stats, ...).
    pub reason: String,
    /// The plan that was installed.
    pub plan: CascadePlan,
    /// Window accuracy of the new plan at publish time (reoptimizer swaps).
    pub window_accuracy: Option<f64>,
    /// Window avg cost of the new plan at publish time (reoptimizer swaps).
    pub window_avg_cost: Option<f64>,
}

impl SwapEvent {
    /// JSON form for the swap log.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("version".to_string(), Value::Num(self.version as f64));
        m.insert("at_query".to_string(), Value::Num(self.at_query as f64));
        m.insert("reason".to_string(), Value::Str(self.reason.clone()));
        m.insert("plan".to_string(), self.plan.to_value());
        m.insert(
            "window_accuracy".to_string(),
            self.window_accuracy.map(Value::Num).unwrap_or(Value::Null),
        );
        m.insert(
            "window_avg_cost".to_string(),
            self.window_avg_cost.map(Value::Num).unwrap_or(Value::Null),
        );
        Value::Obj(m)
    }

    /// Parse an event serialized by [`SwapEvent::to_value`].
    pub fn from_value(v: &Value) -> Result<SwapEvent> {
        use anyhow::Context;
        Ok(SwapEvent {
            version: v.get("version").as_f64().context("swap missing `version`")? as u64,
            at_query: v.get("at_query").as_f64().context("swap missing `at_query`")? as u64,
            reason: v
                .get("reason")
                .as_str()
                .context("swap missing `reason`")?
                .to_string(),
            plan: CascadePlan::from_value(v.get("plan")).context("swap plan")?,
            window_accuracy: v.get("window_accuracy").as_f64(),
            window_avg_cost: v.get("window_avg_cost").as_f64(),
        })
    }
}

/// Shared, atomically swappable handle to the current [`PlanBundle`].
pub struct PlanHandle {
    current: RwLock<Arc<PlanBundle>>,
    next_version: AtomicU64,
    history: Mutex<Vec<SwapEvent>>,
}

impl PlanHandle {
    fn new(initial: PlanBundle) -> PlanHandle {
        let v0 = initial.version;
        PlanHandle {
            current: RwLock::new(Arc::new(initial)),
            next_version: AtomicU64::new(v0 + 1),
            history: Mutex::new(Vec::new()),
        }
    }

    /// The current bundle. Read-lock held only to clone the `Arc` — a
    /// concurrent publish never blocks answering for longer than that
    /// pointer copy.
    pub fn snapshot(&self) -> Arc<PlanBundle> {
        self.current.read().unwrap().clone()
    }

    /// Version of the currently served bundle.
    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    /// Reserve the version number for a bundle about to be built.
    fn reserve_version(&self) -> u64 {
        self.next_version.fetch_add(1, Ordering::Relaxed)
    }

    /// Install `bundle` if its version is still the newest. Returns
    /// whether it was installed; a publish that lost the version race is
    /// dropped entirely (no history entry — it never served traffic).
    /// The history push happens under the same write lock, so the
    /// recorded events are strictly version-ordered.
    fn publish(&self, bundle: PlanBundle, event: SwapEvent) -> bool {
        let bundle = Arc::new(bundle);
        let mut cur = self.current.write().unwrap();
        if cur.version >= bundle.version {
            return false;
        }
        *cur = bundle;
        self.history.lock().unwrap().push(event);
        true
    }

    /// All swaps published so far (oldest first; the initial plan is not
    /// an event).
    pub fn history(&self) -> Vec<SwapEvent> {
        self.history.lock().unwrap().clone()
    }
}

/// A FrugalGPT serving instance for one dataset.
pub struct FrugalService {
    plans: PlanHandle,
    engine: EngineHandle,
    costs: CostModel,
    cache: Mutex<CompletionCache>,
    cfg: ServiceConfig,
    /// Serving-time spend meter (drives the budget-cap degrade).
    pub budget: BudgetTracker,
    /// All serving counters, including the observation window.
    pub metrics: Arc<ServiceMetrics>,
    meta: DatasetMeta,
    /// Shadow-scoring tap + worker (`cfg.shadow`): samples live queries
    /// into the observation window, off the answer path.
    shadow: Option<Shadow>,
}

impl FrugalService {
    /// Build a service around an initial plan (spawning the shadow
    /// worker when configured).
    pub fn new(
        plan: CascadePlan,
        engine: EngineHandle,
        costs: CostModel,
        meta: DatasetMeta,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        let initial = PlanBundle::build(plan, 0, &engine, &costs, &meta)?;
        let metrics = Arc::new(ServiceMetrics::with_window(
            costs.n_models(),
            cfg.window_capacity,
            cfg.window_half_life,
        ));
        let shadow = match &cfg.shadow {
            Some(sc) => Some(Shadow::spawn(
                engine.clone(),
                costs.clone(),
                meta.clone(),
                metrics.clone(),
                sc.clone(),
            )?),
            None => None,
        };
        Ok(FrugalService {
            plans: PlanHandle::new(initial),
            engine,
            cache: Mutex::new(CompletionCache::new(
                cfg.cache_capacity.max(1),
                cfg.cache_min_similarity,
            )),
            budget: BudgetTracker::new(cfg.budget_cap_usd),
            metrics,
            cfg,
            costs,
            meta,
            shadow,
        })
    }

    /// Dataset geometry this service answers for.
    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    /// The currently served plan (a snapshot copy — the live plan may be
    /// swapped at any time).
    pub fn plan(&self) -> CascadePlan {
        self.plans.snapshot().plan.clone()
    }

    /// The current plan bundle (plan + version, immutably).
    pub fn plan_snapshot(&self) -> Arc<PlanBundle> {
        self.plans.snapshot()
    }

    /// Version of the currently served plan.
    pub fn plan_version(&self) -> u64 {
        self.plans.version()
    }

    /// Plan swaps published so far.
    pub fn swap_history(&self) -> Vec<SwapEvent> {
        self.plans.history()
    }

    /// Build and atomically publish a new plan. The bundle (cascade
    /// validation included) is constructed before the swap, so in-flight
    /// `answer()` calls keep running on their snapshots and the handover
    /// is a single pointer store. Returns the new plan version.
    pub fn swap_plan(&self, plan: CascadePlan, reason: &str) -> Result<u64> {
        self.publish_plan(plan, reason, None)
    }

    /// [`FrugalService::swap_plan`] with the window metrics that justified
    /// the swap (recorded in the swap history by the reoptimizer).
    pub fn publish_plan(
        &self,
        plan: CascadePlan,
        reason: &str,
        window_stats: Option<(f64, f64)>,
    ) -> Result<u64> {
        let version = self.plans.reserve_version();
        let bundle = PlanBundle::build(plan.clone(), version, &self.engine, &self.costs, &self.meta)?;
        let event = SwapEvent {
            version,
            at_query: self.metrics.queries.load(Ordering::Relaxed),
            reason: reason.to_string(),
            plan,
            window_accuracy: window_stats.map(|(a, _)| a),
            window_avg_cost: window_stats.map(|(_, c)| c),
        };
        if !self.plans.publish(bundle, event) {
            anyhow::bail!(
                "plan v{version} was superseded by a newer publish before \
                 it could be installed"
            );
        }
        self.metrics.plan_swaps.fetch_add(1, Ordering::Relaxed);
        // Flush completions produced by the superseded plan — under the
        // drift that just triggered this swap, its cached answers are
        // exactly the ones not to keep serving. (Finer-grained: stamp
        // entries with plan_version and decay — see ROADMAP.)
        if self.cfg.cache_enabled {
            self.cache.lock().unwrap().clear();
        }
        Ok(version)
    }

    /// Answer one query (blocking; wrap in `spawn_blocking` from tokio).
    pub fn answer(&self, tokens: &[i32]) -> Result<ServiceAnswer> {
        let t0 = Instant::now();
        self.metrics.queries.fetch_add(1, Ordering::Relaxed);

        // Snapshot the served plan ONCE; everything below — stage walk,
        // cost metering, per-model attribution, the returned answer —
        // comes from this one bundle even if a swap lands mid-query.
        let bundle = self.plans.snapshot();

        // 1. Completion cache (paper Fig. 2c).
        if self.cfg.cache_enabled {
            if let Some(hit) = self.cache.lock().unwrap().get(tokens) {
                self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                let lat = t0.elapsed().as_micros() as u64;
                self.metrics.latency.record_us(lat);
                return Ok(ServiceAnswer {
                    answer: hit.answer,
                    from_cache: true,
                    stopped_at: 0,
                    model: 0,
                    cost_usd: 0.0,
                    plan_version: bundle.version,
                    latency_us: lat,
                    simulated_api_latency_ms: 0.0,
                });
            }
        }

        // Shadow tap: maybe sample this query for all-K evaluation. It
        // sits *after* the cache so only cascade-bound traffic is sampled
        // — the plan never serves cache hits, so learning from them would
        // bias the window toward the hit mix while spending shadow budget
        // on queries the cascade will not see. The tap itself only steps
        // an atomic sampler and enqueues; the fan-out happens on the
        // shadow worker, never on this path.
        if let Some(sh) = &self.shadow {
            sh.offer(tokens);
        }

        // 2. Prompt adaptation (paper Fig. 2a).
        let adapted = self.cfg.prompt_policy.apply(tokens, &self.meta);

        // 3. LLM cascade (paper Fig. 2e), degraded if over budget.
        self.metrics.cascade_invocations.fetch_add(1, Ordering::Relaxed);
        let degraded = self.budget.admit() == Admission::CapReached;
        let (executed, out): (&CascadePlan, CascadeAnswer) = if degraded {
            (bundle.degraded.plan(), bundle.degraded.answer(&adapted)?)
        } else {
            (&bundle.plan, bundle.cascade.answer(&adapted)?)
        };

        self.budget.record(out.cost);
        self.metrics.record_stop(out.stopped_at);
        for (s, &stage_cost) in out.stage_costs.iter().enumerate() {
            if let Some(w) = self.metrics.model(executed.stages[s].model) {
                w.record_invocation(stage_cost);
            }
        }
        let model = executed.stages[out.stopped_at].model;
        if let Some(w) = self.metrics.model(model) {
            // A last-stage stop carries the cascade's sentinel score 1.0,
            // not a scorer measurement — don't let it skew the window.
            let measured = out.stopped_at + 1 < executed.stages.len();
            w.record_accepted(measured.then_some(out.score));
        }

        // 4. Populate the cache — but only if our snapshot is still the
        // served plan. A swap flushes the cache after installing the new
        // bundle; an in-flight answer from the superseded plan must not
        // repopulate it past that flush. The check runs under the cache
        // lock the publisher flushes under, and the flush is ordered
        // after the install, so every interleaving either skips the put
        // (version moved on) or has its entry covered by the flush.
        if self.cfg.cache_enabled {
            let mut cache = self.cache.lock().unwrap();
            if self.plans.version() == bundle.version {
                cache.put(
                    tokens,
                    CachedAnswer { answer: out.answer, score: out.score },
                );
            }
        }

        let lat = t0.elapsed().as_micros() as u64;
        self.metrics.latency.record_us(lat);
        Ok(ServiceAnswer {
            answer: out.answer,
            from_cache: false,
            stopped_at: out.stopped_at,
            model,
            cost_usd: out.cost,
            plan_version: bundle.version,
            latency_us: lat,
            simulated_api_latency_ms: out.simulated_latency_ms,
        })
    }

    /// Report ground truth for an answered query: updates the accepting
    /// model's observed-accuracy window.
    pub fn record_ground_truth(&self, ans: &ServiceAnswer, label: u32) {
        if ans.from_cache {
            return;
        }
        if let Some(w) = self.metrics.model(ans.model) {
            w.record_outcome(ans.answer == label);
        }
    }

    /// Feed one fully-labelled observation (every model's response on one
    /// item) into the reoptimizer's window.
    pub fn observe(&self, obs: Observation) -> Result<()> {
        self.metrics.window.push(obs)
    }

    /// Shadow-scoring accounting, when shadow mode is on.
    pub fn shadow_stats(&self) -> Option<ShadowSnapshot> {
        self.shadow.as_ref().map(|s| s.snapshot())
    }

    /// Handle to the engine actor this service executes on.
    pub fn engine_handle(&self) -> EngineHandle {
        self.engine.clone()
    }

    /// The marketplace cost model this service meters with.
    pub fn costs(&self) -> &CostModel {
        &self.costs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::Stage;

    #[test]
    fn swap_event_json_roundtrip() {
        let ev = SwapEvent {
            version: 3,
            at_query: 1200,
            reason: "window of 256 obs: acc 0.71→0.94".into(),
            plan: CascadePlan::new(vec![
                Stage { model: 1, threshold: 0.62 },
                Stage { model: 11, threshold: 0.0 },
            ]),
            window_accuracy: Some(0.9375),
            window_avg_cost: Some(0.00042),
        };
        let json = ev.to_value().to_json();
        let back = SwapEvent::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.version, 3);
        assert_eq!(back.at_query, 1200);
        assert_eq!(back.reason, ev.reason);
        assert_eq!(back.plan, ev.plan);
        assert_eq!(back.window_accuracy, ev.window_accuracy);
        assert_eq!(back.window_avg_cost, ev.window_avg_cost);
    }

    #[test]
    fn swap_event_without_window_stats() {
        let ev = SwapEvent {
            version: 1,
            at_query: 0,
            reason: "manual".into(),
            plan: CascadePlan::single(2),
            window_accuracy: None,
            window_avg_cost: None,
        };
        let back =
            SwapEvent::from_value(&Value::parse(&ev.to_value().to_json()).unwrap()).unwrap();
        assert_eq!(back.window_accuracy, None);
        assert_eq!(back.window_avg_cost, None);
    }
}
