//! The composed FrugalGPT service: a [`Pipeline`] of first-class strategy
//! stages (paper Fig. 1b — completion cache, shadow tap, prompt
//! adaptation, budget degrade) terminating in the LLM cascade executor.
//! The stack is data ([`ServiceConfig::pipeline`], `serve --pipeline
//! cache,prompt,cascade`), so ablations and production serve the same
//! code path; [`FrugalService::answer_batch`] additionally forms query
//! concatenation groups (Fig. 2b) and meters prompt-amortized input cost
//! — all three paper strategy families behind one API.
//!
//! §Plan lifecycle — the served cascade is no longer a constructor-frozen
//! pair: the service routes every query through a [`PlanHandle`], an
//! atomically swappable `Arc` over an immutable [`PlanBundle`]
//! (plan + live cascade + degraded cascade, all built together).
//! `answer()` grabs one snapshot up front, every pipeline stage reads the
//! plan through the [`QueryCtx`] built around that snapshot, so a
//! concurrent swap can never mix stages, costs, or models from two plans
//! inside one answer. Publishers (`swap_plan` / the `server::reoptimizer`
//! loop) build the new bundle *outside* any lock and install it through a
//! wait-free [`SnapshotCell`] — readers never take a lock at all (two
//! atomics and an `Arc` clone), so a swap storm cannot convoy the answer
//! path; publishers serialize only among themselves. The live
//! [`CostModel`] gets the same treatment: [`FrugalService::reprice`] is a
//! read-modify-write on a snapshot cell, and billing reads never block.
//! Every publish is recorded as a [`SwapEvent`] for the swap-history
//! report.
//!
//! §Cache generations — a publish no longer wipes the completion cache.
//! Entries are stamped with the plan version that produced them; the
//! publisher sweeps the cache with
//! [`plan_accepts_cached`](crate::strategies::pipeline::plan_accepts_cached)
//! — completions the *new* plan would still accept survive (re-stamped to
//! the new generation), the rest are invalidated. Lookups serve only the
//! snapshot's generation, so an in-flight answer racing a swap can at
//! worst insert an entry stamped with the superseded version — inert to
//! every later lookup and lazily reclaimed. Concurrent publishers may
//! sweep out of version order; the result is only ever *extra* conservative
//! misses, never a wrong-generation hit.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::budget::BudgetTracker;
use crate::coordinator::cascade::{Cascade, CascadePlan, HealthView};
use crate::coordinator::optimizer::FrontierPoint;
use crate::coordinator::scorer::Scorer;
use crate::data::DatasetMeta;
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::server::calibrate::{
    CalibratorBundle, CalibratorHandle, CalibratorSwapEvent, SpeculateConfig,
};
use crate::server::health::{HealthConfig, ModelHealth};
use crate::server::metrics::{Observation, ServiceMetrics};
use crate::server::shadow::{Shadow, ShadowConfig, ShadowSnapshot};
use crate::strategies::cache::{CacheStats, ShardedCache};
use crate::strategies::concat;
use crate::strategies::pipeline::{
    build_pipeline, plan_accepts_cached, Pipeline, PipelineSpec, QueryCtx, StageDeps,
    StageKind, StageMetricsSnapshot,
};
use crate::strategies::prompt::PromptPolicy;
use crate::strategies::router::{
    route_plans, ProbeScorer, RouteTarget, RouterBundle, RouterConfig, RouterHandle,
    RouterModel, RouterStats, RouterSwapEvent,
};
use crate::strategies::speculate::{cheapest_pair, SpeculativeLanes};
use crate::util::json::Value;
use crate::util::sync::SnapshotCell;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Master switch for the completion cache (Fig. 2c). Off = the
    /// `cache` pipeline stage is skipped (the "cascade only" ablation).
    pub cache_enabled: bool,
    /// Entries the completion cache retains (LRU beyond this).
    pub cache_capacity: usize,
    /// Similarity threshold for the cache's MinHash tier (≥1.0 = exact only).
    pub cache_min_similarity: f64,
    /// Ways the completion cache is sharded (0 = next power of two ≥ core
    /// count; rounded up to a power of two). Concurrent answers on
    /// different shards never contend — see
    /// [`crate::strategies::cache::ShardedCache`].
    pub cache_shards: usize,
    /// Promote a cache entry on every T-th hit only (1 = exact LRU; see
    /// [`crate::strategies::cache::CompletionCache::with_touch_period`]).
    pub cache_touch_period: u32,
    /// Bench-only baseline: run the plan handle and cost model behind the
    /// `RwLock` they used before the wait-free snapshot cells, so
    /// `benches/serve_hot_path.rs` can measure the contention the cells
    /// removed on the identical code path. Never set in production.
    pub baseline_locks: bool,
    /// Prompt-adaptation policy of the `prompt` stage (Fig. 2a).
    pub prompt_policy: PromptPolicy,
    /// Optional hard budget cap (USD); when reached the `budget` stage
    /// degrades the cascade to its first stage only.
    pub budget_cap_usd: Option<f64>,
    /// Rows kept in the labelled observation window the reoptimizer
    /// re-learns from.
    pub window_capacity: usize,
    /// Exponential-decay half-life of the observation window, in
    /// observations (`None` = hard ring). See
    /// [`crate::server::metrics::ObservationWindow::with_half_life`].
    pub window_half_life: Option<f64>,
    /// Shadow-score a sampled fraction of live traffic into the
    /// observation window (`None` = off). See [`crate::server::shadow`].
    pub shadow: Option<ShadowConfig>,
    /// The serving stage stack (composition as data — see
    /// [`crate::strategies::pipeline`]). Stages whose backing object is
    /// disabled (`cache` with `cache_enabled: false`, `shadow` with no
    /// shadow config) are skipped, so the default full stack adapts to
    /// the flags above.
    pub pipeline: PipelineSpec,
    /// Per-model health layer (circuit breakers + bounded retry, see
    /// [`crate::server::health`]). `None` = strict mode: an engine error
    /// bubbles out of `answer()` (the pre-health behavior). With a config
    /// the cascade skips circuit-open stages and degrades instead of
    /// erroring (skip-never-error).
    pub health: Option<HealthConfig>,
    /// Per-query contextual routing (`--router on`, see
    /// [`crate::strategies::router`]). `None` = the `router` pipeline
    /// stage is skipped entirely — the global-plan baseline. The service
    /// starts every router generation degenerate (zero weights, exact
    /// global-plan behavior); the reoptimizer trains and publishes real
    /// weights on its cadence.
    pub router: Option<RouterConfig>,
    /// Speculative agreement serving (`--speculate`, see
    /// [`crate::strategies::speculate`]). `None` = the `speculate`
    /// pipeline stage is skipped entirely. The service starts every
    /// calibrator generation *disabled* (the stage passes every query —
    /// exact non-speculative behavior); the reoptimizer calibrates the
    /// accept rule from the observation window and publishes it on its
    /// cadence.
    pub speculate: Option<SpeculateConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cache_enabled: true,
            cache_capacity: 4096,
            cache_min_similarity: 1.0,
            cache_shards: 0,
            cache_touch_period: 1,
            baseline_locks: false,
            prompt_policy: PromptPolicy::Full,
            budget_cap_usd: None,
            window_capacity: 4096,
            window_half_life: None,
            shadow: None,
            pipeline: PipelineSpec::full(),
            health: None,
            router: None,
            speculate: None,
        }
    }
}

/// The answer returned to a client. `stopped_at`, `model`, `cost_usd` and
/// `plan_version` all come from the *same* plan snapshot.
#[derive(Debug, Clone)]
pub struct ServiceAnswer {
    /// The answer class returned to the client.
    pub answer: u32,
    /// Whether the completion cache served it (no API was invoked).
    pub from_cache: bool,
    /// Cascade stage that answered; `None` when the cascade never ran
    /// (cache hits — no stage-0 alias in metrics consumers anymore).
    pub stopped_at: Option<usize>,
    /// Marketplace index of the model whose answer was accepted; `None`
    /// when no API was invoked (cache hits).
    pub model: Option<usize>,
    /// Metered marketplace spend of this answer (USD).
    pub cost_usd: f64,
    /// Version of the plan bundle that served this query.
    pub plan_version: u64,
    /// Wall-clock service latency of this answer (µs).
    pub latency_us: u64,
    /// Simulated commercial-API round-trip latency (ms).
    pub simulated_api_latency_ms: f64,
    /// Plan stage indices the cascade skipped because their model was
    /// circuit-open or kept failing (empty when healthy or when no health
    /// layer is configured). Non-empty marks a degraded answer.
    pub skipped_stages: Vec<usize>,
    /// Version of the router bundle whose decision shaped this answer;
    /// `None` when no router routed it (router off, degenerate fast path,
    /// abstention, cache hit). Every routed answer is consistent with
    /// exactly ONE router snapshot, the same way `plan_version` pins the
    /// plan snapshot.
    pub router_version: Option<u64>,
    /// Which serving path produced the answer: `"cache"` (completion
    /// cache, $0), `"speculate"` (calibrated agreement accept),
    /// `"degraded"` (budget-cap fallback or breaker-skipped stages), or
    /// `"cascade"` (the ordinary cascade walk).
    pub origin: &'static str,
}

impl ServiceAnswer {
    /// The canonical wire form: what `frugald` writes on the socket for
    /// every answered query, what the serve summary and `report` render
    /// from, and what [`ServiceAnswer::from_value`] parses back
    /// bit-exactly (f64 fields round-trip through the shortest-printing
    /// serializer in [`crate::util::json`]).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("answer".to_string(), Value::Num(self.answer as f64));
        m.insert("from_cache".to_string(), Value::Bool(self.from_cache));
        m.insert(
            "stopped_at".to_string(),
            self.stopped_at.map(|s| Value::Num(s as f64)).unwrap_or(Value::Null),
        );
        m.insert(
            "model".to_string(),
            self.model.map(|s| Value::Num(s as f64)).unwrap_or(Value::Null),
        );
        m.insert("cost_usd".to_string(), Value::Num(self.cost_usd));
        m.insert("plan_version".to_string(), Value::Num(self.plan_version as f64));
        m.insert("latency_us".to_string(), Value::Num(self.latency_us as f64));
        m.insert(
            "simulated_api_latency_ms".to_string(),
            Value::Num(self.simulated_api_latency_ms),
        );
        m.insert(
            "skipped_stages".to_string(),
            Value::Arr(self.skipped_stages.iter().map(|&s| Value::Num(s as f64)).collect()),
        );
        m.insert(
            "router_version".to_string(),
            self.router_version.map(|v| Value::Num(v as f64)).unwrap_or(Value::Null),
        );
        m.insert("origin".to_string(), Value::Str(self.origin.to_string()));
        Value::Obj(m)
    }

    /// Parse an answer serialized by [`ServiceAnswer::to_value`].
    pub fn from_value(v: &Value) -> Result<ServiceAnswer> {
        use anyhow::Context;
        Ok(ServiceAnswer {
            answer: v.get("answer").as_u32().context("answer missing `answer`")?,
            from_cache: v.get("from_cache").as_bool().context("answer missing `from_cache`")?,
            stopped_at: v.get("stopped_at").as_usize(),
            model: v.get("model").as_usize(),
            cost_usd: v.get("cost_usd").as_f64().context("answer missing `cost_usd`")?,
            plan_version: v
                .get("plan_version")
                .as_f64()
                .context("answer missing `plan_version`")? as u64,
            latency_us: v.get("latency_us").as_f64().context("answer missing `latency_us`")?
                as u64,
            simulated_api_latency_ms: v
                .get("simulated_api_latency_ms")
                .as_f64()
                .context("answer missing `simulated_api_latency_ms`")?,
            skipped_stages: v
                .get("skipped_stages")
                .as_arr()
                .context("answer missing `skipped_stages`")?
                .iter()
                .map(|s| s.as_usize().context("bad skipped stage index"))
                .collect::<Result<_>>()?,
            router_version: v.get("router_version").as_f64().map(|x| x as u64),
            // The origin vocabulary is closed, so the wire string maps
            // back onto the same `&'static str` the service tagged with.
            origin: match v.get("origin").as_str().context("answer missing `origin`")? {
                "cache" => "cache",
                "speculate" => "speculate",
                "degraded" => "degraded",
                "cascade" => "cascade",
                other => anyhow::bail!("unknown answer origin `{other}`"),
            },
        })
    }
}

/// One immutable served-plan generation: the learned plan plus the live
/// and degraded cascades compiled from it. Never mutated after build —
/// swaps replace the whole bundle.
pub struct PlanBundle {
    plan: CascadePlan,
    version: u64,
    cascade: Cascade,
    /// Budget-cap fallback: cheapest stage of `plan` only.
    degraded: Cascade,
}

impl PlanBundle {
    fn build(
        plan: CascadePlan,
        version: u64,
        engine: &EngineHandle,
        costs: &CostModel,
        meta: &DatasetMeta,
        health: Option<Arc<ModelHealth>>,
    ) -> Result<PlanBundle> {
        if plan.is_empty() {
            anyhow::bail!("cannot build a plan bundle from an empty cascade plan");
        }
        // Both compiled cascades share the SAME health registry (an Arc):
        // breaker state survives plan swaps — a new plan does not amnesty
        // a tripped model.
        let view = health.map(|h| h as Arc<dyn HealthView>);
        let degrade_plan = CascadePlan::single(plan.stages[0].model);
        let degraded = Cascade::new(
            degrade_plan,
            engine.clone(),
            Scorer::new(engine.clone(), meta.clone()),
            costs.clone(),
            meta.clone(),
        )?
        .with_health(view.clone());
        let cascade = Cascade::new(
            plan.clone(),
            engine.clone(),
            Scorer::new(engine.clone(), meta.clone()),
            costs.clone(),
            meta.clone(),
        )?
        .with_health(view);
        Ok(PlanBundle { plan, version, cascade, degraded })
    }

    /// The learned plan this bundle serves.
    pub fn plan(&self) -> &CascadePlan {
        &self.plan
    }

    /// Monotone version assigned at publish time.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The live cascade compiled from [`PlanBundle::plan`].
    pub fn cascade(&self) -> &Cascade {
        &self.cascade
    }

    /// The budget-cap fallback cascade (first stage of the plan only).
    pub fn degraded(&self) -> &Cascade {
        &self.degraded
    }
}

/// One published plan swap, kept for the `report swaps` history.
#[derive(Debug, Clone)]
pub struct SwapEvent {
    /// Version of the bundle this publish installed.
    pub version: u64,
    /// `metrics.queries` at publish time.
    pub at_query: u64,
    /// Human-readable cause (manual swap, reoptimizer window stats, ...).
    pub reason: String,
    /// The plan that was installed.
    pub plan: CascadePlan,
    /// Window accuracy of the new plan at publish time (reoptimizer swaps).
    pub window_accuracy: Option<f64>,
    /// Window avg cost of the new plan at publish time (reoptimizer swaps).
    pub window_avg_cost: Option<f64>,
}

impl SwapEvent {
    /// JSON form for the swap log.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("version".to_string(), Value::Num(self.version as f64));
        m.insert("at_query".to_string(), Value::Num(self.at_query as f64));
        m.insert("reason".to_string(), Value::Str(self.reason.clone()));
        m.insert("plan".to_string(), self.plan.to_value());
        m.insert(
            "window_accuracy".to_string(),
            self.window_accuracy.map(Value::Num).unwrap_or(Value::Null),
        );
        m.insert(
            "window_avg_cost".to_string(),
            self.window_avg_cost.map(Value::Num).unwrap_or(Value::Null),
        );
        Value::Obj(m)
    }

    /// Parse an event serialized by [`SwapEvent::to_value`].
    pub fn from_value(v: &Value) -> Result<SwapEvent> {
        use anyhow::Context;
        Ok(SwapEvent {
            version: v.get("version").as_f64().context("swap missing `version`")? as u64,
            at_query: v.get("at_query").as_f64().context("swap missing `at_query`")? as u64,
            reason: v
                .get("reason")
                .as_str()
                .context("swap missing `reason`")?
                .to_string(),
            plan: CascadePlan::from_value(v.get("plan")).context("swap plan")?,
            window_accuracy: v.get("window_accuracy").as_f64(),
            window_avg_cost: v.get("window_avg_cost").as_f64(),
        })
    }
}

/// Shared, atomically swappable handle to the current [`PlanBundle`].
/// Reads are wait-free ([`SnapshotCell`]); publishers serialize among
/// themselves through the history mutex, which also keeps the recorded
/// [`SwapEvent`]s strictly version-ordered with the installs.
pub struct PlanHandle {
    current: SnapshotCell<PlanBundle>,
    next_version: AtomicU64,
    history: Mutex<Vec<SwapEvent>>,
}

impl PlanHandle {
    fn new(initial: PlanBundle, baseline_locks: bool) -> PlanHandle {
        let v0 = initial.version;
        let initial = Arc::new(initial);
        PlanHandle {
            current: if baseline_locks {
                SnapshotCell::new_rwlock_baseline(initial)
            } else {
                SnapshotCell::new(initial)
            },
            next_version: AtomicU64::new(v0 + 1),
            history: Mutex::new(Vec::new()),
        }
    }

    /// The current bundle. Wait-free: two atomics and an `Arc` clone — a
    /// concurrent publish never blocks answering at all.
    pub fn snapshot(&self) -> Arc<PlanBundle> {
        self.current.load()
    }

    /// Version of the currently served bundle.
    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    /// Reserve the version number for a bundle about to be built.
    fn reserve_version(&self) -> u64 {
        self.next_version.fetch_add(1, Ordering::Relaxed)
    }

    /// Install `bundle` if its version is still the newest. Returns
    /// whether it was installed; a publish that lost the version race is
    /// dropped entirely (no history entry — it never served traffic).
    /// The history mutex is held across the install, so the recorded
    /// events are strictly version-ordered; readers never touch it.
    fn publish(&self, bundle: PlanBundle, event: SwapEvent) -> bool {
        let version = bundle.version;
        let mut history = self.history.lock().unwrap();
        if !self
            .current
            .store_if(Arc::new(bundle), |cur| cur.version < version)
        {
            return false;
        }
        history.push(event);
        true
    }

    /// All swaps published so far (oldest first; the initial plan is not
    /// an event).
    pub fn history(&self) -> Vec<SwapEvent> {
        self.history.lock().unwrap().clone()
    }
}

/// A FrugalGPT serving instance for one dataset.
pub struct FrugalService {
    plans: PlanHandle,
    engine: EngineHandle,
    /// Live marketplace pricing, behind a wait-free snapshot cell because
    /// the market can *reprice* mid-serve ([`FrugalService::reprice`]);
    /// the answer path never touches it (each plan bundle bills through
    /// its own frozen copy — one-snapshot-per-answer extends to prices),
    /// and readers of the live model never block on a reprice.
    costs: SnapshotCell<CostModel>,
    /// The sharded completion cache behind the `cache` stage (`None` =
    /// disabled). Internally synchronized per shard — no outer lock.
    cache: Option<Arc<ShardedCache>>,
    /// The composed strategy stack every answer walks.
    pipeline: Pipeline,
    cfg: ServiceConfig,
    /// Serving-time spend meter (drives the `budget` stage's degrade).
    pub budget: Arc<BudgetTracker>,
    /// All serving counters, including the observation window.
    pub metrics: Arc<ServiceMetrics>,
    meta: DatasetMeta,
    /// Shadow-scoring tap + worker behind the `shadow` stage
    /// (`cfg.shadow`): samples live queries into the observation window,
    /// off the answer path.
    shadow: Option<Arc<Shadow>>,
    /// Per-model circuit breakers + retry policy (`cfg.health`); shared
    /// by every plan bundle this service publishes.
    health: Option<Arc<ModelHealth>>,
    /// Swappable router bundle behind the `router` stage (`cfg.router`);
    /// rebuilt against every plan publish so routes and plan can never
    /// come from different generations.
    router: Option<Arc<RouterHandle>>,
    /// Probe model behind the router's probe feature (`cfg.router.probe_model`).
    probe: Option<Arc<ProbeScorer>>,
    /// The two speculative probe lanes behind the `speculate` stage
    /// (`cfg.speculate`); spawned once over the initial plan's cheapest
    /// pair — the stage itself re-derives the current plan's pair per
    /// query and abstains on mismatch.
    speculate: Option<Arc<SpeculativeLanes>>,
    /// Swappable calibrated accept rule for the speculate stage; starts
    /// disabled, republished by the reoptimizer on its cadence.
    calibrator: Option<Arc<CalibratorHandle>>,
    /// Latest full cost–accuracy frontier handed over by the optimizer
    /// ([`FrugalService::install_frontier`]); router rebuilds offer its
    /// points as extra routes.
    frontier_points: Mutex<Vec<FrontierPoint>>,
}

/// Compile the route targets for a router generation: route 0 stays
/// uncompiled (it is the plan bundle's own cascade — the bit-parity
/// path), every other route gets its own cascade sharing the service's
/// health registry, so breaker state is one truth across all routes.
fn build_route_targets(
    plan: &CascadePlan,
    frontier: &[FrontierPoint],
    grid: usize,
    engine: &EngineHandle,
    costs: &CostModel,
    meta: &DatasetMeta,
    health: Option<Arc<ModelHealth>>,
) -> Result<Vec<RouteTarget>> {
    let view = health.map(|h| h as Arc<dyn HealthView>);
    let mut out = Vec::new();
    for (i, (p, skip, label)) in route_plans(plan, frontier, grid).into_iter().enumerate() {
        let cascade = if i == 0 {
            None
        } else {
            Some(Arc::new(
                Cascade::new(
                    p.clone(),
                    engine.clone(),
                    Scorer::new(engine.clone(), meta.clone()),
                    costs.clone(),
                    meta.clone(),
                )?
                .with_health(view.clone()),
            ))
        };
        out.push(RouteTarget { plan: p, skip, cascade, label });
    }
    Ok(out)
}

impl FrugalService {
    /// Build a service around an initial plan, composing the pipeline
    /// from `cfg.pipeline` (and spawning the shadow worker when
    /// configured).
    pub fn new(
        plan: CascadePlan,
        engine: EngineHandle,
        costs: CostModel,
        meta: DatasetMeta,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        cfg.pipeline.validate()?;
        if cfg.shadow.is_some() && !cfg.pipeline.stages.contains(&StageKind::Shadow) {
            anyhow::bail!(
                "shadow scoring is configured but the pipeline spec `{}` has no \
                 `shadow` stage — the worker would spawn and never be fed \
                 (add `shadow` to the spec or drop the shadow config)",
                cfg.pipeline.describe()
            );
        }
        if cfg.router.is_some() && !cfg.pipeline.stages.contains(&StageKind::Router) {
            anyhow::bail!(
                "contextual routing is configured but the pipeline spec `{}` has no \
                 `router` stage — every query would silently serve the global plan \
                 (add `router` to the spec or drop the router config)",
                cfg.pipeline.describe()
            );
        }
        if cfg.speculate.is_some() && !cfg.pipeline.stages.contains(&StageKind::Speculate) {
            anyhow::bail!(
                "speculative serving is configured but the pipeline spec `{}` has no \
                 `speculate` stage — the probe lanes would spawn and never fire \
                 (add `speculate` to the spec or drop the speculate config)",
                cfg.pipeline.describe()
            );
        }
        let health = cfg
            .health
            .as_ref()
            .map(|hc| Arc::new(ModelHealth::new(costs.n_models(), hc.clone())));
        // Router generation 0: degenerate weights (exact global-plan
        // behavior) over the routes of the initial plan — no frontier yet.
        let (router, probe) = match &cfg.router {
            Some(rc) => {
                let probe = match &rc.probe_model {
                    Some(name) => Some(Arc::new(ProbeScorer::spawn(
                        engine.clone(),
                        costs.clone(),
                        meta.clone(),
                        name,
                    )?)),
                    None => None,
                };
                let routes = build_route_targets(
                    &plan,
                    &[],
                    rc.grid,
                    &engine,
                    &costs,
                    &meta,
                    health.clone(),
                )?;
                let model = RouterModel::degenerate(routes.len());
                let handle = RouterHandle::new(RouterBundle::new(0, 0, model, routes)?);
                (Some(Arc::new(handle)), probe)
            }
            None => (None, None),
        };
        // Speculation generation 0: probe lanes over the initial plan's
        // two cheapest distinct models, accept rule DISABLED (the stage
        // passes every query — exact non-speculative behavior) until the
        // reoptimizer calibrates one from the observation window.
        let (speculate, calibrator) = match &cfg.speculate {
            Some(sc) => {
                let pair = match cheapest_pair(&plan, &costs) {
                    Some(p) => p,
                    None => anyhow::bail!(
                        "speculative serving needs a plan with at least two distinct \
                         models (got `{}`)",
                        plan.describe()
                    ),
                };
                let lanes =
                    Arc::new(SpeculativeLanes::spawn(&engine, &costs, &meta, pair)?);
                let handle = Arc::new(CalibratorHandle::new(CalibratorBundle::disabled(
                    0, 0, pair, sc.target,
                )));
                (Some(lanes), Some(handle))
            }
            None => (None, None),
        };
        let initial = PlanBundle::build(plan, 0, &engine, &costs, &meta, health.clone())?;
        let metrics = Arc::new(ServiceMetrics::with_window(
            costs.n_models(),
            cfg.window_capacity,
            cfg.window_half_life,
        ));
        let shadow = match &cfg.shadow {
            Some(sc) => Some(Arc::new(Shadow::spawn(
                engine.clone(),
                costs.clone(),
                meta.clone(),
                metrics.clone(),
                sc.clone(),
            )?)),
            None => None,
        };
        let cache = cfg.cache_enabled.then(|| {
            Arc::new(ShardedCache::new(
                cfg.cache_shards,
                cfg.cache_capacity.max(1),
                cfg.cache_min_similarity,
                cfg.cache_touch_period.max(1),
            ))
        });
        let budget = Arc::new(BudgetTracker::new(cfg.budget_cap_usd));
        let pipeline = build_pipeline(
            &cfg.pipeline,
            &StageDeps {
                cache: cache.clone(),
                shadow: shadow.clone(),
                prompt_policy: cfg.prompt_policy,
                budget: budget.clone(),
                metrics: metrics.clone(),
                router: router.clone(),
                probe: probe.clone(),
                speculate: speculate.clone(),
                calibrator: calibrator.clone(),
                health: health.clone(),
            },
        )?;
        let costs = if cfg.baseline_locks {
            SnapshotCell::new_rwlock_baseline(Arc::new(costs))
        } else {
            SnapshotCell::new(Arc::new(costs))
        };
        Ok(FrugalService {
            plans: PlanHandle::new(initial, cfg.baseline_locks),
            engine,
            cache,
            pipeline,
            budget,
            metrics,
            cfg,
            costs,
            meta,
            shadow,
            health,
            router,
            probe,
            speculate,
            calibrator,
            frontier_points: Mutex::new(Vec::new()),
        })
    }

    /// Dataset geometry this service answers for.
    pub fn meta(&self) -> &DatasetMeta {
        &self.meta
    }

    /// The configuration this service was built with (pipeline spec
    /// included).
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// The currently served plan (a snapshot copy — the live plan may be
    /// swapped at any time).
    pub fn plan(&self) -> CascadePlan {
        self.plans.snapshot().plan.clone()
    }

    /// The current plan bundle (plan + version, immutably).
    pub fn plan_snapshot(&self) -> Arc<PlanBundle> {
        self.plans.snapshot()
    }

    /// Version of the currently served plan.
    pub fn plan_version(&self) -> u64 {
        self.plans.version()
    }

    /// Plan swaps published so far.
    pub fn swap_history(&self) -> Vec<SwapEvent> {
        self.plans.history()
    }

    /// Per-stage counters of the composed pipeline, in stack order.
    pub fn pipeline_metrics(&self) -> Vec<StageMetricsSnapshot> {
        self.pipeline.metrics_snapshot()
    }

    /// Completion-cache counters (aggregated across shards), when the
    /// cache stage is enabled.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Build and atomically publish a new plan. The bundle (cascade
    /// validation included) is constructed before the swap, so in-flight
    /// `answer()` calls keep running on their snapshots and the handover
    /// is a single pointer store. Returns the new plan version.
    pub fn swap_plan(&self, plan: CascadePlan, reason: &str) -> Result<u64> {
        self.publish_plan(plan, reason, None)
    }

    /// [`FrugalService::swap_plan`] with the window metrics that justified
    /// the swap (recorded in the swap history by the reoptimizer).
    pub fn publish_plan(
        &self,
        plan: CascadePlan,
        reason: &str,
        window_stats: Option<(f64, f64)>,
    ) -> Result<u64> {
        let version = self.plans.reserve_version();
        let costs = self.costs.load();
        let bundle = PlanBundle::build(
            plan.clone(),
            version,
            &self.engine,
            &costs,
            &self.meta,
            self.health.clone(),
        )?;
        let event = SwapEvent {
            version,
            at_query: self.metrics.queries.load(Ordering::Relaxed),
            reason: reason.to_string(),
            plan: plan.clone(),
            window_accuracy: window_stats.map(|(a, _)| a),
            window_avg_cost: window_stats.map(|(_, c)| c),
        };
        if !self.plans.publish(bundle, event) {
            anyhow::bail!(
                "plan v{version} was superseded by a newer publish before \
                 it could be installed"
            );
        }
        self.metrics.plan_swaps.fetch_add(1, Ordering::Relaxed);
        // Plan-aware cache sweep (ordered after the install): completions
        // the new plan would still accept survive, re-stamped to this
        // generation; the rest are invalidated. Entries an in-flight
        // answer from the superseded bundle inserts after this sweep stay
        // stamped with the OLD version, so the generation-filtered lookup
        // never serves them — no blanket flush, no recheck dance. The
        // sweep walks shards one at a time, so lookups on other shards
        // keep flowing while it runs.
        if let Some(cache) = &self.cache {
            cache.retain_and_restamp(version, |ans| plan_accepts_cached(&plan, ans));
        }
        // Rebuild the router against the new plan generation (the stage
        // abstains until this lands — a short window of plain global-plan
        // serving, never a mixed-generation route). Learned weights
        // survive the rebuild only when the route plans are unchanged
        // (e.g. a pure reprice); a different plan means the old routes —
        // and a model trained to pick among them — no longer apply, so
        // the model resets to degenerate until the next retrain.
        if let Some(router) = &self.router {
            let grid = self.cfg.router.as_ref().map(|rc| rc.grid).unwrap_or(0);
            let frontier = self.frontier_points.lock().unwrap().clone();
            let routes = build_route_targets(
                &plan,
                &frontier,
                grid,
                &self.engine,
                &costs,
                &self.meta,
                self.health.clone(),
            )?;
            let cur = router.snapshot();
            let model = if cur.model.n_routes() == routes.len()
                && cur
                    .routes
                    .iter()
                    .zip(routes.iter())
                    .all(|(a, b)| a.plan == b.plan && a.skip == b.skip)
            {
                cur.model.clone()
            } else {
                RouterModel::degenerate(routes.len())
            };
            let rv = router.reserve_version();
            let event = RouterSwapEvent {
                version: rv,
                plan_version: version,
                at_query: self.metrics.queries.load(Ordering::Relaxed),
                reason: format!("rebuild against plan v{version}"),
                n_routes: routes.len(),
                degenerate: model.is_degenerate(),
                window_accuracy: None,
                window_avg_cost: None,
            };
            // A lost race means a newer router publish is already in —
            // that bundle supersedes this rebuild by construction.
            router.publish(RouterBundle::new(rv, version, model, routes)?, event);
        }
        Ok(version)
    }

    /// Hand the service the optimizer's full cost–accuracy frontier; the
    /// next router rebuild/publish offers its points as extra routes.
    pub fn install_frontier(&self, points: Vec<FrontierPoint>) {
        *self.frontier_points.lock().unwrap() = points;
    }

    /// The route plans a router generation for the CURRENT plan would
    /// offer, as (plan, prefix-skip) pairs — exactly what
    /// [`crate::server::router_train::train_router`] trains against and
    /// [`FrugalService::publish_router`] compiles. Empty when routing is
    /// off.
    pub fn router_route_specs(&self) -> Vec<(CascadePlan, usize)> {
        let Some(rc) = &self.cfg.router else { return Vec::new() };
        let plan = self.plan();
        let frontier = self.frontier_points.lock().unwrap().clone();
        route_plans(&plan, &frontier, rc.grid)
            .into_iter()
            .map(|(p, s, _)| (p, s))
            .collect()
    }

    /// Marketplace index of the router's probe model, when configured.
    pub fn probe_model_index(&self) -> Option<usize> {
        self.probe.as_ref().map(|p| p.model_index())
    }

    /// Publish a (re)trained router model against the CURRENT plan
    /// snapshot, recording the routed window metrics that justified it.
    /// Returns the new router version.
    pub fn publish_router(
        &self,
        model: RouterModel,
        reason: &str,
        window_stats: Option<(f64, f64)>,
    ) -> Result<u64> {
        let Some(router) = &self.router else {
            anyhow::bail!("cannot publish a router model: routing is not enabled");
        };
        let costs = self.costs.load();
        let plan_bundle = self.plans.snapshot();
        let grid = self.cfg.router.as_ref().map(|rc| rc.grid).unwrap_or(0);
        let frontier = self.frontier_points.lock().unwrap().clone();
        let routes = build_route_targets(
            plan_bundle.plan(),
            &frontier,
            grid,
            &self.engine,
            &costs,
            &self.meta,
            self.health.clone(),
        )?;
        let rv = router.reserve_version();
        let event = RouterSwapEvent {
            version: rv,
            plan_version: plan_bundle.version(),
            at_query: self.metrics.queries.load(Ordering::Relaxed),
            reason: reason.to_string(),
            n_routes: routes.len(),
            degenerate: model.is_degenerate(),
            window_accuracy: window_stats.map(|(a, _)| a),
            window_avg_cost: window_stats.map(|(_, c)| c),
        };
        let bundle = RouterBundle::new(rv, plan_bundle.version(), model, routes)?;
        if !router.publish(bundle, event) {
            anyhow::bail!(
                "router v{rv} was superseded by a newer publish before it could \
                 be installed"
            );
        }
        Ok(rv)
    }

    /// The speculative probe model pair (marketplace indices), when
    /// speculation is on.
    pub fn speculate_pair(&self) -> Option<(usize, usize)> {
        self.speculate.as_ref().map(|l| l.pair())
    }

    /// The current calibrated accept rule, when speculation is on.
    pub fn calibrator_snapshot(&self) -> Option<Arc<CalibratorBundle>> {
        self.calibrator.as_ref().map(|c| c.snapshot())
    }

    /// Calibrator publishes so far (empty when speculation is off).
    pub fn calibrator_history(&self) -> Vec<CalibratorSwapEvent> {
        self.calibrator.as_ref().map(|c| c.history()).unwrap_or_default()
    }

    /// Reserve the version number for a calibrator bundle about to be
    /// built (reoptimizer protocol — mirrors the router's).
    pub fn reserve_calibrator_version(&self) -> Result<u64> {
        match &self.calibrator {
            Some(c) => Ok(c.reserve_version()),
            None => anyhow::bail!("cannot calibrate: speculation is not enabled"),
        }
    }

    /// Publish a (re)calibrated accept rule. Returns whether it was
    /// installed (a lost version race is dropped, like plan publishes).
    pub fn publish_calibrator(&self, bundle: CalibratorBundle, reason: &str) -> Result<bool> {
        match &self.calibrator {
            Some(c) => Ok(c.publish(bundle, reason)),
            None => anyhow::bail!("cannot publish a calibrator: speculation is not enabled"),
        }
    }

    /// The current router bundle, when routing is on.
    pub fn router_snapshot(&self) -> Option<Arc<RouterBundle>> {
        self.router.as_ref().map(|r| r.snapshot())
    }

    /// Router swaps published so far (empty when routing is off).
    pub fn router_swap_history(&self) -> Vec<RouterSwapEvent> {
        self.router.as_ref().map(|r| r.history()).unwrap_or_default()
    }

    /// Router stage counters, when routing is on.
    pub fn router_stats(&self) -> Option<RouterStats> {
        self.router.as_ref().map(|r| r.stats())
    }

    /// Answer one query through the strategy pipeline (blocking; wrap in
    /// `spawn_blocking` from tokio).
    pub fn answer(&self, tokens: &[i32]) -> Result<ServiceAnswer> {
        self.answer_inner(tokens, 1)
    }

    /// Answer a batch through the same pipeline, with **query
    /// concatenation** (paper Fig. 2b): the batch is split into
    /// [`concat::form_groups`] groups of at most `max_group`, and every
    /// group member's billable input is metered as
    /// `prompt/|group| + query` tokens ([`concat::tokens_per_query`]) —
    /// the shared few-shot prompt is paid once per group instead of once
    /// per query. Answers come back in input order, each still served
    /// under its own plan snapshot. Members a stage answers without
    /// reaching the cascade (cache hits) cost $0 as usual; billing for
    /// the rest amortizes over the *formed* group size.
    pub fn answer_batch(
        &self,
        queries: &[&[i32]],
        max_group: usize,
    ) -> Result<Vec<ServiceAnswer>> {
        let mut out = Vec::with_capacity(queries.len());
        for range in concat::form_groups(queries.len(), max_group.max(1)) {
            let group = range.len();
            self.metrics.concat_groups.fetch_add(1, Ordering::Relaxed);
            for i in range {
                out.push(self.answer_inner(queries[i], group)?);
            }
        }
        Ok(out)
    }

    fn answer_inner(&self, tokens: &[i32], concat_group: usize) -> Result<ServiceAnswer> {
        let t0 = Instant::now();
        self.metrics.queries.fetch_add(1, Ordering::Relaxed);

        // Snapshot the served plan ONCE; every pipeline stage below reads
        // the plan, its version, and its compiled cascades from this one
        // bundle even if a swap lands mid-query.
        let bundle = self.plans.snapshot();
        let outcome = self.pipeline.answer(QueryCtx {
            original: tokens,
            tokens: std::borrow::Cow::Borrowed(tokens),
            bundle: &bundle,
            meta: &self.meta,
            degraded: false,
            concat_group,
            route: None,
            probes: Vec::new(),
        })?;

        let lat = t0.elapsed().as_micros() as u64;
        self.metrics.latency.record_us(lat);
        let a = outcome.answer;
        // Spend metering is unconditional — every cascade-produced answer
        // is recorded whether or not the spec includes the `budget` stage
        // (that stage only opts into the cap-degrade behavior).
        if a.model.is_some() {
            self.budget.record(a.cost_usd);
        }
        // Origin precedence: the answering stage names cache/speculate
        // directly; cascade answers split on whether they were served
        // degraded (budget fallback or breaker-skipped stages).
        let origin = match outcome.stage {
            "cache" => "cache",
            "speculate" => "speculate",
            _ if a.degraded => "degraded",
            _ => "cascade",
        };
        Ok(ServiceAnswer {
            answer: a.answer,
            from_cache: outcome.stage == "cache",
            stopped_at: a.stopped_at,
            model: a.model,
            cost_usd: a.cost_usd,
            plan_version: bundle.version(),
            latency_us: lat,
            simulated_api_latency_ms: a.simulated_api_latency_ms,
            skipped_stages: a.skipped_stages,
            router_version: a.router_version,
            origin,
        })
    }

    /// Report ground truth for an answered query: updates the accepting
    /// model's observed-accuracy window (cache hits carry no model and
    /// are skipped).
    pub fn record_ground_truth(&self, ans: &ServiceAnswer, label: u32) {
        let Some(model) = ans.model else { return };
        if let Some(w) = self.metrics.model(model) {
            w.record_outcome(ans.answer == label);
        }
    }

    /// Feed one fully-labelled observation (every model's response on one
    /// item) into the reoptimizer's window.
    pub fn observe(&self, obs: Observation) -> Result<()> {
        self.metrics.window.push(obs)
    }

    /// Shadow-scoring accounting, when shadow mode is on.
    pub fn shadow_stats(&self) -> Option<ShadowSnapshot> {
        self.shadow.as_ref().map(|s| s.snapshot())
    }

    /// Handle to the engine actor this service executes on.
    pub fn engine_handle(&self) -> EngineHandle {
        self.engine.clone()
    }

    /// The marketplace cost model this service meters with (a snapshot
    /// copy — the live pricing may be [`FrugalService::reprice`]d at any
    /// time).
    pub fn costs(&self) -> CostModel {
        (*self.costs.load()).clone()
    }

    /// The per-model health registry, when the health layer is on.
    pub fn health(&self) -> Option<Arc<ModelHealth>> {
        self.health.clone()
    }

    /// Apply a marketplace price step: scale model `model`'s pricing by
    /// `mult` and republish the *current* plan so billing follows the new
    /// prices (plan bundles bill through frozen cost copies). The
    /// reoptimizer then sees the drifted spend through
    /// [`FrugalService::costs`] on its next step and can swap to a plan
    /// that is cheaper under the new prices. Shadow-scoring keeps metering
    /// at launch prices (its worker holds its own copy) — a known,
    /// documented approximation.
    pub fn reprice(&self, model: usize, mult: f64, reason: &str) -> Result<u64> {
        self.costs.update(|c| {
            let mut next = c.clone();
            next.scale_pricing(model, mult)?;
            Ok::<_, anyhow::Error>(next)
        })?;
        self.publish_plan(self.plan(), reason, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::Stage;

    #[test]
    fn swap_event_json_roundtrip() {
        let ev = SwapEvent {
            version: 3,
            at_query: 1200,
            reason: "window of 256 obs: acc 0.71→0.94".into(),
            plan: CascadePlan::new(vec![
                Stage { model: 1, threshold: 0.62 },
                Stage { model: 11, threshold: 0.0 },
            ]),
            window_accuracy: Some(0.9375),
            window_avg_cost: Some(0.00042),
        };
        let json = ev.to_value().to_json();
        let back = SwapEvent::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.version, 3);
        assert_eq!(back.at_query, 1200);
        assert_eq!(back.reason, ev.reason);
        assert_eq!(back.plan, ev.plan);
        assert_eq!(back.window_accuracy, ev.window_accuracy);
        assert_eq!(back.window_avg_cost, ev.window_avg_cost);
    }

    #[test]
    fn swap_event_without_window_stats() {
        let ev = SwapEvent {
            version: 1,
            at_query: 0,
            reason: "manual".into(),
            plan: CascadePlan::single(2),
            window_accuracy: None,
            window_avg_cost: None,
        };
        let back =
            SwapEvent::from_value(&Value::parse(&ev.to_value().to_json()).unwrap()).unwrap();
        assert_eq!(back.window_accuracy, None);
        assert_eq!(back.window_avg_cost, None);
    }

    #[test]
    fn service_answer_wire_roundtrip_is_bit_exact() {
        // Deliberately awkward floats: the wire schema must round-trip
        // them to the exact same bits (shortest-printing f64 serializer).
        let answers = [
            ServiceAnswer {
                answer: 3,
                from_cache: false,
                stopped_at: Some(2),
                model: Some(5),
                cost_usd: 0.1 + 0.2,
                plan_version: 987654321,
                latency_us: 1_234_567,
                simulated_api_latency_ms: 123.456789012345,
                skipped_stages: vec![0, 3],
                router_version: Some(17),
                origin: "degraded",
            },
            ServiceAnswer {
                answer: 0,
                from_cache: true,
                stopped_at: None,
                model: None,
                cost_usd: 1e-17,
                plan_version: 1,
                latency_us: 0,
                simulated_api_latency_ms: 0.0,
                skipped_stages: vec![],
                router_version: None,
                origin: "cache",
            },
            ServiceAnswer {
                answer: 2,
                from_cache: false,
                stopped_at: None,
                model: Some(1),
                cost_usd: 0.000123,
                plan_version: 4,
                latency_us: 88,
                simulated_api_latency_ms: 42.5,
                skipped_stages: vec![],
                router_version: None,
                origin: "speculate",
            },
        ];
        for a in &answers {
            let json = a.to_value().to_json();
            let back = ServiceAnswer::from_value(&Value::parse(&json).unwrap()).unwrap();
            assert_eq!(back.answer, a.answer);
            assert_eq!(back.from_cache, a.from_cache);
            assert_eq!(back.stopped_at, a.stopped_at);
            assert_eq!(back.model, a.model);
            assert_eq!(back.cost_usd.to_bits(), a.cost_usd.to_bits());
            assert_eq!(back.plan_version, a.plan_version);
            assert_eq!(back.latency_us, a.latency_us);
            assert_eq!(
                back.simulated_api_latency_ms.to_bits(),
                a.simulated_api_latency_ms.to_bits()
            );
            assert_eq!(back.skipped_stages, a.skipped_stages);
            assert_eq!(back.router_version, a.router_version);
            assert_eq!(back.origin, a.origin);
            // Serialization is deterministic: a second trip is identical.
            assert_eq!(back.to_value().to_json(), json);
        }
    }
}
