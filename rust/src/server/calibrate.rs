//! SMART-style accept-rule calibration for speculative agreement serving.
//!
//! The speculative stage (`strategies::speculate`) fires the plan's two
//! cheapest models concurrently and wants to accept their answer without
//! consulting the cascade when the pair *agrees*. Agreement is only
//! evidence, not proof: two correlated cheap models can confidently agree
//! on the same wrong answer. Following SMART's accuracy-guarantee framing
//! (PAPERS.md), acceptance is gated on an *estimated* conditional
//! accuracy: from the decay-weighted serving `ObservationWindow` we
//! estimate `P(correct | pair agrees)` and enable the accept rule only
//! when that estimate clears the user-set guarantee `--speculate-target A`
//! with enough evidence weight behind it. A second, stricter rule covers
//! disagreement rows: accept the higher-scoring probe anyway iff both
//! reliability scores clear a *calibrated bar* — the smallest bar whose
//! conditional accuracy estimate also clears `A`.
//!
//! Publication discipline mirrors the router exactly: calibration is an
//! immutable [`CalibratorBundle`] snapshot behind a [`SnapshotCell`],
//! republished on the reoptimizer's hysteresis cadence, stamped with the
//! plan version it was computed against. The serving stage *abstains*
//! (clean `Pass`, zero spend) whenever the stamped plan version is not
//! the one the query is being served under — a plan swap can therefore
//! never pair a stale accept rule with a fresh plan (the
//! accept-rule-abstains-on-stale-plan invariant, pinned by
//! `tests/speculate_pipeline.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::responses::SplitTable;
use crate::util::json::Value;
use crate::util::sync::SnapshotCell;

/// Candidate score bars the disagreement rule is calibrated over. A small
/// fixed grid keeps calibration O(grid · window) and deterministic.
const SCORE_BARS: &[f32] = &[0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95];

/// User-facing speculation knobs (`--speculate` / `--speculate-target`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeculateConfig {
    /// Accuracy guarantee `A`: the accept rule is enabled only while the
    /// estimated `P(correct | accept)` clears this.
    pub target: f64,
    /// Minimum decay weight of supporting window rows before an estimate
    /// is trusted (guards against enabling off three lucky rows).
    pub min_weight: f64,
}

impl Default for SpeculateConfig {
    fn default() -> Self {
        SpeculateConfig { target: 0.9, min_weight: 8.0 }
    }
}

/// The calibration estimates for one ordered model pair, computed over
/// one (decay-weighted) window snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct PairCalibration {
    /// Σ row weight where the pair's answers agree.
    pub agree_weight: f64,
    /// Σ row weight where they agree AND the agreed answer is the label.
    pub agree_correct_weight: f64,
    /// `P(correct | agreement)` estimate (0.0 when no agreement rows).
    pub p_correct_given_agree: f64,
    /// Calibrated disagreement bar: smallest grid bar whose conditional
    /// accuracy clears the target with enough evidence; `None` = the
    /// disagreement rule stays off.
    pub score_bar: Option<f32>,
    /// Σ row weight supporting the chosen bar (0.0 when `score_bar` is
    /// `None`).
    pub bar_weight: f64,
    /// `P(higher-scoring probe correct | disagree, both scores ≥ bar)` at
    /// the chosen bar (0.0 when `score_bar` is `None`).
    pub p_correct_at_bar: f64,
}

impl PairCalibration {
    /// The all-zero calibration of an empty window.
    pub fn empty() -> Self {
        PairCalibration {
            agree_weight: 0.0,
            agree_correct_weight: 0.0,
            p_correct_given_agree: 0.0,
            score_bar: None,
            bar_weight: 0.0,
            p_correct_at_bar: 0.0,
        }
    }
}

/// Estimate the pair-agreement accept rule for models `(a, b)` of `table`
/// against guarantee `target` with evidence floor `min_weight`.
///
/// Row weights are the table's observation weights (exponential decay
/// when the table came from `ObservationWindow::snapshot_table`), so
/// recent traffic dominates both estimates.
pub fn calibrate_pair(
    table: &SplitTable,
    a: usize,
    b: usize,
    target: f64,
    min_weight: f64,
) -> Result<PairCalibration> {
    let k = table.n_models();
    if a >= k || b >= k {
        bail!("calibration pair ({a}, {b}) out of range for {k} models");
    }
    if a == b {
        bail!("calibration pair must be two distinct models (got {a} twice)");
    }
    let mut cal = PairCalibration::empty();
    // Agreement rule: one pass.
    for i in 0..table.len() {
        if table.pred(a, i) == table.pred(b, i) {
            let w = table.weight(i);
            cal.agree_weight += w;
            if table.pred(a, i) == table.labels[i] {
                cal.agree_correct_weight += w;
            }
        }
    }
    if cal.agree_weight > 0.0 {
        cal.p_correct_given_agree = cal.agree_correct_weight / cal.agree_weight;
    }
    // Disagreement rule: lowest bar on the grid that clears the target
    // with enough weight (a lower bar accepts more rows, so we prefer it).
    for &bar in SCORE_BARS {
        let (mut w_bar, mut w_ok) = (0.0f64, 0.0f64);
        for i in 0..table.len() {
            if table.pred(a, i) == table.pred(b, i) {
                continue;
            }
            let (sa, sb) = (table.score(a, i), table.score(b, i));
            if sa < bar || sb < bar {
                continue;
            }
            // Ties attribute to the first lane, exactly as the serving
            // rule does — calibration must estimate the rule it gates.
            let winner = if sb > sa { b } else { a };
            let w = table.weight(i);
            w_bar += w;
            if table.pred(winner, i) == table.labels[i] {
                w_ok += w;
            }
        }
        if w_bar >= min_weight && w_ok / w_bar >= target {
            cal.score_bar = Some(bar);
            cal.bar_weight = w_bar;
            cal.p_correct_at_bar = w_ok / w_bar;
            break;
        }
    }
    Ok(cal)
}

/// One immutable calibration generation: the accept rules the speculative
/// stage serves under, stamped with the plan version they were computed
/// against. Swapped atomically through [`CalibratorHandle`].
#[derive(Debug, Clone)]
pub struct CalibratorBundle {
    /// Monotone calibration generation.
    pub version: u64,
    /// Plan version this calibration was computed against; the stage
    /// abstains when it serves under any other plan.
    pub plan_version: u64,
    /// Marketplace indices of the probe pair `(cheapest, second-cheapest)`.
    pub pair: (usize, usize),
    /// The accuracy guarantee `A` both rules are gated on.
    pub target: f64,
    /// The window estimates behind the rules.
    pub calibration: PairCalibration,
    /// Whether the agreement rule is live (`P(correct | agree) ≥ target`
    /// with enough evidence).
    pub enabled: bool,
}

impl CalibratorBundle {
    /// The generation-0 bundle: both rules off. With this installed the
    /// speculative stage is a bitwise no-op (the safety identity).
    pub fn disabled(version: u64, plan_version: u64, pair: (usize, usize), target: f64) -> Self {
        CalibratorBundle {
            version,
            plan_version,
            pair,
            target,
            calibration: PairCalibration::empty(),
            enabled: false,
        }
    }

    /// Calibrate a bundle from a window snapshot (model order of `table`
    /// must be marketplace order, as `ObservationWindow::snapshot_table`
    /// guarantees).
    pub fn from_table(
        version: u64,
        plan_version: u64,
        pair: (usize, usize),
        cfg: SpeculateConfig,
        table: &SplitTable,
    ) -> Result<Self> {
        let calibration = calibrate_pair(table, pair.0, pair.1, cfg.target, cfg.min_weight)?;
        let enabled = calibration.agree_weight >= cfg.min_weight
            && calibration.p_correct_given_agree >= cfg.target;
        Ok(CalibratorBundle {
            version,
            plan_version,
            pair,
            target: cfg.target,
            calibration,
            enabled,
        })
    }

    /// Whether either accept rule can fire at all. False means the stage
    /// must pass every query untouched (no probes, no spend).
    pub fn accepts_anything(&self) -> bool {
        self.enabled || self.calibration.score_bar.is_some()
    }

    /// Apply the accept rules to one probed pair. Returns
    /// `Some((answer, score, lane))` when the rules accept — `lane` is 0
    /// or 1, the pair slot whose score backs the answer — and `None` when
    /// the query must escalate to the cascade.
    pub fn accept(
        &self,
        pred_a: u32,
        score_a: f32,
        pred_b: u32,
        score_b: f32,
    ) -> Option<(u32, f32, usize)> {
        if pred_a == pred_b {
            if !self.enabled {
                return None;
            }
            // Agreed: attribute to the higher-scoring lane so the cached
            // (model, score) stays a pair a plan threshold can re-check.
            return Some(if score_b > score_a {
                (pred_b, score_b, 1)
            } else {
                (pred_a, score_a, 0)
            });
        }
        let bar = self.calibration.score_bar?;
        if score_a >= bar && score_b >= bar {
            return Some(if score_b > score_a {
                (pred_b, score_b, 1)
            } else {
                (pred_a, score_a, 0)
            });
        }
        None
    }

    /// JSON form (serve summaries, swap logs).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("version".to_string(), Value::Num(self.version as f64));
        m.insert("plan_version".to_string(), Value::Num(self.plan_version as f64));
        m.insert("pair_a".to_string(), Value::Num(self.pair.0 as f64));
        m.insert("pair_b".to_string(), Value::Num(self.pair.1 as f64));
        m.insert("target".to_string(), Value::Num(self.target));
        m.insert("enabled".to_string(), Value::Bool(self.enabled));
        m.insert(
            "agree_weight".to_string(),
            Value::Num(self.calibration.agree_weight),
        );
        m.insert(
            "agree_correct_weight".to_string(),
            Value::Num(self.calibration.agree_correct_weight),
        );
        m.insert(
            "p_correct_given_agree".to_string(),
            Value::Num(self.calibration.p_correct_given_agree),
        );
        m.insert(
            "score_bar".to_string(),
            match self.calibration.score_bar {
                Some(b) => Value::Num(b as f64),
                None => Value::Null,
            },
        );
        m.insert("bar_weight".to_string(), Value::Num(self.calibration.bar_weight));
        m.insert(
            "p_correct_at_bar".to_string(),
            Value::Num(self.calibration.p_correct_at_bar),
        );
        Value::Obj(m)
    }

    /// Parse the [`CalibratorBundle::to_value`] form.
    pub fn from_value(v: &Value) -> Result<CalibratorBundle> {
        let version = v.get("version").as_f64().context("missing version")? as u64;
        let plan_version =
            v.get("plan_version").as_f64().context("missing plan_version")? as u64;
        let pair = (
            v.get("pair_a").as_f64().context("missing pair_a")? as usize,
            v.get("pair_b").as_f64().context("missing pair_b")? as usize,
        );
        let score_bar = match v.get("score_bar") {
            Value::Null => None,
            other => Some(other.as_f64().context("bad score_bar")? as f32),
        };
        Ok(CalibratorBundle {
            version,
            plan_version,
            pair,
            target: v.get("target").as_f64().context("missing target")?,
            enabled: v.get("enabled").as_bool().context("missing enabled")?,
            calibration: PairCalibration {
                agree_weight: v
                    .get("agree_weight")
                    .as_f64()
                    .context("missing agree_weight")?,
                agree_correct_weight: v
                    .get("agree_correct_weight")
                    .as_f64()
                    .context("missing agree_correct_weight")?,
                p_correct_given_agree: v
                    .get("p_correct_given_agree")
                    .as_f64()
                    .context("missing p_correct_given_agree")?,
                score_bar,
                bar_weight: v.get("bar_weight").as_f64().context("missing bar_weight")?,
                p_correct_at_bar: v
                    .get("p_correct_at_bar")
                    .as_f64()
                    .context("missing p_correct_at_bar")?,
            },
        })
    }
}

/// One calibration republish, for the swap log.
#[derive(Debug, Clone)]
pub struct CalibratorSwapEvent {
    /// Generation that was installed.
    pub version: u64,
    /// Plan version it was computed against.
    pub plan_version: u64,
    /// Whether the agreement rule came up enabled.
    pub enabled: bool,
    /// The `P(correct | agree)` estimate behind the decision.
    pub p_correct_given_agree: f64,
    /// Why the reoptimizer republished.
    pub reason: String,
}

impl CalibratorSwapEvent {
    /// JSON form for `report swaps`-style logs.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("version".to_string(), Value::Num(self.version as f64));
        m.insert("plan_version".to_string(), Value::Num(self.plan_version as f64));
        m.insert("enabled".to_string(), Value::Bool(self.enabled));
        m.insert(
            "p_correct_given_agree".to_string(),
            Value::Num(self.p_correct_given_agree),
        );
        m.insert("reason".to_string(), Value::Str(self.reason.clone()));
        Value::Obj(m)
    }
}

/// The swappable calibration handle: wait-free snapshots for the serving
/// stage, version-monotone publication for the reoptimizer. Mirrors
/// `RouterHandle` structurally so the two learned layers share one
/// mental model.
pub struct CalibratorHandle {
    current: SnapshotCell<CalibratorBundle>,
    next_version: AtomicU64,
    history: Mutex<Vec<CalibratorSwapEvent>>,
}

impl CalibratorHandle {
    /// Install the generation-0 bundle.
    pub fn new(bundle: CalibratorBundle) -> Self {
        let next = bundle.version + 1;
        CalibratorHandle {
            current: SnapshotCell::new(Arc::new(bundle)),
            next_version: AtomicU64::new(next),
            history: Mutex::new(Vec::new()),
        }
    }

    /// The live bundle (wait-free).
    pub fn snapshot(&self) -> Arc<CalibratorBundle> {
        self.current.load()
    }

    /// Version of the live bundle.
    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    /// Claim the next calibration generation number.
    pub fn reserve_version(&self) -> u64 {
        self.next_version.fetch_add(1, Ordering::Relaxed)
    }

    /// Install `bundle` iff it is newer than the live one (the same
    /// lost-race tolerance as plan/router publication). Returns whether
    /// the install happened; winners are appended to the swap log.
    pub fn publish(&self, bundle: CalibratorBundle, reason: impl Into<String>) -> bool {
        let event = CalibratorSwapEvent {
            version: bundle.version,
            plan_version: bundle.plan_version,
            enabled: bundle.enabled,
            p_correct_given_agree: bundle.calibration.p_correct_given_agree,
            reason: reason.into(),
        };
        let version = bundle.version;
        let won = self
            .current
            .store_if(Arc::new(bundle), |cur| cur.version < version);
        if won {
            self.history.lock().unwrap().push(event);
        }
        won
    }

    /// Copy of the swap log.
    pub fn history(&self) -> Vec<CalibratorSwapEvent> {
        self.history.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::responses::TableBuilder;

    /// 2-model table: `n_agree_ok` rows agree correctly, `n_agree_bad`
    /// agree on a wrong answer, `n_split` disagree with model 1 right at
    /// high score.
    fn pair_table(n_agree_ok: usize, n_agree_bad: usize, n_split: usize) -> SplitTable {
        let names = vec!["cheap_a".to_string(), "cheap_b".to_string()];
        let mut b = TableBuilder::new("cal", names);
        for _ in 0..n_agree_ok {
            b.push_item(1, &[1, 1], &[0.8, 0.7], &[true, true]).unwrap();
        }
        for _ in 0..n_agree_bad {
            b.push_item(1, &[2, 2], &[0.6, 0.6], &[false, false]).unwrap();
        }
        for _ in 0..n_split {
            b.push_item(1, &[0, 1], &[0.6, 0.9], &[false, true]).unwrap();
        }
        b.finish().unwrap()
    }

    #[test]
    fn agreement_estimate_counts_weighted_rows() {
        let t = pair_table(9, 1, 4);
        let cal = calibrate_pair(&t, 0, 1, 0.9, 8.0).unwrap();
        assert_eq!(cal.agree_weight, 10.0);
        assert_eq!(cal.agree_correct_weight, 9.0);
        assert!((cal.p_correct_given_agree - 0.9).abs() < 1e-12);
        // disagreement rows: both scores ≥ 0.6, winner = model 1, always
        // correct → the lowest bar admitting them wins.
        assert_eq!(cal.score_bar, Some(0.5));
        assert_eq!(cal.bar_weight, 4.0);
        assert_eq!(cal.p_correct_at_bar, 1.0);
    }

    #[test]
    fn bar_needs_evidence_weight() {
        // Only 4 disagreement rows but min_weight 8 → no bar.
        let t = pair_table(9, 1, 4);
        let cal = calibrate_pair(&t, 0, 1, 0.9, 8.0).unwrap();
        assert_eq!(cal.bar_weight, 4.0);
        let strict = calibrate_pair(&t, 0, 1, 0.9, 5.0).unwrap();
        assert_eq!(strict.score_bar, Some(0.5));
        let none = calibrate_pair(&t, 0, 1, 0.9, 100.0).unwrap();
        assert_eq!(none.score_bar, None);
        assert_eq!(none.bar_weight, 0.0);
    }

    #[test]
    fn calibrate_rejects_bad_pairs() {
        let t = pair_table(4, 0, 0);
        assert!(calibrate_pair(&t, 0, 0, 0.9, 1.0).is_err());
        assert!(calibrate_pair(&t, 0, 5, 0.9, 1.0).is_err());
    }

    #[test]
    fn bundle_enables_only_above_target_with_evidence() {
        let cfg = SpeculateConfig { target: 0.9, min_weight: 8.0 };
        // 90% conditional accuracy with weight 10 → enabled.
        let good = CalibratorBundle::from_table(1, 0, (0, 1), cfg, &pair_table(9, 1, 0))
            .unwrap();
        assert!(good.enabled);
        // 80% → disabled.
        let bad = CalibratorBundle::from_table(1, 0, (0, 1), cfg, &pair_table(8, 2, 0))
            .unwrap();
        assert!(!bad.enabled);
        // 100% but only weight 4 → disabled (not enough evidence).
        let thin = CalibratorBundle::from_table(1, 0, (0, 1), cfg, &pair_table(4, 0, 0))
            .unwrap();
        assert!(!thin.enabled);
    }

    #[test]
    fn accept_rules_fire_as_specified() {
        let cfg = SpeculateConfig { target: 0.9, min_weight: 4.0 };
        let b = CalibratorBundle::from_table(1, 0, (0, 1), cfg, &pair_table(9, 1, 4))
            .unwrap();
        assert!(b.enabled);
        assert_eq!(b.calibration.score_bar, Some(0.5));
        // agreement → higher-scoring lane wins the attribution
        assert_eq!(b.accept(3, 0.6, 3, 0.8), Some((3, 0.8, 1)));
        assert_eq!(b.accept(3, 0.8, 3, 0.6), Some((3, 0.8, 0)));
        // score tie attributes to lane 0 (matches calibration's tie rule)
        assert_eq!(b.accept(3, 0.7, 3, 0.7), Some((3, 0.7, 0)));
        // disagreement above the bar → higher-scoring answer accepted
        assert_eq!(b.accept(1, 0.55, 2, 0.95), Some((2, 0.95, 1)));
        // disagreement with one lane under the bar → escalate
        assert_eq!(b.accept(1, 0.4, 2, 0.95), None);
        // disabled bundle accepts nothing, agreement included
        let off = CalibratorBundle::disabled(0, 0, (0, 1), 0.9);
        assert!(!off.accepts_anything());
        assert_eq!(off.accept(3, 0.9, 3, 0.9), None);
    }

    #[test]
    fn bundle_wire_roundtrip_is_bit_exact() {
        let cfg = SpeculateConfig { target: 0.9, min_weight: 4.0 };
        for bundle in [
            CalibratorBundle::from_table(7, 3, (0, 1), cfg, &pair_table(9, 1, 4)).unwrap(),
            CalibratorBundle::disabled(0, 0, (2, 5), 0.85),
        ] {
            let json = bundle.to_value().to_json();
            let back = CalibratorBundle::from_value(&Value::parse(&json).unwrap()).unwrap();
            assert_eq!(back.version, bundle.version);
            assert_eq!(back.plan_version, bundle.plan_version);
            assert_eq!(back.pair, bundle.pair);
            assert_eq!(back.target.to_bits(), bundle.target.to_bits());
            assert_eq!(back.enabled, bundle.enabled);
            assert_eq!(
                back.calibration.agree_weight.to_bits(),
                bundle.calibration.agree_weight.to_bits()
            );
            assert_eq!(
                back.calibration.p_correct_given_agree.to_bits(),
                bundle.calibration.p_correct_given_agree.to_bits()
            );
            assert_eq!(
                back.calibration.score_bar.map(f32::to_bits),
                bundle.calibration.score_bar.map(f32::to_bits)
            );
            // second trip is byte-identical
            assert_eq!(back.to_value().to_json(), json);
        }
    }

    #[test]
    fn handle_publishes_version_monotone() {
        let h = CalibratorHandle::new(CalibratorBundle::disabled(0, 0, (0, 1), 0.9));
        assert_eq!(h.version(), 0);
        let v1 = h.reserve_version();
        let v2 = h.reserve_version();
        assert!(v1 < v2);
        // out-of-order publish: newer first wins, older loses cleanly
        assert!(h.publish(CalibratorBundle::disabled(v2, 1, (0, 1), 0.9), "newer"));
        assert!(!h.publish(CalibratorBundle::disabled(v1, 1, (0, 1), 0.9), "stale"));
        assert_eq!(h.version(), v2);
        let hist = h.history();
        assert_eq!(hist.len(), 1);
        assert_eq!(hist[0].version, v2);
        assert_eq!(hist[0].reason, "newer");
        assert!(hist[0].to_value().to_json().contains("newer"));
    }
}
