//! Shadow scoring: learn the cascade from the service's *own* traffic.
//!
//! The reoptimizer (`server::reoptimizer`) needs fully-labelled
//! observation rows — every marketplace model's (pred, score, correct) on
//! one item — but a served query only executes the stages its cascade
//! reached. Until now those rows came from a pre-labelled feedback stream
//! replayed by the serve driver; this module closes the loop instead
//! (cf. SMART, Jo et al. 2024: accuracy guarantees can be maintained by
//! evaluating stronger models on a *sampled* subset of live queries):
//!
//! 1. a cheap tap on the answer path ([`Shadow::offer`]) samples a
//!    configurable fraction of live *cascade-bound* queries (the tap runs
//!    as the `shadow` stage of `strategies::pipeline`, which the default
//!    spec places after the completion cache: the plan never serves cache
//!    hits, so sampling them would bias the window and waste budget) and
//!    enqueues them on a bounded queue — the answer path never blocks on
//!    shadow work, and a full queue drops (and counts) rather than
//!    backing up serving;
//! 2. a background worker drains the queue in small chunks and fans each
//!    chunk out to **all K models** through per-model [`Batcher`]s
//!    (`submit_async`, so the rows coalesce into batched engine calls
//!    instead of serializing K × chunk round-trips);
//! 3. every answer is scored by the coordinator scorer artifact (again
//!    through a batcher), and the configured **reference model**'s answer
//!    becomes the row's pseudo-label: `correct[m] = preds[m] == label`.
//!    With no ground truth in live traffic, "as good as the reference"
//!    is exactly the guarantee the cascade can chase — the paper's own
//!    evaluation measures cascades against their strongest API;
//! 4. the completed row is pushed into the service's
//!    [`ObservationWindow`](crate::server::metrics::ObservationWindow),
//!    where the reoptimizer re-learns the plan from it.
//!
//! Shadow execution costs real (metered) money — the K marketplace model
//! calls per sampled query; the K scorer executions are local compute,
//! not marketplace spend (`CostModel` has no scorer pricing), so they are
//! not metered — and it is **budget-capped**: once the metered shadow
//! spend reaches `budget_usd`, sampling stops (the spend may overshoot by
//! at most one in-flight chunk). All accounting is exposed via
//! [`ShadowStats`] and lands in the serve report / swap log.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{bail, Result};

use crate::coordinator::cascade::argmax;
use crate::coordinator::scorer::{sigmoid, Scorer};
use crate::data::{prompt, DatasetMeta};
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::server::batcher::{Batcher, BatcherConfig, BatcherHandle};
use crate::server::metrics::{Observation, ServiceMetrics};
use crate::util::json::Value;
use crate::util::rng::{splitmix64_mix, SPLITMIX64_GOLDEN};

/// Tuning for the shadow-scoring loop.
#[derive(Debug, Clone)]
pub struct ShadowConfig {
    /// Fraction of live queries sampled into the shadow path, in (0, 1].
    pub rate: f64,
    /// Hard cap on metered shadow spend (USD); `None` = uncapped.
    pub budget_usd: Option<f64>,
    /// Marketplace index of the pseudo-label reference model. `None`
    /// picks the most expensive API by pricing (the paper's testbed
    /// reference, GPT-4, is its priciest).
    pub reference: Option<usize>,
    /// Cross-referee labelling: when on, the two priciest *non-reference*
    /// models vote on each sampled row first — if they agree, their shared
    /// answer becomes the pseudo-label and the reference API is never
    /// consulted (its call is never metered); only a disagreement
    /// escalates to the reference for the tie-break. Needs ≥ 3 models.
    pub referee: bool,
    /// Uncertainty-aware sampling: when set, queries whose serving
    /// acceptance score landed within this margin of the threshold that
    /// judged them are *always* sampled (they are exactly the rows the
    /// calibrated accept rule and τ sweeps are least sure about), while
    /// everything else keeps the base `rate`. `None` = pure Bernoulli tap.
    pub margin: Option<f32>,
    /// Bounded depth of the sampled-query queue; a full queue drops new
    /// samples (counted in `dropped_queue_full`) instead of blocking the
    /// answer path.
    pub queue_capacity: usize,
    /// Queued rows drained per fan-out round — they ride one batched
    /// engine call per model.
    pub chunk: usize,
    /// Sampler seed (deterministic tests).
    pub seed: u64,
    /// Config of the per-model and scorer batchers.
    pub batcher: BatcherConfig,
}

impl Default for ShadowConfig {
    fn default() -> Self {
        ShadowConfig {
            rate: 0.05,
            budget_usd: None,
            reference: None,
            referee: false,
            margin: None,
            queue_capacity: 256,
            chunk: 8,
            seed: 0x5AD0,
            batcher: BatcherConfig::default(),
        }
    }
}

/// Lock-free shadow accounting. Spend is an exact nano-USD sum (same
/// representation as `BudgetTracker`/`ModelWindow`).
#[derive(Debug, Default)]
pub struct ShadowStats {
    /// Queries the sampler picked.
    pub sampled: AtomicU64,
    /// ... of which were enqueued for the worker.
    pub enqueued: AtomicU64,
    /// ... of which were dropped because the queue was full.
    pub dropped_queue_full: AtomicU64,
    /// Queries dropped after sampling because the budget ran out.
    pub skipped_budget: AtomicU64,
    /// Observation rows completed and pushed into the window.
    pub completed: AtomicU64,
    /// Rows lost to engine/batcher/window errors.
    pub errors: AtomicU64,
    /// Observation rows that were *started* (their model calls may have
    /// been metered) but never reached the window — an engine/batcher call
    /// failed mid-row, or the window rejected the push. Distinct from
    /// `dropped_queue_full`: these rows made it past the queue and then
    /// fell out of the labelled stream. Under fault injection this is the
    /// first counter that moves.
    pub dropped_rows: AtomicU64,
    /// Samples forced in because the serving score was within the
    /// configured margin of its threshold (uncertainty-aware tap; 0 when
    /// `ShadowConfig::margin` is off).
    pub sampled_near_tau: AtomicU64,
    /// Referee-vote rows labelled by agreement — the reference API was
    /// never consulted (0 when `ShadowConfig::referee` is off).
    pub referee_agreements: AtomicU64,
    /// Referee-vote rows escalated to the reference for the tie-break
    /// (disagreement, or a referee call failed).
    pub referee_escalations: AtomicU64,
    /// Metered shadow spend (nano-USD; all K model calls of each row).
    pub spend_nano_usd: AtomicU64,
    /// The reference model's share of `spend_nano_usd` — the spend the
    /// referee vote exists to avoid.
    pub reference_spend_nano_usd: AtomicU64,
    budget_exhausted: AtomicBool,
}

impl ShadowStats {
    /// Metered shadow spend so far (USD).
    pub fn spend_usd(&self) -> f64 {
        self.spend_nano_usd.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// The reference model's share of the metered spend so far (USD).
    pub fn reference_spend_usd(&self) -> f64 {
        self.reference_spend_nano_usd.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Whether the spend cap has been reached (sampling stopped).
    pub fn budget_exhausted(&self) -> bool {
        self.budget_exhausted.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> ShadowSnapshot {
        ShadowSnapshot {
            sampled: self.sampled.load(Ordering::Relaxed),
            enqueued: self.enqueued.load(Ordering::Relaxed),
            dropped_queue_full: self.dropped_queue_full.load(Ordering::Relaxed),
            skipped_budget: self.skipped_budget.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            dropped_rows: self.dropped_rows.load(Ordering::Relaxed),
            sampled_near_tau: self.sampled_near_tau.load(Ordering::Relaxed),
            referee_agreements: self.referee_agreements.load(Ordering::Relaxed),
            referee_escalations: self.referee_escalations.load(Ordering::Relaxed),
            spend_usd: self.spend_usd(),
            reference_spend_usd: self.reference_spend_usd(),
            budget_exhausted: self.budget_exhausted(),
        }
    }
}

/// Point-in-time copy of the shadow accounting (serve report, swap log).
#[derive(Debug, Clone, Default)]
pub struct ShadowSnapshot {
    /// Queries the sampler picked.
    pub sampled: u64,
    /// ... of which were enqueued for the worker.
    pub enqueued: u64,
    /// ... of which were dropped because the queue was full.
    pub dropped_queue_full: u64,
    /// Queries dropped after sampling because the budget ran out.
    pub skipped_budget: u64,
    /// Observation rows completed and pushed into the window.
    pub completed: u64,
    /// Rows lost to engine/batcher/window errors.
    pub errors: u64,
    /// Rows started but never pushed into the window (mid-row failure or
    /// window rejection) — see [`ShadowStats::dropped_rows`].
    pub dropped_rows: u64,
    /// Samples forced in by the near-threshold margin rule.
    pub sampled_near_tau: u64,
    /// Referee-vote rows labelled without consulting the reference.
    pub referee_agreements: u64,
    /// Referee-vote rows escalated to the reference tie-break.
    pub referee_escalations: u64,
    /// Metered shadow spend (USD).
    pub spend_usd: f64,
    /// The reference model's share of `spend_usd`.
    pub reference_spend_usd: f64,
    /// Whether the spend cap has been reached.
    pub budget_exhausted: bool,
}

impl ShadowSnapshot {
    /// JSON form for the serve report and swap log.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("sampled".to_string(), Value::Num(self.sampled as f64));
        m.insert("enqueued".to_string(), Value::Num(self.enqueued as f64));
        m.insert(
            "dropped_queue_full".to_string(),
            Value::Num(self.dropped_queue_full as f64),
        );
        m.insert("skipped_budget".to_string(), Value::Num(self.skipped_budget as f64));
        m.insert("completed".to_string(), Value::Num(self.completed as f64));
        m.insert("errors".to_string(), Value::Num(self.errors as f64));
        m.insert("dropped_rows".to_string(), Value::Num(self.dropped_rows as f64));
        m.insert(
            "sampled_near_tau".to_string(),
            Value::Num(self.sampled_near_tau as f64),
        );
        m.insert(
            "referee_agreements".to_string(),
            Value::Num(self.referee_agreements as f64),
        );
        m.insert(
            "referee_escalations".to_string(),
            Value::Num(self.referee_escalations as f64),
        );
        m.insert("spend_usd".to_string(), Value::Num(self.spend_usd));
        m.insert(
            "reference_spend_usd".to_string(),
            Value::Num(self.reference_spend_usd),
        );
        m.insert(
            "budget_exhausted".to_string(),
            Value::Bool(self.budget_exhausted),
        );
        Value::Obj(m)
    }
}

/// Default pseudo-label reference: the priciest API at a nominal request
/// shape — 256 input tokens and a flat 2-token completion. The nominal
/// completion is NOT answer-length aware (lengths are per-class, and no
/// class is known here); pass `ShadowConfig::reference` explicitly for a
/// marketplace where long completions would reorder the price ranking.
pub fn default_reference(costs: &CostModel) -> usize {
    let mut best = 0;
    let mut best_cost = f64::MIN;
    for (m, p) in costs.pricing.iter().enumerate() {
        let c = p.cost(256, 2);
        if c > best_cost {
            best_cost = c;
            best = m;
        }
    }
    best
}

/// The cross-referee voters: the two priciest models *excluding* the
/// reference, ranked at the same nominal request shape as
/// [`default_reference`] (price is the stand-in for strength throughout
/// the marketplace — the paper's testbed prices its strongest API
/// highest). `None` when fewer than two non-reference models exist.
pub fn referee_pair(costs: &CostModel, reference: usize) -> Option<(usize, usize)> {
    let mut ranked: Vec<usize> = (0..costs.n_models()).filter(|&m| m != reference).collect();
    ranked.sort_by(|&a, &b| {
        let (ca, cb) = (costs.pricing[a].cost(256, 2), costs.pricing[b].cost(256, 2));
        cb.partial_cmp(&ca).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    match ranked[..] {
        [a, b, ..] => Some((a, b)),
        _ => None,
    }
}

/// Lock-free Bernoulli sampler for the answer-path tap: one relaxed
/// `fetch_add` advances a splitmix64 counter, and the mixed output is
/// compared against a precomputed 64-bit threshold. No mutex — concurrent
/// `answer()` callers never serialize on the sampler, and a fixed seed
/// keeps single-threaded tests deterministic.
struct Sampler {
    state: AtomicU64,
    /// Accept when `mix(counter) < threshold`; `u64::MAX` = accept all
    /// (rate 1.0 — the `as u64` cast of `rate * 2^64` would saturate to
    /// MAX anyway, but losing the single top value matters for tests that
    /// expect *every* query sampled).
    threshold: u64,
    accept_all: bool,
}

impl Sampler {
    fn new(rate: f64, seed: u64) -> Sampler {
        Sampler {
            state: AtomicU64::new(seed),
            threshold: (rate * (u64::MAX as f64 + 1.0)) as u64,
            accept_all: rate >= 1.0,
        }
    }

    fn pick(&self) -> bool {
        if self.accept_all {
            return true;
        }
        let s = self
            .state
            .fetch_add(SPLITMIX64_GOLDEN, Ordering::Relaxed)
            .wrapping_add(SPLITMIX64_GOLDEN);
        splitmix64_mix(s) < self.threshold
    }
}

/// The shadow-scoring subsystem for one service: sampling tap + worker
/// thread + per-model/scorer batchers. Dropping it shuts the worker (and
/// its batchers) down; already-queued rows are abandoned.
pub struct Shadow {
    tx: Option<mpsc::SyncSender<Vec<i32>>>,
    sampler: Sampler,
    margin: Option<f32>,
    stats: Arc<ShadowStats>,
    /// Shutdown flag: mpsc receivers keep yielding *buffered* rows after
    /// every sender is dropped, so closing the queue alone would make
    /// `Drop` block while the worker executes (and pays for) the whole
    /// backlog. The worker checks this before each chunk instead.
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Shadow {
    /// Spawn the worker and its batchers. `metrics` is the service's —
    /// completed rows land in `metrics.window`.
    pub fn spawn(
        engine: EngineHandle,
        costs: CostModel,
        meta: DatasetMeta,
        metrics: Arc<ServiceMetrics>,
        cfg: ShadowConfig,
    ) -> Result<Shadow> {
        if !(cfg.rate > 0.0 && cfg.rate <= 1.0) {
            bail!("shadow rate {} outside (0, 1]", cfg.rate);
        }
        let k = costs.n_models();
        let reference = cfg.reference.unwrap_or_else(|| default_reference(&costs));
        if reference >= k {
            bail!("shadow reference model {reference} out of range (marketplace has {k})");
        }
        if let Some(b) = cfg.budget_usd {
            if !(b.is_finite() && b > 0.0) {
                bail!("shadow budget {b} is not finite and positive");
            }
        }
        if let Some(m) = cfg.margin {
            if !(m.is_finite() && m >= 0.0) {
                bail!("shadow margin {m} is not finite and non-negative");
            }
        }
        let referee = if cfg.referee {
            match referee_pair(&costs, reference) {
                Some(pair) => Some(pair),
                None => bail!(
                    "shadow referee vote needs at least two non-reference models \
                     (marketplace has {k}, reference {reference})"
                ),
            }
        } else {
            None
        };
        let stats = Arc::new(ShadowStats::default());
        let (tx, rx) = mpsc::sync_channel::<Vec<i32>>(cfg.queue_capacity.max(1));

        // The batchers are created here but owned by the worker thread, so
        // they live exactly as long as the fan-out loop that uses them.
        let mut batchers = Vec::with_capacity(k + 1);
        let mut model_handles = Vec::with_capacity(k);
        for name in &costs.model_names {
            let b = Batcher::spawn(engine.clone(), meta.name.clone(), name.clone(), cfg.batcher);
            model_handles.push(b.handle());
            batchers.push(b);
        }
        let scorer_batcher =
            Batcher::spawn(engine.clone(), meta.name.clone(), "scorer".into(), cfg.batcher);
        let scorer_handle = scorer_batcher.handle();
        batchers.push(scorer_batcher);
        let scorer = Scorer::new(engine, meta);

        let stats_in = stats.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_in = stop.clone();
        let chunk = cfg.chunk.max(1);
        let budget = cfg.budget_usd;
        let join = std::thread::Builder::new()
            .name("shadow-scorer".into())
            .spawn(move || {
                let _own = batchers; // keep the batcher threads alive
                while let Ok(first) = rx.recv() {
                    if stop_in.load(Ordering::Relaxed) {
                        break; // shutdown: abandon the queued backlog
                    }
                    let mut rows = vec![first];
                    while rows.len() < chunk {
                        match rx.try_recv() {
                            Ok(r) => rows.push(r),
                            Err(_) => break,
                        }
                    }
                    if let Some(cap) = budget {
                        if stats_in.spend_usd() >= cap {
                            stats_in.budget_exhausted.store(true, Ordering::Relaxed);
                            stats_in
                                .skipped_budget
                                .fetch_add(rows.len() as u64, Ordering::Relaxed);
                            continue;
                        }
                    }
                    shadow_chunk(
                        &rows,
                        &model_handles,
                        &scorer_handle,
                        &scorer,
                        &costs,
                        reference,
                        referee,
                        &metrics,
                        &stats_in,
                    );
                    if let Some(cap) = budget {
                        if stats_in.spend_usd() >= cap {
                            stats_in.budget_exhausted.store(true, Ordering::Relaxed);
                        }
                    }
                }
            })
            .expect("spawning shadow worker thread");

        Ok(Shadow {
            tx: Some(tx),
            sampler: Sampler::new(cfg.rate, cfg.seed),
            margin: cfg.margin,
            stats,
            stop,
            join: Some(join),
        })
    }

    /// The per-query tap on the answer path: decide sampling and enqueue.
    /// Never blocks and never locks — the sampler is one relaxed atomic
    /// op, a full queue drops the sample, and an exhausted budget stops
    /// sampling entirely.
    pub fn offer(&self, tokens: &[i32]) {
        self.offer_inner(tokens, false);
    }

    /// [`Shadow::offer`] with the uncertainty signal from the answer path:
    /// `near_tau` marks a query whose serving score fell within
    /// [`ShadowConfig::margin`] of the threshold that judged it. Such
    /// queries bypass the Bernoulli sampler entirely (they are the rows
    /// the calibrated accept rule learns the most from); everything else
    /// keeps the base rate. The budget cap still binds both.
    pub fn offer_scored(&self, tokens: &[i32], near_tau: bool) {
        self.offer_inner(tokens, near_tau);
    }

    /// The sampling margin this tap was configured with (`None` = pure
    /// Bernoulli); the pipeline's shadow stage keys its tap placement on
    /// this.
    pub fn margin(&self) -> Option<f32> {
        self.margin
    }

    fn offer_inner(&self, tokens: &[i32], forced: bool) {
        if self.stats.budget_exhausted() {
            return;
        }
        if forced {
            self.stats.sampled_near_tau.fetch_add(1, Ordering::Relaxed);
        } else if !self.sampler.pick() {
            return;
        }
        self.stats.sampled.fetch_add(1, Ordering::Relaxed);
        let Some(tx) = &self.tx else { return };
        match tx.try_send(tokens.to_vec()) {
            Ok(()) => {
                self.stats.enqueued.fetch_add(1, Ordering::Relaxed);
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.stats.dropped_queue_full.fetch_add(1, Ordering::Relaxed);
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.stats.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Live (lock-free) accounting counters.
    pub fn stats(&self) -> &ShadowStats {
        &self.stats
    }

    /// Point-in-time copy of the accounting.
    pub fn snapshot(&self) -> ShadowSnapshot {
        self.stats.snapshot()
    }
}

impl Drop for Shadow {
    fn drop(&mut self) {
        // Raise the stop flag BEFORE closing the queue: buffered rows
        // keep arriving on `recv()` after the sender drops, and without
        // the flag the worker would execute (and pay for) the whole
        // backlog before exiting. With it, at most the in-flight chunk
        // completes; then join so the batchers (and their engine handles)
        // are released deterministically.
        self.stop.store(true, Ordering::Relaxed);
        self.tx.take();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Execute one chunk: all rows × all models (+ scorer), then push the
/// completed observation rows. A row any model or scorer call fails on is
/// counted as an error and skipped — partial rows would corrupt the
/// window's "every model answered" invariant.
///
/// With `referee` set, the reference model is *deferred*: the two referee
/// models vote first, an agreement becomes the pseudo-label directly
/// (`preds[reference]` is synthesized, no reference call, no reference
/// spend), and only disagreements (or a failed referee call) escalate one
/// reference call for the tie-break. The label assignment below is
/// untouched either way — `preds[reference]` IS the vote outcome.
#[allow(clippy::too_many_arguments)]
fn shadow_chunk(
    rows: &[Vec<i32>],
    models: &[BatcherHandle],
    scorer_batcher: &BatcherHandle,
    scorer: &Scorer,
    costs: &CostModel,
    reference: usize,
    referee: Option<(usize, usize)>,
    metrics: &ServiceMetrics,
    stats: &ShadowStats,
) {
    let k = models.len();
    let n = rows.len();

    // Fan out: submit every row to every model before collecting anything,
    // so the per-model batchers see the whole chunk at once. In referee
    // mode the reference is left out of the fan-out — its (expensive)
    // call is only paid for rows the vote cannot settle.
    let mut pending = Vec::with_capacity(k);
    for (m, h) in models.iter().enumerate() {
        let per: Vec<_> = if referee.is_some() && m == reference {
            (0..n).map(|_| None).collect()
        } else {
            rows.iter().map(|row| h.submit_async(row.clone()).ok()).collect()
        };
        pending.push(per);
    }
    let mut preds: Vec<Vec<Option<u32>>> = vec![vec![None; n]; k];
    for (m, per) in pending.into_iter().enumerate() {
        for (r, rx) in per.into_iter().enumerate() {
            preds[m][r] = rx
                .and_then(|rx| rx.recv().ok())
                .and_then(|res| res.ok())
                .map(|logits| argmax(&logits) as u32);
        }
    }

    // Meter the spend of every model call that produced an answer (all of
    // these were real engine calls — the deferred reference column is
    // still all-None here).
    let toks: Vec<u32> = rows.iter().map(|r| prompt::input_tokens(r)).collect();
    let mut chunk_spend = 0.0;
    let mut reference_spend = 0.0;
    for r in 0..n {
        for (m, p) in preds.iter().enumerate() {
            if let Some(pred) = p[r] {
                let c = costs.call_cost(m, toks[r], pred);
                chunk_spend += c;
                if m == reference {
                    reference_spend += c;
                }
            }
        }
    }

    // The referee vote: agreement synthesizes the reference column (the
    // agreed answer becomes the pseudo-label for free); anything else
    // escalates one real reference call.
    if let Some((ra, rb)) = referee {
        let mut escalated: Vec<(usize, mpsc::Receiver<Result<Vec<f32>>>)> = Vec::new();
        for r in 0..n {
            match (preds[ra][r], preds[rb][r]) {
                (Some(a), Some(b)) if a == b => {
                    preds[reference][r] = Some(a);
                    stats.referee_agreements.fetch_add(1, Ordering::Relaxed);
                }
                _ => {
                    stats.referee_escalations.fetch_add(1, Ordering::Relaxed);
                    if let Ok(rx) = models[reference].submit_async(rows[r].clone()) {
                        escalated.push((r, rx));
                    }
                }
            }
        }
        for (r, rx) in escalated {
            preds[reference][r] = rx
                .recv()
                .ok()
                .and_then(|res| res.ok())
                .map(|logits| argmax(&logits) as u32);
            if let Some(pred) = preds[reference][r] {
                let c = costs.call_cost(reference, toks[r], pred);
                chunk_spend += c;
                reference_spend += c;
            }
        }
    }
    let nano = (chunk_spend * 1e9).round().max(0.0) as u64;
    stats.spend_nano_usd.fetch_add(nano, Ordering::Relaxed);
    let ref_nano = (reference_spend * 1e9).round().max(0.0) as u64;
    stats.reference_spend_nano_usd.fetch_add(ref_nano, Ordering::Relaxed);
    let valid: Vec<bool> = (0..n).map(|r| (0..k).all(|m| preds[m][r].is_some())).collect();

    // Score every (row, answer) pair through the scorer batcher.
    let mut score_rx = Vec::with_capacity(k);
    for p in &preds {
        let per: Vec<_> = (0..n)
            .map(|r| {
                if !valid[r] {
                    return None;
                }
                scorer_batcher.submit_async(scorer.input(&rows[r], p[r].unwrap())).ok()
            })
            .collect();
        score_rx.push(per);
    }
    let mut scores: Vec<Vec<Option<f32>>> = vec![vec![None; n]; k];
    for (m, per) in score_rx.into_iter().enumerate() {
        for (r, rx) in per.into_iter().enumerate() {
            scores[m][r] = rx
                .and_then(|rx| rx.recv().ok())
                .and_then(|res| res.ok())
                .and_then(|logits| logits.first().copied())
                .map(sigmoid);
        }
    }

    // Assemble pseudo-labelled observation rows.
    for r in 0..n {
        let complete = valid[r] && (0..k).all(|m| scores[m][r].is_some());
        if !complete {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            stats.dropped_rows.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        let label = preds[reference][r].unwrap();
        let row_preds: Vec<u32> = (0..k).map(|m| preds[m][r].unwrap()).collect();
        let row_scores: Vec<f32> = (0..k).map(|m| scores[m][r].unwrap()).collect();
        let row_correct: Vec<bool> = row_preds.iter().map(|&p| p == label).collect();
        let obs = Observation {
            label,
            input_tokens: toks[r],
            preds: row_preds,
            scores: row_scores,
            correct: row_correct,
        };
        match metrics.window.push(obs) {
            Ok(()) => {
                stats.completed.fetch_add(1, Ordering::Relaxed);
            }
            Err(_) => {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                stats.dropped_rows.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marketplace::{LatencyModel, Pricing};
    use std::time::{Duration, Instant};

    const K: usize = 3;

    fn sim_meta() -> DatasetMeta {
        DatasetMeta {
            name: "sim".into(),
            seq: 8,
            n_classes: 4,
            n_examples: 0,
            qlen: 4,
            block_len: 1,
            q_offset: 0,
            scorer_seq: 8,
            answer_lens: vec![1, 1, 1, 1],
        }
    }

    fn sim_costs() -> CostModel {
        CostModel {
            dataset: "sim".into(),
            model_names: (0..K).map(|m| format!("api_{m}")).collect(),
            pricing: vec![
                Pricing::new(2.0, 2.0, 0.0),
                Pricing::new(10.0, 10.0, 0.0),
                Pricing::new(30.0, 60.0, 0.0),
            ],
            latency: vec![LatencyModel { base_ms: 1.0, per_1k_tokens_ms: 1.0 }; K],
            answer_lens: vec![1, 1, 1, 1],
        }
    }

    /// Truth = first body token mod classes. Model 2 always right, model 1
    /// always wrong, model 0 right; scorer logit +4 when the scored answer
    /// matches the truth, -4 otherwise.
    fn sim_engine() -> EngineHandle {
        EngineHandle::simulated(move |_ds, model, rows| {
            Ok(rows
                .iter()
                .map(|r| {
                    let truth = r[1].rem_euclid(4) as u32;
                    if model == "scorer" {
                        let ans = (r[6] - crate::data::layout::LABEL_BASE) as u32;
                        vec![if ans == truth { 4.0 } else { -4.0 }]
                    } else {
                        let answer = match model {
                            "api_0" => truth,
                            "api_1" => (truth + 2) % 4,
                            _ => truth,
                        };
                        let mut logits = vec![0.0f32; 4];
                        logits[answer as usize] = 1.0;
                        logits
                    }
                })
                .collect())
        })
    }

    fn query_row(j: i32) -> Vec<i32> {
        use crate::data::layout;
        vec![layout::CLS, 10 + j, 11, 12, 13, layout::QSEP, layout::PAD, layout::PAD]
    }

    fn wait_until(deadline_ms: u64, mut done: impl FnMut() -> bool) -> bool {
        let deadline = Instant::now() + Duration::from_millis(deadline_ms);
        while Instant::now() < deadline {
            if done() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        done()
    }

    #[test]
    fn sampler_rate_is_respected() {
        let all = Sampler::new(1.0, 1);
        assert!((0..1000).all(|_| all.pick()), "rate 1.0 samples every query");
        let quarter = Sampler::new(0.25, 42);
        let hits = (0..10_000).filter(|_| quarter.pick()).count();
        assert!(
            (1_800..3_200).contains(&hits),
            "rate 0.25 sampled {hits}/10000"
        );
        let never = Sampler::new(1e-12, 7);
        assert_eq!((0..10_000).filter(|_| never.pick()).count(), 0);
    }

    #[test]
    fn default_reference_is_priciest_api() {
        assert_eq!(default_reference(&sim_costs()), 2);
        let full = CostModel::from_table1("t1", vec![1, 1, 2, 1]);
        // j1_jumbo's $0.005 per-request fee dominates every per-token
        // price at a 256-token request — it is Table 1's priciest call.
        assert_eq!(full.model_names[default_reference(&full)], "j1_jumbo");
    }

    #[test]
    fn sampled_queries_become_pseudo_labelled_window_rows() {
        let costs = sim_costs();
        let metrics = Arc::new(ServiceMetrics::with_models(K, 64));
        let shadow = Shadow::spawn(
            sim_engine(),
            costs,
            sim_meta(),
            metrics.clone(),
            ShadowConfig { rate: 1.0, reference: Some(2), ..Default::default() },
        )
        .unwrap();
        for j in 0..16 {
            shadow.offer(&query_row(j));
        }
        assert!(
            wait_until(5_000, || metrics.window.len() >= 16),
            "window never filled: {:?}",
            shadow.snapshot()
        );
        let snap = shadow.snapshot();
        assert_eq!(snap.sampled, 16);
        assert_eq!(snap.completed, 16);
        assert_eq!(snap.errors, 0);
        assert!(snap.spend_usd > 0.0);
        let (table, toks) = metrics
            .window
            .snapshot_table("sim", &["api_0".into(), "api_1".into(), "api_2".into()])
            .unwrap();
        assert_eq!(table.len(), 16);
        assert_eq!(toks, vec![6u32; 16]);
        // pseudo-labels: models 0 and 2 agree with the reference, 1 never
        assert_eq!(table.accuracy(0), 1.0);
        assert_eq!(table.accuracy(1), 0.0);
        assert_eq!(table.accuracy(2), 1.0);
        // calibrated scores: right answers near sigmoid(4), wrong near sigmoid(-4)
        for i in 0..table.len() {
            assert!(table.score(0, i) > 0.9);
            assert!(table.score(1, i) < 0.1);
        }
    }

    #[test]
    fn shadow_budget_caps_spend_and_stops_sampling() {
        let costs = sim_costs();
        // One full row costs Σ_m call_cost(m, 6, ans) ≈ 3.2e-5 USD; cap
        // after roughly two rows.
        let metrics = Arc::new(ServiceMetrics::with_models(K, 64));
        let shadow = Shadow::spawn(
            sim_engine(),
            costs.clone(),
            sim_meta(),
            metrics.clone(),
            ShadowConfig {
                rate: 1.0,
                reference: Some(2),
                budget_usd: Some(5.0e-5),
                chunk: 1,
                ..Default::default()
            },
        )
        .unwrap();
        for j in 0..64 {
            shadow.offer(&query_row(j));
            // give the single-row chunks time to meter spend
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(
            wait_until(5_000, || shadow.stats().budget_exhausted()),
            "budget never tripped: {:?}",
            shadow.snapshot()
        );
        let before = shadow.snapshot();
        for j in 0..32 {
            shadow.offer(&query_row(j));
        }
        let after = shadow.snapshot();
        assert_eq!(before.sampled, after.sampled, "exhausted budget stops sampling");
        // Overshoot is bounded by one chunk (chunk = 1 row here).
        let per_row: f64 = (0..K).map(|m| costs.call_cost(m, 6, 0)).sum();
        assert!(after.spend_usd <= 5.0e-5 + per_row + 1e-12);
        assert!(after.completed < 64);
    }

    #[test]
    fn mid_row_failures_count_as_dropped_rows() {
        // api_1 always fails → every row is incomplete, nothing reaches
        // the window, and every started row lands in `dropped_rows`.
        let engine = EngineHandle::simulated(move |_ds, model, rows| {
            if model == "api_1" {
                anyhow::bail!("injected outage: api_1 is down");
            }
            Ok(rows
                .iter()
                .map(|_| {
                    if model == "scorer" {
                        vec![4.0]
                    } else {
                        vec![1.0, 0.0, 0.0, 0.0]
                    }
                })
                .collect())
        });
        let metrics = Arc::new(ServiceMetrics::with_models(K, 64));
        let shadow = Shadow::spawn(
            engine,
            sim_costs(),
            sim_meta(),
            metrics.clone(),
            ShadowConfig { rate: 1.0, reference: Some(2), ..Default::default() },
        )
        .unwrap();
        for j in 0..8 {
            shadow.offer(&query_row(j));
        }
        assert!(
            wait_until(5_000, || shadow.snapshot().dropped_rows >= 8),
            "rows never dropped: {:?}",
            shadow.snapshot()
        );
        let snap = shadow.snapshot();
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.dropped_rows, 8);
        assert_eq!(metrics.window.len(), 0);
        // the JSON snapshot carries the counter for `report swaps`
        let v = snap.to_value();
        assert_eq!(v.get("dropped_rows").as_f64(), Some(8.0));
    }

    #[test]
    fn referee_pair_is_priciest_non_reference_models() {
        // prices 2 / 10 / 30; reference 2 → referees are 1 then 0.
        assert_eq!(referee_pair(&sim_costs(), 2), Some((1, 0)));
        // reference mid-pack: the priciest and cheapest remain.
        assert_eq!(referee_pair(&sim_costs(), 1), Some((2, 0)));
        // two models leave only one non-reference candidate.
        let two = CostModel {
            model_names: vec!["a".into(), "b".into()],
            pricing: vec![Pricing::new(2.0, 2.0, 0.0), Pricing::new(30.0, 60.0, 0.0)],
            latency: vec![LatencyModel { base_ms: 1.0, per_1k_tokens_ms: 1.0 }; 2],
            ..sim_costs()
        };
        assert_eq!(referee_pair(&two, 1), None);
    }

    /// Agreement path: both referees answer the truth, so every row is
    /// labelled by the vote and the reference API — wired to *fail* here —
    /// is provably never consulted and never billed.
    #[test]
    fn referee_agreement_labels_without_reference_spend() {
        let engine = EngineHandle::simulated(move |_ds, model, rows| {
            rows.iter()
                .map(|r| {
                    let truth = r[1].rem_euclid(4) as u32;
                    match model {
                        "scorer" => Ok(vec![4.0f32]),
                        "api_2" => anyhow::bail!("reference must not be consulted"),
                        _ => {
                            let mut logits = vec![0.0f32; 4];
                            logits[truth as usize] = 1.0;
                            Ok(logits)
                        }
                    }
                })
                .collect()
        });
        let metrics = Arc::new(ServiceMetrics::with_models(K, 64));
        let shadow = Shadow::spawn(
            engine,
            sim_costs(),
            sim_meta(),
            metrics.clone(),
            ShadowConfig {
                rate: 1.0,
                reference: Some(2),
                referee: true,
                ..Default::default()
            },
        )
        .unwrap();
        for j in 0..16 {
            shadow.offer(&query_row(j));
        }
        assert!(
            wait_until(5_000, || metrics.window.len() >= 16),
            "window never filled: {:?}",
            shadow.snapshot()
        );
        let snap = shadow.snapshot();
        assert_eq!(snap.referee_agreements, 16);
        assert_eq!(snap.referee_escalations, 0);
        assert_eq!(snap.completed, 16);
        assert_eq!(
            snap.reference_spend_usd, 0.0,
            "an agreed vote must not bill the reference"
        );
        assert!(snap.spend_usd > 0.0, "the referees themselves are metered");
        // The synthesized reference column agrees with the label by
        // construction, and both referees match it too.
        let (table, _) = metrics
            .window
            .snapshot_table("sim", &["api_0".into(), "api_1".into(), "api_2".into()])
            .unwrap();
        for m in 0..K {
            assert_eq!(table.accuracy(m), 1.0, "model {m}");
        }
    }

    /// Disagreement path: the referees never agree (api_1 is always
    /// wrong), so every row escalates to the reference tie-break — the
    /// labels are exactly the single-reference labels, at full reference
    /// spend.
    #[test]
    fn referee_disagreement_escalates_to_reference_tie_break() {
        let costs = sim_costs();
        let metrics = Arc::new(ServiceMetrics::with_models(K, 64));
        let shadow = Shadow::spawn(
            sim_engine(),
            costs.clone(),
            sim_meta(),
            metrics.clone(),
            ShadowConfig {
                rate: 1.0,
                reference: Some(2),
                referee: true,
                ..Default::default()
            },
        )
        .unwrap();
        for j in 0..16 {
            shadow.offer(&query_row(j));
        }
        assert!(
            wait_until(5_000, || metrics.window.len() >= 16),
            "window never filled: {:?}",
            shadow.snapshot()
        );
        let snap = shadow.snapshot();
        assert_eq!(snap.referee_agreements, 0);
        assert_eq!(snap.referee_escalations, 16);
        assert_eq!(snap.completed, 16);
        // every row paid one reference call
        let per_ref: f64 = costs.call_cost(2, 6, 0) * 16.0;
        assert!((snap.reference_spend_usd - per_ref).abs() < 1e-9);
        // the tie-break reproduces the single-reference labels exactly
        let (table, _) = metrics
            .window
            .snapshot_table("sim", &["api_0".into(), "api_1".into(), "api_2".into()])
            .unwrap();
        assert_eq!(table.accuracy(0), 1.0);
        assert_eq!(table.accuracy(1), 0.0);
        assert_eq!(table.accuracy(2), 1.0);
    }

    /// Uncertainty-aware sampling: at the same base rate (= the same
    /// budget posture), near-τ offers are all admitted while far offers
    /// are thinned by the Bernoulli sampler — so the near-τ share of the
    /// sampled set strictly exceeds its share of the offered traffic.
    #[test]
    fn near_tau_offers_are_over_represented_at_equal_budget() {
        let metrics = Arc::new(ServiceMetrics::with_models(K, 256));
        let shadow = Shadow::spawn(
            sim_engine(),
            sim_costs(),
            sim_meta(),
            metrics,
            ShadowConfig {
                rate: 0.25,
                reference: Some(2),
                margin: Some(0.05),
                queue_capacity: 512,
                ..Default::default()
            },
        )
        .unwrap();
        // 20% of offered traffic is near-τ, 80% is far.
        for j in 0..100 {
            shadow.offer_scored(&query_row(j), j % 5 == 0);
        }
        let snap = shadow.snapshot();
        assert_eq!(snap.sampled_near_tau, 20, "every near-τ offer is admitted");
        let far_sampled = snap.sampled - snap.sampled_near_tau;
        assert!(
            (8..=36).contains(&far_sampled),
            "far offers must be thinned at the base rate, got {far_sampled}/80"
        );
        let near_share_sampled = snap.sampled_near_tau as f64 / snap.sampled as f64;
        assert!(
            near_share_sampled > 0.2,
            "near-τ share of samples {near_share_sampled} must exceed its 0.2 traffic share"
        );
    }

    #[test]
    fn rejects_bad_configs() {
        let mk = |cfg: ShadowConfig| {
            Shadow::spawn(
                sim_engine(),
                sim_costs(),
                sim_meta(),
                Arc::new(ServiceMetrics::with_models(K, 8)),
                cfg,
            )
        };
        assert!(mk(ShadowConfig { rate: 0.0, ..Default::default() }).is_err());
        assert!(mk(ShadowConfig { rate: 1.5, ..Default::default() }).is_err());
        assert!(mk(ShadowConfig { reference: Some(9), ..Default::default() }).is_err());
        assert!(
            mk(ShadowConfig { budget_usd: Some(0.0), ..Default::default() }).is_err()
        );
        assert!(mk(ShadowConfig { margin: Some(-0.1), ..Default::default() }).is_err());
        assert!(
            mk(ShadowConfig { margin: Some(f32::NAN), ..Default::default() }).is_err()
        );
        assert!(mk(ShadowConfig { rate: 1.0, ..Default::default() }).is_ok());
        assert!(mk(ShadowConfig { referee: true, ..Default::default() }).is_ok());
        // a 2-model marketplace cannot seat two non-reference referees
        let two = CostModel {
            model_names: vec!["a".into(), "b".into()],
            pricing: vec![Pricing::new(2.0, 2.0, 0.0), Pricing::new(30.0, 60.0, 0.0)],
            latency: vec![LatencyModel { base_ms: 1.0, per_1k_tokens_ms: 1.0 }; 2],
            ..sim_costs()
        };
        assert!(Shadow::spawn(
            sim_engine(),
            two,
            sim_meta(),
            Arc::new(ServiceMetrics::with_models(2, 8)),
            ShadowConfig { referee: true, ..Default::default() },
        )
        .is_err());
    }
}
