//! Deterministic training of the per-query meta-router.
//!
//! The router ([`crate::strategies::router`]) is a multinomial logistic
//! model over per-query features; this module learns its weights from the
//! same decay-weighted [`ObservationWindow`](crate::server::metrics::ObservationWindow)
//! rows the plan reoptimizer already re-learns from — no extra labelling
//! machinery, no dependencies, and bit-reproducible given the same window
//! (fixed iteration order, full-batch gradient descent, seeded init).
//!
//! §Targets — routing is cost-sensitive classification, not plain
//! accuracy: for every window row each candidate route is *replayed*
//! ([`replay::replay_item`]) and scored with the utility
//! `correct − λ · cost`, where λ normalizes marketplace dollars against
//! the global route's mean window cost ([`RouterTrainConfig::cost_weight`]
//! units of accuracy for a whole global-route budget). The
//! highest-utility route is the training target, ties resolved to the
//! LOWEST route index — so when routing cannot help, every target is
//! route 0 and the trained model converges to the global plan.
//!
//! §Gate — the reoptimizer retrains on its cadence and publishes through
//! [`evaluate_router`] + the same `swap_worthy` hysteresis as plans, so a
//! noisy window cannot thrash router generations.

use anyhow::{bail, Result};

use crate::coordinator::cascade::{replay, CascadePlan};
use crate::coordinator::responses::SplitTable;
use crate::marketplace::CostModel;
use crate::strategies::router::{features, RouterModel, FEAT_PROBE, N_FEATURES};
use crate::util::rng::Rng;

/// Tuning for one router training run.
#[derive(Debug, Clone)]
pub struct RouterTrainConfig {
    /// Full-batch gradient-descent epochs.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
    /// Cost sensitivity of the route-utility targets: how many units of
    /// accuracy one whole global-route budget is worth (λ =
    /// `cost_weight / mean global-route cost`). 0.0 = accuracy only.
    pub cost_weight: f64,
    /// Seed of the tiny symmetric-breaking init noise.
    pub seed: u64,
}

impl Default for RouterTrainConfig {
    fn default() -> Self {
        RouterTrainConfig { epochs: 200, lr: 0.5, cost_weight: 0.25, seed: 0x5EED_F00D }
    }
}

/// A route candidate as the trainer sees it: the plan to replay plus how
/// many global-plan stages it skips (mirrors
/// [`crate::strategies::router::route_plans`] minus the labels).
pub type RouteSpec = (CascadePlan, usize);

/// Window-replay metrics of a routed policy (weighted means when the
/// table carries decay weights — same semantics as `replay::replay`).
#[derive(Debug, Clone)]
pub struct RoutedReplay {
    /// (Weighted) fraction of items the routed policy answers correctly.
    pub accuracy: f64,
    /// (Weighted) average USD per query, probe spend included.
    pub avg_cost: f64,
    /// How many items each route was picked for (unweighted counts).
    pub route_counts: Vec<u64>,
}

/// A trained router plus its training-window metrics.
#[derive(Debug, Clone)]
pub struct TrainedRouter {
    /// The learned weights.
    pub model: RouterModel,
    /// Routed accuracy on the training window.
    pub train_accuracy: f64,
    /// Routed average cost on the training window (USD per query).
    pub train_avg_cost: f64,
    /// Training-target histogram (how many rows preferred each route).
    pub target_counts: Vec<u64>,
}

/// The per-row feature vector the trainer and evaluator share with the
/// serving stage: length from the window's billable input tokens, probe
/// score from the probe model's *observed* window score (exactly what the
/// serving probe measures — the scorer's `g(q, probe answer)`), cache
/// signal 0.0 (the window carries no cache state; the weight stays
/// whatever it was initialized to, and serve-time extraction is gated on
/// it being nonzero).
fn row_features(
    table: &SplitTable,
    input_tokens: &[u32],
    probe_model: Option<usize>,
    i: usize,
) -> [f32; N_FEATURES] {
    let probe_score = probe_model.map(|m| table.score(m, i)).unwrap_or(0.0);
    features(input_tokens[i], probe_score, 0.0)
}

/// Marketplace cost of the probe call on row `i` (0.0 without a probe).
fn probe_cost(
    table: &SplitTable,
    costs: &CostModel,
    input_tokens: &[u32],
    probe_model: Option<usize>,
    i: usize,
) -> f64 {
    match probe_model {
        Some(m) => costs.call_cost(m, input_tokens[i], table.pred(m, i)),
        None => 0.0,
    }
}

fn softmax_in_place(z: &mut [f32]) {
    let max = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in z.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    for x in z.iter_mut() {
        *x /= sum;
    }
}

/// Train a router on a labelled window table. `routes[0]` must be the
/// global plan (the zero-utility baseline ties resolve to); `probe_model`
/// is the marketplace index of the probe (adds its score feature AND its
/// per-row cost to every route's utility-neutral overhead).
pub fn train_router(
    table: &SplitTable,
    input_tokens: &[u32],
    routes: &[RouteSpec],
    probe_model: Option<usize>,
    costs: &CostModel,
    cfg: &RouterTrainConfig,
) -> Result<TrainedRouter> {
    if routes.is_empty() {
        bail!("router training needs at least the global route");
    }
    if input_tokens.len() != table.len() {
        bail!(
            "input_tokens covers {} rows but the table has {}",
            input_tokens.len(),
            table.len()
        );
    }
    if table.len() == 0 {
        bail!("router training needs a non-empty window table");
    }
    let n = table.len();
    let n_routes = routes.len();

    // Replay every route on every row once; route 0's weighted mean cost
    // normalizes λ so `cost_weight` is unitless.
    let mut outcome = vec![(false, 0.0f64); n * n_routes];
    let mut w_sum = 0.0f64;
    let mut base_cost = 0.0f64;
    for i in 0..n {
        let w = table.weight(i);
        w_sum += w;
        for (r, (plan, _)) in routes.iter().enumerate() {
            let o = replay::replay_item(plan, table, costs, input_tokens, i);
            outcome[i * n_routes + r] = (o.correct, o.cost);
        }
        base_cost += w * outcome[i * n_routes].1;
    }
    let lambda = if cfg.cost_weight > 0.0 {
        cfg.cost_weight / (base_cost / w_sum).max(1e-12)
    } else {
        0.0
    };

    // Cost-sensitive targets: best utility, ties to the LOWEST index so
    // "routing can't help" degenerates to the global plan.
    let mut targets = vec![0usize; n];
    let mut target_counts = vec![0u64; n_routes];
    for i in 0..n {
        let mut best = 0usize;
        let mut best_u = f64::NEG_INFINITY;
        for r in 0..n_routes {
            let (correct, cost) = outcome[i * n_routes + r];
            let u = (correct as u64) as f64 - lambda * cost;
            if u > best_u {
                best_u = u;
                best = r;
            }
        }
        targets[i] = best;
        target_counts[best] += 1;
    }

    // Features once per row.
    let feats: Vec<[f32; N_FEATURES]> =
        (0..n).map(|i| row_features(table, input_tokens, probe_model, i)).collect();

    // Full-batch softmax regression, seeded tiny init noise (symmetric
    // breaking; small enough that an all-route-0 target set still decides
    // route 0 after the first epochs pull the bias apart).
    let mut rng = Rng::new(cfg.seed);
    let mut weights = vec![[0.0f32; N_FEATURES]; n_routes];
    for row in weights.iter_mut() {
        for w in row.iter_mut() {
            *w = (rng.f64() as f32 - 0.5) * 1e-3;
        }
    }
    let inv_w = (1.0 / w_sum) as f32;
    let mut z = vec![0.0f32; n_routes];
    let mut grad = vec![[0.0f32; N_FEATURES]; n_routes];
    for _ in 0..cfg.epochs {
        for g in grad.iter_mut() {
            *g = [0.0; N_FEATURES];
        }
        for i in 0..n {
            let f = &feats[i];
            for (r, zr) in z.iter_mut().enumerate() {
                *zr = weights[r].iter().zip(f.iter()).map(|(w, x)| w * x).sum();
            }
            softmax_in_place(&mut z);
            let wi = table.weight(i) as f32;
            for r in 0..n_routes {
                let err = wi * (z[r] - ((r == targets[i]) as u64) as f32);
                for (g, x) in grad[r].iter_mut().zip(f.iter()) {
                    *g += err * x;
                }
            }
        }
        for (wr, gr) in weights.iter_mut().zip(grad.iter()) {
            for (w, g) in wr.iter_mut().zip(gr.iter()) {
                *w -= cfg.lr * g * inv_w;
            }
        }
    }

    let model = RouterModel { weights };
    let eval = evaluate_router(&model, table, input_tokens, routes, probe_model, costs)?;
    Ok(TrainedRouter {
        model,
        train_accuracy: eval.accuracy,
        train_avg_cost: eval.avg_cost,
        target_counts,
    })
}

/// Replay a routed policy on a window table: decide each row with the
/// model (same features as serving), replay the chosen route, and return
/// weighted accuracy / cost — probe spend included whenever the model
/// actually reads the probe feature (mirroring the serving stage's paid
/// feature gate). This is what the reoptimizer feeds the `swap_worthy`
/// hysteresis gate.
pub fn evaluate_router(
    model: &RouterModel,
    table: &SplitTable,
    input_tokens: &[u32],
    routes: &[RouteSpec],
    probe_model: Option<usize>,
    costs: &CostModel,
) -> Result<RoutedReplay> {
    if routes.is_empty() || model.n_routes() != routes.len() {
        bail!(
            "router evaluation: model scores {} routes, got {}",
            model.n_routes(),
            routes.len()
        );
    }
    if input_tokens.len() != table.len() || table.len() == 0 {
        bail!("router evaluation needs a non-empty, token-aligned table");
    }
    let pay_probe = probe_model.is_some() && model.uses_feature(FEAT_PROBE);
    let mut acc = 0.0f64;
    let mut cost = 0.0f64;
    let mut w_sum = 0.0f64;
    let mut route_counts = vec![0u64; routes.len()];
    for i in 0..table.len() {
        let f = row_features(table, input_tokens, probe_model, i);
        let r = model.decide(&f).min(routes.len() - 1);
        route_counts[r] += 1;
        let o = replay::replay_item(&routes[r].0, table, costs, input_tokens, i);
        let w = table.weight(i);
        w_sum += w;
        acc += w * ((o.correct as u64) as f64);
        let mut c = o.cost;
        if pay_probe {
            c += probe_cost(table, costs, input_tokens, probe_model, i);
        }
        cost += w * c;
    }
    Ok(RoutedReplay {
        accuracy: acc / w_sum,
        avg_cost: cost / w_sum,
        route_counts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::Stage;
    use crate::coordinator::responses::TableBuilder;
    use crate::marketplace::{LatencyModel, Pricing};
    use crate::strategies::router::FEAT_BIAS;

    /// Two models: model 0 cheap, model 1 pricey but always right.
    fn costs2(cheap: f64, pricey: f64) -> CostModel {
        CostModel {
            dataset: "synth".into(),
            model_names: vec!["m0".into(), "m1".into()],
            pricing: vec![
                Pricing::new(cheap, cheap, 0.0),
                Pricing::new(pricey, pricey, 0.0),
            ],
            latency: vec![LatencyModel { base_ms: 1.0, per_1k_tokens_ms: 0.0 }; 2],
            answer_lens: vec![1, 1, 1, 1],
        }
    }

    /// Even items: SHORT (40 tokens) and model 0 answers them correctly
    /// with a confident score. Odd items: LONG (400 tokens) and model 0
    /// is wrong but *equally confident* — no (L, τ) separates the
    /// populations, only the router's length feature can.
    fn two_population_table(n: usize) -> (crate::coordinator::responses::SplitTable, Vec<u32>) {
        let mut b = TableBuilder::new("synth", vec!["m0".into(), "m1".into()]);
        let mut tokens = Vec::with_capacity(n);
        for i in 0..n {
            let label = (i % 4) as u32;
            let easy = i % 2 == 0;
            let m0_pred = if easy { label } else { (label + 1) % 4 };
            b.push_item(label, &[m0_pred, label], &[0.9, 0.97], &[easy, true]).unwrap();
            tokens.push(if easy { 40 } else { 400 });
        }
        (b.finish().unwrap(), tokens)
    }

    fn routes_pair() -> Vec<RouteSpec> {
        let global = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.95 }, // never accepts: always escalates
            Stage { model: 1, threshold: 0.0 },
        ]);
        let skip1 = CascadePlan::single(1);
        let cheap_only = CascadePlan::single(0);
        vec![(global, 0), (skip1, 1), (cheap_only, 0)]
    }

    #[test]
    fn targets_prefer_cheapest_correct_route_and_ties_go_to_global() {
        let (table, tokens) = two_population_table(64);
        let costs = costs2(2.0, 8.0);
        let trained = train_router(
            &table,
            &tokens,
            &routes_pair(),
            None,
            &costs,
            &RouterTrainConfig::default(),
        )
        .unwrap();
        // Easy rows: cheap-only is correct at a fraction of the cost →
        // target route 2. Hard rows: skip straight to model 1 (route 1)
        // beats paying the doomed model-0 call first (route 0).
        assert_eq!(trained.target_counts[2], 32, "easy rows target cheap-only");
        assert_eq!(trained.target_counts[1], 32, "hard rows target the skip");
        assert_eq!(trained.target_counts[0], 0);
    }

    #[test]
    fn trained_router_separates_populations_by_length() {
        let (table, tokens) = two_population_table(128);
        let costs = costs2(2.0, 8.0);
        let routes = routes_pair();
        let cfg = RouterTrainConfig::default();
        let trained = train_router(&table, &tokens, &routes, None, &costs, &cfg).unwrap();
        let eval =
            evaluate_router(&trained.model, &table, &tokens, &routes, None, &costs).unwrap();
        // Perfect accuracy (cheap on easy, skip-to-pricey on hard) at a
        // cost strictly below the global plan's replay.
        let global = replay::replay(&routes[0].0, &table, &costs, &tokens);
        assert!(eval.accuracy >= global.accuracy - 1e-9, "no accuracy loss");
        assert!(
            eval.avg_cost < global.avg_cost * 0.85,
            "routed cost {:.3e} should undercut global {:.3e} by >15%",
            eval.avg_cost,
            global.avg_cost
        );
        // The decisions themselves split by population.
        assert!(eval.route_counts[2] >= 51, "≥80% of easy rows routed cheap");
        assert!(eval.route_counts[1] >= 51, "≥80% of hard rows skip the prefix");
    }

    #[test]
    fn training_is_deterministic_for_a_fixed_seed() {
        let (table, tokens) = two_population_table(64);
        let costs = costs2(2.0, 8.0);
        let routes = routes_pair();
        let cfg = RouterTrainConfig::default();
        let a = train_router(&table, &tokens, &routes, None, &costs, &cfg).unwrap();
        let b = train_router(&table, &tokens, &routes, None, &costs, &cfg).unwrap();
        for (wa, wb) in a.model.weights.iter().zip(b.model.weights.iter()) {
            for (x, y) in wa.iter().zip(wb.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "training must be bit-reproducible");
            }
        }
        let c = train_router(
            &table,
            &tokens,
            &routes,
            None,
            &costs,
            &RouterTrainConfig { seed: 42, ..cfg },
        )
        .unwrap();
        assert_ne!(
            a.model.weights[0], c.model.weights[0],
            "a different seed perturbs the init"
        );
    }

    #[test]
    fn when_routing_cannot_help_the_model_converges_to_global() {
        // One model, one route-like choice structure: global vs an
        // identical copy — utilities tie everywhere, targets all route 0.
        let mut b = TableBuilder::new("synth", vec!["m0".into(), "m1".into()]);
        for i in 0..48 {
            let label = (i % 4) as u32;
            b.push_item(label, &[label, label], &[0.9, 0.9], &[true, true]).unwrap();
        }
        let table = b.finish().unwrap();
        let tokens = vec![64u32; 48];
        let costs = costs2(2.0, 2.0);
        let global = CascadePlan::single(0);
        let routes: Vec<RouteSpec> = vec![(global.clone(), 0), (global, 0)];
        let trained = train_router(
            &table,
            &tokens,
            &routes,
            None,
            &costs,
            &RouterTrainConfig::default(),
        )
        .unwrap();
        assert_eq!(trained.target_counts, vec![48, 0], "ties resolve to route 0");
        let eval =
            evaluate_router(&trained.model, &table, &tokens, &routes, None, &costs).unwrap();
        assert_eq!(eval.route_counts[0], 48, "trained model picks route 0 everywhere");
    }

    #[test]
    fn probe_feature_and_probe_billing_flow_through_evaluation() {
        let (table, tokens) = two_population_table(64);
        let costs = costs2(2.0, 8.0);
        let routes = routes_pair();
        // Hand-built model that reads ONLY the probe feature: high probe
        // score (model 0 confident + correct ≈ both populations here have
        // score 0.9, so this stays on one route — the point is billing).
        let mut model = RouterModel::degenerate(3);
        model.weights[2][FEAT_PROBE] = 5.0;
        let with_probe =
            evaluate_router(&model, &table, &tokens, &routes, Some(0), &costs).unwrap();
        let mut free = RouterModel::degenerate(3);
        free.weights[2][FEAT_BIAS] = 5.0; // same decisions, no probe read
        let without =
            evaluate_router(&free, &table, &tokens, &routes, Some(0), &costs).unwrap();
        assert_eq!(with_probe.route_counts, without.route_counts);
        assert!(
            with_probe.avg_cost > without.avg_cost,
            "reading the probe must bill the probe call"
        );
    }

    #[test]
    fn validation_rejects_malformed_inputs() {
        let (table, tokens) = two_population_table(16);
        let costs = costs2(2.0, 8.0);
        let cfg = RouterTrainConfig::default();
        assert!(train_router(&table, &tokens, &[], None, &costs, &cfg).is_err());
        assert!(
            train_router(&table, &tokens[..8], &routes_pair(), None, &costs, &cfg).is_err()
        );
        let m = RouterModel::degenerate(2);
        assert!(evaluate_router(&m, &table, &tokens, &routes_pair(), None, &costs).is_err());
    }
}
