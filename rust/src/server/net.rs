//! The network front door: a hand-rolled thread-per-core TCP accept
//! loop serving line-delimited JSON over a [`FrugalService`] — no
//! framework, no async runtime, same vendored-substrate discipline as
//! the rest of the crate.
//!
//! ## Protocol (`frugald/1`)
//!
//! One frame per `\n`-terminated line, both directions. Query frames
//! are JSON objects:
//!
//! ```json
//! {"query": [17, 42, 9], "id": 7}
//! ```
//!
//! and are answered with the canonical [`ServiceAnswer`] wire schema
//! ([`ServiceAnswer::to_value`]) plus the echoed `id` (if any). Admin
//! frames start with `/`:
//!
//! * `/health` — liveness + plan version + lifetime counters;
//! * `/metrics` — the full [`MetricsSnapshot`] wire schema
//!   (`MetricsSnapshot::to_value`, parseable by `from_value`);
//! * `/reprice <model> <mult>` — marketplace price step (index or
//!   name), republishes the plan;
//! * `/shutdown` — graceful drain: acceptors stop, in-flight requests
//!   finish, every connection closes.
//!
//! Errors are replies, not disconnects: a malformed or oversized frame
//! gets `{"error": ..., "code": ...}` and the connection survives —
//! only EOF/io failure closes it. Per-connection backpressure is
//! structural: each connection is served synchronously (read → answer →
//! write), so a client gets at most one answer in flight per pipelined
//! batch it actually wrote, and a stalled reader stalls only itself.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::server::service::{FrugalService, ServiceAnswer};
use crate::util::json::Value;

/// Protocol identifier echoed by `/health`.
pub const WIRE_PROTOCOL: &str = "frugald/1";

/// Tuning for the TCP front door.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Hard per-frame byte cap; longer lines are drained to the next
    /// newline and rejected with an `oversized` error reply.
    pub max_line_bytes: usize,
    /// Concurrent-connection cap; accepts beyond it are refused with an
    /// `overloaded` error line.
    pub max_connections: usize,
    /// Acceptor threads (thread-per-core by default).
    pub accept_threads: usize,
    /// Poll tick at which acceptors and idle connections observe the
    /// shutdown flag.
    pub tick: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_line_bytes: 64 * 1024,
            max_connections: 1024,
            accept_threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            tick: Duration::from_millis(25),
        }
    }
}

/// Lifetime counters of one front door (all relaxed atomics).
#[derive(Debug, Default)]
pub struct NetStats {
    /// Connections accepted.
    pub accepted: AtomicU64,
    /// Connections refused at the `max_connections` cap.
    pub rejected: AtomicU64,
    /// Frames that reached the dispatcher.
    pub requests: AtomicU64,
    /// Query frames answered successfully.
    pub answered: AtomicU64,
    /// Admin frames served.
    pub admin: AtomicU64,
    /// Malformed/unparseable/oversized frames (error reply sent, connection kept).
    pub protocol_errors: AtomicU64,
    /// Oversized frames among the protocol errors.
    pub oversized: AtomicU64,
    /// Query frames whose answer failed service-side.
    pub answer_errors: AtomicU64,
    /// Connections that vanished mid-frame (EOF with bytes pending).
    pub half_frames: AtomicU64,
}

impl NetStats {
    /// JSON form (all counters), embedded in `/health` replies and the
    /// daemon's exit report.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        let mut put = |k: &str, v: &AtomicU64| {
            m.insert(k.to_string(), Value::Num(v.load(Ordering::Relaxed) as f64));
        };
        put("accepted", &self.accepted);
        put("rejected", &self.rejected);
        put("requests", &self.requests);
        put("answered", &self.answered);
        put("admin", &self.admin);
        put("protocol_errors", &self.protocol_errors);
        put("oversized", &self.oversized);
        put("answer_errors", &self.answer_errors);
        put("half_frames", &self.half_frames);
        Value::Obj(m)
    }

    fn bump(&self, c: &AtomicU64) {
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// Active-connection gauge: handlers hold a guard; `join` waits for the
/// count to drain after the acceptors stop.
#[derive(Default)]
struct ConnGauge {
    active: Mutex<usize>,
    drained: Condvar,
}

impl ConnGauge {
    fn current(&self) -> usize {
        *self.active.lock().unwrap()
    }

    fn enter(self: &Arc<Self>) -> ConnGuard {
        *self.active.lock().unwrap() += 1;
        ConnGuard(self.clone())
    }

    fn wait_drained(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut active = self.active.lock().unwrap();
        while *active > 0 {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            let (a, _) = self.drained.wait_timeout(active, left).unwrap();
            active = a;
        }
        true
    }
}

struct ConnGuard(Arc<ConnGauge>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        *self.0.active.lock().unwrap() -= 1;
        self.0.drained.notify_all();
    }
}

/// One bound, serving front door. Dropping it (after [`FrontDoor::join`])
/// releases the listening socket.
pub struct FrontDoor {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    stats: Arc<NetStats>,
    gauge: Arc<ConnGauge>,
    acceptors: Vec<JoinHandle<()>>,
    /// Kept so the listening socket lives exactly as long as the door.
    _listener: TcpListener,
}

impl FrontDoor {
    /// Bind `addr` (port 0 picks an ephemeral port — read it back via
    /// [`FrontDoor::local_addr`]) and start the acceptor threads.
    pub fn bind(svc: Arc<FrugalService>, addr: &str, cfg: NetConfig) -> Result<FrontDoor> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding front door on {addr}"))?;
        listener.set_nonblocking(true).context("nonblocking listener")?;
        let addr = listener.local_addr().context("local_addr")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(NetStats::default());
        let gauge = Arc::new(ConnGauge::default());
        let cfg = Arc::new(cfg);
        let mut acceptors = Vec::new();
        for _ in 0..cfg.accept_threads.max(1) {
            let l = listener.try_clone().context("cloning listener")?;
            let svc = svc.clone();
            let shutdown = shutdown.clone();
            let stats = stats.clone();
            let gauge = gauge.clone();
            let cfg = cfg.clone();
            acceptors.push(std::thread::spawn(move || {
                accept_loop(l, svc, shutdown, stats, gauge, cfg)
            }));
        }
        Ok(FrontDoor { addr, shutdown, stats, gauge, acceptors, _listener: listener })
    }

    /// The bound address (resolves `--listen host:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Lifetime counters.
    pub fn stats(&self) -> Arc<NetStats> {
        self.stats.clone()
    }

    /// Ask the door to drain (what `/shutdown` does from the wire).
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until shutdown is requested (by [`FrontDoor::request_shutdown`]
    /// or a `/shutdown` frame) and every connection has drained.
    pub fn join(self) -> Result<Arc<NetStats>> {
        for a in self.acceptors {
            a.join().map_err(|_| anyhow::anyhow!("acceptor thread panicked"))?;
        }
        // Acceptors only exit on the shutdown flag; give in-flight
        // connections a grace period to finish their current frame.
        if !self.gauge.wait_drained(Duration::from_secs(10)) {
            anyhow::bail!("{} connections still active after drain grace", self.gauge.current());
        }
        Ok(self.stats)
    }
}

fn accept_loop(
    listener: TcpListener,
    svc: Arc<FrugalService>,
    shutdown: Arc<AtomicBool>,
    stats: Arc<NetStats>,
    gauge: Arc<ConnGauge>,
    cfg: Arc<NetConfig>,
) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if gauge.current() >= cfg.max_connections {
                    stats.bump(&stats.rejected);
                    refuse(stream);
                    continue;
                }
                stats.bump(&stats.accepted);
                let guard = gauge.enter();
                let svc = svc.clone();
                let shutdown = shutdown.clone();
                let stats = stats.clone();
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    let _guard = guard;
                    // Io errors just close this connection; the error
                    // surface of the protocol is in-band replies.
                    let _ = serve_conn(&svc, stream, &shutdown, &stats, &cfg);
                });
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(cfg.tick);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => {
                // Transient accept failure (EMFILE, ECONNABORTED, ...):
                // back off a tick instead of killing the acceptor.
                std::thread::sleep(cfg.tick);
            }
        }
    }
}

fn refuse(mut stream: TcpStream) {
    let _ = stream.write_all(
        format!("{}\n", error_reply("server at connection capacity", "overloaded", None).to_json())
            .as_bytes(),
    );
}

/// Outcome of reading one frame.
enum Frame {
    /// A complete line (without the trailing `\n`).
    Line(Vec<u8>),
    /// The line exceeded `max_line_bytes`; the excess was drained to the
    /// newline, the connection is intact.
    Oversized,
    /// Clean end of stream (`mid_frame` when bytes were pending).
    Eof { mid_frame: bool },
}

/// Read one `\n`-delimited frame, tolerating arbitrarily fragmented
/// reads, enforcing the byte cap, and observing the shutdown flag while
/// idle (the stream carries a read timeout of one tick).
fn read_frame<R: BufRead>(
    r: &mut R,
    max: usize,
    shutdown: &AtomicBool,
) -> std::io::Result<Frame> {
    let mut buf: Vec<u8> = Vec::new();
    let mut dropping = false;
    loop {
        let (used, done) = {
            let chunk = match r.fill_buf() {
                Ok(c) => c,
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    if shutdown.load(Ordering::SeqCst) {
                        return Ok(Frame::Eof { mid_frame: dropping || !buf.is_empty() });
                    }
                    continue;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if chunk.is_empty() {
                return Ok(Frame::Eof { mid_frame: dropping || !buf.is_empty() });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    if !dropping && buf.len() + pos > max {
                        dropping = true;
                    }
                    if !dropping {
                        buf.extend_from_slice(&chunk[..pos]);
                    }
                    (pos + 1, true)
                }
                None => {
                    if !dropping {
                        buf.extend_from_slice(chunk);
                        if buf.len() > max {
                            dropping = true;
                            buf.clear();
                        }
                    }
                    (chunk.len(), false)
                }
            }
        };
        r.consume(used);
        if done {
            return Ok(if dropping { Frame::Oversized } else { Frame::Line(std::mem::take(&mut buf)) });
        }
    }
}

fn serve_conn(
    svc: &FrugalService,
    stream: TcpStream,
    shutdown: &AtomicBool,
    stats: &NetStats,
    cfg: &NetConfig,
) -> Result<()> {
    // Accepted sockets may inherit nonblocking from the listener on some
    // platforms; force blocking + a tick-sized read timeout so idle
    // connections observe shutdown without busy-polling.
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(cfg.tick)).ok();
    let mut writer = stream.try_clone().context("cloning connection stream")?;
    let mut reader = BufReader::new(stream);
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let reply = match read_frame(&mut reader, cfg.max_line_bytes, shutdown)? {
            Frame::Eof { mid_frame } => {
                if mid_frame {
                    stats.bump(&stats.half_frames);
                }
                return Ok(());
            }
            Frame::Oversized => {
                stats.bump(&stats.requests);
                stats.bump(&stats.protocol_errors);
                stats.bump(&stats.oversized);
                error_reply(
                    &format!("frame exceeds {} bytes", cfg.max_line_bytes),
                    "oversized",
                    None,
                )
            }
            Frame::Line(bytes) => {
                if bytes.iter().all(u8::is_ascii_whitespace) {
                    continue; // blank keep-alive line
                }
                stats.bump(&stats.requests);
                match dispatch(svc, &bytes, shutdown, stats) {
                    Some(v) => v,
                    None => continue,
                }
            }
        };
        writer.write_all(reply.to_json().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

fn error_reply(msg: &str, code: &str, id: Option<Value>) -> Value {
    let mut m = std::collections::HashMap::new();
    m.insert("error".to_string(), Value::Str(msg.to_string()));
    m.insert("code".to_string(), Value::Str(code.to_string()));
    if let Some(id) = id {
        m.insert("id".to_string(), id);
    }
    Value::Obj(m)
}

fn dispatch(
    svc: &FrugalService,
    line: &[u8],
    shutdown: &AtomicBool,
    stats: &NetStats,
) -> Option<Value> {
    let text = match std::str::from_utf8(line) {
        Ok(t) => t.trim(),
        Err(_) => {
            stats.bump(&stats.protocol_errors);
            return Some(error_reply("frame is not UTF-8", "bad_frame", None));
        }
    };
    if let Some(verb) = text.strip_prefix('/') {
        stats.bump(&stats.admin);
        return Some(admin(svc, verb, shutdown, stats));
    }
    let v = match Value::parse(text) {
        Ok(v) => v,
        Err(e) => {
            stats.bump(&stats.protocol_errors);
            return Some(error_reply(&format!("bad JSON: {e}"), "bad_json", None));
        }
    };
    let id = match v.get("id") {
        Value::Null => None,
        other => Some(other.clone()),
    };
    let tokens: Option<Vec<i32>> = v
        .get("query")
        .as_arr()
        .map(|arr| arr.iter().filter_map(|t| t.as_f64().map(|f| f as i32)).collect());
    let tokens = match tokens {
        Some(t) if !t.is_empty() && t.len() == v.get("query").as_arr().unwrap().len() => t,
        _ => {
            stats.bump(&stats.protocol_errors);
            return Some(error_reply(
                "`query` must be a non-empty array of integer tokens",
                "bad_request",
                id,
            ));
        }
    };
    match svc.answer(&tokens) {
        Ok(ans) => {
            stats.bump(&stats.answered);
            let mut reply = match ans.to_value() {
                Value::Obj(m) => m,
                _ => unreachable!("ServiceAnswer::to_value returns an object"),
            };
            if let Some(id) = id {
                reply.insert("id".to_string(), id);
            }
            Some(Value::Obj(reply))
        }
        Err(e) => {
            stats.bump(&stats.answer_errors);
            Some(error_reply(&format!("answer failed: {e:#}"), "answer_failed", id))
        }
    }
}

fn admin(svc: &FrugalService, verb: &str, shutdown: &AtomicBool, stats: &NetStats) -> Value {
    let mut parts = verb.split_whitespace();
    match parts.next().unwrap_or("") {
        "health" => {
            let mut m = std::collections::HashMap::new();
            m.insert("protocol".to_string(), Value::Str(WIRE_PROTOCOL.to_string()));
            m.insert("status".to_string(), Value::Str("ok".to_string()));
            m.insert("plan_version".to_string(), Value::Num(svc.plan_version() as f64));
            m.insert("spend_usd".to_string(), Value::Num(svc.budget.spent_usd()));
            m.insert("net".to_string(), stats.to_value());
            if let Some(h) = svc.health() {
                m.insert(
                    "breakers".to_string(),
                    Value::Arr(h.snapshot().iter().map(|s| s.to_value()).collect()),
                );
            }
            Value::Obj(m)
        }
        "metrics" => svc.metrics.snapshot().to_value(),
        "reprice" => {
            let (model, mult) = (parts.next(), parts.next().and_then(|m| m.parse::<f64>().ok()));
            let names = svc.costs().model_names;
            let model = model.and_then(|m| {
                m.parse::<usize>().ok().filter(|&i| i < names.len()).or_else(|| {
                    names.iter().position(|n| n == m)
                })
            });
            match (model, mult) {
                (Some(model), Some(mult)) if mult > 0.0 => {
                    match svc.reprice(model, mult, "admin /reprice") {
                        Ok(version) => {
                            let mut m = std::collections::HashMap::new();
                            m.insert("ok".to_string(), Value::Bool(true));
                            m.insert("model".to_string(), Value::Str(names[model].clone()));
                            m.insert("plan_version".to_string(), Value::Num(version as f64));
                            Value::Obj(m)
                        }
                        Err(e) => error_reply(&format!("reprice failed: {e:#}"), "reprice_failed", None),
                    }
                }
                _ => error_reply(
                    "usage: /reprice <model index|name> <positive multiplier>",
                    "bad_request",
                    None,
                ),
            }
        }
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            let mut m = std::collections::HashMap::new();
            m.insert("ok".to_string(), Value::Bool(true));
            m.insert("draining".to_string(), Value::Bool(true));
            Value::Obj(m)
        }
        other => error_reply(&format!("unknown admin verb `/{other}`"), "unknown_verb", None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    /// A reader that hands out its payload `chunk` bytes at a time —
    /// the in-memory stand-in for fragmented TCP reads.
    struct Chunked {
        data: Vec<u8>,
        pos: usize,
        chunk: usize,
    }

    impl Read for Chunked {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(out.len()).min(self.data.len() - self.pos);
            out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn frames(data: &[u8], chunk: usize, max: usize) -> Vec<Frame> {
        let flag = AtomicBool::new(false);
        let mut r = BufReader::with_capacity(
            chunk.max(1),
            Chunked { data: data.to_vec(), pos: 0, chunk },
        );
        let mut out = Vec::new();
        loop {
            let f = read_frame(&mut r, max, &flag).unwrap();
            let eof = matches!(f, Frame::Eof { .. });
            out.push(f);
            if eof {
                return out;
            }
        }
    }

    #[test]
    fn fragmented_reads_reassemble_lines() {
        for chunk in [1, 2, 3, 7, 64] {
            let fs = frames(b"hello\nworld\n", chunk, 1024);
            assert_eq!(fs.len(), 3, "chunk={chunk}");
            assert!(matches!(&fs[0], Frame::Line(l) if l == b"hello"));
            assert!(matches!(&fs[1], Frame::Line(l) if l == b"world"));
            assert!(matches!(fs[2], Frame::Eof { mid_frame: false }));
        }
    }

    #[test]
    fn oversized_line_is_drained_not_fatal() {
        let mut data = vec![b'a'; 300];
        data.push(b'\n');
        data.extend_from_slice(b"ok\n");
        for chunk in [1, 5, 512] {
            let fs = frames(&data, chunk, 100);
            assert!(matches!(fs[0], Frame::Oversized), "chunk={chunk}");
            assert!(matches!(&fs[1], Frame::Line(l) if l == b"ok"));
            assert!(matches!(fs[2], Frame::Eof { mid_frame: false }));
        }
    }

    #[test]
    fn oversized_detection_counts_buffered_prefix() {
        // 90 bytes buffered + 20 before the newline = 110 > 100: the cap
        // applies to the whole logical line, not per-chunk.
        let mut data = vec![b'b'; 110];
        data.push(b'\n');
        let fs = frames(&data, 90, 100);
        assert!(matches!(fs[0], Frame::Oversized));
    }

    #[test]
    fn eof_mid_frame_is_flagged() {
        let fs = frames(b"complete\nhalf", 4, 1024);
        assert!(matches!(&fs[0], Frame::Line(l) if l == b"complete"));
        assert!(matches!(fs[1], Frame::Eof { mid_frame: true }));
    }

    #[test]
    fn empty_lines_and_exact_cap_pass() {
        let fs = frames(b"\nabc\n", 2, 3);
        assert!(matches!(&fs[0], Frame::Line(l) if l.is_empty()));
        assert!(matches!(&fs[1], Frame::Line(l) if l == b"abc"));
    }
}
