//! Per-model health: a lock-free circuit breaker for every marketplace
//! model, plus bounded retry with deterministically-jittered exponential
//! backoff — the availability layer the live cascade consults so one
//! rate-limited API degrades routing instead of erroring answers.
//!
//! §Breaker. Each model gets the classic three-state machine, with all
//! state in relaxed atomics (the same accounting style as
//! `server::shadow`'s stats — no locks anywhere near the answer path):
//!
//! * **Closed** — calls flow. Failures feed a consecutive-failure count
//!   and a decay-windowed (EWMA) failure rate; crossing either trip
//!   threshold opens the breaker.
//! * **Open** — calls are *skipped* (the cascade routes around the
//!   model). Recovery is **call-count-based, never wall-clock**: each
//!   skipped consult ticks a cooldown counter down, and the consult that
//!   exhausts it moves the breaker to HalfOpen — so hermetic tests
//!   indexed by query count see deterministic trip/recover points.
//! * **HalfOpen** — exactly one probe call is admitted (an atomic claim
//!   flag serializes concurrent consults). A probe success closes the
//!   breaker; a probe failure re-opens it with a fresh cooldown.
//!
//! §Retry. [`ModelHealth::retry_backoff_us`] derives each retry's backoff
//! from `util::rng::splitmix64_mix` over an atomic counter stream — the
//! same splitmix idiom as the shadow sampler — so the jitter sequence is
//! a pure function of the configured seed (no `Instant::now` anywhere).
//!
//! §Locality. Breaker decisions are *local* to one model: tripping model
//! `m` never touches model `n`'s state, and the registry never inspects
//! the plan — the cascade asks one question (`admit(m)`) per stage and
//! reports one outcome (`record(m, ok)`) per call. Pinned by
//! `breaker_decisions_are_local` below.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::coordinator::cascade::{Gate, HealthView};
use crate::util::json::Value;
use crate::util::rng::{splitmix64_mix, SPLITMIX64_GOLDEN};

/// Breaker state values (stored in an `AtomicU64`).
const STATE_CLOSED: u64 = 0;
const STATE_OPEN: u64 = 1;
const STATE_HALF_OPEN: u64 = 2;

/// Fixed-point scale of the EWMA failure rate (1.0 == `RATE_ONE`).
const RATE_ONE: u64 = 1_000_000;

/// Observable state of one model's circuit breaker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Calls flow normally.
    Closed,
    /// Calls are skipped; a call-count cooldown is ticking.
    Open,
    /// One probe call is admitted to test recovery.
    HalfOpen,
}

impl BreakerState {
    /// Stable lowercase name (serve summary, `report health`).
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }

    fn from_u64(v: u64) -> BreakerState {
        match v {
            STATE_OPEN => BreakerState::Open,
            STATE_HALF_OPEN => BreakerState::HalfOpen,
            _ => BreakerState::Closed,
        }
    }
}

/// Health-layer tuning. Everything is counted in *calls/consults*, never
/// wall-clock time, so scripted scenarios stay deterministic.
#[derive(Debug, Clone)]
pub struct HealthConfig {
    /// Consecutive failures that trip the breaker open.
    pub trip_consecutive: u64,
    /// Decay-windowed failure rate (0..1] that trips the breaker once
    /// `min_calls` outcomes have been observed.
    pub trip_rate: f64,
    /// Minimum observed calls before the rate threshold may trip.
    pub min_calls: u64,
    /// Decay window of the failure-rate EWMA, in calls.
    pub ewma_window: u64,
    /// Skipped consults an open breaker waits before admitting a
    /// half-open probe.
    pub cooldown: u64,
    /// Bounded retries per engine call (0 = fail fast).
    pub max_retries: u32,
    /// Base backoff before the first retry (µs); doubles per attempt,
    /// jittered to `[0.5, 1.5)` of the exponential value. 0 = no sleep
    /// (hermetic tests).
    pub backoff_base_us: u64,
    /// Seed of the deterministic jitter stream.
    pub seed: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig {
            trip_consecutive: 3,
            trip_rate: 0.6,
            min_calls: 8,
            ewma_window: 16,
            cooldown: 16,
            max_retries: 2,
            backoff_base_us: 200,
            seed: 0x48EA_17,
        }
    }
}

/// One model's breaker: all state in relaxed atomics.
#[derive(Debug)]
pub struct Breaker {
    state: AtomicU64,
    /// Cooldown consults left while open.
    cooldown_left: AtomicU64,
    /// Claim flag serializing the half-open probe.
    probe_claimed: AtomicBool,
    /// Outcomes observed (successes + failures).
    calls: AtomicU64,
    /// Failed calls (total, monotone).
    failures: AtomicU64,
    /// Current consecutive-failure run.
    consecutive: AtomicU64,
    /// EWMA failure rate in `RATE_ONE` fixed point.
    rate_fp: AtomicU64,
    /// Closed→Open transitions.
    trips: AtomicU64,
    /// HalfOpen→Closed transitions (successful probes).
    recoveries: AtomicU64,
    /// Consults answered with `Gate::Skip`.
    skips: AtomicU64,
    /// Bounded retries spent against this model.
    retries: AtomicU64,
    // per-breaker copies of the registry config (no pointer chasing)
    trip_consecutive: u64,
    trip_rate_fp: u64,
    min_calls: u64,
    ewma_window: u64,
    cooldown: u64,
}

impl Breaker {
    fn new(cfg: &HealthConfig) -> Breaker {
        Breaker {
            state: AtomicU64::new(STATE_CLOSED),
            cooldown_left: AtomicU64::new(0),
            probe_claimed: AtomicBool::new(false),
            calls: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            consecutive: AtomicU64::new(0),
            rate_fp: AtomicU64::new(0),
            trips: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            skips: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            trip_consecutive: cfg.trip_consecutive.max(1),
            trip_rate_fp: (cfg.trip_rate.clamp(0.0, 1.0) * RATE_ONE as f64) as u64,
            min_calls: cfg.min_calls.max(1),
            ewma_window: cfg.ewma_window.max(1),
            cooldown: cfg.cooldown.max(1),
        }
    }

    /// Current state.
    pub fn state(&self) -> BreakerState {
        BreakerState::from_u64(self.state.load(Ordering::Relaxed))
    }

    /// May the model be called right now? Open breakers tick their
    /// cooldown; the consult that exhausts it claims the half-open probe.
    pub fn admit(&self) -> Gate {
        match self.state.load(Ordering::Relaxed) {
            STATE_CLOSED => Gate::Allow,
            STATE_OPEN => {
                let exhausted = self
                    .cooldown_left
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
                    .is_err();
                if exhausted
                    && self
                        .state
                        .compare_exchange(
                            STATE_OPEN,
                            STATE_HALF_OPEN,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        )
                        .is_ok()
                    && !self.probe_claimed.swap(true, Ordering::Relaxed)
                {
                    return Gate::Probe;
                }
                self.skips.fetch_add(1, Ordering::Relaxed);
                Gate::Skip
            }
            _ => {
                // HalfOpen: exactly one in-flight probe at a time.
                if self.probe_claimed.swap(true, Ordering::Relaxed) {
                    self.skips.fetch_add(1, Ordering::Relaxed);
                    Gate::Skip
                } else {
                    Gate::Probe
                }
            }
        }
    }

    /// Report a call outcome (success closes a half-open breaker; failure
    /// trips closed breakers over threshold and re-opens half-open ones).
    pub fn record(&self, ok: bool) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let rate = self.update_rate(!ok);
        if ok {
            self.consecutive.store(0, Ordering::Relaxed);
            if self
                .state
                .compare_exchange(
                    STATE_HALF_OPEN,
                    STATE_CLOSED,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_ok()
            {
                self.recoveries.fetch_add(1, Ordering::Relaxed);
                // A recovered model starts with a clean slate: the storm's
                // failure rate must not instantly re-trip it.
                self.rate_fp.store(0, Ordering::Relaxed);
                self.probe_claimed.store(false, Ordering::Relaxed);
            }
            return;
        }
        self.failures.fetch_add(1, Ordering::Relaxed);
        let consec = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        match self.state.load(Ordering::Relaxed) {
            STATE_HALF_OPEN => self.trip(), // failed probe → re-open
            STATE_CLOSED => {
                let seen = self.calls.load(Ordering::Relaxed);
                if consec >= self.trip_consecutive
                    || (seen >= self.min_calls && rate > self.trip_rate_fp)
                {
                    self.trip();
                }
            }
            _ => {}
        }
    }

    /// Open the breaker (arming the cooldown and probe gate *before* the
    /// state flip, so a racing `admit` never sees open with stale arms).
    fn trip(&self) {
        self.cooldown_left.store(self.cooldown, Ordering::Relaxed);
        self.probe_claimed.store(false, Ordering::Relaxed);
        if self.state.swap(STATE_OPEN, Ordering::Relaxed) != STATE_OPEN {
            self.trips.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// CAS-loop EWMA update; returns the new fixed-point rate.
    fn update_rate(&self, failed: bool) -> u64 {
        let sample = if failed { RATE_ONE } else { 0 } as i64;
        let w = self.ewma_window as i64;
        let mut cur = self.rate_fp.load(Ordering::Relaxed);
        loop {
            let next = (cur as i64 + (sample - cur as i64) / w).clamp(0, RATE_ONE as i64) as u64;
            match self.rate_fp.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return next,
                Err(v) => cur = v,
            }
        }
    }

    /// Point-in-time copy of the breaker's counters.
    pub fn snapshot(&self) -> BreakerSnapshot {
        BreakerSnapshot {
            state: self.state(),
            calls: self.calls.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            failure_rate: self.rate_fp.load(Ordering::Relaxed) as f64 / RATE_ONE as f64,
            trips: self.trips.load(Ordering::Relaxed),
            recoveries: self.recoveries.load(Ordering::Relaxed),
            skips: self.skips.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time breaker counters for one model (serve summary, swap log,
/// `report health`).
#[derive(Debug, Clone, PartialEq)]
pub struct BreakerSnapshot {
    /// Breaker state at snapshot time.
    pub state: BreakerState,
    /// Outcomes observed.
    pub calls: u64,
    /// Failed calls.
    pub failures: u64,
    /// Decay-windowed failure rate (0..1).
    pub failure_rate: f64,
    /// Closed→Open transitions.
    pub trips: u64,
    /// Successful half-open probes.
    pub recoveries: u64,
    /// Consults skipped while open/half-open.
    pub skips: u64,
    /// Bounded retries spent.
    pub retries: u64,
}

impl BreakerSnapshot {
    /// JSON form for the swap log's `health` section.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("state".to_string(), Value::Str(self.state.name().to_string()));
        m.insert("calls".to_string(), Value::Num(self.calls as f64));
        m.insert("failures".to_string(), Value::Num(self.failures as f64));
        m.insert("failure_rate".to_string(), Value::Num(self.failure_rate));
        m.insert("trips".to_string(), Value::Num(self.trips as f64));
        m.insert("recoveries".to_string(), Value::Num(self.recoveries as f64));
        m.insert("skips".to_string(), Value::Num(self.skips as f64));
        m.insert("retries".to_string(), Value::Num(self.retries as f64));
        Value::Obj(m)
    }
}

/// The per-model health registry: one [`Breaker`] per marketplace model
/// plus the deterministic retry/backoff stream. Shared (`Arc`) between
/// the service, every plan bundle's cascades, and the serve report.
#[derive(Debug)]
pub struct ModelHealth {
    breakers: Vec<Breaker>,
    cfg: HealthConfig,
    /// splitmix64 counter stream feeding the backoff jitter.
    jitter_state: AtomicU64,
}

impl ModelHealth {
    /// A registry of `n_models` closed breakers.
    pub fn new(n_models: usize, cfg: HealthConfig) -> ModelHealth {
        ModelHealth {
            breakers: (0..n_models).map(|_| Breaker::new(&cfg)).collect(),
            jitter_state: AtomicU64::new(splitmix64_mix(cfg.seed)),
            cfg,
        }
    }

    /// The tuning this registry was built with.
    pub fn config(&self) -> &HealthConfig {
        &self.cfg
    }

    /// Models tracked.
    pub fn n_models(&self) -> usize {
        self.breakers.len()
    }

    /// Model `m`'s breaker (`None` out of range).
    pub fn breaker(&self, m: usize) -> Option<&Breaker> {
        self.breakers.get(m)
    }

    /// Model `m`'s current breaker state (out of range → Closed).
    pub fn state(&self, m: usize) -> BreakerState {
        self.breakers.get(m).map(|b| b.state()).unwrap_or(BreakerState::Closed)
    }

    /// Per-model snapshots, marketplace order.
    pub fn snapshot(&self) -> Vec<BreakerSnapshot> {
        self.breakers.iter().map(Breaker::snapshot).collect()
    }
}

impl HealthView for ModelHealth {
    /// Gate one call against model `m`. Out-of-range indices are allowed
    /// through — an unknown model is the engine's error to raise, not an
    /// availability decision.
    fn admit(&self, m: usize) -> Gate {
        self.breakers.get(m).map(Breaker::admit).unwrap_or(Gate::Allow)
    }

    fn record(&self, m: usize, ok: bool) {
        if let Some(b) = self.breakers.get(m) {
            b.record(ok);
        }
    }

    fn max_retries(&self) -> u32 {
        self.cfg.max_retries
    }

    /// Count one retry against model `m` and return its backoff:
    /// `base · 2^(attempt-1)`, jittered to `[0.5, 1.5)` of that value by
    /// the splitmix64 stream — deterministic in `cfg.seed`, no wall clock.
    fn retry_backoff_us(&self, m: usize, attempt: u32) -> u64 {
        if let Some(b) = self.breakers.get(m) {
            b.retries.fetch_add(1, Ordering::Relaxed);
        }
        if self.cfg.backoff_base_us == 0 {
            return 0;
        }
        let s = self
            .jitter_state
            .fetch_add(SPLITMIX64_GOLDEN, Ordering::Relaxed)
            .wrapping_add(SPLITMIX64_GOLDEN);
        let frac = (splitmix64_mix(s) >> 11) as f64 / (1u64 << 53) as f64;
        let exp = self.cfg.backoff_base_us as f64
            * 2f64.powi(attempt.saturating_sub(1).min(20) as i32);
        (exp * (0.5 + frac)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            trip_consecutive: 3,
            cooldown: 4,
            max_retries: 1,
            backoff_base_us: 100,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn breaker_trips_on_consecutive_failures_and_recovers_via_probe() {
        let h = ModelHealth::new(2, cfg());
        // three consecutive failures trip the breaker
        for _ in 0..3 {
            assert_eq!(h.admit(0), Gate::Allow);
            h.record(0, false);
        }
        assert_eq!(h.state(0), BreakerState::Open);
        assert_eq!(h.breaker(0).unwrap().snapshot().trips, 1);
        // cooldown: 4 skipped consults...
        for _ in 0..4 {
            assert_eq!(h.admit(0), Gate::Skip);
        }
        // ...then the next consult is the half-open probe
        assert_eq!(h.admit(0), Gate::Probe);
        assert_eq!(h.state(0), BreakerState::HalfOpen);
        // concurrent consults are skipped while the probe is in flight
        assert_eq!(h.admit(0), Gate::Skip);
        // probe success closes the breaker
        h.record(0, true);
        assert_eq!(h.state(0), BreakerState::Closed);
        assert_eq!(h.admit(0), Gate::Allow);
        assert_eq!(h.breaker(0).unwrap().snapshot().recoveries, 1);
    }

    #[test]
    fn failed_probe_reopens_with_fresh_cooldown() {
        let h = ModelHealth::new(1, cfg());
        for _ in 0..3 {
            h.record(0, false);
        }
        for _ in 0..4 {
            assert_eq!(h.admit(0), Gate::Skip);
        }
        assert_eq!(h.admit(0), Gate::Probe);
        h.record(0, false); // probe fails
        assert_eq!(h.state(0), BreakerState::Open);
        assert_eq!(h.breaker(0).unwrap().snapshot().trips, 2);
        // a full fresh cooldown before the next probe
        for _ in 0..4 {
            assert_eq!(h.admit(0), Gate::Skip);
        }
        assert_eq!(h.admit(0), Gate::Probe);
        h.record(0, true);
        assert_eq!(h.state(0), BreakerState::Closed);
    }

    #[test]
    fn breaker_decisions_are_local() {
        // Tripping model 0 must not move model 1's breaker at all.
        let h = ModelHealth::new(2, cfg());
        for _ in 0..10 {
            h.record(0, false);
            h.record(1, true);
        }
        assert_eq!(h.state(0), BreakerState::Open);
        assert_eq!(h.state(1), BreakerState::Closed);
        assert_eq!(h.admit(1), Gate::Allow);
        let s1 = h.breaker(1).unwrap().snapshot();
        assert_eq!((s1.trips, s1.skips, s1.failures), (0, 0, 0));
    }

    #[test]
    fn rate_threshold_trips_without_a_consecutive_run() {
        // alternate fail/fail/ok: never 3 consecutive, but the EWMA climbs
        // past trip_rate after min_calls.
        let h = ModelHealth::new(1, HealthConfig { trip_rate: 0.4, ..cfg() });
        let mut tripped = false;
        for _ in 0..40 {
            if h.state(0) == BreakerState::Open {
                tripped = true;
                break;
            }
            h.record(0, false);
            h.record(0, false);
            h.record(0, true);
        }
        assert!(tripped, "EWMA failure rate never tripped the breaker");
    }

    #[test]
    fn backoff_is_deterministic_in_seed_and_bounded() {
        let a = ModelHealth::new(1, cfg());
        let b = ModelHealth::new(1, cfg());
        for attempt in 1..=4u32 {
            let x = a.retry_backoff_us(0, attempt);
            assert_eq!(x, b.retry_backoff_us(0, attempt), "attempt {attempt}");
            let exp = 100u64 << (attempt - 1);
            assert!(x >= exp / 2 && x < exp + exp / 2, "attempt {attempt}: {x}");
        }
        assert_eq!(a.breaker(0).unwrap().snapshot().retries, 4);
        // zero base = hermetic no-sleep mode
        let z = ModelHealth::new(1, HealthConfig { backoff_base_us: 0, ..cfg() });
        assert_eq!(z.retry_backoff_us(0, 1), 0);
        // a different seed produces a different jitter stream
        let c = ModelHealth::new(1, HealthConfig { seed: 8, ..cfg() });
        let d = ModelHealth::new(1, cfg());
        assert_ne!(
            (1..=8).map(|i| c.retry_backoff_us(0, i)).collect::<Vec<_>>(),
            (1..=8).map(|i| d.retry_backoff_us(0, i)).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn snapshot_json_has_the_report_keys() {
        let h = ModelHealth::new(1, cfg());
        h.record(0, false);
        let v = h.snapshot()[0].to_value();
        assert_eq!(v.get("state").as_str(), Some("closed"));
        assert_eq!(v.get("failures").as_f64(), Some(1.0));
        assert!(v.get("failure_rate").as_f64().unwrap() > 0.0);
    }
}
