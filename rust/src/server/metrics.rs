//! Serving metrics: lock-free counters, a fixed-bucket latency histogram,
//! per-model observed cost/score/correctness windows, and the bounded
//! observation ring the online reoptimizer drains
//! (see `server::reoptimizer`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::responses::{SplitTable, TableBuilder};
use crate::util::json::Value;

/// Log-spaced latency buckets in microseconds (upper bounds).
pub const BUCKETS_US: [u64; 12] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000,
    u64::MAX,
];

/// Cascade depths tracked exactly by `stopped_at`; deeper stops land in a
/// single overflow bucket instead of being silently dropped (plans can now
/// hot-swap to arbitrary lengths, so no fixed plan bound exists up front).
pub const MAX_STOP_DEPTH: usize = 8;

/// Latency histogram with atomic buckets.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; 12],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    /// Record one latency sample (microseconds).
    pub fn record_us(&self, us: u64) {
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(11);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0.0 when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Largest recorded sample (microseconds).
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return BUCKETS_US[i].min(self.max_us());
            }
        }
        self.max_us()
    }
}

/// Per-model serving window: everything the service observes about one
/// marketplace API while answering traffic. Costs are exact nano-USD
/// sums (same representation as `BudgetTracker`); scores accumulate in
/// 1e-6 units so a mean is recoverable without floats in the hot path.
#[derive(Debug, Default)]
pub struct ModelWindow {
    /// Times this model's stage was invoked.
    pub invocations: AtomicU64,
    /// Times this model's answer was accepted (it answered the query).
    pub accepted: AtomicU64,
    /// Metered spend attributed to this model (nano-USD).
    pub cost_nano_usd: AtomicU64,
    /// Accepted answers that carried a *measured* reliability score (a
    /// final cascade stage accepts with a sentinel 1.0, which would skew
    /// the mean — those count in `accepted` but not here).
    pub scored: AtomicU64,
    /// Sum of those measured scores (1e-6 units).
    pub score_micro_sum: AtomicU64,
    /// Accepted answers with ground truth reported back.
    pub labeled: AtomicU64,
    /// ... of which were correct.
    pub labeled_correct: AtomicU64,
    /// Cascade stages this model would have served but was skipped for —
    /// its circuit breaker was open (see `server::health`). Skips cost
    /// nothing and are NOT invocations; they explain degraded answers.
    pub skips: AtomicU64,
}

impl ModelWindow {
    /// Count one invocation of this model's stage and meter its cost.
    pub fn record_invocation(&self, cost_usd: f64) {
        self.invocations.fetch_add(1, Ordering::Relaxed);
        let nano = (cost_usd * 1e9).round().max(0.0) as u64;
        self.cost_nano_usd.fetch_add(nano, Ordering::Relaxed);
    }

    /// Count a cascade stage skipped because this model was circuit-open.
    pub fn record_skip(&self) {
        self.skips.fetch_add(1, Ordering::Relaxed);
    }

    /// Count an accepted answer. `score` is `None` when the stage was the
    /// cascade's last (its 1.0 is a "always answers" sentinel, not a
    /// scorer output).
    pub fn record_accepted(&self, score: Option<f32>) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = score {
            self.scored.fetch_add(1, Ordering::Relaxed);
            let micro = (f64::from(s) * 1e6).round().max(0.0) as u64;
            self.score_micro_sum.fetch_add(micro, Ordering::Relaxed);
        }
    }

    /// Record ground truth for an answer this model produced.
    pub fn record_outcome(&self, correct: bool) {
        self.labeled.fetch_add(1, Ordering::Relaxed);
        self.labeled_correct.fetch_add(correct as u64, Ordering::Relaxed);
    }

    /// Point-in-time copy of the window's counters.
    pub fn snapshot(&self) -> ModelWindowSnapshot {
        let invocations = self.invocations.load(Ordering::Relaxed);
        let accepted = self.accepted.load(Ordering::Relaxed);
        let scored = self.scored.load(Ordering::Relaxed);
        let labeled = self.labeled.load(Ordering::Relaxed);
        ModelWindowSnapshot {
            invocations,
            accepted,
            cost_usd: self.cost_nano_usd.load(Ordering::Relaxed) as f64 / 1e9,
            mean_accepted_score: if scored == 0 {
                0.0
            } else {
                self.score_micro_sum.load(Ordering::Relaxed) as f64 / 1e6
                    / scored as f64
            },
            labeled,
            observed_accuracy: if labeled == 0 {
                0.0
            } else {
                self.labeled_correct.load(Ordering::Relaxed) as f64 / labeled as f64
            },
            skips: self.skips.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one model's window.
#[derive(Debug, Clone, Default)]
pub struct ModelWindowSnapshot {
    /// Times this model's stage was invoked.
    pub invocations: u64,
    /// Times its answer was accepted.
    pub accepted: u64,
    /// Metered spend attributed to it (USD).
    pub cost_usd: f64,
    /// Mean of the *measured* acceptance scores (final-stage sentinel
    /// acceptances excluded).
    pub mean_accepted_score: f64,
    /// Accepted answers with ground truth reported back.
    pub labeled: u64,
    /// Fraction of labeled answers that were correct.
    pub observed_accuracy: f64,
    /// Stages skipped because this model's circuit breaker was open.
    pub skips: u64,
}

/// One fully-labelled observation: every marketplace model's response on
/// one served item. This is the unit the reoptimizer learns from — the
/// paper's cascade training needs *all* APIs' answers per item, so these
/// rows come from a labelled feedback stream (in the serving driver: the
/// offline response table row of each served test item), not from the
/// cascade's own partial executions.
#[derive(Debug, Clone)]
pub struct Observation {
    /// Ground-truth (or pseudo-label) answer class of the item.
    pub label: u32,
    /// Billable prompt tokens of the item.
    pub input_tokens: u32,
    /// `preds[m]`: model m's answer class.
    pub preds: Vec<u32>,
    /// `scores[m]`: the reliability score of model m's answer.
    pub scores: Vec<f32>,
    /// `correct[m]`: whether model m's answer matches `label`.
    pub correct: Vec<bool>,
}

/// Bounded ring of the most recent [`Observation`]s — the sliding window
/// of traffic the reoptimizer re-learns the cascade from. Old rows fall
/// off the back, so the window tracks the *current* query mix. Rows are
/// `Arc`ed so a snapshot clones pointers, not data — the serving path's
/// `push` never waits behind a deep copy of the whole window.
///
/// With a `half_life` the window is additionally *decay-weighted*:
/// [`ObservationWindow::snapshot_table`] assigns row weights
/// `2^(-age / half_life)` (age in observations, newest = 0), so the
/// optimizer tracks fast drifts without shrinking the effective sample —
/// old rows fade smoothly instead of being either fully counted or gone.
#[derive(Debug)]
pub struct ObservationWindow {
    /// Number of models every observation must cover.
    n_models: usize,
    cap: usize,
    /// Exponential-decay half-life in observations; `None` = hard ring
    /// (every retained row weighs 1.0).
    half_life: Option<f64>,
    rows: Mutex<VecDeque<Arc<Observation>>>,
    total: AtomicU64,
}

impl ObservationWindow {
    /// A hard ring (no decay) over `cap` rows covering `n_models` APIs.
    pub fn new(n_models: usize, cap: usize) -> Self {
        Self::with_half_life(n_models, cap, None)
    }

    /// A window whose snapshots decay-weight rows by age. A non-finite or
    /// non-positive half-life means "no decay" (hard ring).
    pub fn with_half_life(n_models: usize, cap: usize, half_life: Option<f64>) -> Self {
        let cap = cap.max(1);
        ObservationWindow {
            n_models,
            cap,
            half_life: half_life.filter(|h| h.is_finite() && *h > 0.0),
            // Preallocate the ring at capacity: `push` holds the lock on
            // the serving hot path, and a growth realloc under that lock
            // would stall every concurrent answer. The ring never exceeds
            // `cap` rows (pop-before-push when full), so after this no
            // push ever reallocates. Pinned by
            // `window_ring_never_reallocates_after_construction`.
            rows: Mutex::new(VecDeque::with_capacity(cap)),
            total: AtomicU64::new(0),
        }
    }

    /// Maximum rows retained.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// The decay half-life in observations, if decay is configured.
    pub fn half_life(&self) -> Option<f64> {
        self.half_life
    }

    /// Rows currently retained.
    pub fn len(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    /// Whether the window holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Observations ever pushed (including ones that fell off the ring).
    pub fn total_observed(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Append one fully-labelled observation (validates that it covers
    /// every model); the oldest row falls off a full ring.
    pub fn push(&self, obs: Observation) -> Result<()> {
        if obs.preds.len() != self.n_models
            || obs.scores.len() != self.n_models
            || obs.correct.len() != self.n_models
        {
            anyhow::bail!(
                "observation covers {} models, window expects {}",
                obs.preds.len(),
                self.n_models
            );
        }
        let obs = Arc::new(obs);
        let mut rows = self.rows.lock().unwrap();
        if rows.len() == self.cap {
            rows.pop_front();
        }
        rows.push_back(obs);
        self.total.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Materialize the current window as a fresh training slice for
    /// `CascadeOptimizer::new`: a model-major [`SplitTable`] plus the
    /// per-item billable token counts. With a half-life configured the
    /// table is decay-weighted (`2^(-age / half_life)`, newest row age 0).
    /// `None` while the window is empty.
    pub fn snapshot_table(
        &self,
        dataset: &str,
        model_names: &[String],
    ) -> Option<(SplitTable, Vec<u32>)> {
        // Arc clones only — the lock is held for a pointer-copy loop, so
        // concurrent `push` (the serving hot path) never stalls on the
        // O(window · K) table build below.
        let rows: Vec<Arc<Observation>> = {
            let guard = self.rows.lock().unwrap();
            guard.iter().cloned().collect()
        };
        if rows.is_empty() {
            return None;
        }
        let mut b = TableBuilder::new(dataset, model_names.to_vec());
        let mut tokens = Vec::with_capacity(rows.len());
        let newest = rows.len() - 1;
        for (idx, o) in rows.iter().enumerate() {
            match self.half_life {
                None => b
                    .push_item(o.label, &o.preds, &o.scores, &o.correct)
                    .expect("window rows validated at push"),
                Some(hl) => {
                    let age = (newest - idx) as f64;
                    // Clamp away the f64 underflow floor: 2^(-age/hl)
                    // rounds to 0.0 past age ≈ 1074·hl, and the table
                    // rejects non-positive weights.
                    let w = (-age / hl).exp2().max(1e-300);
                    b.push_item_weighted(o.label, &o.preds, &o.scores, &o.correct, w)
                        .expect("window rows validated at push")
                }
            }
            tokens.push(o.input_tokens);
        }
        let table = b.finish().expect("window rows are rectangular");
        Some((table, tokens))
    }
}

/// Aggregate serving metrics for one service instance.
#[derive(Debug)]
pub struct ServiceMetrics {
    /// Queries answered (cache hits included).
    pub queries: AtomicU64,
    /// Queries served from the completion cache.
    pub cache_hits: AtomicU64,
    /// Queries that reached the cascade.
    pub cascade_invocations: AtomicU64,
    /// Concatenation groups formed by `answer_batch` (paper Fig. 2b);
    /// each group bills its shared prompt once.
    pub concat_groups: AtomicU64,
    /// Queries answered at each cascade depth (0..MAX_STOP_DEPTH exact).
    stopped_at: [AtomicU64; MAX_STOP_DEPTH],
    /// Queries answered at depth ≥ MAX_STOP_DEPTH (counted, not dropped).
    stopped_at_overflow: AtomicU64,
    /// Failed answers (engine or scorer errors).
    pub errors: AtomicU64,
    /// Queries answered by the speculative agreement stage (calibrated
    /// accept — the cascade never ran; see `strategies::speculate`).
    pub speculative_accepts: AtomicU64,
    /// Queries the speculative stage probed but escalated to the cascade
    /// (disagreement, or scores under the calibrated bar).
    pub speculative_escalations: AtomicU64,
    /// Estimated spend avoided by speculative accepts, vs escalating the
    /// query to the plan's terminal model (nano-USD; an estimate — the
    /// counterfactual cascade was never run).
    pub speculative_saved_spend_nano_usd: AtomicU64,
    /// End-to-end answer latency histogram.
    pub latency: Histogram,
    /// Plans published over this service's lifetime (initial plan = 0).
    pub plan_swaps: AtomicU64,
    /// One window per marketplace model (index-aligned with the cost
    /// model), empty when built via `Default`.
    per_model: Vec<ModelWindow>,
    /// Labelled full-row observations for the reoptimizer.
    pub window: ObservationWindow,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        ServiceMetrics::with_models(0, 4096)
    }
}

impl ServiceMetrics {
    /// Metrics for a marketplace of `n_models` APIs with an observation
    /// ring of `window_cap` rows.
    pub fn with_models(n_models: usize, window_cap: usize) -> Self {
        Self::with_window(n_models, window_cap, None)
    }

    /// [`ServiceMetrics::with_models`] with a decay half-life on the
    /// observation window (see [`ObservationWindow::with_half_life`]).
    pub fn with_window(
        n_models: usize,
        window_cap: usize,
        half_life: Option<f64>,
    ) -> Self {
        ServiceMetrics {
            queries: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cascade_invocations: AtomicU64::new(0),
            concat_groups: AtomicU64::new(0),
            stopped_at: Default::default(),
            stopped_at_overflow: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            speculative_accepts: AtomicU64::new(0),
            speculative_escalations: AtomicU64::new(0),
            speculative_saved_spend_nano_usd: AtomicU64::new(0),
            latency: Histogram::default(),
            plan_swaps: AtomicU64::new(0),
            per_model: (0..n_models).map(|_| ModelWindow::default()).collect(),
            window: ObservationWindow::with_half_life(n_models, window_cap, half_life),
        }
    }

    /// Count a query answered at cascade depth `depth` (0-based). Depths
    /// beyond [`MAX_STOP_DEPTH`] go to the overflow bucket.
    pub fn record_stop(&self, depth: usize) {
        match self.stopped_at.get(depth) {
            Some(b) => b.fetch_add(1, Ordering::Relaxed),
            None => self.stopped_at_overflow.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// The per-model window of marketplace model `m`, if tracked.
    pub fn model(&self, m: usize) -> Option<&ModelWindow> {
        self.per_model.get(m)
    }

    /// Number of per-model windows (0 for `Default`-built metrics).
    pub fn n_models(&self) -> usize {
        self.per_model.len()
    }

    /// Point-in-time copy of every counter, for reports.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            queries: self.queries.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cascade_invocations: self.cascade_invocations.load(Ordering::Relaxed),
            concat_groups: self.concat_groups.load(Ordering::Relaxed),
            stopped_at: self
                .stopped_at
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            stopped_at_overflow: self.stopped_at_overflow.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            speculative_accepts: self.speculative_accepts.load(Ordering::Relaxed),
            speculative_escalations: self
                .speculative_escalations
                .load(Ordering::Relaxed),
            speculative_saved_spend_usd: self
                .speculative_saved_spend_nano_usd
                .load(Ordering::Relaxed) as f64
                / 1e9,
            plan_swaps: self.plan_swaps.load(Ordering::Relaxed),
            per_model: self.per_model.iter().map(ModelWindow::snapshot).collect(),
            window_len: self.window.len(),
            window_total: self.window.total_observed(),
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.quantile_us(0.50),
            p95_us: self.latency.quantile_us(0.95),
            p99_us: self.latency.quantile_us(0.99),
            max_us: self.latency.max_us(),
        }
    }
}

/// A point-in-time copy of the metrics, for reports.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Queries answered (cache hits included).
    pub queries: u64,
    /// Queries served from the completion cache.
    pub cache_hits: u64,
    /// Queries that reached the cascade.
    pub cascade_invocations: u64,
    /// Concatenation groups formed by `answer_batch`.
    pub concat_groups: u64,
    /// Exact counts for depths 0..MAX_STOP_DEPTH.
    pub stopped_at: Vec<u64>,
    /// Queries stopping at depth ≥ MAX_STOP_DEPTH.
    pub stopped_at_overflow: u64,
    /// Failed answers.
    pub errors: u64,
    /// Queries answered by the speculative agreement stage.
    pub speculative_accepts: u64,
    /// Queries the speculative stage probed but escalated to the cascade.
    pub speculative_escalations: u64,
    /// Estimated spend avoided by speculative accepts (USD).
    pub speculative_saved_spend_usd: f64,
    /// Plans published over the service lifetime.
    pub plan_swaps: u64,
    /// One snapshot per marketplace model.
    pub per_model: Vec<ModelWindowSnapshot>,
    /// Rows currently in the observation window.
    pub window_len: usize,
    /// Observations ever pushed (including evicted ones).
    pub window_total: u64,
    /// Mean answer latency (µs).
    pub mean_latency_us: f64,
    /// Median answer latency (µs, bucket upper bound).
    pub p50_us: u64,
    /// 95th-percentile answer latency (µs, bucket upper bound).
    pub p95_us: u64,
    /// 99th-percentile answer latency (µs, bucket upper bound).
    pub p99_us: u64,
    /// Largest recorded answer latency (µs).
    pub max_us: u64,
}

impl ModelWindowSnapshot {
    /// The canonical wire form of one model's observed window.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("invocations".to_string(), Value::Num(self.invocations as f64));
        m.insert("accepted".to_string(), Value::Num(self.accepted as f64));
        m.insert("cost_usd".to_string(), Value::Num(self.cost_usd));
        m.insert(
            "mean_accepted_score".to_string(),
            Value::Num(self.mean_accepted_score),
        );
        m.insert("labeled".to_string(), Value::Num(self.labeled as f64));
        m.insert(
            "observed_accuracy".to_string(),
            Value::Num(self.observed_accuracy),
        );
        m.insert("skips".to_string(), Value::Num(self.skips as f64));
        Value::Obj(m)
    }

    /// Parse a snapshot serialized by [`ModelWindowSnapshot::to_value`].
    pub fn from_value(v: &Value) -> Result<ModelWindowSnapshot> {
        use anyhow::Context;
        let num =
            |k: &str| v.get(k).as_f64().with_context(|| format!("model window missing `{k}`"));
        Ok(ModelWindowSnapshot {
            invocations: num("invocations")? as u64,
            accepted: num("accepted")? as u64,
            cost_usd: num("cost_usd")?,
            mean_accepted_score: num("mean_accepted_score")?,
            labeled: num("labeled")? as u64,
            observed_accuracy: num("observed_accuracy")?,
            skips: num("skips")? as u64,
        })
    }
}

impl MetricsSnapshot {
    /// The canonical wire form of a metrics snapshot: what `frugald`
    /// replies to `/metrics`, what `serve --metrics-json` writes, and
    /// what `report metrics` renders — all three speak exactly this
    /// schema, pinned bit-exactly by `metrics_snapshot_wire_roundtrip`.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("queries".to_string(), Value::Num(self.queries as f64));
        m.insert("cache_hits".to_string(), Value::Num(self.cache_hits as f64));
        m.insert(
            "cascade_invocations".to_string(),
            Value::Num(self.cascade_invocations as f64),
        );
        m.insert("concat_groups".to_string(), Value::Num(self.concat_groups as f64));
        m.insert(
            "stopped_at".to_string(),
            Value::Arr(self.stopped_at.iter().map(|&c| Value::Num(c as f64)).collect()),
        );
        m.insert(
            "stopped_at_overflow".to_string(),
            Value::Num(self.stopped_at_overflow as f64),
        );
        m.insert("errors".to_string(), Value::Num(self.errors as f64));
        m.insert(
            "speculative_accepts".to_string(),
            Value::Num(self.speculative_accepts as f64),
        );
        m.insert(
            "speculative_escalations".to_string(),
            Value::Num(self.speculative_escalations as f64),
        );
        m.insert(
            "speculative_saved_spend_usd".to_string(),
            Value::Num(self.speculative_saved_spend_usd),
        );
        m.insert("plan_swaps".to_string(), Value::Num(self.plan_swaps as f64));
        m.insert(
            "per_model".to_string(),
            Value::Arr(self.per_model.iter().map(ModelWindowSnapshot::to_value).collect()),
        );
        m.insert("window_len".to_string(), Value::Num(self.window_len as f64));
        m.insert("window_total".to_string(), Value::Num(self.window_total as f64));
        m.insert("mean_latency_us".to_string(), Value::Num(self.mean_latency_us));
        m.insert("p50_us".to_string(), Value::Num(self.p50_us as f64));
        m.insert("p95_us".to_string(), Value::Num(self.p95_us as f64));
        m.insert("p99_us".to_string(), Value::Num(self.p99_us as f64));
        m.insert("max_us".to_string(), Value::Num(self.max_us as f64));
        Value::Obj(m)
    }

    /// Parse a snapshot serialized by [`MetricsSnapshot::to_value`].
    pub fn from_value(v: &Value) -> Result<MetricsSnapshot> {
        use anyhow::Context;
        let num = |k: &str| {
            v.get(k).as_f64().with_context(|| format!("metrics snapshot missing `{k}`"))
        };
        Ok(MetricsSnapshot {
            queries: num("queries")? as u64,
            cache_hits: num("cache_hits")? as u64,
            cascade_invocations: num("cascade_invocations")? as u64,
            concat_groups: num("concat_groups")? as u64,
            stopped_at: v
                .get("stopped_at")
                .as_arr()
                .context("metrics snapshot missing `stopped_at`")?
                .iter()
                .map(|c| c.as_f64().map(|f| f as u64).context("bad stop count"))
                .collect::<Result<_>>()?,
            stopped_at_overflow: num("stopped_at_overflow")? as u64,
            errors: num("errors")? as u64,
            speculative_accepts: num("speculative_accepts")? as u64,
            speculative_escalations: num("speculative_escalations")? as u64,
            speculative_saved_spend_usd: num("speculative_saved_spend_usd")?,
            plan_swaps: num("plan_swaps")? as u64,
            per_model: v
                .get("per_model")
                .as_arr()
                .context("metrics snapshot missing `per_model`")?
                .iter()
                .map(ModelWindowSnapshot::from_value)
                .collect::<Result<_>>()?,
            window_len: num("window_len")? as usize,
            window_total: num("window_total")? as u64,
            mean_latency_us: num("mean_latency_us")?,
            p50_us: num("p50_us")? as u64,
            p95_us: num("p95_us")? as u64,
            p99_us: num("p99_us")? as u64,
            max_us: num("max_us")? as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for us in [10u64, 80, 300, 900, 3_000, 9_000, 40_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        assert!(p50 <= p95);
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 40_000);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_copies_counters() {
        let m = ServiceMetrics::with_models(2, 16);
        m.queries.fetch_add(3, Ordering::Relaxed);
        m.record_stop(1);
        m.record_stop(1);
        m.latency.record_us(500);
        m.model(0).unwrap().record_invocation(0.001);
        m.model(0).unwrap().record_accepted(Some(0.75));
        m.model(0).unwrap().record_accepted(None); // last-stage sentinel
        m.model(0).unwrap().record_outcome(true);
        m.model(1).unwrap().record_skip();
        let s = m.snapshot();
        assert_eq!(s.queries, 3);
        assert_eq!(s.stopped_at[1], 2);
        assert_eq!(s.stopped_at.iter().sum::<u64>(), 2);
        assert_eq!(s.p50_us, 500);
        assert_eq!(s.per_model[0].invocations, 1);
        assert!((s.per_model[0].cost_usd - 0.001).abs() < 1e-9);
        assert_eq!(s.per_model[0].accepted, 2);
        // the sentinel acceptance must not drag the mean toward 1.0
        assert!((s.per_model[0].mean_accepted_score - 0.75).abs() < 1e-6);
        assert_eq!(s.per_model[0].labeled, 1);
        assert_eq!(s.per_model[0].skips, 0);
        assert_eq!(s.per_model[1].invocations, 0);
        assert_eq!(s.per_model[1].skips, 1, "breaker skips are model-attributed");
    }

    #[test]
    fn deep_stops_overflow_instead_of_vanishing() {
        let m = ServiceMetrics::with_models(1, 4);
        m.record_stop(0);
        m.record_stop(MAX_STOP_DEPTH - 1);
        m.record_stop(MAX_STOP_DEPTH); // would have been dropped before
        m.record_stop(MAX_STOP_DEPTH + 5);
        let s = m.snapshot();
        assert_eq!(s.stopped_at[0], 1);
        assert_eq!(s.stopped_at[MAX_STOP_DEPTH - 1], 1);
        assert_eq!(s.stopped_at_overflow, 2);
        let total: u64 = s.stopped_at.iter().sum::<u64>() + s.stopped_at_overflow;
        assert_eq!(total, 4, "every stop is accounted for");
    }

    #[test]
    fn observation_window_is_bounded_and_rebuilds_tables() {
        let w = ObservationWindow::new(2, 3);
        let names = vec!["a".to_string(), "b".to_string()];
        for i in 0..5u32 {
            w.push(Observation {
                label: i % 2,
                input_tokens: 40 + i,
                preds: vec![i % 2, 1 - i % 2],
                scores: vec![0.9, 0.1],
                correct: vec![true, false],
            })
            .unwrap();
        }
        assert_eq!(w.len(), 3, "ring keeps only the newest cap rows");
        assert_eq!(w.total_observed(), 5);
        let (table, tokens) = w.snapshot_table("toy", &names).unwrap();
        assert_eq!(table.len(), 3);
        assert_eq!(table.n_models(), 2);
        // newest three observations are items 2, 3, 4
        assert_eq!(tokens, vec![42, 43, 44]);
        assert_eq!(table.accuracy(0), 1.0);
        assert_eq!(table.accuracy(1), 0.0);
        // mis-sized observations are rejected
        assert!(w
            .push(Observation {
                label: 0,
                input_tokens: 1,
                preds: vec![0],
                scores: vec![0.5],
                correct: vec![true],
            })
            .is_err());
    }

    #[test]
    fn half_life_window_emits_decayed_weights() {
        let w = ObservationWindow::with_half_life(1, 8, Some(2.0));
        assert_eq!(w.half_life(), Some(2.0));
        for i in 0..5u32 {
            w.push(Observation {
                label: 0,
                input_tokens: i,
                preds: vec![0],
                scores: vec![0.5],
                correct: vec![true],
            })
            .unwrap();
        }
        let (table, tokens) = w.snapshot_table("toy", &["a".to_string()]).unwrap();
        assert_eq!(tokens, vec![0, 1, 2, 3, 4]);
        assert!(table.is_weighted());
        let ws = table.weights().unwrap();
        // ages 4..0 at half-life 2 → 2^-2, 2^-1.5, 2^-1, 2^-0.5, 2^0
        assert_eq!(ws.len(), 5);
        assert!((ws[4] - 1.0).abs() < 1e-15, "newest row weighs 1.0");
        assert!((ws[0] - 0.25).abs() < 1e-15, "age 4 at half-life 2 → 1/4");
        for pair in ws.windows(2) {
            assert!(pair[0] < pair[1], "weights increase toward the newest row");
        }
        assert!(table.total_weight() < 5.0);

        // degenerate half-lives fall back to the hard ring
        assert_eq!(ObservationWindow::with_half_life(1, 8, Some(0.0)).half_life(), None);
        assert_eq!(
            ObservationWindow::with_half_life(1, 8, Some(f64::NAN)).half_life(),
            None
        );
    }

    /// The ring is preallocated at capacity and `push` pops before it
    /// pushes, so the backing buffer must never grow — not during
    /// warmup, not at steady state. A realloc here would happen under
    /// the hot-path lock.
    #[test]
    fn window_ring_never_reallocates_after_construction() {
        let w = ObservationWindow::new(1, 64);
        let cap0 = w.rows.lock().unwrap().capacity();
        assert!(cap0 >= 64, "ring preallocated at construction");
        for i in 0..256u32 {
            w.push(Observation {
                label: 0,
                input_tokens: i,
                preds: vec![0],
                scores: vec![0.5],
                correct: vec![true],
            })
            .unwrap();
            assert_eq!(
                w.rows.lock().unwrap().capacity(),
                cap0,
                "push #{i} grew the ring buffer"
            );
        }
        assert_eq!(w.len(), 64);
    }

    #[test]
    fn empty_window_has_no_table() {
        let w = ObservationWindow::new(3, 8);
        assert!(w.snapshot_table("toy", &["a".into(), "b".into(), "c".into()]).is_none());
    }

    #[test]
    fn metrics_snapshot_wire_roundtrip_is_bit_exact() {
        let snap = MetricsSnapshot {
            queries: 12345,
            cache_hits: 678,
            cascade_invocations: 11000,
            concat_groups: 42,
            stopped_at: vec![9000, 1500, 500, 0, 0, 0, 0, 0],
            stopped_at_overflow: 3,
            errors: 1,
            speculative_accepts: 321,
            speculative_escalations: 79,
            speculative_saved_spend_usd: 0.1 + 0.7,
            plan_swaps: 7,
            per_model: vec![
                ModelWindowSnapshot {
                    invocations: 1000,
                    accepted: 900,
                    cost_usd: 0.1 + 0.2,
                    mean_accepted_score: 0.87654321,
                    labeled: 500,
                    observed_accuracy: 1.0 / 3.0,
                    skips: 4,
                },
                ModelWindowSnapshot {
                    invocations: 0,
                    accepted: 0,
                    cost_usd: 0.0,
                    mean_accepted_score: 0.0,
                    labeled: 0,
                    observed_accuracy: 0.0,
                    skips: 0,
                },
            ],
            window_len: 256,
            window_total: 9999,
            mean_latency_us: 1234.56789,
            p50_us: 1000,
            p95_us: 2500,
            p99_us: 5000,
            max_us: 100000,
        };
        let json = snap.to_value().to_json();
        let back = MetricsSnapshot::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.queries, snap.queries);
        assert_eq!(back.cache_hits, snap.cache_hits);
        assert_eq!(back.cascade_invocations, snap.cascade_invocations);
        assert_eq!(back.concat_groups, snap.concat_groups);
        assert_eq!(back.stopped_at, snap.stopped_at);
        assert_eq!(back.stopped_at_overflow, snap.stopped_at_overflow);
        assert_eq!(back.errors, snap.errors);
        assert_eq!(back.speculative_accepts, snap.speculative_accepts);
        assert_eq!(back.speculative_escalations, snap.speculative_escalations);
        assert_eq!(
            back.speculative_saved_spend_usd.to_bits(),
            snap.speculative_saved_spend_usd.to_bits()
        );
        assert_eq!(back.plan_swaps, snap.plan_swaps);
        assert_eq!(back.per_model.len(), snap.per_model.len());
        for (b, s) in back.per_model.iter().zip(&snap.per_model) {
            assert_eq!(b.invocations, s.invocations);
            assert_eq!(b.accepted, s.accepted);
            assert_eq!(b.cost_usd.to_bits(), s.cost_usd.to_bits());
            assert_eq!(b.mean_accepted_score.to_bits(), s.mean_accepted_score.to_bits());
            assert_eq!(b.labeled, s.labeled);
            assert_eq!(b.observed_accuracy.to_bits(), s.observed_accuracy.to_bits());
            assert_eq!(b.skips, s.skips);
        }
        assert_eq!(back.window_len, snap.window_len);
        assert_eq!(back.window_total, snap.window_total);
        assert_eq!(back.mean_latency_us.to_bits(), snap.mean_latency_us.to_bits());
        assert_eq!(back.p50_us, snap.p50_us);
        assert_eq!(back.p95_us, snap.p95_us);
        assert_eq!(back.p99_us, snap.p99_us);
        assert_eq!(back.max_us, snap.max_us);
        // Deterministic serializer: a second trip is byte-identical.
        assert_eq!(back.to_value().to_json(), json);
    }
}
