//! Serving metrics: lock-free counters + a fixed-bucket latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency buckets in microseconds (upper bounds).
pub const BUCKETS_US: [u64; 12] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000,
    u64::MAX,
];

/// Latency histogram with atomic buckets.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; 12],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    pub fn record_us(&self, us: u64) {
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(11);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return BUCKETS_US[i].min(self.max_us());
            }
        }
        self.max_us()
    }
}

/// Aggregate serving metrics for one service instance.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub queries: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cascade_invocations: AtomicU64,
    /// Total model calls broken out by cascade depth reached (1..=3).
    pub stopped_at: [AtomicU64; 3],
    pub errors: AtomicU64,
    pub latency: Histogram,
}

impl ServiceMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            queries: self.queries.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cascade_invocations: self.cascade_invocations.load(Ordering::Relaxed),
            stopped_at: [
                self.stopped_at[0].load(Ordering::Relaxed),
                self.stopped_at[1].load(Ordering::Relaxed),
                self.stopped_at[2].load(Ordering::Relaxed),
            ],
            errors: self.errors.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.quantile_us(0.50),
            p95_us: self.latency.quantile_us(0.95),
            p99_us: self.latency.quantile_us(0.99),
            max_us: self.latency.max_us(),
        }
    }
}

/// A point-in-time copy of the metrics, for reports.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub queries: u64,
    pub cache_hits: u64,
    pub cascade_invocations: u64,
    pub stopped_at: [u64; 3],
    pub errors: u64,
    pub mean_latency_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::default();
        for us in [10u64, 80, 300, 900, 3_000, 9_000, 40_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        assert!(p50 <= p95);
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 40_000);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_copies_counters() {
        let m = ServiceMetrics::default();
        m.queries.fetch_add(3, Ordering::Relaxed);
        m.stopped_at[1].fetch_add(2, Ordering::Relaxed);
        m.latency.record_us(500);
        let s = m.snapshot();
        assert_eq!(s.queries, 3);
        assert_eq!(s.stopped_at, [0, 2, 0]);
        assert_eq!(s.p50_us, 500);
    }
}
