//! Dynamic batcher: coalesces concurrent single-row inference requests
//! into batched PJRT executions (vLLM-style continuous batching, adapted
//! to a fixed-shape classifier: batch across *requests*, not tokens).
//!
//! One batcher per (dataset, model). Requests queue up; the worker drains
//! up to `max_batch` of them, waiting at most `max_wait` for stragglers
//! once the first request of a batch has arrived, then issues one
//! `execute_batch` and fans results back out over per-request reply
//! channels. Pure std threading (no async runtime in this environment).
//!
//! §Lanes — submission used to funnel through one mpsc channel, so every
//! client thread (and the shadow fan-out, which pushes rows×models at
//! once) contended on a single queue. Each batcher now owns
//! [`BatcherConfig::lanes`] independent submission lanes; a submitting
//! thread is pinned to a lane by a hash of its thread id (per-worker
//! lanes — two pipeline workers in different lanes never touch the same
//! queue mutex), and the drain *work-steals*: it starts at a rotating
//! home lane and sweeps the others, so a batch fills from every lane
//! that has traffic and no lane can be starved. Wakeups are
//! park/unpark-token based — a submit costs one short per-lane lock plus
//! an unpark, never a shared mutex. Pinned by
//! `work_stealing_drain_batches_across_lanes`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::EngineHandle;

struct Item {
    row: Vec<i32>,
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// The shared lane state between submitters and the drain worker.
struct Lanes {
    /// One short-critical-section queue per submission lane.
    queues: Vec<Mutex<VecDeque<Item>>>,
    /// Live [`BatcherHandle`] count; the worker exits when it reaches 0
    /// and every lane has drained.
    handles: AtomicUsize,
    /// The drain worker's thread handle, registered before its first
    /// scan, so submitters can unpark it.
    worker: OnceLock<std::thread::Thread>,
}

impl Lanes {
    /// Pop up to `want - rows.len()` items, sweeping every lane starting
    /// from `start` (the work-stealing drain). Lane locks are taken one
    /// at a time and released between lanes.
    fn take_available(
        &self,
        start: usize,
        want: usize,
        rows: &mut Vec<Vec<i32>>,
        replies: &mut Vec<mpsc::SyncSender<Result<Vec<f32>>>>,
    ) {
        let n = self.queues.len();
        for off in 0..n {
            if rows.len() >= want {
                break;
            }
            let mut q = self.queues[(start + off) % n].lock().unwrap();
            while rows.len() < want {
                match q.pop_front() {
                    Some(item) => {
                        rows.push(item.row);
                        replies.push(item.reply);
                    }
                    None => break,
                }
            }
        }
    }

    /// Wake the drain worker (unpark-token semantics: never blocks, and a
    /// wake delivered before the worker parks is not lost).
    fn wake(&self) {
        if let Some(t) = self.worker.get() {
            t.unpark();
        }
    }
}

/// The lane a submitting thread is pinned to: a hash of its thread id.
/// Computed once per thread; the same thread always lands on the same
/// lane of a given batcher, so pipeline workers submitting concurrently
/// spread across lanes instead of contending on one queue.
fn thread_lane_hash() -> u64 {
    use std::hash::{Hash, Hasher};
    thread_local! {
        static LANE_HASH: u64 = {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            std::thread::current().id().hash(&mut h);
            h.finish()
        };
    }
    LANE_HASH.with(|h| *h)
}

/// Handle for submitting rows to a batcher. Cheap to clone; each clone
/// keeps the drain worker alive.
pub struct BatcherHandle {
    lanes: Arc<Lanes>,
}

impl Clone for BatcherHandle {
    fn clone(&self) -> Self {
        self.lanes.handles.fetch_add(1, Ordering::SeqCst);
        BatcherHandle { lanes: self.lanes.clone() }
    }
}

impl Drop for BatcherHandle {
    fn drop(&mut self) {
        if self.lanes.handles.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Last handle gone: wake the worker so it can drain and exit.
            self.lanes.wake();
        }
    }
}

impl BatcherHandle {
    /// Submit one row; blocks until its batch has executed.
    pub fn submit(&self, row: Vec<i32>) -> Result<Vec<f32>> {
        self.submit_async(row)?
            .recv()
            .map_err(|_| anyhow!("batcher dropped reply"))?
    }

    /// Submit one row without waiting: the returned receiver yields the
    /// row's result once its batch has executed. Lets one caller fan a
    /// set of rows out to several batchers (e.g. `server::shadow` hitting
    /// every marketplace model) and only then collect — the submissions
    /// coalesce into batches instead of serializing on each reply.
    pub fn submit_async(
        &self,
        row: Vec<i32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        let (tx, rx) = mpsc::sync_channel(1);
        let n = self.lanes.queues.len();
        let lane = (thread_lane_hash() as usize) % n;
        self.lanes.queues[lane]
            .lock()
            .unwrap()
            .push_back(Item { row, reply: tx });
        self.lanes.wake();
        Ok(rx)
    }
}

/// Configuration for one dynamic batcher.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Largest batch drained into one `execute_batch` call.
    pub max_batch: usize,
    /// How long to wait for stragglers after the first queued row.
    pub max_wait: Duration,
    /// Submission lanes (0 = next power of two ≥ core count, capped at
    /// 8). Each submitting thread is pinned to one lane by thread-id
    /// hash; the drain work-steals across all of them.
    pub lanes: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        // §Perf: the PJRT engine is a single-stream actor, so waiting long
        // for stragglers only adds latency; 300µs captures genuinely
        // concurrent arrivals (batch-8 execs are ~1.8ms) without stalling
        // the pipe. max_batch 8 matches the engine's preferred chunk.
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(300),
            lanes: 0,
        }
    }
}

impl BatcherConfig {
    /// The resolved lane count (power of two, at least 1).
    fn resolved_lanes(&self) -> usize {
        let n = if self.lanes == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8)
        } else {
            self.lanes
        };
        n.next_power_of_two()
    }
}

/// The batcher: owns its worker thread; dropping all handles stops it.
pub struct Batcher {
    handle: BatcherHandle,
    _join: std::thread::JoinHandle<()>,
}

impl Batcher {
    /// Start the worker thread for one (dataset, model) batcher.
    pub fn spawn(
        engine: EngineHandle,
        dataset: String,
        model: String,
        cfg: BatcherConfig,
    ) -> Batcher {
        let lanes = Arc::new(Lanes {
            queues: (0..cfg.resolved_lanes())
                .map(|_| Mutex::new(VecDeque::new()))
                .collect(),
            handles: AtomicUsize::new(1),
            worker: OnceLock::new(),
        });
        let worker_lanes = lanes.clone();
        let join = std::thread::Builder::new()
            .name(format!("batcher-{dataset}-{model}"))
            .spawn(move || worker(engine, dataset, model, cfg, worker_lanes))
            .expect("spawning batcher thread");
        Batcher { handle: BatcherHandle { lanes }, _join: join }
    }

    /// A cheap, cloneable submission handle.
    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }
}

fn worker(
    engine: EngineHandle,
    dataset: String,
    model: String,
    cfg: BatcherConfig,
    lanes: Arc<Lanes>,
) {
    // Register BEFORE the first scan: a submit that misses the handle
    // here happened before this thread ran, so the scan below sees its
    // item; every later submit unparks us.
    lanes
        .worker
        .set(std::thread::current())
        .expect("batcher worker registers once");
    let n = lanes.queues.len();
    let mut home = 0usize;
    loop {
        // Rows are *moved* into the engine call and replies are kept in a
        // parallel, index-aligned vec — the worker never copies a token
        // row (they were cloned per request before PR 1).
        let mut rows: Vec<Vec<i32>> = Vec::with_capacity(cfg.max_batch);
        let mut replies: Vec<mpsc::SyncSender<Result<Vec<f32>>>> =
            Vec::with_capacity(cfg.max_batch);

        // Phase 1: park until the first item of the next batch arrives
        // (or every handle is gone and the lanes are drained).
        loop {
            lanes.take_available(home, cfg.max_batch, &mut rows, &mut replies);
            if !rows.is_empty() {
                break;
            }
            if lanes.handles.load(Ordering::SeqCst) == 0 {
                // Final sweep: a push by the last handle happened before
                // its drop, so observing 0 handles means this scan sees
                // every item that will ever arrive.
                lanes.take_available(home, cfg.max_batch, &mut rows, &mut replies);
                if rows.is_empty() {
                    return;
                }
                break;
            }
            // A submit between the scan above and this park left an
            // unpark token, so the park returns immediately — no lost
            // wakeup. Spurious returns just rescan.
            std::thread::park();
        }

        // Phase 2: hold the batch open for stragglers (across ALL lanes —
        // the steal sweep keeps filling from whichever lane has traffic).
        let deadline = Instant::now() + cfg.max_wait;
        while rows.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            std::thread::park_timeout(deadline - now);
            lanes.take_available(home, cfg.max_batch, &mut rows, &mut replies);
        }
        // Rotate the home lane so no lane is systematically drained last.
        home = (home + 1) % n;

        match engine.execute_batch(&dataset, &model, rows) {
            Ok(outs) => {
                for (reply, out) in replies.into_iter().zip(outs) {
                    let _ = reply.send(Ok(out));
                }
            }
            Err(e) => {
                // A failed batch poisons nothing: fan the error out to the
                // submitters it affected and keep serving. Under injected
                // faults (429 storms, outages) this loop sees errors on
                // every batch for a while — the worker must outlive them
                // so the breaker's half-open probes have a path to run on.
                let msg = format!("{e:#}");
                for reply in replies {
                    let _ = reply.send(Err(anyhow!("{msg}")));
                }
                continue;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Echo engine: each output row is `[first_token, batch_marker]`, so a
    /// reply identifies both the request it belongs to and the batch it
    /// rode in.
    fn echo_engine() -> EngineHandle {
        let mut batch_no = 0.0f32;
        EngineHandle::simulated(move |_, _, rows| {
            batch_no += 1.0;
            Ok(rows.iter().map(|r| vec![r[0] as f32, batch_no]).collect())
        })
    }

    /// The PR-1 rewrite keys replies by index instead of cloning rows —
    /// prove every concurrent submitter gets the reply for *its own* row,
    /// now across multiple submission lanes.
    #[test]
    fn concurrent_submitters_get_their_own_replies() {
        let batcher = Batcher::spawn(
            echo_engine(),
            "toy".into(),
            "m".into(),
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                lanes: 4,
            },
        );
        let h = batcher.handle();
        let mut clients = Vec::new();
        for c in 0..8i32 {
            let h = h.clone();
            clients.push(std::thread::spawn(move || {
                for j in 0..64i32 {
                    let token = c * 1000 + j;
                    let out = h.submit(vec![token, 7, 7]).expect("submit");
                    assert_eq!(
                        out[0] as i32, token,
                        "client {c} got a reply for someone else's row"
                    );
                }
            }));
        }
        for c in clients {
            c.join().expect("client thread");
        }
    }

    /// A lone request must flush on the wait timeout, not hang waiting
    /// for a full batch.
    #[test]
    fn flush_on_timeout_single_request() {
        let batcher = Batcher::spawn(
            echo_engine(),
            "toy".into(),
            "m".into(),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(5),
                lanes: 2,
            },
        );
        let t0 = Instant::now();
        let out = batcher.handle().submit(vec![42]).expect("submit");
        assert_eq!(out[0] as i32, 42);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "single request must flush promptly on max_wait"
        );
    }

    /// Concurrent same-instant submissions actually coalesce: with a
    /// generous window, all stragglers ride one engine call.
    #[test]
    fn concurrent_submissions_share_batches() {
        let calls = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let calls_in = calls.clone();
        let engine = EngineHandle::simulated(move |_, _, rows| {
            calls_in.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // hold the batch open so stragglers can queue behind it
            std::thread::sleep(Duration::from_millis(10));
            Ok(rows.iter().map(|r| vec![r[0] as f32]).collect())
        });
        let batcher = Batcher::spawn(
            engine,
            "toy".into(),
            "m".into(),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(50),
                lanes: 4,
            },
        );
        let h = batcher.handle();
        let mut clients = Vec::new();
        for c in 0..16i32 {
            let h = h.clone();
            clients.push(std::thread::spawn(move || {
                h.submit(vec![c]).expect("submit")
            }));
        }
        for (c, t) in clients.into_iter().enumerate() {
            let out = t.join().expect("client");
            assert_eq!(out[0] as usize, c);
        }
        let n_calls = calls.load(std::sync::atomic::Ordering::Relaxed);
        assert!(
            n_calls < 16,
            "16 concurrent submissions should coalesce, saw {n_calls} engine calls"
        );
    }

    /// `submit_async` lets one thread keep many rows in flight; the
    /// replies arrive on the right receivers and the rows coalesce into
    /// shared engine calls.
    #[test]
    fn async_submissions_fan_out_and_coalesce() {
        let calls = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let calls_in = calls.clone();
        let engine = EngineHandle::simulated(move |_, _, rows| {
            calls_in.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Ok(rows.iter().map(|r| vec![r[0] as f32]).collect())
        });
        let batcher = Batcher::spawn(
            engine,
            "toy".into(),
            "m".into(),
            BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(20),
                lanes: 4,
            },
        );
        let h = batcher.handle();
        let pending: Vec<_> = (0..12i32)
            .map(|i| h.submit_async(vec![i]).expect("submit_async"))
            .collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let out = rx.recv().expect("reply arrives").expect("row result");
            assert_eq!(out[0] as usize, i, "reply routed to the wrong receiver");
        }
        let n_calls = calls.load(std::sync::atomic::Ordering::Relaxed);
        assert!(n_calls < 12, "12 in-flight rows should coalesce, saw {n_calls} calls");
    }

    /// The work-stealing drain: rows submitted from several threads —
    /// which pin to several different lanes — must still coalesce into
    /// shared engine calls, i.e. one batch picks up items across lanes
    /// instead of serving each lane in isolation.
    #[test]
    fn work_stealing_drain_batches_across_lanes() {
        let calls = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let calls_in = calls.clone();
        let engine = EngineHandle::simulated(move |_, _, rows| {
            calls_in.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(5));
            Ok(rows.iter().map(|r| vec![r[0] as f32]).collect())
        });
        let batcher = Batcher::spawn(
            engine,
            "toy".into(),
            "m".into(),
            BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(50),
                lanes: 8,
            },
        );
        let h = batcher.handle();
        let mut clients = Vec::new();
        for c in 0..8i32 {
            let h = h.clone();
            clients.push(std::thread::spawn(move || {
                // Each thread (hence each lane) keeps several rows in
                // flight so the drain has cross-lane work to steal.
                let pending: Vec<_> = (0..4i32)
                    .map(|j| h.submit_async(vec![c * 100 + j]).expect("submit"))
                    .collect();
                for (j, rx) in pending.into_iter().enumerate() {
                    let out = rx.recv().expect("reply").expect("row");
                    assert_eq!(out[0] as i32, c * 100 + j as i32);
                }
            }));
        }
        for t in clients {
            t.join().expect("client");
        }
        let n_calls = calls.load(std::sync::atomic::Ordering::Relaxed);
        assert!(
            n_calls < 8,
            "32 rows from 8 lanes must coalesce across lanes \
             (saw {n_calls} engine calls for 8 submitting threads)"
        );
    }

    /// An engine failure fans the error out to every submitter in the
    /// batch instead of wedging them.
    #[test]
    fn engine_error_reaches_every_submitter() {
        let engine = EngineHandle::simulated(|_, _, _| anyhow::bail!("engine exploded"));
        let batcher = Batcher::spawn(
            engine,
            "toy".into(),
            "m".into(),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(20),
                lanes: 2,
            },
        );
        let h = batcher.handle();
        let mut clients = Vec::new();
        for c in 0..4i32 {
            let h = h.clone();
            clients.push(std::thread::spawn(move || h.submit(vec![c])));
        }
        for t in clients {
            let res = t.join().expect("client");
            let err = res.expect_err("engine failure must propagate");
            assert!(format!("{err}").contains("engine exploded"));
        }
    }

    /// After a batch fails, the worker keeps serving: the next batch on
    /// the same batcher succeeds. This is the substrate the circuit
    /// breaker's recovery probes stand on — a transient fault must not
    /// retire the worker thread.
    #[test]
    fn batcher_serves_after_engine_failure() {
        let fail_once = Arc::new(std::sync::atomic::AtomicBool::new(true));
        let fail_in = fail_once.clone();
        let engine = EngineHandle::simulated(move |_, _, rows| {
            if fail_in.swap(false, std::sync::atomic::Ordering::Relaxed) {
                anyhow::bail!("429 rate limited: transient");
            }
            Ok(rows.iter().map(|r| vec![r[0] as f32]).collect())
        });
        let batcher = Batcher::spawn(
            engine,
            "toy".into(),
            "m".into(),
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                lanes: 1,
            },
        );
        let h = batcher.handle();
        let err = h.submit(vec![1]).expect_err("first batch fails");
        assert!(format!("{err}").contains("429"));
        let out = h
            .submit(vec![2])
            .expect("worker must survive the failed batch and serve again");
        assert_eq!(out[0] as i32, 2);
    }
}
