//! Dynamic batcher: coalesces concurrent single-row inference requests
//! into batched PJRT executions (vLLM-style continuous batching, adapted
//! to a fixed-shape classifier: batch across *requests*, not tokens).
//!
//! One batcher per (dataset, model). Requests queue up; the worker drains
//! up to `max_batch` of them, waiting at most `max_wait` for stragglers
//! once the first request of a batch has arrived, then issues one
//! `execute_batch` and fans results back out over per-request reply
//! channels. Pure std threading (no async runtime in this environment).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::EngineHandle;

struct Item {
    row: Vec<i32>,
    reply: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// Handle for submitting rows to a batcher. Cheap to clone.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Item>,
}

impl BatcherHandle {
    /// Submit one row; blocks until its batch has executed.
    pub fn submit(&self, row: Vec<i32>) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Item { row, reply: tx })
            .map_err(|_| anyhow!("batcher worker is gone"))?;
        rx.recv().map_err(|_| anyhow!("batcher dropped reply"))?
    }
}

/// Configuration for one dynamic batcher.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        // §Perf: the PJRT engine is a single-stream actor, so waiting long
        // for stragglers only adds latency; 300µs captures genuinely
        // concurrent arrivals (batch-8 execs are ~1.8ms) without stalling
        // the pipe. max_batch 8 matches the engine's preferred chunk.
        BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(300) }
    }
}

/// The batcher: owns its worker thread; dropping all handles stops it.
pub struct Batcher {
    handle: BatcherHandle,
    _join: std::thread::JoinHandle<()>,
}

impl Batcher {
    pub fn spawn(
        engine: EngineHandle,
        dataset: String,
        model: String,
        cfg: BatcherConfig,
    ) -> Batcher {
        let (tx, rx) = mpsc::channel::<Item>();
        let join = std::thread::Builder::new()
            .name(format!("batcher-{dataset}-{model}"))
            .spawn(move || worker(engine, dataset, model, cfg, rx))
            .expect("spawning batcher thread");
        Batcher { handle: BatcherHandle { tx }, _join: join }
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }
}

fn worker(
    engine: EngineHandle,
    dataset: String,
    model: String,
    cfg: BatcherConfig,
    rx: mpsc::Receiver<Item>,
) {
    loop {
        // Block for the first item of the next batch.
        let first = match rx.recv() {
            Ok(i) => i,
            Err(_) => break, // all handles dropped
        };
        // Rows are *moved* into the engine call and replies are kept in a
        // parallel, index-aligned vec — the worker never copies a token
        // row (they were cloned per request before PR 1).
        let mut rows: Vec<Vec<i32>> = Vec::with_capacity(cfg.max_batch);
        let mut replies: Vec<mpsc::SyncSender<Result<Vec<f32>>>> =
            Vec::with_capacity(cfg.max_batch);
        rows.push(first.row);
        replies.push(first.reply);
        let deadline = Instant::now() + cfg.max_wait;
        while rows.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(item) => {
                    rows.push(item.row);
                    replies.push(item.reply);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        match engine.execute_batch(&dataset, &model, rows) {
            Ok(outs) => {
                for (reply, out) in replies.into_iter().zip(outs) {
                    let _ = reply.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for reply in replies {
                    let _ = reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}
