//! One config surface for every serving entry point.
//!
//! `frugalgpt serve`, `examples/serve_workload.rs` and the `frugald`
//! network daemon all build their [`ServiceConfig`] through
//! [`ServiceConfig::from_args`] and their driver-level knobs through
//! [`ServeTuning::from_args`] — both driven by the declarative flag
//! tables below, which are ALSO what renders the usage text
//! ([`serve_usage`]). One table, three entry points, zero drift: a flag
//! added here parses everywhere and documents itself; the
//! `table_covers_every_flag` test plus a `debug_assert` in the checked
//! accessors keep the table and the parser from diverging.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::eval::simulate::ScenarioTimeline;
use crate::server::calibrate::SpeculateConfig;
use crate::server::health::HealthConfig;
use crate::server::reoptimizer::ReoptimizerConfig;
use crate::server::service::ServiceConfig;
use crate::server::shadow::ShadowConfig;
use crate::strategies::pipeline::PipelineSpec;
use crate::strategies::prompt::PromptPolicy;
use crate::strategies::router::RouterConfig;
use crate::util::args::Args;

/// One `--flag` in the shared serving flag tables.
pub struct FlagSpec {
    /// Flag name (without the leading `--`).
    pub name: &'static str,
    /// Metavar for the flag's value; `None` marks a boolean switch.
    pub value: Option<&'static str>,
    /// Human-readable default, empty when the flag defaults to "off".
    pub default: &'static str,
    /// One-line help text.
    pub help: &'static str,
}

/// Flags consumed by [`ServiceConfig::from_args`] — the service-level
/// config surface shared verbatim by all three entry points.
pub const SERVE_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        name: "no-cache",
        value: None,
        default: "",
        help: "disable the completion cache (the cascade-only ablation)",
    },
    FlagSpec {
        name: "cache-capacity",
        value: Some("N"),
        default: "4096",
        help: "completion-cache entries retained (LRU beyond this)",
    },
    FlagSpec {
        name: "cache-similar",
        value: None,
        default: "",
        help: "accept near-miss cache hits via the MinHash tier (min similarity 0.8)",
    },
    FlagSpec {
        name: "cache-shards",
        value: Some("N"),
        default: "0 = auto",
        help: "completion-cache shards (0 = next power of two >= cores)",
    },
    FlagSpec {
        name: "cache-touch",
        value: Some("T"),
        default: "1",
        help: "promote a cache entry on every T-th hit only (1 = exact LRU)",
    },
    FlagSpec {
        name: "prompt-keep",
        value: Some("K"),
        default: "full prompt",
        help: "prompt adaptation: keep only K few-shot examples (Fig. 2a)",
    },
    FlagSpec {
        name: "budget-cap",
        value: Some("USD"),
        default: "uncapped",
        help: "hard spend cap; past it the budget stage degrades to stage 0",
    },
    FlagSpec {
        name: "window",
        value: Some("CAP"),
        default: "2048",
        help: "labelled observation rows kept for the reoptimizer",
    },
    FlagSpec {
        name: "window-half-life",
        value: Some("H"),
        default: "hard ring",
        help: "decay-weight the observation window with half-life H observations",
    },
    FlagSpec {
        name: "shadow-rate",
        value: Some("R"),
        default: "0",
        help: "shadow-score fraction R of live queries on ALL models (needs --reoptimize-every)",
    },
    FlagSpec {
        name: "shadow-budget",
        value: Some("USD"),
        default: "uncapped",
        help: "hard spend cap for the shadow scorer",
    },
    FlagSpec {
        name: "shadow-referee",
        value: None,
        default: "",
        help: "label shadow rows by top-2 referee vote; the reference API is only consulted on disagreement",
    },
    FlagSpec {
        name: "shadow-margin",
        value: Some("M"),
        default: "off",
        help: "always shadow-sample queries whose serving score landed within M of its threshold",
    },
    FlagSpec {
        name: "pipeline",
        value: Some("SPEC"),
        default: "cache,shadow,prompt,budget,speculate,router,cascade",
        help: "serving stage stack as data, e.g. cache,prompt,cascade",
    },
    FlagSpec {
        name: "speculate",
        value: None,
        default: "",
        help: "speculative agreement serving: fire the plan's two cheapest models concurrently, accept on calibrated agreement",
    },
    FlagSpec {
        name: "speculate-target",
        value: Some("A"),
        default: "0.9",
        help: "calibrated accept bar: enable the agreement rule only when P(correct | agree) >= A in the window",
    },
    FlagSpec {
        name: "router",
        value: None,
        default: "",
        help: "per-query contextual routing: a learned meta-router picks a frontier point or skips a cascade prefix",
    },
    FlagSpec {
        name: "router-grid",
        value: Some("N"),
        default: "4",
        help: "max frontier points offered as routes beyond the global plan and its prefix-skips",
    },
    FlagSpec {
        name: "probe-model",
        value: Some("NAME"),
        default: "off",
        help: "marketplace model scored per query as the router's probe feature (billed onto routed answers)",
    },
    FlagSpec {
        name: "breaker",
        value: None,
        default: "implied by --scenario",
        help: "per-model circuit breakers + bounded retry",
    },
    FlagSpec {
        name: "breaker-trip",
        value: Some("T"),
        default: "3",
        help: "consecutive failures that trip a model's breaker",
    },
    FlagSpec {
        name: "breaker-cooldown",
        value: Some("C"),
        default: "16",
        help: "consults a tripped breaker stays open before a probe",
    },
    FlagSpec {
        name: "retries",
        value: Some("R"),
        default: "2",
        help: "bounded per-call retries before the breaker counts a failure",
    },
    FlagSpec {
        name: "scenario",
        value: Some("NAME|PATH"),
        default: "off",
        help: "replay a scripted fault timeline (builtin `storm`, or a scenario JSON)",
    },
];

/// Flags consumed by [`ServeTuning::from_args`] — driver-level knobs
/// (re-optimization cadence, concat grouping, report sinks) shared by
/// the entry points that drive a query loop.
pub const TUNING_FLAGS: &[FlagSpec] = &[
    FlagSpec {
        name: "reoptimize-every",
        value: Some("N"),
        default: "off",
        help: "re-learn the cascade from the observation window every N queries",
    },
    FlagSpec {
        name: "hysteresis",
        value: Some("H"),
        default: "0.005",
        help: "swap only when the re-learned plan wins by more than H",
    },
    FlagSpec {
        name: "min-window",
        value: Some("M"),
        default: "128",
        help: "observation rows required before the reoptimizer acts",
    },
    FlagSpec {
        name: "concat",
        value: Some("G"),
        default: "1",
        help: "serve via answer_batch with concatenation groups of G (Fig. 2b)",
    },
    FlagSpec {
        name: "swap-log",
        value: Some("PATH"),
        default: "",
        help: "write the plan-swap log as JSON (render with `report swaps`)",
    },
    FlagSpec {
        name: "metrics-json",
        value: Some("PATH"),
        default: "",
        help: "write the final metrics snapshot in the canonical wire schema (render with `report metrics`)",
    },
];

fn known_flag(name: &str) -> bool {
    SERVE_FLAGS.iter().chain(TUNING_FLAGS).any(|f| f.name == name)
}

/// Checked view over [`Args`]: every lookup `debug_assert`s the flag is
/// in one of the tables, so the parser cannot quietly consume a flag the
/// usage text does not document.
struct Table<'a>(&'a Args);

impl Table<'_> {
    fn get(&self, name: &str) -> Option<&str> {
        debug_assert!(known_flag(name), "flag --{name} missing from the flag tables");
        self.0.get(name)
    }
    fn get_f64(&self, name: &str) -> Option<f64> {
        debug_assert!(known_flag(name), "flag --{name} missing from the flag tables");
        self.0.get_f64(name)
    }
    fn get_usize(&self, name: &str) -> Option<usize> {
        debug_assert!(known_flag(name), "flag --{name} missing from the flag tables");
        self.0.get_usize(name)
    }
    fn has(&self, name: &str) -> bool {
        debug_assert!(known_flag(name), "flag --{name} missing from the flag tables");
        self.0.has(name)
    }
}

fn render_table(flags: &[FlagSpec]) -> String {
    let mut out = String::new();
    for f in flags {
        let head = match f.value {
            Some(v) => format!("--{} {}", f.name, v),
            None => format!("--{}", f.name),
        };
        out.push_str(&format!("  {head:<26} {}", f.help));
        if !f.default.is_empty() {
            out.push_str(&format!(" [default: {}]", f.default));
        }
        out.push('\n');
    }
    out
}

/// The serving flag reference, generated from the same tables
/// [`ServiceConfig::from_args`] and [`ServeTuning::from_args`] consume —
/// the usage text can no longer drift from the real flag set.
pub fn serve_usage() -> String {
    format!(
        "service flags (shared by `frugalgpt serve`, examples/serve_workload, frugald):\n\
         {}driver flags:\n{}",
        render_table(SERVE_FLAGS),
        render_table(TUNING_FLAGS)
    )
}

impl ServiceConfig {
    /// Build the service configuration from CLI flags — THE one config
    /// surface. Validation lives here too: `--shadow-rate` demands
    /// `--reoptimize-every` (shadow scoring spends real budget filling
    /// the observation window, and only the reoptimizer reads it),
    /// rates must be probabilities, and structural knobs must be
    /// non-degenerate. `--breaker` or `--scenario` turn the per-model
    /// health layer on.
    pub fn from_args(args: &Args) -> Result<ServiceConfig> {
        let a = Table(args);

        let shadow_rate = a.get_f64("shadow-rate").unwrap_or(0.0);
        if !(0.0..=1.0).contains(&shadow_rate) {
            bail!("--shadow-rate must be in [0, 1], got {shadow_rate}");
        }
        if shadow_rate > 0.0 && a.get_usize("reoptimize-every").is_none() {
            bail!(
                "--shadow-rate needs --reoptimize-every: shadow scoring spends real \
                 budget filling the observation window, and only the reoptimizer \
                 reads it"
            );
        }
        if shadow_rate == 0.0 {
            if a.has("shadow-referee") {
                bail!("--shadow-referee needs --shadow-rate (shadow scoring is off)");
            }
            if a.get_f64("shadow-margin").is_some() {
                bail!("--shadow-margin needs --shadow-rate (shadow scoring is off)");
            }
        }
        if !a.has("speculate") && a.get_f64("speculate-target").is_some() {
            bail!("--speculate-target needs --speculate (speculation is off by default)");
        }
        let speculate_target = a.get_f64("speculate-target").unwrap_or(0.9);
        if !(0.0..=1.0).contains(&speculate_target) || speculate_target == 0.0 {
            bail!("--speculate-target must be in (0, 1], got {speculate_target}");
        }
        let cache_touch = a.get_usize("cache-touch").unwrap_or(1);
        if cache_touch == 0 {
            bail!("--cache-touch must be >= 1 (1 = exact LRU)");
        }
        let window = a.get_usize("window").unwrap_or(2048);
        if window == 0 {
            bail!("--window must be >= 1");
        }
        if let Some(cap) = a.get_f64("budget-cap") {
            if cap <= 0.0 {
                bail!("--budget-cap must be positive, got {cap}");
            }
        }
        let pipeline = match a.get("pipeline") {
            Some(spec) => PipelineSpec::parse(spec).context("--pipeline")?,
            None => PipelineSpec::full(),
        };
        // --breaker (implied by --scenario): injected faults must degrade
        // the cascade instead of erroring the service.
        let health = (a.has("breaker") || a.get("scenario").is_some()).then(|| HealthConfig {
            trip_consecutive: a.get_usize("breaker-trip").unwrap_or(3) as u64,
            cooldown: a.get_usize("breaker-cooldown").unwrap_or(16) as u64,
            max_retries: a.get_usize("retries").unwrap_or(2) as u32,
            ..Default::default()
        });
        if !a.has("router") {
            if a.get_usize("router-grid").is_some() {
                bail!("--router-grid needs --router (routing is off by default)");
            }
            if a.get("probe-model").is_some() {
                bail!("--probe-model needs --router (routing is off by default)");
            }
        }
        let router = a.has("router").then(|| RouterConfig {
            grid: a.get_usize("router-grid").unwrap_or(4),
            probe_model: a.get("probe-model").map(str::to_string),
        });

        Ok(ServiceConfig {
            cache_enabled: !a.has("no-cache"),
            cache_capacity: a.get_usize("cache-capacity").unwrap_or(4096),
            cache_min_similarity: if a.has("cache-similar") { 0.8 } else { 1.0 },
            cache_shards: a.get_usize("cache-shards").unwrap_or(0),
            cache_touch_period: cache_touch as u32,
            baseline_locks: false,
            prompt_policy: match a.get_usize("prompt-keep") {
                Some(k) => PromptPolicy::Fixed(k),
                None => PromptPolicy::Full,
            },
            budget_cap_usd: a.get_f64("budget-cap"),
            window_capacity: window,
            window_half_life: a.get_f64("window-half-life"),
            shadow: (shadow_rate > 0.0).then(|| ShadowConfig {
                rate: shadow_rate,
                budget_usd: a.get_f64("shadow-budget"),
                referee: a.has("shadow-referee"),
                margin: a.get_f64("shadow-margin").map(|m| m as f32),
                ..Default::default()
            }),
            health,
            pipeline,
            router,
            speculate: a.has("speculate").then(|| SpeculateConfig {
                target: speculate_target,
                ..Default::default()
            }),
        })
    }
}

/// Driver-level serving knobs parsed from the same flag tables: the
/// scenario timeline, re-optimization cadence, concat grouping, and
/// report sinks. Entry points that drive a query loop share this so the
/// flags behave identically everywhere.
#[derive(Debug, Clone)]
pub struct ServeTuning {
    /// Scripted fault timeline (`--scenario`), already resolved from the
    /// builtin registry or loaded from disk.
    pub scenario: Option<ScenarioTimeline>,
    /// Re-learn cadence in answered queries (`--reoptimize-every`).
    pub reoptimize_every: Option<usize>,
    /// Observation rows required before the reoptimizer acts.
    pub min_window: usize,
    /// Swap margin (`--hysteresis`).
    pub hysteresis: f64,
    /// Concatenation group size for `answer_batch` (`--concat`).
    pub concat_group: usize,
    /// Plan-swap log sink (`--swap-log`).
    pub swap_log: Option<String>,
    /// Canonical metrics-snapshot sink (`--metrics-json`).
    pub metrics_json: Option<String>,
}

impl ServeTuning {
    /// Parse the driver knobs; resolves `--scenario` to a timeline.
    pub fn from_args(args: &Args) -> Result<ServeTuning> {
        let a = Table(args);
        let scenario = match a.get("scenario") {
            Some(s) => Some(match ScenarioTimeline::builtin(s) {
                Some(t) => t,
                None => ScenarioTimeline::load(Path::new(s))
                    .with_context(|| format!("--scenario {s}"))?,
            }),
            None => None,
        };
        let reoptimize_every = a.get_usize("reoptimize-every");
        if reoptimize_every == Some(0) {
            bail!("--reoptimize-every must be >= 1");
        }
        let hysteresis = a.get_f64("hysteresis").unwrap_or(0.005);
        if hysteresis < 0.0 {
            bail!("--hysteresis must be >= 0, got {hysteresis}");
        }
        Ok(ServeTuning {
            scenario,
            reoptimize_every,
            min_window: a.get_usize("min-window").unwrap_or(128),
            hysteresis,
            concat_group: a.get_usize("concat").unwrap_or(1).max(1),
            swap_log: a.get("swap-log").map(str::to_string),
            metrics_json: a.get("metrics-json").map(str::to_string),
        })
    }

    /// Reoptimizer configuration at `budget_usd_per_10k` — `None` when
    /// `--reoptimize-every` is off. The interval only matters for
    /// [`crate::server::reoptimizer::Reoptimizer::spawn`]-style
    /// background stepping (frugald); query-loop drivers call `step()`
    /// on their own cadence.
    pub fn reopt_config(&self, budget_usd_per_10k: f64) -> Option<ReoptimizerConfig> {
        self.reoptimize_every.map(|_| ReoptimizerConfig {
            budget_usd_per_10k,
            min_window: self.min_window,
            hysteresis: self.hysteresis,
            ..Default::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn empty_args_yield_the_full_default_stack() {
        let cfg = ServiceConfig::from_args(&parse("")).unwrap();
        assert!(cfg.cache_enabled);
        assert_eq!(cfg.cache_capacity, 4096);
        assert_eq!(cfg.cache_min_similarity, 1.0);
        assert!(cfg.shadow.is_none());
        assert!(cfg.health.is_none());
        assert!(!cfg.baseline_locks);
        assert_eq!(cfg.pipeline.describe(), PipelineSpec::full().describe());
        let t = ServeTuning::from_args(&parse("")).unwrap();
        assert!(t.scenario.is_none());
        assert!(t.reoptimize_every.is_none());
        assert_eq!(t.concat_group, 1);
        assert!(t.reopt_config(1.0).is_none());
    }

    #[test]
    fn shadow_rate_demands_reoptimize_every() {
        assert!(ServiceConfig::from_args(&parse("--shadow-rate 0.2")).is_err());
        let cfg =
            ServiceConfig::from_args(&parse("--shadow-rate 0.2 --reoptimize-every 50")).unwrap();
        assert_eq!(cfg.shadow.as_ref().unwrap().rate, 0.2);
        assert!(ServiceConfig::from_args(&parse("--shadow-rate 1.5 --reoptimize-every 50")).is_err());
    }

    #[test]
    fn breaker_and_scenario_turn_health_on() {
        let cfg = ServiceConfig::from_args(&parse("--breaker --breaker-trip 5 --retries 1"))
            .unwrap();
        let h = cfg.health.unwrap();
        assert_eq!(h.trip_consecutive, 5);
        assert_eq!(h.max_retries, 1);
        assert_eq!(h.cooldown, 16);
        let cfg = ServiceConfig::from_args(&parse("--scenario storm")).unwrap();
        assert!(cfg.health.is_some());
        let t = ServeTuning::from_args(&parse("--scenario storm")).unwrap();
        assert!(t.scenario.is_some());
    }

    #[test]
    fn router_flags_parse_and_demand_the_master_switch() {
        let cfg = ServiceConfig::from_args(&parse("")).unwrap();
        assert!(cfg.router.is_none(), "routing must be off by default");
        let cfg = ServiceConfig::from_args(&parse("--router")).unwrap();
        let rc = cfg.router.unwrap();
        assert_eq!(rc.grid, 4);
        assert!(rc.probe_model.is_none());
        let cfg = ServiceConfig::from_args(&parse(
            "--router --router-grid 2 --probe-model gpt_j",
        ))
        .unwrap();
        let rc = cfg.router.unwrap();
        assert_eq!(rc.grid, 2);
        assert_eq!(rc.probe_model.as_deref(), Some("gpt_j"));
        // Router knobs without the master switch are configuration errors,
        // not silent no-ops.
        assert!(ServiceConfig::from_args(&parse("--router-grid 2")).is_err());
        assert!(ServiceConfig::from_args(&parse("--probe-model gpt_j")).is_err());
    }

    #[test]
    fn speculate_flags_parse_and_demand_the_master_switch() {
        let cfg = ServiceConfig::from_args(&parse("")).unwrap();
        assert!(cfg.speculate.is_none(), "speculation must be off by default");
        let cfg = ServiceConfig::from_args(&parse("--speculate")).unwrap();
        assert_eq!(cfg.speculate.unwrap().target, 0.9);
        let cfg =
            ServiceConfig::from_args(&parse("--speculate --speculate-target 0.8")).unwrap();
        assert_eq!(cfg.speculate.unwrap().target, 0.8);
        // knob without the master switch is a configuration error
        assert!(ServiceConfig::from_args(&parse("--speculate-target 0.8")).is_err());
        assert!(
            ServiceConfig::from_args(&parse("--speculate --speculate-target 1.5")).is_err()
        );
        assert!(
            ServiceConfig::from_args(&parse("--speculate --speculate-target 0")).is_err()
        );
    }

    #[test]
    fn shadow_referee_and_margin_demand_shadow() {
        assert!(ServiceConfig::from_args(&parse("--shadow-referee")).is_err());
        assert!(ServiceConfig::from_args(&parse("--shadow-margin 0.05")).is_err());
        let cfg = ServiceConfig::from_args(&parse(
            "--shadow-rate 0.2 --reoptimize-every 50 --shadow-referee --shadow-margin 0.05",
        ))
        .unwrap();
        let sh = cfg.shadow.unwrap();
        assert!(sh.referee);
        assert_eq!(sh.margin, Some(0.05));
    }

    #[test]
    fn degenerate_knobs_are_rejected() {
        assert!(ServiceConfig::from_args(&parse("--cache-touch 0")).is_err());
        assert!(ServiceConfig::from_args(&parse("--window 0")).is_err());
        assert!(ServiceConfig::from_args(&parse("--budget-cap -1")).is_err());
        assert!(ServiceConfig::from_args(&parse("--pipeline cache,nonsense")).is_err());
        assert!(ServeTuning::from_args(&parse("--reoptimize-every 0")).is_err());
        assert!(ServeTuning::from_args(&parse("--hysteresis -0.1")).is_err());
    }

    #[test]
    fn reopt_config_carries_the_tuning() {
        let t = ServeTuning::from_args(&parse(
            "--reoptimize-every 40 --hysteresis 0.01 --min-window 64",
        ))
        .unwrap();
        let rc = t.reopt_config(6.5).unwrap();
        assert_eq!(rc.budget_usd_per_10k, 6.5);
        assert_eq!(rc.min_window, 64);
        assert_eq!(rc.hysteresis, 0.01);
    }

    #[test]
    fn table_covers_every_flag_and_usage_renders_it() {
        let mut names: Vec<&str> =
            SERVE_FLAGS.iter().chain(TUNING_FLAGS).map(|f| f.name).collect();
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate flag in the tables");
        let usage = serve_usage();
        for n in names {
            assert!(usage.contains(&format!("--{n}")), "usage text is missing --{n}");
        }
    }
}
