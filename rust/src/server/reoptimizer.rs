//! Online re-optimization: re-learn the served cascade from live traffic
//! and hot-swap it atomically.
//!
//! The paper trains `(L, τ)` once on a labelled train split; this module
//! closes the loop at serving time (cf. SMART, Jo et al. 2024, and
//! budget-constrained contextual cascade policies, Zhang et al. 2024):
//!
//! 1. the service accumulates a sliding [`ObservationWindow`] of
//!    fully-labelled rows — every marketplace model's (pred, score,
//!    correct) on recently served items. The rows come either from an
//!    external labelled feedback stream or, with `server::shadow`
//!    enabled, from the service's *own* sampled traffic (pseudo-labelled
//!    against a reference model) — in which case the loop needs zero
//!    offline labels;
//! 2. each [`Reoptimizer::step`] drains that window into a fresh
//!    `SplitTable` slice — decay-*weighted* when the window has a
//!    half-life, so recent traffic dominates the re-learn without
//!    shrinking the sample — and re-runs the full `CascadeOptimizer`
//!    sweep against the configured budget (both the candidate metrics and
//!    the current plan's replay below use the same weights, so the
//!    comparison stays apples-to-apples);
//! 3. if the candidate plan beats the currently served plan on the same
//!    window by more than the **hysteresis** margin, it is published
//!    through the service's `PlanHandle` — a single atomic pointer swap
//!    that in-flight `answer()` calls never observe mid-query.
//!
//! Hysteresis is what keeps sampling noise from thrashing plans: a
//! candidate must improve window accuracy by `hysteresis` (absolute), or
//! match accuracy and cut window cost by a `hysteresis` fraction, before
//! a swap is published. An identical plan is always kept.
//!
//! Two driving modes share [`Reoptimizer::step`]:
//! * **synchronous** — the serving driver calls `step()` every N queries
//!   (`frugalgpt serve --reoptimize-every N`), deterministic and easy to
//!   test;
//! * **background** — [`Reoptimizer::spawn`] runs the same step on its own
//!   thread every `interval` until the handle is stopped/dropped.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::cascade::replay;
use crate::coordinator::optimizer::{CascadeOptimizer, OptimizerOptions};
use crate::coordinator::responses::SplitTable;
use crate::marketplace::CostModel;
use crate::server::calibrate::{CalibratorBundle, SpeculateConfig};
use crate::server::metrics::ObservationWindow;
use crate::server::router_train::{evaluate_router, train_router, RouterTrainConfig};
use crate::server::service::FrugalService;
use crate::strategies::router::RouterModel;

/// Tuning for the re-optimization loop.
#[derive(Debug, Clone)]
pub struct ReoptimizerConfig {
    /// Budget the re-learned plan must fit (USD per 10k queries).
    /// `f64::MAX` = unconstrained (chase the top of the frontier).
    pub budget_usd_per_10k: f64,
    /// Minimum observation-window rows before a step will act.
    pub min_window: usize,
    /// Swap margin: required absolute window-accuracy improvement, or (at
    /// matched accuracy) required fractional window-cost reduction.
    pub hysteresis: f64,
    /// Poll period of the background mode ([`Reoptimizer::spawn`]).
    pub interval: Duration,
    /// Search options for the window sweeps. The default grid is finer
    /// than windows need; callers typically shrink `grid` for latency.
    pub optimizer: OptimizerOptions,
    /// Tuning of the router co-training pass that rides every step when
    /// the service has contextual routing enabled (no-op otherwise).
    pub router_train: RouterTrainConfig,
}

impl Default for ReoptimizerConfig {
    fn default() -> Self {
        ReoptimizerConfig {
            budget_usd_per_10k: f64::MAX,
            min_window: 128,
            hysteresis: 0.005,
            interval: Duration::from_secs(2),
            optimizer: OptimizerOptions::default(),
            router_train: RouterTrainConfig::default(),
        }
    }
}

/// What one [`Reoptimizer::step`] did.
#[derive(Debug, Clone)]
pub enum ReoptOutcome {
    /// Not enough labelled observations yet.
    WindowTooSmall {
        /// Rows currently in the window.
        have: usize,
        /// Configured `min_window`.
        need: usize,
    },
    /// The current plan survives (identical re-learn, inside hysteresis,
    /// or no plan fits the budget on this window — `reason` says which).
    Kept { reason: String },
    /// A new plan was published.
    Swapped {
        /// Version of the published bundle.
        version: u64,
        /// Window accuracy of the new plan.
        window_accuracy: f64,
        /// Window average cost per query (USD) of the new plan.
        window_avg_cost: f64,
    },
}

/// Decide whether a candidate plan's window metrics justify replacing the
/// current plan's. Pure so the hysteresis band is unit-testable:
/// accuracy must improve by more than `hysteresis` (absolute), or hold
/// (within 1e-12) while cost drops by more than a `hysteresis` fraction.
pub fn swap_worthy(
    current: (f64, f64),
    candidate: (f64, f64),
    hysteresis: f64,
) -> bool {
    let (cur_acc, cur_cost) = current;
    let (cand_acc, cand_cost) = candidate;
    if cand_acc > cur_acc + hysteresis {
        return true;
    }
    cand_acc >= cur_acc - 1e-12 && cand_cost < cur_cost * (1.0 - hysteresis)
}

/// The re-optimization driver for one service.
pub struct Reoptimizer {
    svc: Arc<FrugalService>,
    cfg: ReoptimizerConfig,
    steps: AtomicU64,
    swaps: AtomicU64,
    router_swaps: AtomicU64,
    calibrator_swaps: AtomicU64,
}

impl Reoptimizer {
    /// A driver for `svc` with the given tuning (no thread yet — use
    /// [`Reoptimizer::step`] directly or [`Reoptimizer::spawn`]).
    pub fn new(svc: Arc<FrugalService>, cfg: ReoptimizerConfig) -> Reoptimizer {
        Reoptimizer {
            svc,
            cfg,
            steps: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            router_swaps: AtomicU64::new(0),
            calibrator_swaps: AtomicU64::new(0),
        }
    }

    /// The tuning this driver runs with.
    pub fn config(&self) -> &ReoptimizerConfig {
        &self.cfg
    }

    /// Steps run so far (both modes).
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Swaps published so far by this reoptimizer.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Router models published so far by this reoptimizer's co-training.
    pub fn router_swaps(&self) -> u64 {
        self.router_swaps.load(Ordering::Relaxed)
    }

    /// Calibrator bundles published so far by this reoptimizer (the
    /// speculative accept rule's republish cadence).
    pub fn calibrator_swaps(&self) -> u64 {
        self.calibrator_swaps.load(Ordering::Relaxed)
    }

    /// One full re-optimization pass: window → table slice → sweep →
    /// hysteresis gate → (maybe) publish. When the service has contextual
    /// routing enabled, the same window then co-trains the router — so a
    /// router retrain always follows a plan swap on the same cadence,
    /// through the same `swap_worthy` hysteresis.
    pub fn step(&self) -> Result<ReoptOutcome> {
        self.steps.fetch_add(1, Ordering::Relaxed);
        let window: &ObservationWindow = &self.svc.metrics.window;
        let have = window.len();
        if have < self.cfg.min_window {
            return Ok(ReoptOutcome::WindowTooSmall { have, need: self.cfg.min_window });
        }
        // Fresh marketplace prices every step: `svc.costs()` is an owned
        // snapshot, so a `PriceStep` applied via `FrugalService::reprice`
        // feeds straight into the sweep and the `swap_worthy` cost branch.
        let costs = self.svc.costs();
        let (table, tokens) = window
            .snapshot_table(&costs.dataset, &costs.model_names)
            .context("window emptied between len() and snapshot")?;
        let outcome = self.plan_step(&table, &tokens, &costs)?;
        // Router co-training rides the same window (route specs reflect
        // the plan published above, if any — `router_route_specs` reads
        // the live plan handle).
        self.router_step(&table, &tokens, &costs)?;
        // The speculative accept rule is recalibrated from the same
        // window and stamped with the (possibly just-published) plan
        // version — this is how the speculate stage exits its
        // abstain-on-stale-plan state after a swap.
        self.calibrate_step(&table)?;
        Ok(outcome)
    }

    /// The plan phase of one step (the pre-router reoptimizer, verbatim).
    fn plan_step(
        &self,
        table: &SplitTable,
        tokens: &[u32],
        costs: &CostModel,
    ) -> Result<ReoptOutcome> {
        let opt = CascadeOptimizer::new(table, costs, tokens.to_vec(), self.cfg.optimizer.clone())
            .context("building window optimizer")?;
        let candidate = match opt.optimize(self.cfg.budget_usd_per_10k) {
            Ok(c) => c,
            Err(e) => {
                return Ok(ReoptOutcome::Kept {
                    reason: format!("no plan fits budget on current window: {e}"),
                })
            }
        };

        let current_plan = self.svc.plan();
        if candidate.plan == current_plan {
            return Ok(ReoptOutcome::Kept { reason: "re-learned plan is identical".into() });
        }

        // Score BOTH plans on the same window so the comparison is
        // apples-to-apples under the live traffic mix.
        let cur = replay::replay(&current_plan, table, costs, tokens);
        if !swap_worthy(
            (cur.accuracy, cur.avg_cost),
            (candidate.train_accuracy, candidate.train_avg_cost),
            self.cfg.hysteresis,
        ) {
            return Ok(ReoptOutcome::Kept {
                reason: format!(
                    "within hysteresis: window acc {:.4}→{:.4}, cost ${:.4}→${:.4}/10k",
                    cur.accuracy,
                    candidate.train_accuracy,
                    cur.avg_cost * 1e4,
                    candidate.train_avg_cost * 1e4
                ),
            });
        }

        let weight_note = if table.is_weighted() {
            format!(" (decay weight {:.1})", table.total_weight())
        } else {
            String::new()
        };
        let reason = format!(
            "window of {} obs{}: acc {:.4}→{:.4}, cost ${:.4}→${:.4}/10k",
            table.len(),
            weight_note,
            cur.accuracy,
            candidate.train_accuracy,
            cur.avg_cost * 1e4,
            candidate.train_avg_cost * 1e4
        );
        let version = self.svc.publish_plan(
            candidate.plan,
            &reason,
            Some((candidate.train_accuracy, candidate.train_avg_cost)),
        )?;
        self.swaps.fetch_add(1, Ordering::Relaxed);
        Ok(ReoptOutcome::Swapped {
            version,
            window_accuracy: candidate.train_accuracy,
            window_avg_cost: candidate.train_avg_cost,
        })
    }

    /// The router phase of one step: retrain on the window, evaluate both
    /// the incumbent model and the retrained one on the same rows and
    /// route set, and publish through the service only when the retrain
    /// clears the same hysteresis band plans must clear. No-op when the
    /// service has routing off or the plan offers nothing to route to.
    fn router_step(
        &self,
        table: &SplitTable,
        tokens: &[u32],
        costs: &CostModel,
    ) -> Result<Option<u64>> {
        let specs = self.svc.router_route_specs();
        if specs.len() < 2 {
            return Ok(None);
        }
        let Some(cur_bundle) = self.svc.router_snapshot() else { return Ok(None) };
        let probe = self.svc.probe_model_index();
        let trained =
            train_router(table, tokens, &specs, probe, costs, &self.cfg.router_train)?;
        // Incumbent policy on the SAME window and route set. Right after a
        // plan swap the incumbent was reset to the degenerate model, so
        // this is the plain global-plan baseline — exactly what the
        // retrain must beat to justify routing at all.
        let cur_model = if cur_bundle.model.n_routes() == specs.len() {
            cur_bundle.model.clone()
        } else {
            RouterModel::degenerate(specs.len())
        };
        if trained.model == cur_model {
            return Ok(None);
        }
        let cur = evaluate_router(&cur_model, table, tokens, &specs, probe, costs)?;
        if !swap_worthy(
            (cur.accuracy, cur.avg_cost),
            (trained.train_accuracy, trained.train_avg_cost),
            self.cfg.hysteresis,
        ) {
            return Ok(None);
        }
        let reason = format!(
            "router retrain on window of {} obs: acc {:.4}→{:.4}, cost ${:.4}→${:.4}/10k",
            table.len(),
            cur.accuracy,
            trained.train_accuracy,
            cur.avg_cost * 1e4,
            trained.train_avg_cost * 1e4
        );
        let version = self.svc.publish_router(
            trained.model,
            &reason,
            Some((trained.train_accuracy, trained.train_avg_cost)),
        )?;
        self.router_swaps.fetch_add(1, Ordering::Relaxed);
        Ok(Some(version))
    }

    /// The calibration phase of one step: re-estimate the speculative
    /// accept rule (`P(correct | agreement)` for the probe pair, plus the
    /// disagreement score bar) from the same window slice, and publish it
    /// stamped with the *current* plan version. Publication is skipped
    /// when nothing material changed — same enabled state, same plan
    /// stamp, and an estimate inside the hysteresis band — so steady
    /// traffic does not churn calibrator generations. No-op when
    /// speculation is off.
    fn calibrate_step(&self, table: &SplitTable) -> Result<Option<u64>> {
        let Some(pair) = self.svc.speculate_pair() else { return Ok(None) };
        let Some(cur) = self.svc.calibrator_snapshot() else { return Ok(None) };
        let cfg = SpeculateConfig { target: cur.target, ..Default::default() };
        let plan_version = self.svc.plan_version();
        let version = self.svc.reserve_calibrator_version()?;
        let bundle = CalibratorBundle::from_table(version, plan_version, pair, cfg, table)?;
        let materially_equal = bundle.enabled == cur.enabled
            && bundle.plan_version == cur.plan_version
            && bundle.pair == cur.pair
            && (bundle.calibration.p_correct_given_agree
                - cur.calibration.p_correct_given_agree)
                .abs()
                <= self.cfg.hysteresis
            && bundle.calibration.score_bar.map(f32::to_bits)
                == cur.calibration.score_bar.map(f32::to_bits);
        if materially_equal {
            return Ok(None);
        }
        let reason = format!(
            "recalibrated on window of {} obs: P(correct|agree) {:.4}→{:.4}, enabled {}→{}, plan v{}",
            table.len(),
            cur.calibration.p_correct_given_agree,
            bundle.calibration.p_correct_given_agree,
            cur.enabled,
            bundle.enabled,
            plan_version
        );
        if self.svc.publish_calibrator(bundle, &reason)? {
            self.calibrator_swaps.fetch_add(1, Ordering::Relaxed);
            Ok(Some(version))
        } else {
            Ok(None)
        }
    }

    /// Run `step()` every `cfg.interval` on a background thread until the
    /// returned handle is stopped (or dropped). Step errors are counted on
    /// the service's error metric, never fatal to the loop.
    pub fn spawn(self) -> ReoptimizerHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_in = stop.clone();
        let interval = self.cfg.interval;
        let join = std::thread::Builder::new()
            .name("reoptimizer".into())
            .spawn(move || {
                while !stop_in.load(Ordering::Relaxed) {
                    std::thread::park_timeout(interval);
                    if stop_in.load(Ordering::Relaxed) {
                        break;
                    }
                    if self.step().is_err() {
                        self.svc.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
            .expect("spawning reoptimizer thread");
        ReoptimizerHandle { stop, join: Some(join) }
    }
}

/// Owns the background re-optimization thread; stopping (or dropping)
/// shuts it down promptly.
pub struct ReoptimizerHandle {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ReoptimizerHandle {
    /// Stop the background thread and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            j.thread().unpark();
            let _ = j.join();
        }
    }
}

impl Drop for ReoptimizerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hysteresis_band_blocks_noise_and_passes_real_gains() {
        let h = 0.01;
        // clear accuracy win
        assert!(swap_worthy((0.80, 1.0), (0.83, 1.2), h));
        // inside the accuracy band, same cost → no swap
        assert!(!swap_worthy((0.80, 1.0), (0.805, 1.0), h));
        // matched accuracy, real cost cut → swap
        assert!(swap_worthy((0.80, 1.0), (0.80, 0.7), h));
        // matched accuracy, cost cut inside the band → no swap
        assert!(!swap_worthy((0.80, 1.0), (0.80, 0.995), h));
        // worse accuracy never swaps, however cheap
        assert!(!swap_worthy((0.80, 1.0), (0.60, 0.01), h));
        // exact tie (same acc, same cost) → no swap
        assert!(!swap_worthy((0.80, 1.0), (0.80, 1.0), h));
    }
}
