//! The serving front end: request intake, dynamic batching, metrics, the
//! composed FrugalGPT service (cache → prompt adaptation → cascade →
//! budget metering), and the online re-optimization loop that re-learns
//! and hot-swaps the served cascade as traffic drifts.

pub mod batcher;
pub mod metrics;
pub mod reoptimizer;
pub mod service;
