//! The tokio serving front end: request intake, dynamic batching,
//! metrics, and the composed FrugalGPT service (cache → prompt adaptation
//! → cascade → budget metering).

pub mod batcher;
pub mod metrics;
pub mod service;
