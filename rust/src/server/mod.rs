//! The serving front end: request intake, dynamic batching, metrics, the
//! composed FrugalGPT service (a `strategies::pipeline` stack — by
//! default cache → shadow tap → prompt adaptation → budget degrade →
//! cascade — with composition as data), shadow scoring of sampled live
//! traffic, per-model health (circuit breakers + bounded retry/backoff)
//! so a misbehaving marketplace API degrades the cascade instead of
//! erroring it, and the online re-optimization loop that re-learns and
//! hot-swaps the served cascade as traffic drifts — with shadow + decay
//! windows the loop is self-contained: no offline labels enter it.
//!
//! Two modules make it an actual network service: [`config`] is the one
//! config surface (flag table → [`service::ServiceConfig`]) shared by
//! every entry point, and [`net`] is the TCP front door (`frugald/1`
//! line-delimited JSON) that `frugald` binds over the composed service.

pub mod batcher;
pub mod calibrate;
pub mod config;
pub mod health;
pub mod metrics;
pub mod net;
pub mod reoptimizer;
pub mod router_train;
pub mod service;
pub mod shadow;
