//! The paper's §3 cost-reduction strategies beyond the cascade.
//!
//! * [`cache`] — **completion cache** (LLM approximation, Fig. 2c): store
//!   responses and reuse them for identical/similar queries.
//! * [`prompt`] — **prompt adaptation** (Fig. 2a): shrink the few-shot
//!   prompt to cut input-token cost.
//! * [`concat`] — **query concatenation** (Fig. 2b): share one prompt
//!   across several queries.
//!
//! All three compose with the cascade (paper "Compositions") — the
//! `strategies_demo` example and the `report -- strategies` ablation
//! evaluate each one and their stack.

pub mod cache;
pub mod concat;
pub mod prompt;
