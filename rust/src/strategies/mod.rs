//! The paper's §3 cost-reduction strategies beyond the cascade.
//!
//! * [`cache`] — **completion cache** (LLM approximation, Fig. 2c): store
//!   responses and reuse them for identical/similar queries.
//! * [`prompt`] — **prompt adaptation** (Fig. 2a): shrink the few-shot
//!   prompt to cut input-token cost.
//! * [`concat`] — **query concatenation** (Fig. 2b): share one prompt
//!   across several queries.
//! * [`router`] — **per-query contextual routing**: a learned meta-router
//!   that picks a frontier point or skips a cascade prefix per query
//!   (FORC-style, see PAPERS.md) instead of serving one global (L, τ).
//! * [`speculate`] — **speculative agreement serving**: fire the plan's
//!   two cheapest models concurrently and accept on calibrated agreement
//!   (SMART-style guarantee, see PAPERS.md), escalating to the cascade
//!   with the probe results attached so no stage is billed twice.
//!
//! All three compose with the cascade (paper "Compositions") through the
//! [`pipeline`] module: each strategy is a first-class [`pipeline::Strategy`]
//! stage, and [`pipeline::PipelineSpec`] makes the composition *data*
//! (`serve --pipeline cache,prompt,cascade`). The `strategies_demo`
//! example and the `report -- strategies` ablation drive the exact stack
//! production serves.

pub mod cache;
pub mod concat;
pub mod pipeline;
pub mod prompt;
pub mod router;
pub mod speculate;
