//! Speculative agreement serving (the "fire-two-cheapest" stage).
//!
//! FrugalGPT's cascade consults models *sequentially*: the second-cheapest
//! model only runs after the cheapest one answered and failed its
//! threshold. This stage converts that latency chain into concurrency:
//! it submits the plan's two cheapest models at once through their
//! per-model [`Batcher`] lanes (`submit_async` — no new threads beyond
//! the lanes' own workers) and accepts immediately when the calibrated
//! accept rules fire (see `server::calibrate`): the pair agrees on the
//! answer, or both reliability scores clear the calibrated bar. When the
//! rules decline, the query escalates: the probe results ride along on
//! [`QueryCtx::probes`] as [`StageSeed`]s, and the cascade executor reuses
//! them instead of re-invoking (and re-billing) the already-answered
//! stages.
//!
//! Degradation is never an error: an open circuit breaker on either probe
//! model (`server::health`) drops speculation to a single probe (seed
//! only, never an accept — one voice is not an agreement) or to a clean
//! `Pass`; a probe lane failure is swallowed the same way, after feeding
//! the breaker. With acceptance disabled (generation-0 calibration, or a
//! stale plan stamp) the stage passes every query untouched — no probes,
//! no spend, no context mutation — so the speculative pipeline reproduces
//! the non-speculative one bitwise (the safety identity, property-tested
//! in `tests/properties.rs`).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::cascade::{argmax, CascadePlan, HealthView, StageSeed};
use crate::coordinator::scorer::Scorer;
use crate::data::DatasetMeta;
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::server::batcher::{Batcher, BatcherConfig, BatcherHandle};
use crate::server::calibrate::CalibratorHandle;
use crate::server::health::{BreakerState, ModelHealth};
use crate::server::metrics::ServiceMetrics;
use crate::strategies::concat;
use crate::strategies::pipeline::{Decision, QueryCtx, StageAnswer, Strategy};

/// Nominal input size used only to *rank* models by price when picking
/// the probe pair (ranking, not metering — real spend is always billed at
/// the query's actual amortized tokens).
const PROBE_RANK_TOKENS: u32 = 256;

/// The two cheapest distinct models of `plan` under `costs`, cheapest
/// first (ties break toward the lower marketplace index). `None` when the
/// plan has fewer than two distinct models — speculation needs a pair.
pub fn cheapest_pair(plan: &CascadePlan, costs: &CostModel) -> Option<(usize, usize)> {
    let mut models: Vec<usize> = Vec::new();
    for s in plan.stages.iter() {
        if !models.contains(&s.model) {
            models.push(s.model);
        }
    }
    if models.len() < 2 {
        return None;
    }
    models.sort_by(|&a, &b| {
        let ca = costs.call_cost(a, PROBE_RANK_TOKENS, 0);
        let cb = costs.call_cost(b, PROBE_RANK_TOKENS, 0);
        ca.partial_cmp(&cb)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    Some((models[0], models[1]))
}

/// One probe lane: a dedicated [`Batcher`] worker bound to one model.
struct ProbeLane {
    /// Keeps the worker thread alive for the service lifetime.
    _batcher: Batcher,
    handle: BatcherHandle,
    model: usize,
}

/// The pair of probe lanes plus the scorer and cost meter they share —
/// the service-owned execution half of the speculative stage (the
/// decision half lives in the swappable `CalibratorBundle`).
pub struct SpeculativeLanes {
    lanes: [ProbeLane; 2],
    scorer: Scorer,
    costs: CostModel,
}

impl SpeculativeLanes {
    /// Spawn both lanes against `engine` for the marketplace pair
    /// `(cheapest, second-cheapest)`.
    pub fn spawn(
        engine: &EngineHandle,
        costs: &CostModel,
        meta: &DatasetMeta,
        pair: (usize, usize),
    ) -> Result<SpeculativeLanes> {
        let mk = |m: usize| -> Result<ProbeLane> {
            let name = costs
                .model_names
                .get(m)
                .cloned()
                .with_context(|| format!("probe model index {m} not in marketplace"))?;
            let batcher = Batcher::spawn(
                engine.clone(),
                costs.dataset.clone(),
                name,
                BatcherConfig::default(),
            );
            Ok(ProbeLane { handle: batcher.handle(), _batcher: batcher, model: m })
        };
        Ok(SpeculativeLanes {
            lanes: [mk(pair.0)?, mk(pair.1)?],
            scorer: Scorer::new(engine.clone(), meta.clone()),
            costs: costs.clone(),
        })
    }

    /// The marketplace pair the lanes are bound to, lane order.
    pub fn pair(&self) -> (usize, usize) {
        (self.lanes[0].model, self.lanes[1].model)
    }

    /// Fire the lanes marked `up` concurrently and collect whatever
    /// succeeds, lane order. Lane failures are *degradation, not errors*:
    /// a failed submit/recv/score drops that lane's seed and records the
    /// failure with the breaker (when a health layer exists); successes
    /// record too, so probe traffic drives trip and recovery like any
    /// other call.
    pub fn fire(
        &self,
        tokens: &[i32],
        billed: u32,
        up: [bool; 2],
        health: Option<&ModelHealth>,
    ) -> Vec<StageSeed> {
        // Submit everything first — the whole point is concurrency.
        let mut pending = Vec::with_capacity(2);
        for (lane, &fire) in self.lanes.iter().zip(&up) {
            if !fire {
                pending.push(None);
                continue;
            }
            match lane.handle.submit_async(tokens.to_vec()) {
                Ok(rx) => pending.push(Some(rx)),
                Err(_) => {
                    if let Some(h) = health {
                        h.record(lane.model, false);
                    }
                    pending.push(None);
                }
            }
        }
        // Then collect.
        let mut seeds = Vec::with_capacity(2);
        for (lane, rx) in self.lanes.iter().zip(pending) {
            let Some(rx) = rx else { continue };
            let seed = rx
                .recv()
                .map_err(anyhow::Error::from)
                .and_then(|r| r)
                .and_then(|logits| {
                    let pred = argmax(&logits) as u32;
                    let score = self.scorer.score(tokens, pred)?;
                    Ok(StageSeed {
                        model: lane.model,
                        answer: pred,
                        score,
                        cost_usd: self.costs.call_cost(lane.model, billed, pred),
                        latency_ms: self.costs.latency[lane.model]
                            .latency_ms(billed + self.costs.answer_len(pred)),
                    })
                });
            match seed {
                Ok(seed) => {
                    if let Some(h) = health {
                        h.record(lane.model, true);
                    }
                    seeds.push(seed);
                }
                Err(_) => {
                    if let Some(h) = health {
                        h.record(lane.model, false);
                    }
                }
            }
        }
        seeds
    }
}

/// The pipeline stage. Sits between `budget` and `router` in the full
/// stack: an accept preempts both the router's probe spend and the
/// cascade; an escalation leaves routing untouched and only attaches
/// seeds.
pub struct SpeculativeStage {
    /// The probe lanes (service-owned, shared with nothing else).
    pub lanes: Arc<SpeculativeLanes>,
    /// The swappable accept-rule snapshot handle.
    pub calibrator: Arc<CalibratorHandle>,
    /// Circuit breakers (`None` = no health layer; both lanes always up).
    pub health: Option<Arc<ModelHealth>>,
    /// Service counters (`speculative_*`).
    pub metrics: Arc<ServiceMetrics>,
}

impl SpeculativeStage {
    /// Whether `m` may be probed: anything but an open breaker. This is a
    /// pure read ([`ModelHealth::state`]) — speculation must not tick
    /// cooldowns or claim half-open probe slots; the cascade's own
    /// `admit` calls drive those.
    fn model_up(&self, m: usize) -> bool {
        match &self.health {
            Some(h) => h.state(m) != BreakerState::Open,
            None => true,
        }
    }
}

impl Strategy for SpeculativeStage {
    fn name(&self) -> &'static str {
        "speculate"
    }

    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision> {
        let bundle = self.calibrator.snapshot();
        // Safety identity: with no accept rule live there is nothing an
        // escalation could buy either — pass with zero side effects so
        // the pipeline stays bitwise identical to the non-speculative one.
        if !bundle.accepts_anything() {
            return Ok(Decision::Pass);
        }
        // Abstain-on-stale-plan: the rules were calibrated against a plan
        // this query is not being served under.
        if bundle.plan_version != ctx.bundle.version() {
            return Ok(Decision::Pass);
        }
        // A republished pair that the lanes were not built for (plan
        // swapped to different cheap models) cannot be probed.
        if bundle.pair != self.lanes.pair() {
            return Ok(Decision::Pass);
        }
        // The budget cap is a hard promise: a degraded query runs the
        // single-stage fallback and must not pay for probes on top.
        if ctx.degraded {
            return Ok(Decision::Pass);
        }
        let pair = self.lanes.pair();
        let up = [self.model_up(pair.0), self.model_up(pair.1)];
        if !up[0] && !up[1] {
            // Both probe breakers open: degrade to a clean Pass.
            return Ok(Decision::Pass);
        }
        let (prompt_toks, query_toks) = concat::split_row_tokens(&ctx.tokens, ctx.meta);
        let billed = concat::amortized_input(prompt_toks, query_toks, ctx.concat_group);
        let seeds = self.lanes.fire(&ctx.tokens, billed, up, self.health.as_deref());
        if seeds.len() == 2 {
            if let Some((answer, score, lane)) = bundle.accept(
                seeds[0].answer,
                seeds[0].score,
                seeds[1].answer,
                seeds[1].score,
            ) {
                let cost_usd: f64 = seeds.iter().map(|s| s.cost_usd).sum();
                // Concurrent fire: the pair's wall-clock is the slower
                // probe, not the sum.
                let latency_ms = seeds.iter().fold(0.0f64, |a, s| a.max(s.latency_ms));
                self.metrics.speculative_accepts.fetch_add(1, Ordering::Relaxed);
                // Spend-avoided estimate: what the plan's terminal model
                // would have billed for this query, less what the pair
                // cost. An estimate (the cascade might have stopped
                // earlier), surfaced as such in `report metrics`.
                let terminal = ctx.bundle.cascade().plan().stages.last().map(|s| s.model);
                if let Some(t) = terminal {
                    let saved =
                        (self.lanes.costs.call_cost(t, billed, answer) - cost_usd).max(0.0);
                    self.metrics
                        .speculative_saved_spend_nano_usd
                        .fetch_add((saved * 1e9).round().max(0.0) as u64, Ordering::Relaxed);
                }
                return Ok(Decision::Answer(StageAnswer {
                    answer,
                    score,
                    cost_usd,
                    model: Some(seeds[lane].model),
                    stopped_at: None,
                    skipped_stages: Vec::new(),
                    simulated_api_latency_ms: latency_ms,
                    router_version: None,
                    degraded: false,
                }));
            }
        }
        if seeds.is_empty() {
            // Every fired lane failed — degrade to a clean Pass (the
            // breaker heard about it; the cascade will retry on its own
            // terms).
            return Ok(Decision::Pass);
        }
        // Escalate: the cascade consumes the seeds instead of re-billing
        // those stages (single-probe degradation lands here too — one
        // voice is not an agreement, but its answer is still paid for).
        self.metrics.speculative_escalations.fetch_add(1, Ordering::Relaxed);
        ctx.probes = seeds;
        Ok(Decision::Pass)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::Stage;
    use crate::eval::simulate::SimWorld;

    #[test]
    fn cheapest_pair_ranks_by_call_cost() {
        let world = SimWorld::new(4, 32, 7);
        // Sim prices are a ladder in model index: 0 and 1 are cheapest.
        let plan = CascadePlan::new(vec![
            Stage { model: 2, threshold: 0.5 },
            Stage { model: 0, threshold: 0.6 },
            Stage { model: 3, threshold: 0.0 },
        ]);
        assert_eq!(cheapest_pair(&plan, &world.costs), Some((0, 2)));
        let pair_plan = CascadePlan::pair(1, 0.5, 3);
        assert_eq!(cheapest_pair(&pair_plan, &world.costs), Some((1, 3)));
        // fewer than two distinct models → no pair
        assert_eq!(cheapest_pair(&CascadePlan::single(2), &world.costs), None);
        let dup = CascadePlan::new(vec![
            Stage { model: 1, threshold: 0.5 },
            Stage { model: 1, threshold: 0.0 },
        ]);
        assert_eq!(cheapest_pair(&dup, &world.costs), None);
    }

    #[test]
    fn lanes_fire_both_probes_and_meter_costs() {
        let world = SimWorld::new(3, 24, 11);
        let engine = world.engine().unwrap();
        let lanes =
            SpeculativeLanes::spawn(&engine, &world.costs, &world.meta, (0, 1)).unwrap();
        assert_eq!(lanes.pair(), (0, 1));
        let i = 3;
        let tokens = world.row(i);
        let billed = world.input_tokens()[i];
        let seeds = lanes.fire(tokens, billed, [true, true], None);
        assert_eq!(seeds.len(), 2);
        for (lane, seed) in seeds.iter().enumerate() {
            assert_eq!(seed.model, lane);
            // the sim engine answers straight from the response table
            assert_eq!(seed.answer, world.table.pred(lane, i));
            let want = world.costs.call_cost(lane, billed, seed.answer);
            assert_eq!(seed.cost_usd.to_bits(), want.to_bits());
            assert!(seed.latency_ms > 0.0);
            assert!((0.0..=1.0).contains(&seed.score));
        }
    }

    #[test]
    fn lanes_single_probe_mode_fires_one() {
        let world = SimWorld::new(3, 24, 11);
        let engine = world.engine().unwrap();
        let lanes =
            SpeculativeLanes::spawn(&engine, &world.costs, &world.meta, (0, 1)).unwrap();
        let seeds = lanes.fire(world.row(0), world.input_tokens()[0], [false, true], None);
        assert_eq!(seeds.len(), 1);
        assert_eq!(seeds[0].model, 1);
    }
}
