//! Query concatenation (paper §3, Strategy 1 / Fig. 2b).
//!
//! Processing queries one-by-one re-sends the same few-shot prompt every
//! time. Concatenation sends the prompt once for a group of `g` queries,
//! so the per-query input cost drops from `prompt + query` to
//! `prompt/g + query`. This module models the *billing* effect (which is
//! what the strategy is about) and provides the grouping machinery the
//! server's batcher uses to form concatenation groups.

use crate::data::{prompt, DatasetMeta};

/// Billable input tokens per query when `group` queries share one prompt.
///
/// `prompt_tokens`: tokens of the shared few-shot prefix;
/// `query_tokens`: tokens of one query segment.
pub fn tokens_per_query(prompt_tokens: u32, query_tokens: u32, group: usize) -> f64 {
    assert!(group > 0);
    prompt_tokens as f64 / group as f64 + query_tokens as f64
}

/// Cost multiplier of concatenation vs. individual queries (< 1).
pub fn savings_ratio(prompt_tokens: u32, query_tokens: u32, group: usize) -> f64 {
    let single = (prompt_tokens + query_tokens) as f64;
    tokens_per_query(prompt_tokens, query_tokens, group) / single
}

/// Split the prompt/query token budget of a dataset row layout.
pub fn split_tokens(meta: &DatasetMeta) -> (u32, u32) {
    let prompt = (meta.n_examples * meta.block_len) as u32;
    let query = meta.query_len() as u32;
    (prompt, query)
}

/// Integer billable input tokens for one member of a concatenation group
/// (the metering unit `FrugalService::answer_batch` charges): exactly
/// [`tokens_per_query`], rounded up to whole tokens. A group of one bills
/// the full prompt unchanged.
pub fn amortized_input(prompt_tokens: u32, query_tokens: u32, group: usize) -> u32 {
    tokens_per_query(prompt_tokens, query_tokens, group).ceil() as u32
}

/// Split a concrete (possibly prompt-adapted) token row into its billable
/// `(prompt, query)` token counts: non-PAD tokens before the query offset
/// are the shareable prompt, the rest is the per-query segment. Unlike
/// [`split_tokens`] this reflects *this row's actual content* — prompt
/// adaptation may have truncated examples, and concatenation then
/// amortizes only the prompt that is still there (the two strategies
/// compose without double-counting).
pub fn split_row_tokens(tokens: &[i32], meta: &DatasetMeta) -> (u32, u32) {
    let boundary = meta.q_offset.min(tokens.len());
    let prompt = prompt::input_tokens(&tokens[..boundary]);
    let total = prompt::input_tokens(tokens);
    (prompt, total - prompt)
}

/// Greedy group former: batches queries into concatenation groups of at
/// most `max_group`, returning group index ranges over the input order.
pub fn form_groups(n: usize, max_group: usize) -> Vec<std::ops::Range<usize>> {
    assert!(max_group > 0);
    let mut out = Vec::with_capacity(n.div_ceil(max_group));
    let mut i = 0;
    while i < n {
        let j = (i + max_group).min(n);
        out.push(i..j);
        i = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_of_one_changes_nothing() {
        assert_eq!(tokens_per_query(24, 18, 1), 42.0);
        assert!((savings_ratio(24, 18, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn savings_grow_with_group_and_prompt_share() {
        let r2 = savings_ratio(24, 18, 2);
        let r8 = savings_ratio(24, 18, 8);
        assert!(r8 < r2 && r2 < 1.0);
        // with a prompt-dominated layout the savings approach prompt share
        let r_big = savings_ratio(1000, 10, 100);
        assert!(r_big < 0.03);
    }

    #[test]
    fn amortized_input_rounds_up_and_caps_at_single() {
        assert_eq!(amortized_input(24, 18, 1), 42);
        assert_eq!(amortized_input(24, 18, 8), 21); // 3 + 18
        assert_eq!(amortized_input(25, 18, 8), 22); // ceil(3.125) + 18
        assert!(amortized_input(1000, 10, 100) < amortized_input(1000, 10, 2));
    }

    #[test]
    fn split_row_tokens_counts_actual_content() {
        use crate::data::layout;
        let meta = DatasetMeta {
            name: "t".into(),
            seq: 20,
            n_classes: 4,
            n_examples: 4,
            qlen: 6,
            block_len: 3,
            q_offset: 12,
            scorer_seq: 20,
            answer_lens: vec![1; 4],
        };
        let mut row = vec![layout::PAD; meta.seq];
        for j in 0..meta.n_examples {
            row[j * 3] = layout::SEP_EX;
            row[j * 3 + 1] = 20 + j as i32;
            row[j * 3 + 2] = layout::LABEL_BASE + (j % 4) as i32;
        }
        row[meta.q_offset] = layout::CLS;
        for p in 0..meta.qlen {
            row[meta.q_offset + 1 + p] = 100 + p as i32;
        }
        row[meta.q_offset + 1 + meta.qlen] = layout::QSEP;
        let (p, q) = split_row_tokens(&row, &meta);
        assert_eq!(p, 12, "4 dense example blocks of 3 tokens");
        assert_eq!(q, 8, "CLS + 6 body + QSEP");
        // prompt adaptation shrinks the shareable prompt, not the query
        let truncated = crate::data::prompt::truncate_examples(&row, &meta, 1);
        let (tp, tq) = split_row_tokens(&truncated, &meta);
        assert_eq!((tp, tq), (3, 8));
    }

    #[test]
    fn groups_cover_everything_once() {
        for (n, g) in [(10, 3), (9, 3), (1, 8), (0, 4)] {
            let groups = form_groups(n, g);
            let total: usize = groups.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            for w in groups.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            assert!(groups.iter().all(|r| r.len() <= g));
        }
    }
}
