//! Query concatenation (paper §3, Strategy 1 / Fig. 2b).
//!
//! Processing queries one-by-one re-sends the same few-shot prompt every
//! time. Concatenation sends the prompt once for a group of `g` queries,
//! so the per-query input cost drops from `prompt + query` to
//! `prompt/g + query`. This module models the *billing* effect (which is
//! what the strategy is about) and provides the grouping machinery the
//! server's batcher uses to form concatenation groups.

use crate::data::DatasetMeta;

/// Billable input tokens per query when `group` queries share one prompt.
///
/// `prompt_tokens`: tokens of the shared few-shot prefix;
/// `query_tokens`: tokens of one query segment.
pub fn tokens_per_query(prompt_tokens: u32, query_tokens: u32, group: usize) -> f64 {
    assert!(group > 0);
    prompt_tokens as f64 / group as f64 + query_tokens as f64
}

/// Cost multiplier of concatenation vs. individual queries (< 1).
pub fn savings_ratio(prompt_tokens: u32, query_tokens: u32, group: usize) -> f64 {
    let single = (prompt_tokens + query_tokens) as f64;
    tokens_per_query(prompt_tokens, query_tokens, group) / single
}

/// Split the prompt/query token budget of a dataset row layout.
pub fn split_tokens(meta: &DatasetMeta) -> (u32, u32) {
    let prompt = (meta.n_examples * meta.block_len) as u32;
    let query = meta.query_len() as u32;
    (prompt, query)
}

/// Greedy group former: batches queries into concatenation groups of at
/// most `max_group`, returning group index ranges over the input order.
pub fn form_groups(n: usize, max_group: usize) -> Vec<std::ops::Range<usize>> {
    assert!(max_group > 0);
    let mut out = Vec::with_capacity(n.div_ceil(max_group));
    let mut i = 0;
    while i < n {
        let j = (i + max_group).min(n);
        out.push(i..j);
        i = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_of_one_changes_nothing() {
        assert_eq!(tokens_per_query(24, 18, 1), 42.0);
        assert!((savings_ratio(24, 18, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn savings_grow_with_group_and_prompt_share() {
        let r2 = savings_ratio(24, 18, 2);
        let r8 = savings_ratio(24, 18, 8);
        assert!(r8 < r2 && r2 < 1.0);
        // with a prompt-dominated layout the savings approach prompt share
        let r_big = savings_ratio(1000, 10, 100);
        assert!(r_big < 0.03);
    }

    #[test]
    fn groups_cover_everything_once() {
        for (n, g) in [(10, 3), (9, 3), (1, 8), (0, 4)] {
            let groups = form_groups(n, g);
            let total: usize = groups.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            for w in groups.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            assert!(groups.iter().all(|r| r.len() <= g));
        }
    }
}
