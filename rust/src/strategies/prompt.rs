//! Prompt adaptation (paper §3, Strategy 1 / Fig. 2a): *prompt selection*.
//!
//! Few-shot prompts dominate input-token cost. Prompt selection keeps only
//! `k' ≤ k` in-context examples. The simulated models were trained with
//! variable-k truncation, so accuracy degrades gracefully — and episodic
//! queries genuinely need the examples, making the choice a real
//! accuracy/cost trade-off (evaluated by `report -- strategies`).

use crate::data::{prompt, DatasetMeta};

/// A prompt-selection policy: how many in-context examples to keep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromptPolicy {
    /// Keep the full prompt (baseline).
    Full,
    /// Always keep exactly `k` examples.
    Fixed(usize),
    /// Keep `full` examples for queries that carry the episodic marker
    /// (they need the prompt to be answerable) and `cheap` otherwise —
    /// the "which examples to maintain for various queries" idea.
    Adaptive { cheap: usize, full: usize },
}

impl PromptPolicy {
    /// Number of examples to keep for this query.
    pub fn keep(&self, tokens: &[i32], meta: &DatasetMeta) -> usize {
        match *self {
            PromptPolicy::Full => meta.n_examples,
            PromptPolicy::Fixed(k) => k.min(meta.n_examples),
            PromptPolicy::Adaptive { cheap, full } => {
                if prompt::is_episodic(tokens, meta) {
                    full.min(meta.n_examples)
                } else {
                    cheap.min(meta.n_examples)
                }
            }
        }
    }

    /// Apply the policy: returns the (possibly truncated) token row.
    pub fn apply(&self, tokens: &[i32], meta: &DatasetMeta) -> Vec<i32> {
        let keep = self.keep(tokens, meta);
        if keep >= meta.n_examples {
            tokens.to_vec()
        } else {
            prompt::truncate_examples(tokens, meta, keep)
        }
    }

    /// Billable input tokens after applying the policy.
    pub fn input_tokens(&self, tokens: &[i32], meta: &DatasetMeta) -> u32 {
        prompt::input_tokens(&self.apply(tokens, meta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::layout;

    fn meta() -> DatasetMeta {
        DatasetMeta {
            name: "t".into(),
            seq: 32,
            n_classes: 4,
            n_examples: 4,
            qlen: 6,
            block_len: 3,
            q_offset: 12,
            scorer_seq: 32,
            answer_lens: vec![1; 4],
        }
    }

    fn row(episodic: bool) -> Vec<i32> {
        let m = meta();
        let mut t = vec![layout::PAD; m.seq];
        for j in 0..m.n_examples {
            t[j * 3] = layout::SEP_EX;
            t[j * 3 + 1] = 20 + j as i32;
            t[j * 3 + 2] = layout::LABEL_BASE + (j % 4) as i32;
        }
        t[m.q_offset] = layout::CLS;
        for p in 0..m.qlen {
            t[m.q_offset + 1 + p] = 110 + p as i32;
        }
        if episodic {
            t[m.q_offset + 2] = layout::EPI_MARK;
        }
        t[m.q_offset + 1 + m.qlen] = layout::QSEP;
        t
    }

    #[test]
    fn full_keeps_everything() {
        let m = meta();
        let t = row(false);
        assert_eq!(PromptPolicy::Full.apply(&t, &m), t);
    }

    #[test]
    fn fixed_truncates_and_saves_tokens() {
        let m = meta();
        let t = row(false);
        let full = PromptPolicy::Full.input_tokens(&t, &m);
        let cut = PromptPolicy::Fixed(1).input_tokens(&t, &m);
        assert_eq!(full - cut, 3 * 3); // 3 dropped blocks × 3 tokens
    }

    #[test]
    fn adaptive_spends_on_episodic_only() {
        let m = meta();
        let pol = PromptPolicy::Adaptive { cheap: 0, full: 4 };
        assert_eq!(pol.keep(&row(false), &m), 0);
        assert_eq!(pol.keep(&row(true), &m), 4);
    }

    #[test]
    fn fixed_clamps_to_available_examples() {
        let m = meta();
        assert_eq!(PromptPolicy::Fixed(99).keep(&row(false), &m), 4);
    }
}
