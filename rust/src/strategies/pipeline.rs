//! The strategy pipeline: the paper's three cost-reduction strategy
//! families (§3, Fig. 2) as first-class, composable serving stages.
//!
//! `FrugalService::answer()` used to hard-code one fixed sequence inline
//! (cache → shadow tap → prompt adaptation → budget degrade → cascade).
//! This module turns each step into a [`Strategy`] — a stage that looks
//! at a [`QueryCtx`] and either **answers** the query, **transforms** it,
//! or **passes** — and a [`Pipeline`] that composes an ordered stack of
//! them terminating in the cascade executor. Composition is *data*
//! ([`PipelineSpec`]: `"cache,prompt,cascade"` on the CLI, a JSON array
//! in a config file), so the `report strategies` ablation, the
//! `strategies_demo` example, and production serving all drive the same
//! code path with different stage stacks.
//!
//! Every stage sees the same [`QueryCtx`], which carries the
//! [`PlanBundle`] snapshot the service took for this query — stages are
//! plan-version-aware *by construction* (the completion cache stamps
//! entries with the bundle version; the cascade executes the bundle's
//! compiled cascades), so a concurrent plan swap can never mix two plans
//! inside one answer, stage by stage. Each stage also owns a lock-free
//! [`StageMetrics`] sink, surfaced per stage in the serve report.
//!
//! Layering: this module is the *composition* layer — it may depend on
//! both the pure `coordinator` types and the `server` runtime objects
//! (bundle, metrics, shadow). Nothing in `coordinator` depends on it.

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::budget::{Admission, BudgetTracker};
use crate::coordinator::cascade::{CascadePlan, StageSeed};
use crate::data::{prompt, DatasetMeta};
use crate::server::calibrate::CalibratorHandle;
use crate::server::health::ModelHealth;
use crate::server::metrics::ServiceMetrics;
use crate::server::service::PlanBundle;
use crate::server::shadow::Shadow;
use crate::strategies::cache::{CachedAnswer, ShardedCache};
use crate::strategies::concat;
use crate::strategies::prompt::PromptPolicy;
use crate::strategies::router::{ProbeScorer, RouteDecision, RouterHandle, RouterStage};
use crate::strategies::speculate::{SpeculativeLanes, SpeculativeStage};
use crate::util::json::Value;

/// Everything a stage may read (and the two fields it may flag) about the
/// query currently walking the pipeline. One `QueryCtx` is built per
/// answer around ONE plan-bundle snapshot.
pub struct QueryCtx<'a> {
    /// The client's token row, untouched — cache keys hash this so a
    /// transformed query still hits its original entry.
    pub original: &'a [i32],
    /// The current (possibly transformed) token row later stages consume.
    /// Borrowed until the first `Decision::Transform` takes ownership.
    pub tokens: Cow<'a, [i32]>,
    /// The plan-bundle snapshot this query is served under; every stage
    /// reads plan, version, and compiled cascades from here and nowhere
    /// else (the one-snapshot-per-answer invariant).
    pub bundle: &'a PlanBundle,
    /// Dataset geometry of the token layout.
    pub meta: &'a DatasetMeta,
    /// Set by the budget stage when the spend cap is exhausted: the
    /// cascade executor then runs the bundle's degraded (first-stage-only)
    /// cascade.
    pub degraded: bool,
    /// Size of the concatenation group this query rides in (1 = solo).
    /// The cascade executor bills `prompt/group + query` input tokens
    /// (paper Fig. 2b) when > 1.
    pub concat_group: usize,
    /// Set by the `router` stage when the learned meta-router picked a
    /// non-default route (a prefix-skip of the global plan, or another
    /// frontier point): the cascade executor then runs that route's
    /// cascade instead of the bundle default. `None` = the global plan
    /// (identical code path to no router at all).
    pub route: Option<RouteDecision>,
    /// Set by the `speculate` stage when it probed but declined to
    /// accept: the already-invoked, already-billed probe results. The
    /// cascade executor consumes matching seeds instead of re-invoking
    /// those stages, and bills unconsumed ones onto the answer (the probe
    /// call was real spend either way). Empty = no speculation happened
    /// (identical code path to no speculate stage at all).
    pub probes: Vec<StageSeed>,
}

/// The answer a stage produced for the query.
#[derive(Debug, Clone)]
pub struct StageAnswer {
    /// The answer class.
    pub answer: u32,
    /// Reliability score attached to the answer.
    pub score: f32,
    /// Marketplace spend of producing it (0 for cache hits).
    pub cost_usd: f64,
    /// Marketplace index of the producing model; `None` when no API was
    /// invoked (completion-cache hits).
    pub model: Option<usize>,
    /// Cascade stage that answered; `None` when the cascade never ran.
    pub stopped_at: Option<usize>,
    /// Plan stage indices the cascade skipped because their model's
    /// circuit breaker was open (empty when healthy or no health layer;
    /// see `server::health`). A non-empty list marks a degraded answer.
    pub skipped_stages: Vec<usize>,
    /// Simulated commercial-API round-trip latency (ms).
    pub simulated_api_latency_ms: f64,
    /// Version of the [`crate::strategies::router::RouterBundle`] whose
    /// decision shaped this answer; `None` when no router routed it (no
    /// router stage, degenerate fast path, abstention, cache hit).
    pub router_version: Option<u64>,
    /// Whether the answer was served degraded — the budget cap's
    /// single-stage fallback, or a cascade that skipped breaker-open
    /// stages. Feeds the `origin` tag on the wire answer.
    pub degraded: bool,
}

/// What a stage decided about the query.
pub enum Decision {
    /// The stage produced the final answer; no later stage runs.
    Answer(StageAnswer),
    /// The stage rewrote the query tokens (e.g. prompt adaptation); later
    /// stages see the new row.
    Transform(Vec<i32>),
    /// Nothing to do for this query.
    Pass,
}

/// One composable serving stage. Implementations must be cheap to call
/// and thread-safe — the service drives one pipeline from many client
/// threads.
pub trait Strategy: Send + Sync {
    /// Stable stage name (the [`PipelineSpec`] vocabulary).
    fn name(&self) -> &'static str;

    /// Inspect the query and decide: answer it, transform it, or pass.
    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision>;

    /// Called (in reverse stack order) on every stage *above* the one
    /// that answered, once the final answer is known — the population /
    /// metering hook (cache fill, budget metering).
    fn on_answer(&self, _ctx: &QueryCtx, _answer: &StageAnswer) {}

    /// Whether this stage answers every query it sees (the pipeline must
    /// terminate in exactly one such stage).
    fn is_terminal(&self) -> bool {
        false
    }
}

/// Lock-free per-stage counters (one per pipeline stage).
#[derive(Debug, Default)]
pub struct StageMetrics {
    /// Queries that reached this stage.
    pub queries: AtomicU64,
    /// ... it answered.
    pub answered: AtomicU64,
    /// ... it transformed.
    pub transformed: AtomicU64,
    /// ... it passed through untouched.
    pub passed: AtomicU64,
}

/// Point-in-time copy of one stage's counters, tagged with the stage name.
#[derive(Debug, Clone)]
pub struct StageMetricsSnapshot {
    /// Stage name (the [`PipelineSpec`] vocabulary).
    pub stage: &'static str,
    /// Queries that reached the stage.
    pub queries: u64,
    /// ... it answered.
    pub answered: u64,
    /// ... it transformed.
    pub transformed: u64,
    /// ... it passed through.
    pub passed: u64,
}

struct PipelineStage {
    strategy: Box<dyn Strategy>,
    metrics: StageMetrics,
}

/// An ordered stack of [`Strategy`] stages terminating in the cascade
/// executor. Built once per service; driven concurrently.
pub struct Pipeline {
    stages: Vec<PipelineStage>,
}

/// What the pipeline produced for one query.
pub struct PipelineOutcome {
    /// The final answer.
    pub answer: StageAnswer,
    /// Index (in the composed stack) of the answering stage.
    pub answered_by: usize,
    /// Name of the answering stage.
    pub stage: &'static str,
}

impl Pipeline {
    /// Compose a stack. Exactly one terminal stage is required and it
    /// must be last — every query must reach an answer.
    pub fn new(stages: Vec<Box<dyn Strategy>>) -> Result<Pipeline> {
        if stages.is_empty() {
            bail!("a pipeline needs at least the cascade executor");
        }
        for (i, s) in stages.iter().enumerate() {
            if s.is_terminal() && i + 1 != stages.len() {
                bail!(
                    "terminal stage `{}` must be last in the pipeline",
                    s.name()
                );
            }
        }
        if !stages.last().unwrap().is_terminal() {
            bail!(
                "pipeline must terminate in an answering stage (got `{}`)",
                stages.last().unwrap().name()
            );
        }
        Ok(Pipeline {
            stages: stages
                .into_iter()
                .map(|strategy| PipelineStage { strategy, metrics: StageMetrics::default() })
                .collect(),
        })
    }

    /// Stage names in stack order.
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.stages.iter().map(|s| s.strategy.name()).collect()
    }

    /// Walk the stack: each stage answers, transforms, or passes; the
    /// first answer wins and the stages above it get their `on_answer`
    /// hook (reverse order), so e.g. the cache populates from cascade
    /// answers and the budget meters their spend.
    pub fn answer(&self, mut ctx: QueryCtx) -> Result<PipelineOutcome> {
        for (idx, stage) in self.stages.iter().enumerate() {
            stage.metrics.queries.fetch_add(1, Ordering::Relaxed);
            match stage.strategy.on_query(&mut ctx)? {
                Decision::Answer(answer) => {
                    stage.metrics.answered.fetch_add(1, Ordering::Relaxed);
                    for prior in self.stages[..idx].iter().rev() {
                        prior.strategy.on_answer(&ctx, &answer);
                    }
                    return Ok(PipelineOutcome {
                        answer,
                        answered_by: idx,
                        stage: stage.strategy.name(),
                    });
                }
                Decision::Transform(tokens) => {
                    stage.metrics.transformed.fetch_add(1, Ordering::Relaxed);
                    ctx.tokens = Cow::Owned(tokens);
                }
                Decision::Pass => {
                    stage.metrics.passed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        // Unreachable for well-behaved stages (`Pipeline::new` enforces a
        // terminal last stage); a custom terminal stage that passed
        // anyway is a bug we surface as an error, not a panic.
        bail!("pipeline exhausted without an answer — the terminal stage did not answer")
    }

    /// Point-in-time copy of every stage's counters, in stack order.
    pub fn metrics_snapshot(&self) -> Vec<StageMetricsSnapshot> {
        self.stages
            .iter()
            .map(|s| StageMetricsSnapshot {
                stage: s.strategy.name(),
                queries: s.metrics.queries.load(Ordering::Relaxed),
                answered: s.metrics.answered.load(Ordering::Relaxed),
                transformed: s.metrics.transformed.load(Ordering::Relaxed),
                passed: s.metrics.passed.load(Ordering::Relaxed),
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Pipeline composition as data
// ---------------------------------------------------------------------------

/// The stage vocabulary of [`PipelineSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// Completion cache (Fig. 2c) — answers repeats for $0.
    Cache,
    /// Shadow-scoring tap — samples cascade-bound traffic for learning.
    Shadow,
    /// Prompt adaptation (Fig. 2a) — shrinks the few-shot prompt.
    Prompt,
    /// Budget-cap degrade — flags cap exhaustion for the cascade.
    Budget,
    /// Speculative agreement probe — fires the plan's two cheapest models
    /// concurrently and accepts on calibrated agreement (see
    /// [`crate::strategies::speculate`]).
    Speculate,
    /// Learned per-query meta-router — picks a frontier point or skips a
    /// cascade prefix (see [`crate::strategies::router`]).
    Router,
    /// The LLM cascade executor (Fig. 2e) — the terminal stage.
    Cascade,
}

impl StageKind {
    /// The spec name of this stage.
    pub fn name(self) -> &'static str {
        match self {
            StageKind::Cache => "cache",
            StageKind::Shadow => "shadow",
            StageKind::Prompt => "prompt",
            StageKind::Budget => "budget",
            StageKind::Speculate => "speculate",
            StageKind::Router => "router",
            StageKind::Cascade => "cascade",
        }
    }

    /// Parse one spec name.
    pub fn parse(s: &str) -> Result<StageKind> {
        Ok(match s.trim() {
            "cache" => StageKind::Cache,
            "shadow" => StageKind::Shadow,
            "prompt" => StageKind::Prompt,
            "budget" => StageKind::Budget,
            "speculate" => StageKind::Speculate,
            "router" => StageKind::Router,
            "cascade" => StageKind::Cascade,
            other => bail!(
                "unknown pipeline stage `{other}` \
                 (expected cache|shadow|prompt|budget|speculate|router|cascade)"
            ),
        })
    }
}

/// Pipeline composition as data: an ordered stage list, e.g.
/// `serve --pipeline cache,prompt,cascade` or the JSON array form in a
/// service-config file. Validation enforces the one structural rule —
/// `cascade` present exactly once, last — plus no duplicate stages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineSpec {
    /// The ordered stages.
    pub stages: Vec<StageKind>,
}

impl Default for PipelineSpec {
    fn default() -> Self {
        PipelineSpec::full()
    }
}

impl PipelineSpec {
    /// The full production stack: cache → shadow → prompt → budget →
    /// speculate → router → cascade. The speculate and router slots sit
    /// after the prompt transform (their features and probes must see the
    /// tokens the cascade will bill); speculate precedes router so a
    /// calibrated accept also saves the router's probe spend. Both are
    /// skipped entirely when unconfigured, so the default spec reproduces
    /// the pre-speculation stack exactly.
    pub fn full() -> PipelineSpec {
        PipelineSpec {
            stages: vec![
                StageKind::Cache,
                StageKind::Shadow,
                StageKind::Prompt,
                StageKind::Budget,
                StageKind::Speculate,
                StageKind::Router,
                StageKind::Cascade,
            ],
        }
    }

    /// Parse a comma-separated stage list (`"cache,prompt,cascade"`).
    pub fn parse(s: &str) -> Result<PipelineSpec> {
        let spec = PipelineSpec {
            stages: s
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(StageKind::parse)
                .collect::<Result<_>>()?,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Check the structural rules (cascade exactly once and last, no
    /// duplicates).
    pub fn validate(&self) -> Result<()> {
        if self.stages.last() != Some(&StageKind::Cascade) {
            bail!(
                "pipeline spec must end in `cascade` (got `{}`)",
                self.describe()
            );
        }
        for (i, a) in self.stages.iter().enumerate() {
            if self.stages[..i].contains(a) {
                bail!("duplicate pipeline stage `{}`", a.name());
            }
        }
        Ok(())
    }

    /// Human-readable form, e.g. `cache,prompt,cascade`.
    pub fn describe(&self) -> String {
        self.stages
            .iter()
            .map(|s| s.name().to_string())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// JSON form: an array of stage names.
    pub fn to_value(&self) -> Value {
        Value::Arr(
            self.stages
                .iter()
                .map(|s| Value::Str(s.name().to_string()))
                .collect(),
        )
    }

    /// Parse the [`PipelineSpec::to_value`] form (validated).
    pub fn from_value(v: &Value) -> Result<PipelineSpec> {
        let arr = match v.as_arr() {
            Some(a) => a,
            None => bail!("pipeline spec must be a JSON array of stage names"),
        };
        let spec = PipelineSpec {
            stages: arr
                .iter()
                .map(|x| match x.as_str() {
                    Some(s) => StageKind::parse(s),
                    None => bail!("pipeline stage names must be strings"),
                })
                .collect::<Result<_>>()?,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Shared service state the stage constructors borrow from.
pub struct StageDeps {
    /// The sharded completion cache (`None` = cache disabled; the stage
    /// is then skipped even if the spec names it). Internally
    /// synchronized per shard — no outer lock.
    pub cache: Option<Arc<ShardedCache>>,
    /// The shadow tap (`None` = shadow off; the stage is then skipped).
    pub shadow: Option<Arc<Shadow>>,
    /// Prompt-adaptation policy for the `prompt` stage.
    pub prompt_policy: PromptPolicy,
    /// Serving spend meter for the `budget` stage.
    pub budget: Arc<BudgetTracker>,
    /// Service-level counters (cache hits, cascade stops, per-model
    /// windows).
    pub metrics: Arc<ServiceMetrics>,
    /// The swappable router bundle handle (`None` = router off; the
    /// `router` stage is then skipped).
    pub router: Option<Arc<RouterHandle>>,
    /// The probe model behind the router's probe feature (`None` = the
    /// feature stays 0.0).
    pub probe: Option<Arc<ProbeScorer>>,
    /// The two pre-spawned speculative probe lanes (`None` = speculation
    /// off; the `speculate` stage is then skipped).
    pub speculate: Option<Arc<SpeculativeLanes>>,
    /// The swappable calibrated accept rule (`None` = speculation off).
    pub calibrator: Option<Arc<CalibratorHandle>>,
    /// The per-model circuit breakers (`None` = no health layer; the
    /// speculate stage then treats every probe model as up).
    pub health: Option<Arc<ModelHealth>>,
}

/// Build the composed stack a [`PipelineSpec`] describes. Stages whose
/// backing object is disabled (`cache` without a cache, `shadow` without
/// a tap) are skipped, so one spec serves every config ablation.
pub fn build_pipeline(spec: &PipelineSpec, deps: &StageDeps) -> Result<Pipeline> {
    spec.validate()?;
    let mut stages: Vec<Box<dyn Strategy>> = Vec::with_capacity(spec.stages.len());
    for kind in &spec.stages {
        match kind {
            StageKind::Cache => {
                if let Some(cache) = &deps.cache {
                    stages.push(Box::new(CacheStage {
                        cache: cache.clone(),
                        metrics: deps.metrics.clone(),
                    }));
                }
            }
            StageKind::Shadow => {
                if let Some(shadow) = &deps.shadow {
                    stages.push(Box::new(ShadowStage { shadow: shadow.clone() }));
                }
            }
            StageKind::Prompt => {
                stages.push(Box::new(PromptStage { policy: deps.prompt_policy }));
            }
            StageKind::Budget => {
                stages.push(Box::new(BudgetStage { budget: deps.budget.clone() }));
            }
            StageKind::Speculate => {
                if let (Some(lanes), Some(calibrator)) = (&deps.speculate, &deps.calibrator) {
                    stages.push(Box::new(SpeculativeStage {
                        lanes: lanes.clone(),
                        calibrator: calibrator.clone(),
                        health: deps.health.clone(),
                        metrics: deps.metrics.clone(),
                    }));
                }
            }
            StageKind::Router => {
                if let Some(router) = &deps.router {
                    stages.push(Box::new(RouterStage {
                        router: router.clone(),
                        cache: deps.cache.clone(),
                        probe: deps.probe.clone(),
                    }));
                }
            }
            StageKind::Cascade => {
                stages.push(Box::new(CascadeStage { metrics: deps.metrics.clone() }));
            }
        }
    }
    Pipeline::new(stages)
}

/// Would `plan` still accept a cached completion? True when the model
/// that produced the answer is a stage of the plan and the cached
/// reliability score clears that stage's threshold (the final stage
/// accepts unconditionally). This is the survival predicate of the
/// plan-swap cache sweep (`CompletionCache::retain_and_restamp`): it
/// keeps exactly the completions the new plan could have served itself
/// had it reached that stage.
pub fn plan_accepts_cached(plan: &CascadePlan, ans: &CachedAnswer) -> bool {
    let Some(model) = ans.model else { return false };
    if plan.is_empty() {
        return false;
    }
    let last = plan.stages.len() - 1;
    plan.stages
        .iter()
        .enumerate()
        .any(|(s, st)| st.model == model && (s == last || ans.score > st.threshold))
}

// ---------------------------------------------------------------------------
// The stage implementations
// ---------------------------------------------------------------------------

/// Completion cache (paper Fig. 2c) as a stage: answers repeats for $0,
/// populates from later stages' answers. Keys on the *original* tokens
/// and serves only entries of the snapshot's plan generation. The cache
/// is sharded by query hash, so concurrent lookups on different shards
/// never contend on one lock.
struct CacheStage {
    cache: Arc<ShardedCache>,
    metrics: Arc<ServiceMetrics>,
}

impl Strategy for CacheStage {
    fn name(&self) -> &'static str {
        "cache"
    }

    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision> {
        let hit = self.cache.get(ctx.original, ctx.bundle.version());
        match hit {
            Some(hit) => {
                self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                Ok(Decision::Answer(StageAnswer {
                    answer: hit.answer,
                    score: hit.score,
                    cost_usd: 0.0,
                    model: None,
                    stopped_at: None,
                    skipped_stages: Vec::new(),
                    simulated_api_latency_ms: 0.0,
                    router_version: None,
                    degraded: false,
                }))
            }
            None => Ok(Decision::Pass),
        }
    }

    /// Populate from a cascade answer, stamped with the snapshot's plan
    /// version. No install-race recheck is needed anymore: an entry
    /// stamped by a superseded bundle simply never matches a newer
    /// generation's lookups (and is lazily reclaimed), so the old
    /// "re-check the version under the cache lock" dance is gone.
    fn on_answer(&self, ctx: &QueryCtx, answer: &StageAnswer) {
        if answer.model.is_none() {
            return;
        }
        self.cache.put(
            ctx.original,
            CachedAnswer {
                answer: answer.answer,
                score: answer.score,
                model: answer.model,
                plan_version: ctx.bundle.version(),
            },
        );
    }
}

/// The shadow-scoring tap as a stage: one relaxed-atomic sample decision
/// plus a `try_send` — never blocks, never answers. Place it after
/// `cache` so only cascade-bound traffic is sampled (the cache-before-tap
/// invariant is now spelled by the spec order).
struct ShadowStage {
    shadow: Arc<Shadow>,
}

impl Strategy for ShadowStage {
    fn name(&self) -> &'static str {
        "shadow"
    }

    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision> {
        // With a sampling margin configured the tap moves to `on_answer`
        // (the uncertainty signal — the serving score — does not exist
        // yet); without one this is the legacy pre-answer tap, bitwise.
        if self.shadow.margin().is_none() {
            self.shadow.offer(&ctx.tokens);
        }
        Ok(Decision::Pass)
    }

    /// Uncertainty-aware tap: a query whose measured score landed within
    /// the margin of the global-plan threshold that judged it bypasses
    /// the Bernoulli sampler — those rows sit exactly where the τ sweeps
    /// and the speculative accept rule are least certain. Final-stage and
    /// cache/speculate answers (no serving τ) keep the base rate. Offers
    /// `ctx.original`, the same untouched row the pre-answer tap sees in
    /// the default stack (shadow precedes the prompt transform there).
    fn on_answer(&self, ctx: &QueryCtx, answer: &StageAnswer) {
        let Some(margin) = self.shadow.margin() else { return };
        let plan = ctx.bundle.plan();
        let near = match answer.stopped_at {
            Some(s) if s + 1 < plan.stages.len() => match plan.stages.get(s) {
                Some(st) => (answer.score - st.threshold).abs() <= margin,
                None => false,
            },
            _ => false,
        };
        self.shadow.offer_scored(ctx.original, near);
    }
}

/// Prompt adaptation (paper Fig. 2a) as a stage: truncates the few-shot
/// prompt per the policy, transforming the row later stages consume.
struct PromptStage {
    policy: PromptPolicy,
}

impl Strategy for PromptStage {
    fn name(&self) -> &'static str {
        "prompt"
    }

    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision> {
        let keep = self.policy.keep(&ctx.tokens, ctx.meta);
        if keep >= ctx.meta.n_examples {
            Ok(Decision::Pass)
        } else {
            Ok(Decision::Transform(prompt::truncate_examples(
                &ctx.tokens,
                ctx.meta,
                keep,
            )))
        }
    }
}

/// Budget-cap degrade as a stage: flags the context when the cap is
/// exhausted, so the cascade executor runs the degraded single-stage
/// cascade. Spend *metering* is NOT this stage's job — the service
/// records every cascade answer's cost unconditionally (a spec without
/// `budget` still meters spend; it only opts out of the degrade).
struct BudgetStage {
    budget: Arc<BudgetTracker>,
}

impl Strategy for BudgetStage {
    fn name(&self) -> &'static str {
        "budget"
    }

    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision> {
        if self.budget.admit() == Admission::CapReached {
            ctx.degraded = true;
        }
        Ok(Decision::Pass)
    }
}

/// The LLM cascade executor (paper Fig. 2e): the terminal stage. Executes
/// the snapshot bundle's live cascade (or its degraded fallback when the
/// budget stage flagged the context), meters amortized input cost for
/// concatenation groups, and feeds the service-level cascade metrics.
struct CascadeStage {
    metrics: Arc<ServiceMetrics>,
}

impl Strategy for CascadeStage {
    fn name(&self) -> &'static str {
        "cascade"
    }

    fn is_terminal(&self) -> bool {
        true
    }

    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision> {
        self.metrics.cascade_invocations.fetch_add(1, Ordering::Relaxed);
        // Billable input: the row's actual (possibly prompt-adapted)
        // tokens, with the shareable prompt amortized across the
        // concatenation group (paper Fig. 2b; a solo query bills in full).
        let (prompt_toks, query_toks) = concat::split_row_tokens(&ctx.tokens, ctx.meta);
        let billed = concat::amortized_input(prompt_toks, query_toks, ctx.concat_group);
        // Cascade selection: budget degrade wins over routing (the cap is
        // a hard promise); otherwise a router decision picks its route's
        // compiled cascade, with `None` meaning the bundle's own global
        // cascade — the identical object the no-router path executes.
        let route = if ctx.degraded { None } else { ctx.route.as_ref() };
        let (cascade, skip) = match route {
            Some(r) => (
                r.cascade.as_deref().unwrap_or_else(|| ctx.bundle.cascade()),
                r.skip,
            ),
            None if ctx.degraded => (ctx.bundle.degraded(), 0),
            None => (ctx.bundle.cascade(), 0),
        };
        let executed = cascade.plan();
        // Probe seeds from the speculate stage: the executor reuses a
        // seed's already-billed answer instead of re-invoking its model
        // (the never-re-bill contract lives in `answer_billed_seeded`).
        let out = cascade.answer_billed_seeded(&ctx.tokens, billed, &ctx.probes)?;

        // `skip` keeps prefix-skip routes reporting stage indices in
        // GLOBAL plan coordinates (skip=0 — the identity — changes
        // nothing; frontier-point routes report their own plan's
        // coordinates).
        self.metrics.record_stop(out.stopped_at + skip);
        // `stage_costs` may cover a subset of the plan when health skipped
        // stages — `invoked_models` is its model attribution, parallel by
        // construction (plan indexing would mis-bill the survivors).
        for (i, &stage_cost) in out.stage_costs.iter().enumerate() {
            if let Some(w) = self.metrics.model(out.invoked_models[i]) {
                w.record_invocation(stage_cost);
            }
        }
        for &s in &out.skipped_stages {
            if let Some(w) = self.metrics.model(executed.stages[s].model) {
                w.record_skip();
            }
        }
        let model = executed.stages[out.stopped_at].model;
        if let Some(w) = self.metrics.model(model) {
            // Sentinel acceptances (last-stage stop, or a degraded
            // fallback answering terminally from a non-final stage) carry
            // 1.0, not a scorer measurement — keep them out of the mean.
            w.record_accepted((!out.sentinel_score).then_some(out.score));
        }
        // Probe spend is metered onto the answer (the probe call is a
        // real marketplace call); the `> 0.0` guard keeps the no-probe
        // path bit-identical to the pre-router cost arithmetic.
        let mut cost_usd = out.cost;
        if let Some(r) = route {
            if r.probe_cost_usd > 0.0 {
                cost_usd += r.probe_cost_usd;
            }
        }
        // Speculative probe seeds the executed cascade did NOT consume
        // (the route skipped their stage, or the cascade stopped before
        // reaching them) were still real marketplace calls — bill each
        // onto the answer and attribute it to its model's window, by
        // multiplicity against the invoked set so a consumed seed is
        // never double-billed.
        let mut sim_latency = out.simulated_latency_ms;
        if !ctx.probes.is_empty() {
            let mut invoked = out.invoked_models.clone();
            for seed in &ctx.probes {
                match invoked.iter().position(|&m| m == seed.model) {
                    Some(i) => {
                        invoked.remove(i);
                    }
                    None => {
                        cost_usd += seed.cost_usd;
                        if let Some(w) = self.metrics.model(seed.model) {
                            w.record_invocation(seed.cost_usd);
                        }
                    }
                }
            }
            // The probes flew concurrently with each other before the
            // cascade ran; the escalation path pays the slower probe's
            // round trip on top of the cascade's own.
            let probe_ms = ctx
                .probes
                .iter()
                .map(|s| s.latency_ms)
                .fold(0.0_f64, f64::max);
            sim_latency += probe_ms;
        }
        Ok(Decision::Answer(StageAnswer {
            answer: out.answer,
            score: out.score,
            cost_usd,
            model: Some(model),
            stopped_at: Some(out.stopped_at + skip),
            skipped_stages: out.skipped_stages.iter().map(|&s| s + skip).collect(),
            simulated_api_latency_ms: sim_latency,
            router_version: route.map(|r| r.router_version),
            degraded: ctx.degraded || !out.skipped_stages.is_empty(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::Stage;

    #[test]
    fn spec_parse_validate_and_describe() {
        let spec = PipelineSpec::parse("cache,prompt,cascade").unwrap();
        assert_eq!(
            spec.stages,
            vec![StageKind::Cache, StageKind::Prompt, StageKind::Cascade]
        );
        assert_eq!(spec.describe(), "cache,prompt,cascade");
        assert_eq!(PipelineSpec::parse("cascade").unwrap().stages.len(), 1);
        assert!(PipelineSpec::full().validate().is_ok());
        // whitespace tolerated
        assert_eq!(
            PipelineSpec::parse(" cache , cascade ").unwrap().describe(),
            "cache,cascade"
        );
    }

    #[test]
    fn spec_rejects_malformed_stacks() {
        for bad in [
            "cache,prompt",          // no terminal cascade
            "cascade,cache",         // cascade not last
            "cache,cache,cascade",   // duplicate
            "teleport,cascade",      // unknown stage
            "",                      // empty
        ] {
            assert!(PipelineSpec::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn spec_json_roundtrip() {
        let spec = PipelineSpec::full();
        let json = spec.to_value().to_json();
        let back = PipelineSpec::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back, spec);
        assert!(PipelineSpec::from_value(&Value::parse("[\"cache\"]").unwrap()).is_err());
    }

    #[test]
    fn plan_acceptance_predicate_truth_table() {
        let plan = CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.8 },
            Stage { model: 2, threshold: 0.0 },
        ]);
        let mk = |model: Option<usize>, score: f32| CachedAnswer {
            answer: 1,
            score,
            model,
            plan_version: 0,
        };
        // front-stage model, score clears its threshold → kept
        assert!(plan_accepts_cached(&plan, &mk(Some(0), 0.9)));
        // front-stage model, score under its threshold → dropped
        assert!(!plan_accepts_cached(&plan, &mk(Some(0), 0.5)));
        // last-stage model accepts unconditionally (sentinel 1.0 included)
        assert!(plan_accepts_cached(&plan, &mk(Some(2), 1.0)));
        assert!(plan_accepts_cached(&plan, &mk(Some(2), 0.01)));
        // model not in the plan → dropped
        assert!(!plan_accepts_cached(&plan, &mk(Some(1), 0.99)));
        // no producing model → dropped
        assert!(!plan_accepts_cached(&plan, &mk(None, 0.99)));
    }
}
