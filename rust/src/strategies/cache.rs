//! Completion cache (paper §3, LLM approximation, Fig. 2c).
//!
//! Stores `(query → completion)` and serves repeats without touching any
//! LLM API. Two lookup tiers:
//!
//! 1. **exact** — hash of the full query token sequence;
//! 2. **similar** — MinHash over token 3-grams; a cached entry is reused
//!    when its estimated Jaccard similarity clears a threshold (the
//!    paper's "if a similar query has been previously answered").
//!
//! Bounded LRU with O(1) touch *and* eviction: recency is an intrusive
//! doubly-linked list threaded through the slot arena (`lru_prev` /
//! `lru_next` indices), so promoting an entry on hit is three pointer
//! swaps — no positional scan. Single-writer behind a mutex — the
//! coordinator consults it before the cascade, so its hit path must be
//! far cheaper than even the cheapest API call (see benches/cache.rs; the
//! similar tier remains an O(len) signature scan by design).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// Number of MinHash permutations (signature size).
const SIGNATURE: usize = 16;

/// Null slot index for the intrusive LRU list.
const NIL: usize = usize::MAX;

/// A cached completion.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedAnswer {
    /// The completion's answer class.
    pub answer: u32,
    /// Reliability score the answer carried when cached.
    pub score: f32,
}

#[derive(Debug, Clone)]
struct Entry {
    key: u64,
    signature: [u64; SIGNATURE],
    answer: CachedAnswer,
}

/// Cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Total `get` calls.
    pub lookups: u64,
    /// Hits on the exact-hash tier.
    pub exact_hits: u64,
    /// Hits on the MinHash similar tier.
    pub similar_hits: u64,
    /// New entries inserted.
    pub insertions: u64,
    /// Entries evicted by the LRU bound.
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups served from either tier.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            (self.exact_hits + self.similar_hits) as f64 / self.lookups as f64
        }
    }
}

/// The completion cache. Not internally synchronized — wrap in a mutex (the
/// server does) or keep per-worker instances.
pub struct CompletionCache {
    capacity: usize,
    /// Similarity threshold in [0,1]; ≥ 1.0 disables the similar tier.
    min_similarity: f64,
    by_key: HashMap<u64, usize>, // exact-hash → slot
    slots: Vec<Option<Entry>>,
    /// Intrusive LRU list over slots: `lru_head` = oldest, `lru_tail` =
    /// most recent; `NIL` terminates both ends. Free slots are not linked.
    lru_prev: Vec<usize>,
    lru_next: Vec<usize>,
    lru_head: usize,
    lru_tail: usize,
    free: Vec<usize>,
    stats: CacheStats,
}

impl CompletionCache {
    /// A cache bounded to `capacity` entries; `min_similarity` ≥ 1.0
    /// disables the MinHash similar tier.
    pub fn new(capacity: usize, min_similarity: f64) -> Self {
        assert!(capacity > 0);
        CompletionCache {
            capacity,
            min_similarity,
            by_key: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            lru_prev: Vec::with_capacity(capacity),
            lru_next: Vec::with_capacity(capacity),
            lru_head: NIL,
            lru_tail: NIL,
            free: Vec::new(),
            stats: CacheStats::default(),
        }
    }

    /// Counter snapshot (survives `clear`).
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    /// Drop every entry (the server flushes on a plan swap so completions
    /// produced by a superseded plan stop being served). Counters in
    /// `stats` survive; capacity and tiers are unchanged.
    pub fn clear(&mut self) {
        self.by_key.clear();
        self.slots.clear();
        self.lru_prev.clear();
        self.lru_next.clear();
        self.lru_head = NIL;
        self.lru_tail = NIL;
        self.free.clear();
    }

    /// Look up a query. Exact match first, then the MinHash similar tier.
    pub fn get(&mut self, query: &[i32]) -> Option<CachedAnswer> {
        self.stats.lookups += 1;
        let key = exact_key(query);
        if let Some(&slot) = self.by_key.get(&key) {
            self.stats.exact_hits += 1;
            self.touch(slot);
            return Some(self.slots[slot].as_ref().unwrap().answer.clone());
        }
        if self.min_similarity < 1.0 {
            let sig = minhash(query);
            let mut best: Option<(usize, f64)> = None;
            for (slot, e) in self.slots.iter().enumerate() {
                if let Some(e) = e {
                    let sim = signature_similarity(&sig, &e.signature);
                    if sim >= self.min_similarity
                        && best.map_or(true, |(_, b)| sim > b)
                    {
                        best = Some((slot, sim));
                    }
                }
            }
            if let Some((slot, _)) = best {
                self.stats.similar_hits += 1;
                self.touch(slot);
                return Some(self.slots[slot].as_ref().unwrap().answer.clone());
            }
        }
        None
    }

    /// Insert (or overwrite) a completion for a query.
    pub fn put(&mut self, query: &[i32], answer: CachedAnswer) {
        let key = exact_key(query);
        if let Some(&slot) = self.by_key.get(&key) {
            self.slots[slot].as_mut().unwrap().answer = answer;
            self.touch(slot);
            return;
        }
        self.stats.insertions += 1;
        if self.by_key.len() >= self.capacity {
            self.evict_oldest();
        }
        let entry = Entry { key, signature: minhash(query), answer };
        let slot = if let Some(s) = self.free.pop() {
            self.slots[s] = Some(entry);
            s
        } else {
            self.slots.push(Some(entry));
            self.lru_prev.push(NIL);
            self.lru_next.push(NIL);
            self.slots.len() - 1
        };
        self.by_key.insert(key, slot);
        self.attach_tail(slot);
    }

    /// Unlink `slot` from the recency list. O(1).
    fn detach(&mut self, slot: usize) {
        let (p, n) = (self.lru_prev[slot], self.lru_next[slot]);
        if p == NIL {
            self.lru_head = n;
        } else {
            self.lru_next[p] = n;
        }
        if n == NIL {
            self.lru_tail = p;
        } else {
            self.lru_prev[n] = p;
        }
    }

    /// Link `slot` as the most recently used. O(1).
    fn attach_tail(&mut self, slot: usize) {
        self.lru_prev[slot] = self.lru_tail;
        self.lru_next[slot] = NIL;
        if self.lru_tail == NIL {
            self.lru_head = slot;
        } else {
            self.lru_next[self.lru_tail] = slot;
        }
        self.lru_tail = slot;
    }

    /// Promote `slot` to most recently used. O(1).
    fn touch(&mut self, slot: usize) {
        if self.lru_tail != slot {
            self.detach(slot);
            self.attach_tail(slot);
        }
    }

    fn evict_oldest(&mut self) {
        let slot = self.lru_head;
        if slot == NIL {
            return;
        }
        self.detach(slot);
        if let Some(e) = self.slots[slot].take() {
            self.by_key.remove(&e.key);
            self.free.push(slot);
            self.stats.evictions += 1;
        }
    }
}

fn exact_key(query: &[i32]) -> u64 {
    let mut h = DefaultHasher::new();
    query.hash(&mut h);
    h.finish()
}

/// MinHash signature over token 3-grams (shift-mix "permutations").
fn minhash(query: &[i32]) -> [u64; SIGNATURE] {
    let mut sig = [u64::MAX; SIGNATURE];
    if query.len() < 3 {
        let mut h = DefaultHasher::new();
        query.hash(&mut h);
        let v = h.finish();
        for (p, s) in sig.iter_mut().enumerate() {
            *s = mix(v, p as u64);
        }
        return sig;
    }
    for w in query.windows(3) {
        let mut h = DefaultHasher::new();
        w.hash(&mut h);
        let v = h.finish();
        for p in 0..SIGNATURE {
            let m = mix(v, p as u64);
            if m < sig[p] {
                sig[p] = m;
            }
        }
    }
    sig
}

#[inline]
fn mix(v: u64, perm: u64) -> u64 {
    // splitmix64 step with a per-permutation offset.
    crate::util::rng::splitmix64_mix(
        v ^ perm.wrapping_mul(crate::util::rng::SPLITMIX64_GOLDEN),
    )
}

/// Estimated Jaccard similarity of two signatures.
fn signature_similarity(a: &[u64; SIGNATURE], b: &[u64; SIGNATURE]) -> f64 {
    let eq = a.iter().zip(b).filter(|(x, y)| x == y).count();
    eq as f64 / SIGNATURE as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(seed: i32, len: usize) -> Vec<i32> {
        (0..len as i32).map(|i| seed * 31 + i * 7 % 97).collect()
    }

    #[test]
    fn clear_empties_and_cache_stays_usable() {
        let mut c = CompletionCache::new(4, 1.0);
        for s in 0..6 {
            c.put(&q(s, 8), CachedAnswer { answer: s as u32, score: 0.5 });
        }
        assert_eq!(c.len(), 4);
        c.clear();
        assert!(c.is_empty());
        assert!(c.get(&q(5, 8)).is_none());
        // reusable after clear: inserts, hits, and eviction still work
        for s in 10..16 {
            c.put(&q(s, 8), CachedAnswer { answer: s as u32, score: 0.5 });
        }
        assert_eq!(c.len(), 4);
        assert_eq!(c.get(&q(15, 8)).unwrap().answer, 15);
    }

    #[test]
    fn exact_hit_roundtrip() {
        let mut c = CompletionCache::new(4, 1.0);
        assert!(c.get(&q(1, 16)).is_none());
        c.put(&q(1, 16), CachedAnswer { answer: 2, score: 0.9 });
        let hit = c.get(&q(1, 16)).unwrap();
        assert_eq!(hit.answer, 2);
        assert_eq!(c.stats().exact_hits, 1);
        assert_eq!(c.stats().lookups, 2);
    }

    #[test]
    fn similar_hit_on_small_perturbation() {
        let mut c = CompletionCache::new(8, 0.7);
        let base = q(3, 32);
        c.put(&base, CachedAnswer { answer: 1, score: 0.8 });
        let mut nearly = base.clone();
        nearly[5] += 1; // one token differs
        let hit = c.get(&nearly);
        assert!(hit.is_some(), "1-token perturbation should hit similar tier");
        assert_eq!(c.stats().similar_hits, 1);
    }

    #[test]
    fn dissimilar_query_misses() {
        let mut c = CompletionCache::new(8, 0.7);
        c.put(&q(3, 32), CachedAnswer { answer: 1, score: 0.8 });
        assert!(c.get(&q(99, 32)).is_none());
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = CompletionCache::new(2, 1.0);
        c.put(&q(1, 8), CachedAnswer { answer: 1, score: 0.5 });
        c.put(&q(2, 8), CachedAnswer { answer: 2, score: 0.5 });
        c.get(&q(1, 8)); // touch 1 → 2 is now oldest
        c.put(&q(3, 8), CachedAnswer { answer: 3, score: 0.5 });
        assert!(c.get(&q(2, 8)).is_none(), "entry 2 should be evicted");
        assert!(c.get(&q(1, 8)).is_some());
        assert!(c.get(&q(3, 8)).is_some());
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn put_same_key_overwrites_without_eviction() {
        let mut c = CompletionCache::new(2, 1.0);
        c.put(&q(1, 8), CachedAnswer { answer: 1, score: 0.5 });
        c.put(&q(1, 8), CachedAnswer { answer: 7, score: 0.9 });
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&q(1, 8)).unwrap().answer, 7);
        assert_eq!(c.stats().evictions, 0);
    }

    /// The intrusive list must evict in exactly the same order as a naive
    /// recency queue across an arbitrary op mix (model-based check).
    #[test]
    fn lru_order_matches_naive_model() {
        use crate::util::rng::Rng;
        let cap = 9;
        let mut c = CompletionCache::new(cap, 1.0);
        // Naive model: VecDeque-of-keys recency (front = oldest), the
        // data structure the pre-PR-1 implementation scanned linearly.
        let mut model: std::collections::VecDeque<i32> = Default::default();
        let mut rng = Rng::new(0xCAFE);
        for step in 0..5000 {
            let id = rng.below(40) as i32;
            if rng.bool(0.55) {
                c.put(&q(id, 8), CachedAnswer { answer: id as u32, score: 0.5 });
                if let Some(pos) = model.iter().position(|&k| k == id) {
                    model.remove(pos);
                } else if model.len() == cap {
                    model.pop_front();
                }
                model.push_back(id);
            } else {
                let hit = c.get(&q(id, 8)).is_some();
                let model_hit = model.contains(&id);
                assert_eq!(hit, model_hit, "step {step}: hit mismatch for {id}");
                if let Some(pos) = model.iter().position(|&k| k == id) {
                    model.remove(pos);
                    model.push_back(id);
                }
            }
            assert_eq!(c.len(), model.len(), "step {step}: size drifted");
        }
        // After the run, residency must agree element-for-element.
        let resident = model.clone();
        for &id in &resident {
            assert!(c.get(&q(id, 8)).is_some(), "model key {id} missing from cache");
        }
    }

    #[test]
    fn touch_most_recent_is_noop() {
        let mut c = CompletionCache::new(3, 1.0);
        for id in 0..3 {
            c.put(&q(id, 8), CachedAnswer { answer: id as u32, score: 0.5 });
        }
        // Touch the tail repeatedly; order must stay 0 (oldest), 1, 2.
        for _ in 0..5 {
            assert!(c.get(&q(2, 8)).is_some());
        }
        c.put(&q(3, 8), CachedAnswer { answer: 3, score: 0.5 });
        assert!(c.get(&q(0, 8)).is_none(), "0 was oldest and must evict");
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn minhash_similarity_sane() {
        let a = minhash(&q(5, 64));
        assert_eq!(signature_similarity(&a, &a), 1.0);
        let b = minhash(&q(6, 64));
        assert!(signature_similarity(&a, &b) < 0.8);
    }
}
