//! Completion cache (paper §3, LLM approximation, Fig. 2c).
//!
//! Stores `(query → completion)` and serves repeats without touching any
//! LLM API. Two lookup tiers:
//!
//! 1. **exact** — hash of the full query token sequence;
//! 2. **similar** — MinHash over token 3-grams; a cached entry is reused
//!    when its estimated Jaccard similarity clears a threshold (the
//!    paper's "if a similar query has been previously answered").
//!
//! Bounded LRU with O(1) eviction. Single-writer behind a mutex — the
//! coordinator consults it before the cascade, so its hit path must be
//! far cheaper than even the cheapest API call (see benches/cache.rs).

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};

/// Number of MinHash permutations (signature size).
const SIGNATURE: usize = 16;

/// A cached completion.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedAnswer {
    pub answer: u32,
    pub score: f32,
}

#[derive(Debug, Clone)]
struct Entry {
    key: u64,
    signature: [u64; SIGNATURE],
    answer: CachedAnswer,
}

/// Cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub lookups: u64,
    pub exact_hits: u64,
    pub similar_hits: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            (self.exact_hits + self.similar_hits) as f64 / self.lookups as f64
        }
    }
}

/// The completion cache. Not internally synchronized — wrap in a mutex (the
/// server does) or keep per-worker instances.
pub struct CompletionCache {
    capacity: usize,
    /// Similarity threshold in [0,1]; ≥ 1.0 disables the similar tier.
    min_similarity: f64,
    by_key: HashMap<u64, usize>, // exact-hash → slot
    slots: Vec<Option<Entry>>,
    lru: VecDeque<usize>, // front = oldest
    free: Vec<usize>,
    stats: CacheStats,
}

impl CompletionCache {
    pub fn new(capacity: usize, min_similarity: f64) -> Self {
        assert!(capacity > 0);
        CompletionCache {
            capacity,
            min_similarity,
            by_key: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            lru: VecDeque::with_capacity(capacity),
            free: Vec::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    /// Look up a query. Exact match first, then the MinHash similar tier.
    pub fn get(&mut self, query: &[i32]) -> Option<CachedAnswer> {
        self.stats.lookups += 1;
        let key = exact_key(query);
        if let Some(&slot) = self.by_key.get(&key) {
            self.stats.exact_hits += 1;
            self.touch(slot);
            return Some(self.slots[slot].as_ref().unwrap().answer.clone());
        }
        if self.min_similarity < 1.0 {
            let sig = minhash(query);
            let mut best: Option<(usize, f64)> = None;
            for (slot, e) in self.slots.iter().enumerate() {
                if let Some(e) = e {
                    let sim = signature_similarity(&sig, &e.signature);
                    if sim >= self.min_similarity
                        && best.map_or(true, |(_, b)| sim > b)
                    {
                        best = Some((slot, sim));
                    }
                }
            }
            if let Some((slot, _)) = best {
                self.stats.similar_hits += 1;
                self.touch(slot);
                return Some(self.slots[slot].as_ref().unwrap().answer.clone());
            }
        }
        None
    }

    /// Insert (or overwrite) a completion for a query.
    pub fn put(&mut self, query: &[i32], answer: CachedAnswer) {
        let key = exact_key(query);
        if let Some(&slot) = self.by_key.get(&key) {
            self.slots[slot].as_mut().unwrap().answer = answer;
            self.touch(slot);
            return;
        }
        self.stats.insertions += 1;
        if self.by_key.len() >= self.capacity {
            self.evict_oldest();
        }
        let entry = Entry { key, signature: minhash(query), answer };
        let slot = if let Some(s) = self.free.pop() {
            self.slots[s] = Some(entry);
            s
        } else {
            self.slots.push(Some(entry));
            self.slots.len() - 1
        };
        self.by_key.insert(key, slot);
        self.lru.push_back(slot);
    }

    fn touch(&mut self, slot: usize) {
        if let Some(pos) = self.lru.iter().position(|&s| s == slot) {
            self.lru.remove(pos);
            self.lru.push_back(slot);
        }
    }

    fn evict_oldest(&mut self) {
        if let Some(slot) = self.lru.pop_front() {
            if let Some(e) = self.slots[slot].take() {
                self.by_key.remove(&e.key);
                self.free.push(slot);
                self.stats.evictions += 1;
            }
        }
    }
}

fn exact_key(query: &[i32]) -> u64 {
    let mut h = DefaultHasher::new();
    query.hash(&mut h);
    h.finish()
}

/// MinHash signature over token 3-grams (shift-mix "permutations").
fn minhash(query: &[i32]) -> [u64; SIGNATURE] {
    let mut sig = [u64::MAX; SIGNATURE];
    if query.len() < 3 {
        let mut h = DefaultHasher::new();
        query.hash(&mut h);
        let v = h.finish();
        for (p, s) in sig.iter_mut().enumerate() {
            *s = mix(v, p as u64);
        }
        return sig;
    }
    for w in query.windows(3) {
        let mut h = DefaultHasher::new();
        w.hash(&mut h);
        let v = h.finish();
        for p in 0..SIGNATURE {
            let m = mix(v, p as u64);
            if m < sig[p] {
                sig[p] = m;
            }
        }
    }
    sig
}

#[inline]
fn mix(v: u64, perm: u64) -> u64 {
    // splitmix64 step with a per-permutation offset.
    let mut z = v ^ (perm.wrapping_mul(0x9E3779B97F4A7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Estimated Jaccard similarity of two signatures.
fn signature_similarity(a: &[u64; SIGNATURE], b: &[u64; SIGNATURE]) -> f64 {
    let eq = a.iter().zip(b).filter(|(x, y)| x == y).count();
    eq as f64 / SIGNATURE as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(seed: i32, len: usize) -> Vec<i32> {
        (0..len as i32).map(|i| seed * 31 + i * 7 % 97).collect()
    }

    #[test]
    fn exact_hit_roundtrip() {
        let mut c = CompletionCache::new(4, 1.0);
        assert!(c.get(&q(1, 16)).is_none());
        c.put(&q(1, 16), CachedAnswer { answer: 2, score: 0.9 });
        let hit = c.get(&q(1, 16)).unwrap();
        assert_eq!(hit.answer, 2);
        assert_eq!(c.stats().exact_hits, 1);
        assert_eq!(c.stats().lookups, 2);
    }

    #[test]
    fn similar_hit_on_small_perturbation() {
        let mut c = CompletionCache::new(8, 0.7);
        let base = q(3, 32);
        c.put(&base, CachedAnswer { answer: 1, score: 0.8 });
        let mut nearly = base.clone();
        nearly[5] += 1; // one token differs
        let hit = c.get(&nearly);
        assert!(hit.is_some(), "1-token perturbation should hit similar tier");
        assert_eq!(c.stats().similar_hits, 1);
    }

    #[test]
    fn dissimilar_query_misses() {
        let mut c = CompletionCache::new(8, 0.7);
        c.put(&q(3, 32), CachedAnswer { answer: 1, score: 0.8 });
        assert!(c.get(&q(99, 32)).is_none());
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = CompletionCache::new(2, 1.0);
        c.put(&q(1, 8), CachedAnswer { answer: 1, score: 0.5 });
        c.put(&q(2, 8), CachedAnswer { answer: 2, score: 0.5 });
        c.get(&q(1, 8)); // touch 1 → 2 is now oldest
        c.put(&q(3, 8), CachedAnswer { answer: 3, score: 0.5 });
        assert!(c.get(&q(2, 8)).is_none(), "entry 2 should be evicted");
        assert!(c.get(&q(1, 8)).is_some());
        assert!(c.get(&q(3, 8)).is_some());
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn put_same_key_overwrites_without_eviction() {
        let mut c = CompletionCache::new(2, 1.0);
        c.put(&q(1, 8), CachedAnswer { answer: 1, score: 0.5 });
        c.put(&q(1, 8), CachedAnswer { answer: 7, score: 0.9 });
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&q(1, 8)).unwrap().answer, 7);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn minhash_similarity_sane() {
        let a = minhash(&q(5, 64));
        assert_eq!(signature_similarity(&a, &a), 1.0);
        let b = minhash(&q(6, 64));
        assert!(signature_similarity(&a, &b) < 0.8);
    }
}
