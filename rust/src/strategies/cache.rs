//! Completion cache (paper §3, LLM approximation, Fig. 2c).
//!
//! Stores `(query → completion)` and serves repeats without touching any
//! LLM API. Two lookup tiers:
//!
//! 1. **exact** — hash of the full query token sequence;
//! 2. **similar** — MinHash over token 3-grams; a cached entry is reused
//!    when its estimated Jaccard similarity clears a threshold (the
//!    paper's "if a similar query has been previously answered").
//!
//! Bounded LRU with O(1) touch *and* eviction: recency is an intrusive
//! doubly-linked list threaded through the slot arena (`lru_prev` /
//! `lru_next` indices), so promoting an entry on hit is three pointer
//! swaps — no positional scan. Single-writer behind a mutex — the
//! coordinator consults it before the cascade, so its hit path must be
//! far cheaper than even the cheapest API call (see benches/cache.rs; the
//! similar tier remains an O(len) signature scan by design).
//!
//! §Generations — entries are *plan-aware*: every [`CachedAnswer`] is
//! stamped with the `plan_version` it was produced under, and lookups
//! ([`CompletionCache::get`]) serve only the caller's current generation
//! (a stale entry found under the key is lazily invalidated instead of
//! served). On a plan swap the publisher calls
//! [`CompletionCache::retain_and_restamp`] with a survival predicate
//! (typically "would the new plan still accept this completion?" — see
//! `strategies::pipeline::plan_accepts_cached`): surviving entries are
//! re-stamped to the new generation so the warm set carries across the
//! swap, everything else is invalidated. This replaces the old blanket
//! `clear()`-on-swap, whose hit rate restarted from zero on every swap.
//!
//! §Sharding — [`ShardedCache`] partitions the key space N ways by a
//! splitmix64 re-mix of the exact key, one [`CompletionCache`] (own
//! intrusive LRU, own generation sweep, own [`CacheStats`]) behind a
//! short mutex per shard. Concurrent lookups on different shards never
//! contend, the plan-swap sweep walks shards independently, and stats
//! aggregate on read so serve/report summaries are unchanged. With one
//! shard it IS the single cache (the equivalence is property-tested in
//! `tests/cache_sharding.rs`). The similar tier becomes shard-local for
//! N > 1: a near-duplicate query is only found if it hashes to the same
//! shard as the original — the exact tier (the default; the similar tier
//! is opt-in via `--cache-similar`) partitions losslessly.
//!
//! §Sampled touch — a hit-heavy shard is still write-bound if every hit
//! promotes its entry (the LRU touch takes `&mut`). A cache built with
//! [`CompletionCache::with_touch_period`]`(T)` promotes only every T-th
//! hit (per-cache hit counter, deterministic): T=1 (the default) is
//! exact LRU — pinned by `sampled_touch_t1_is_exact_lru` — and larger T
//! trades eviction-order fidelity for hit-path writes, never
//! correctness (the hit set is unaffected; only recency order coarsens).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

/// Number of MinHash permutations (signature size).
const SIGNATURE: usize = 16;

/// Null slot index for the intrusive LRU list.
const NIL: usize = usize::MAX;

/// A cached completion, stamped with the plan generation that produced it.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedAnswer {
    /// The completion's answer class.
    pub answer: u32,
    /// Reliability score the answer carried when cached.
    pub score: f32,
    /// Marketplace index of the model whose answer was cached (`None` for
    /// entries that did not come from a cascade stage).
    pub model: Option<usize>,
    /// Version of the plan bundle that served the cached answer; lookups
    /// only ever serve the caller's current generation.
    pub plan_version: u64,
}

impl CachedAnswer {
    /// A generation-0 entry with no producing model (tests / benches; the
    /// serving path stamps real versions via struct literals).
    pub fn fresh(answer: u32, score: f32) -> Self {
        CachedAnswer { answer, score, model: None, plan_version: 0 }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    key: u64,
    signature: [u64; SIGNATURE],
    answer: CachedAnswer,
}

/// Cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Total `get` calls.
    pub lookups: u64,
    /// Hits on the exact-hash tier.
    pub exact_hits: u64,
    /// Hits on the MinHash similar tier.
    pub similar_hits: u64,
    /// New entries inserted.
    pub insertions: u64,
    /// Entries evicted by the LRU bound.
    pub evictions: u64,
    /// Entries invalidated by generation churn: dropped by a swap's
    /// [`CompletionCache::retain_and_restamp`] predicate or lazily on a
    /// stale-generation lookup.
    pub invalidations: u64,
}

impl CacheStats {
    /// Fraction of lookups served from either tier.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            (self.exact_hits + self.similar_hits) as f64 / self.lookups as f64
        }
    }
}

/// The completion cache. Not internally synchronized — wrap in a mutex (the
/// server does) or keep per-worker instances.
pub struct CompletionCache {
    capacity: usize,
    /// Similarity threshold in [0,1]; ≥ 1.0 disables the similar tier.
    min_similarity: f64,
    by_key: HashMap<u64, usize>, // exact-hash → slot
    slots: Vec<Option<Entry>>,
    /// Intrusive LRU list over slots: `lru_head` = oldest, `lru_tail` =
    /// most recent; `NIL` terminates both ends. Free slots are not linked.
    lru_prev: Vec<usize>,
    lru_next: Vec<usize>,
    lru_head: usize,
    lru_tail: usize,
    free: Vec<usize>,
    stats: CacheStats,
    /// Promote an entry on every T-th hit only (1 = exact LRU).
    touch_period: u32,
    /// Hits seen, for the sampled-touch schedule.
    hit_ticks: u64,
}

impl CompletionCache {
    /// A cache bounded to `capacity` entries; `min_similarity` ≥ 1.0
    /// disables the MinHash similar tier.
    pub fn new(capacity: usize, min_similarity: f64) -> Self {
        assert!(capacity > 0);
        CompletionCache {
            capacity,
            min_similarity,
            by_key: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            lru_prev: Vec::with_capacity(capacity),
            lru_next: Vec::with_capacity(capacity),
            lru_head: NIL,
            lru_tail: NIL,
            free: Vec::new(),
            stats: CacheStats::default(),
            touch_period: 1,
            hit_ticks: 0,
        }
    }

    /// Sampled-touch mode: promote an entry on every `period`-th hit
    /// instead of every hit, so hit-heavy workloads are not write-bound
    /// on the recency list. `period` = 1 (the default) reproduces exact
    /// LRU order bit-for-bit.
    pub fn with_touch_period(mut self, period: u32) -> Self {
        assert!(period >= 1, "touch period must be at least 1");
        self.touch_period = period;
        self
    }

    /// Promote `slot` if this hit falls on the sampled-touch schedule.
    fn sampled_touch(&mut self, slot: usize) {
        self.hit_ticks = self.hit_ticks.wrapping_add(1);
        if self.touch_period == 1 || self.hit_ticks % self.touch_period as u64 == 0 {
            self.touch(slot);
        }
    }

    /// Counter snapshot (survives `clear`).
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Entries currently cached.
    pub fn len(&self) -> usize {
        self.by_key.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.by_key.is_empty()
    }

    /// Drop every entry. (Plan swaps no longer use this — the publisher
    /// sweeps with [`CompletionCache::retain_and_restamp`] so the warm
    /// set survives; `clear` remains for operational resets.) Counters in
    /// `stats` survive; capacity and tiers are unchanged.
    pub fn clear(&mut self) {
        self.by_key.clear();
        self.slots.clear();
        self.lru_prev.clear();
        self.lru_next.clear();
        self.lru_head = NIL;
        self.lru_tail = NIL;
        self.free.clear();
    }

    /// Look up a query for the caller's current plan `generation`. Exact
    /// match first, then the MinHash similar tier. An entry stamped with a
    /// different generation is never served — a stale exact match is
    /// lazily invalidated on the spot, and the similar scan skips stale
    /// entries entirely.
    pub fn get(&mut self, query: &[i32], generation: u64) -> Option<CachedAnswer> {
        self.stats.lookups += 1;
        let key = exact_key(query);
        if let Some(&slot) = self.by_key.get(&key) {
            let stamped = self.slots[slot].as_ref().unwrap().answer.plan_version;
            if stamped == generation {
                self.stats.exact_hits += 1;
                self.sampled_touch(slot);
                return Some(self.slots[slot].as_ref().unwrap().answer.clone());
            }
            if stamped < generation {
                // Stale generation under the exact key: it can never be
                // served again (swaps only move the generation forward),
                // so reclaim the slot now.
                self.invalidate(slot);
            }
            // stamped > generation: an in-flight reader still holding a
            // pre-swap snapshot found an entry the swap just re-stamped
            // (or a post-swap answer inserted). Miss for THIS caller, but
            // the entry is valid for the current generation — leave it.
        }
        if self.min_similarity < 1.0 {
            let sig = minhash(query);
            let mut best: Option<(usize, f64)> = None;
            for (slot, e) in self.slots.iter().enumerate() {
                if let Some(e) = e {
                    if e.answer.plan_version != generation {
                        continue;
                    }
                    let sim = signature_similarity(&sig, &e.signature);
                    if sim >= self.min_similarity
                        && best.map_or(true, |(_, b)| sim > b)
                    {
                        best = Some((slot, sim));
                    }
                }
            }
            if let Some((slot, _)) = best {
                self.stats.similar_hits += 1;
                self.sampled_touch(slot);
                return Some(self.slots[slot].as_ref().unwrap().answer.clone());
            }
        }
        None
    }

    /// Non-mutating cache-signal probe (the router's `FEAT_CACHE`
    /// feature): 1.0 when an exact current-generation entry exists,
    /// otherwise the best similar-tier signature similarity clearing the
    /// threshold (0.0 when the similar tier is disabled or nothing
    /// clears it). Unlike [`CompletionCache::get`] this records no
    /// stats, promotes no recency, and reclaims nothing — a pure read,
    /// so probing the signal never perturbs what the cache stage itself
    /// will observe a moment later.
    pub fn peek_similarity(&self, query: &[i32], generation: u64) -> f64 {
        let key = exact_key(query);
        if let Some(&slot) = self.by_key.get(&key) {
            if self.slots[slot].as_ref().unwrap().answer.plan_version == generation {
                return 1.0;
            }
        }
        if self.min_similarity < 1.0 {
            let sig = minhash(query);
            let mut best = 0.0f64;
            for e in self.slots.iter().flatten() {
                if e.answer.plan_version != generation {
                    continue;
                }
                let sim = signature_similarity(&sig, &e.signature);
                if sim >= self.min_similarity && sim > best {
                    best = sim;
                }
            }
            return best;
        }
        0.0
    }

    /// The plan-swap sweep: keep (and re-stamp to `generation`) every
    /// entry the predicate approves, invalidate the rest. Returns how many
    /// entries survived. The predicate typically asks whether the *new*
    /// plan would still accept the cached completion
    /// (`strategies::pipeline::plan_accepts_cached`), so the warm set
    /// carries across a swap instead of restarting from zero.
    pub fn retain_and_restamp(
        &mut self,
        generation: u64,
        mut keep: impl FnMut(&CachedAnswer) -> bool,
    ) -> usize {
        let mut retained = 0usize;
        for slot in 0..self.slots.len() {
            let Some(e) = self.slots[slot].as_mut() else { continue };
            if keep(&e.answer) {
                e.answer.plan_version = generation;
                retained += 1;
            } else {
                self.invalidate(slot);
            }
        }
        retained
    }

    /// Drop one occupied slot outside the LRU-bound path (generation
    /// churn). O(1).
    fn invalidate(&mut self, slot: usize) {
        self.detach(slot);
        if let Some(e) = self.slots[slot].take() {
            self.by_key.remove(&e.key);
            self.free.push(slot);
            self.stats.invalidations += 1;
        }
    }

    /// Insert (or overwrite) a completion for a query.
    pub fn put(&mut self, query: &[i32], answer: CachedAnswer) {
        let key = exact_key(query);
        if let Some(&slot) = self.by_key.get(&key) {
            self.slots[slot].as_mut().unwrap().answer = answer;
            self.touch(slot);
            return;
        }
        self.stats.insertions += 1;
        if self.by_key.len() >= self.capacity {
            self.evict_oldest();
        }
        let entry = Entry { key, signature: minhash(query), answer };
        let slot = if let Some(s) = self.free.pop() {
            self.slots[s] = Some(entry);
            s
        } else {
            self.slots.push(Some(entry));
            self.lru_prev.push(NIL);
            self.lru_next.push(NIL);
            self.slots.len() - 1
        };
        self.by_key.insert(key, slot);
        self.attach_tail(slot);
    }

    /// Unlink `slot` from the recency list. O(1).
    fn detach(&mut self, slot: usize) {
        let (p, n) = (self.lru_prev[slot], self.lru_next[slot]);
        if p == NIL {
            self.lru_head = n;
        } else {
            self.lru_next[p] = n;
        }
        if n == NIL {
            self.lru_tail = p;
        } else {
            self.lru_prev[n] = p;
        }
    }

    /// Link `slot` as the most recently used. O(1).
    fn attach_tail(&mut self, slot: usize) {
        self.lru_prev[slot] = self.lru_tail;
        self.lru_next[slot] = NIL;
        if self.lru_tail == NIL {
            self.lru_head = slot;
        } else {
            self.lru_next[self.lru_tail] = slot;
        }
        self.lru_tail = slot;
    }

    /// Promote `slot` to most recently used. O(1).
    fn touch(&mut self, slot: usize) {
        if self.lru_tail != slot {
            self.detach(slot);
            self.attach_tail(slot);
        }
    }

    fn evict_oldest(&mut self) {
        let slot = self.lru_head;
        if slot == NIL {
            return;
        }
        self.detach(slot);
        if let Some(e) = self.slots[slot].take() {
            self.by_key.remove(&e.key);
            self.free.push(slot);
            self.stats.evictions += 1;
        }
    }
}

/// Next power of two ≥ the machine's core count: the default shard count
/// for [`ShardedCache`], so a full complement of serving threads maps
/// ~1:1 onto shards.
pub fn default_cache_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .next_power_of_two()
}

/// An N-way sharded completion cache: the key space is partitioned by a
/// splitmix64 re-mix of the exact key, each shard is a full
/// [`CompletionCache`] (own intrusive LRU, own generation sweep, own
/// stats) behind its own short mutex. Internally synchronized — the
/// serving layer shares it as a bare `Arc`, and lookups on different
/// shards proceed concurrently. See the module docs (§Sharding) for the
/// similar-tier caveat at N > 1.
pub struct ShardedCache {
    shards: Vec<Mutex<CompletionCache>>,
    /// `shards.len() - 1`; shard count is always a power of two.
    mask: u64,
}

impl ShardedCache {
    /// A cache of `shards` ways (0 ⇒ [`default_cache_shards`]; rounded up
    /// to a power of two) holding `capacity` entries in total, split
    /// evenly across shards. `min_similarity` and `touch_period` apply
    /// per shard exactly as on [`CompletionCache`].
    pub fn new(
        shards: usize,
        capacity: usize,
        min_similarity: f64,
        touch_period: u32,
    ) -> Self {
        assert!(capacity > 0);
        let n = if shards == 0 { default_cache_shards() } else { shards }
            .next_power_of_two();
        let per_shard = capacity.div_ceil(n).max(1);
        ShardedCache {
            shards: (0..n)
                .map(|_| {
                    Mutex::new(
                        CompletionCache::new(per_shard, min_similarity)
                            .with_touch_period(touch_period),
                    )
                })
                .collect(),
            mask: (n - 1) as u64,
        }
    }

    /// Number of shards (a power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard a query's exact key lands on (the splitmix64 re-mix of
    /// the exact hash, masked). Exposed so the sharding property test can
    /// drive a per-shard reference model.
    pub fn shard_of(&self, query: &[i32]) -> usize {
        (crate::util::rng::splitmix64_mix(exact_key(query)) & self.mask) as usize
    }

    /// Look up a query for the caller's current plan `generation` on its
    /// shard. Locks exactly one shard.
    pub fn get(&self, query: &[i32], generation: u64) -> Option<CachedAnswer> {
        let s = self.shard_of(query);
        self.shards[s].lock().unwrap().get(query, generation)
    }

    /// Insert (or overwrite) a completion on the query's shard.
    pub fn put(&self, query: &[i32], answer: CachedAnswer) {
        let s = self.shard_of(query);
        self.shards[s].lock().unwrap().put(query, answer)
    }

    /// Non-mutating cache-signal probe on the query's shard — see
    /// [`CompletionCache::peek_similarity`]. Locks exactly one shard for
    /// the duration of the read and changes nothing.
    pub fn peek_similarity(&self, query: &[i32], generation: u64) -> f64 {
        let s = self.shard_of(query);
        self.shards[s].lock().unwrap().peek_similarity(query, generation)
    }

    /// The plan-swap sweep, shard by shard: each shard is locked, swept
    /// with [`CompletionCache::retain_and_restamp`], and released before
    /// the next — answer-path lookups on other shards are never stalled
    /// behind the whole sweep. Returns total survivors.
    pub fn retain_and_restamp(
        &self,
        generation: u64,
        mut keep: impl FnMut(&CachedAnswer) -> bool,
    ) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().retain_and_restamp(generation, &mut keep))
            .sum()
    }

    /// Aggregate counter snapshot across shards — serve/report summaries
    /// read the same totals a single cache would produce.
    pub fn stats(&self) -> CacheStats {
        let mut agg = CacheStats::default();
        for s in &self.shards {
            let st = s.lock().unwrap().stats();
            agg.lookups += st.lookups;
            agg.exact_hits += st.exact_hits;
            agg.similar_hits += st.similar_hits;
            agg.insertions += st.insertions;
            agg.evictions += st.evictions;
            agg.invalidations += st.invalidations;
        }
        agg
    }

    /// Entries currently cached across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether no shard holds any entry.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }
}

fn exact_key(query: &[i32]) -> u64 {
    let mut h = DefaultHasher::new();
    query.hash(&mut h);
    h.finish()
}

/// MinHash signature over token 3-grams (shift-mix "permutations").
fn minhash(query: &[i32]) -> [u64; SIGNATURE] {
    let mut sig = [u64::MAX; SIGNATURE];
    if query.len() < 3 {
        let mut h = DefaultHasher::new();
        query.hash(&mut h);
        let v = h.finish();
        for (p, s) in sig.iter_mut().enumerate() {
            *s = mix(v, p as u64);
        }
        return sig;
    }
    for w in query.windows(3) {
        let mut h = DefaultHasher::new();
        w.hash(&mut h);
        let v = h.finish();
        for p in 0..SIGNATURE {
            let m = mix(v, p as u64);
            if m < sig[p] {
                sig[p] = m;
            }
        }
    }
    sig
}

#[inline]
fn mix(v: u64, perm: u64) -> u64 {
    // splitmix64 step with a per-permutation offset.
    crate::util::rng::splitmix64_mix(
        v ^ perm.wrapping_mul(crate::util::rng::SPLITMIX64_GOLDEN),
    )
}

/// Estimated Jaccard similarity of two signatures.
fn signature_similarity(a: &[u64; SIGNATURE], b: &[u64; SIGNATURE]) -> f64 {
    let eq = a.iter().zip(b).filter(|(x, y)| x == y).count();
    eq as f64 / SIGNATURE as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(seed: i32, len: usize) -> Vec<i32> {
        (0..len as i32).map(|i| seed * 31 + i * 7 % 97).collect()
    }

    #[test]
    fn clear_empties_and_cache_stays_usable() {
        let mut c = CompletionCache::new(4, 1.0);
        for s in 0..6 {
            c.put(&q(s, 8), CachedAnswer::fresh(s as u32, 0.5));
        }
        assert_eq!(c.len(), 4);
        c.clear();
        assert!(c.is_empty());
        assert!(c.get(&q(5, 8), 0).is_none());
        // reusable after clear: inserts, hits, and eviction still work
        for s in 10..16 {
            c.put(&q(s, 8), CachedAnswer::fresh(s as u32, 0.5));
        }
        assert_eq!(c.len(), 4);
        assert_eq!(c.get(&q(15, 8), 0).unwrap().answer, 15);
    }

    #[test]
    fn exact_hit_roundtrip() {
        let mut c = CompletionCache::new(4, 1.0);
        assert!(c.get(&q(1, 16), 0).is_none());
        c.put(&q(1, 16), CachedAnswer::fresh(2, 0.9));
        let hit = c.get(&q(1, 16), 0).unwrap();
        assert_eq!(hit.answer, 2);
        assert_eq!(c.stats().exact_hits, 1);
        assert_eq!(c.stats().lookups, 2);
    }

    #[test]
    fn similar_hit_on_small_perturbation() {
        let mut c = CompletionCache::new(8, 0.7);
        let base = q(3, 32);
        c.put(&base, CachedAnswer::fresh(1, 0.8));
        let mut nearly = base.clone();
        nearly[5] += 1; // one token differs
        let hit = c.get(&nearly, 0);
        assert!(hit.is_some(), "1-token perturbation should hit similar tier");
        assert_eq!(c.stats().similar_hits, 1);
    }

    #[test]
    fn dissimilar_query_misses() {
        let mut c = CompletionCache::new(8, 0.7);
        c.put(&q(3, 32), CachedAnswer::fresh(1, 0.8));
        assert!(c.get(&q(99, 32), 0).is_none());
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut c = CompletionCache::new(2, 1.0);
        c.put(&q(1, 8), CachedAnswer::fresh(1, 0.5));
        c.put(&q(2, 8), CachedAnswer::fresh(2, 0.5));
        c.get(&q(1, 8), 0); // touch 1 → 2 is now oldest
        c.put(&q(3, 8), CachedAnswer::fresh(3, 0.5));
        assert!(c.get(&q(2, 8), 0).is_none(), "entry 2 should be evicted");
        assert!(c.get(&q(1, 8), 0).is_some());
        assert!(c.get(&q(3, 8), 0).is_some());
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn put_same_key_overwrites_without_eviction() {
        let mut c = CompletionCache::new(2, 1.0);
        c.put(&q(1, 8), CachedAnswer::fresh(1, 0.5));
        c.put(&q(1, 8), CachedAnswer::fresh(7, 0.9));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&q(1, 8), 0).unwrap().answer, 7);
        assert_eq!(c.stats().evictions, 0);
    }

    /// The intrusive list must evict in exactly the same order as a naive
    /// recency queue across an arbitrary op mix (model-based check).
    #[test]
    fn lru_order_matches_naive_model() {
        use crate::util::rng::Rng;
        let cap = 9;
        let mut c = CompletionCache::new(cap, 1.0);
        // Naive model: VecDeque-of-keys recency (front = oldest), the
        // data structure the pre-PR-1 implementation scanned linearly.
        let mut model: std::collections::VecDeque<i32> = Default::default();
        let mut rng = Rng::new(0xCAFE);
        for step in 0..5000 {
            let id = rng.below(40) as i32;
            if rng.bool(0.55) {
                c.put(&q(id, 8), CachedAnswer::fresh(id as u32, 0.5));
                if let Some(pos) = model.iter().position(|&k| k == id) {
                    model.remove(pos);
                } else if model.len() == cap {
                    model.pop_front();
                }
                model.push_back(id);
            } else {
                let hit = c.get(&q(id, 8), 0).is_some();
                let model_hit = model.contains(&id);
                assert_eq!(hit, model_hit, "step {step}: hit mismatch for {id}");
                if let Some(pos) = model.iter().position(|&k| k == id) {
                    model.remove(pos);
                    model.push_back(id);
                }
            }
            assert_eq!(c.len(), model.len(), "step {step}: size drifted");
        }
        // After the run, residency must agree element-for-element.
        let resident = model.clone();
        for &id in &resident {
            assert!(c.get(&q(id, 8), 0).is_some(), "model key {id} missing from cache");
        }
    }

    #[test]
    fn touch_most_recent_is_noop() {
        let mut c = CompletionCache::new(3, 1.0);
        for id in 0..3 {
            c.put(&q(id, 8), CachedAnswer::fresh(id as u32, 0.5));
        }
        // Touch the tail repeatedly; order must stay 0 (oldest), 1, 2.
        for _ in 0..5 {
            assert!(c.get(&q(2, 8), 0).is_some());
        }
        c.put(&q(3, 8), CachedAnswer::fresh(3, 0.5));
        assert!(c.get(&q(0, 8), 0).is_none(), "0 was oldest and must evict");
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn stale_generation_is_missed_and_lazily_invalidated() {
        let mut c = CompletionCache::new(4, 1.0);
        c.put(&q(1, 8), CachedAnswer { answer: 3, score: 0.9, model: Some(2), plan_version: 0 });
        assert_eq!(c.get(&q(1, 8), 0).unwrap().answer, 3, "same generation hits");
        assert!(c.get(&q(1, 8), 1).is_none(), "newer generation must miss");
        assert_eq!(c.stats().invalidations, 1, "stale entry reclaimed on lookup");
        assert!(c.is_empty());
        // and the slot is reusable
        c.put(&q(1, 8), CachedAnswer { answer: 5, score: 0.9, model: Some(1), plan_version: 1 });
        assert_eq!(c.get(&q(1, 8), 1).unwrap().answer, 5);
        // A reader still holding an OLDER generation must miss but NOT
        // destroy the newer entry (in-flight answer racing a swap).
        assert!(c.get(&q(1, 8), 0).is_none(), "pre-swap reader misses");
        assert_eq!(c.stats().invalidations, 1, "newer entry is left intact");
        assert_eq!(
            c.get(&q(1, 8), 1).unwrap().answer,
            5,
            "current-generation traffic still hits after the stale read"
        );
    }

    #[test]
    fn similar_tier_never_serves_stale_generations() {
        let mut c = CompletionCache::new(8, 0.7);
        let base = q(3, 32);
        c.put(&base, CachedAnswer { answer: 1, score: 0.8, model: Some(0), plan_version: 0 });
        let mut nearly = base.clone();
        nearly[5] += 1;
        assert!(c.get(&nearly, 0).is_some(), "current generation: similar hit");
        assert!(c.get(&nearly, 7).is_none(), "stale generation: no similar hit");
    }

    #[test]
    fn retain_and_restamp_keeps_and_promotes_survivors() {
        let mut c = CompletionCache::new(8, 1.0);
        for id in 0..6 {
            c.put(
                &q(id, 8),
                CachedAnswer {
                    answer: id as u32,
                    score: 0.5,
                    model: Some(id as usize % 3),
                    plan_version: 0,
                },
            );
        }
        // Keep only entries produced by model 1; re-stamp them to gen 1.
        let kept = c.retain_and_restamp(1, |a| a.model == Some(1));
        assert_eq!(kept, 2, "ids 1 and 4 carry model 1");
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().invalidations, 4);
        for id in [1i32, 4] {
            let hit = c.get(&q(id, 8), 1).expect("survivor serves the new generation");
            assert_eq!(hit.plan_version, 1, "survivors are re-stamped");
        }
        assert!(c.get(&q(0, 8), 1).is_none());
        // LRU structure stays sound after the sweep: fill to capacity and
        // evict in order.
        for id in 10..18 {
            c.put(&q(id, 8), CachedAnswer::fresh(id as u32, 0.5));
        }
        assert_eq!(c.len(), 8);
    }

    /// The router's cache-signal probe must see exactly what `get` would
    /// serve — without perturbing stats, recency, or stale entries.
    #[test]
    fn peek_similarity_is_pure_and_generation_aware() {
        let mut c = CompletionCache::new(4, 1.0);
        assert_eq!(c.peek_similarity(&q(1, 8), 0), 0.0, "empty cache → no signal");
        c.put(&q(1, 8), CachedAnswer { answer: 3, score: 0.9, model: Some(0), plan_version: 2 });
        assert_eq!(c.peek_similarity(&q(1, 8), 2), 1.0, "exact current-gen entry");
        assert_eq!(c.peek_similarity(&q(1, 8), 3), 0.0, "stale generation → no signal");
        let before = c.stats();
        for _ in 0..10 {
            c.peek_similarity(&q(1, 8), 2);
            c.peek_similarity(&q(1, 8), 3);
        }
        assert_eq!(c.stats(), before, "peek records no stats");
        // Peeking a NEWER generation at a stale entry must not reclaim it
        // (get would): the entry still serves its own generation.
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&q(1, 8), 2).unwrap().answer, 3);
        // Peeks don't promote: with cap 2, peek the oldest then insert —
        // it must still evict first.
        let mut c = CompletionCache::new(2, 1.0);
        c.put(&q(1, 8), CachedAnswer::fresh(1, 0.5));
        c.put(&q(2, 8), CachedAnswer::fresh(2, 0.5));
        for _ in 0..5 {
            assert_eq!(c.peek_similarity(&q(1, 8), 0), 1.0);
        }
        c.put(&q(3, 8), CachedAnswer::fresh(3, 0.5));
        assert!(c.get(&q(1, 8), 0).is_none(), "peeked entry was not promoted");
    }

    #[test]
    fn peek_similarity_reports_similar_tier_strength() {
        let mut c = CompletionCache::new(8, 0.7);
        let base = q(3, 32);
        c.put(&base, CachedAnswer::fresh(1, 0.8));
        let mut nearly = base.clone();
        nearly[5] += 1;
        let sim = c.peek_similarity(&nearly, 0);
        assert!((0.7..=1.0).contains(&sim), "similar entry reports its strength: {sim}");
        assert_eq!(c.peek_similarity(&q(99, 32), 0), 0.0, "dissimilar → 0");
        assert_eq!(c.stats().similar_hits, 0, "peek is not a hit");
        // Sharded wrapper delegates to the right shard.
        let sc = ShardedCache::new(4, 64, 1.0, 1);
        sc.put(&q(7, 8), CachedAnswer::fresh(7, 0.5));
        assert_eq!(sc.peek_similarity(&q(7, 8), 0), 1.0);
        assert_eq!(sc.peek_similarity(&q(8, 8), 0), 0.0);
        let st = sc.stats();
        assert_eq!((st.lookups, st.exact_hits), (0, 0), "sharded peek records no stats");
    }

    #[test]
    fn minhash_similarity_sane() {
        let a = minhash(&q(5, 64));
        assert_eq!(signature_similarity(&a, &a), 1.0);
        let b = minhash(&q(6, 64));
        assert!(signature_similarity(&a, &b) < 0.8);
    }

    /// Satellite pin: touch period 1 (the default) must reproduce exact
    /// LRU order — same model-based check as `lru_order_matches_naive_model`
    /// but with the sampled-touch path explicitly engaged.
    #[test]
    fn sampled_touch_t1_is_exact_lru() {
        use crate::util::rng::Rng;
        let cap = 7;
        let mut c = CompletionCache::new(cap, 1.0).with_touch_period(1);
        let mut model: std::collections::VecDeque<i32> = Default::default();
        let mut rng = Rng::new(0xBEEF);
        for step in 0..4000 {
            let id = rng.below(30) as i32;
            if rng.bool(0.5) {
                c.put(&q(id, 8), CachedAnswer::fresh(id as u32, 0.5));
                if let Some(pos) = model.iter().position(|&k| k == id) {
                    model.remove(pos);
                } else if model.len() == cap {
                    model.pop_front();
                }
                model.push_back(id);
            } else {
                let hit = c.get(&q(id, 8), 0).is_some();
                assert_eq!(hit, model.contains(&id), "step {step}: hit mismatch");
                if let Some(pos) = model.iter().position(|&k| k == id) {
                    model.remove(pos);
                    model.push_back(id);
                }
            }
            assert_eq!(c.len(), model.len(), "step {step}: size drifted");
        }
    }

    /// With a huge touch period, hits never promote: eviction runs in
    /// pure insertion order even though every entry was hit — the hit SET
    /// is unchanged, only recency order coarsens.
    #[test]
    fn sampled_touch_skips_promotion_between_samples() {
        let mut c = CompletionCache::new(2, 1.0).with_touch_period(u32::MAX);
        c.put(&q(1, 8), CachedAnswer::fresh(1, 0.5));
        c.put(&q(2, 8), CachedAnswer::fresh(2, 0.5));
        // Hit entry 1 repeatedly; an exact-LRU cache would protect it.
        for _ in 0..10 {
            assert!(c.get(&q(1, 8), 0).is_some(), "hit set must be unaffected");
        }
        c.put(&q(3, 8), CachedAnswer::fresh(3, 0.5));
        assert!(
            c.get(&q(1, 8), 0).is_none(),
            "unsampled hits must not promote: 1 stays oldest and evicts"
        );
        assert!(c.get(&q(2, 8), 0).is_some());
    }

    /// The deterministic 1-in-T schedule: with T=2 every second hit
    /// promotes, so two hits on the oldest entry save it exactly when the
    /// second hit lands.
    #[test]
    fn sampled_touch_period_two_promotes_every_second_hit() {
        let mut c = CompletionCache::new(2, 1.0).with_touch_period(2);
        c.put(&q(1, 8), CachedAnswer::fresh(1, 0.5));
        c.put(&q(2, 8), CachedAnswer::fresh(2, 0.5));
        // Hit 1 twice: tick 1 (skipped), tick 2 (touches → 2 now oldest).
        assert!(c.get(&q(1, 8), 0).is_some());
        assert!(c.get(&q(1, 8), 0).is_some());
        c.put(&q(3, 8), CachedAnswer::fresh(3, 0.5));
        assert!(c.get(&q(2, 8), 0).is_none(), "2 evicts after 1's sampled touch");
        assert!(c.get(&q(1, 8), 0).is_some());
    }

    #[test]
    fn sharded_cache_roundtrip_and_aggregate_stats() {
        let c = ShardedCache::new(4, 64, 1.0, 1);
        assert_eq!(c.shard_count(), 4);
        assert!(c.is_empty());
        for id in 0..32 {
            c.put(&q(id, 8), CachedAnswer::fresh(id as u32, 0.5));
        }
        assert_eq!(c.len(), 32);
        for id in 0..32 {
            assert_eq!(c.get(&q(id, 8), 0).unwrap().answer, id as u32);
        }
        let st = c.stats();
        assert_eq!(st.insertions, 32);
        assert_eq!(st.exact_hits, 32);
        assert_eq!(st.lookups, 32);
    }

    #[test]
    fn sharded_cache_rounds_up_and_defaults_shards() {
        assert_eq!(ShardedCache::new(3, 16, 1.0, 1).shard_count(), 4);
        let auto = ShardedCache::new(0, 16, 1.0, 1);
        assert_eq!(auto.shard_count(), default_cache_shards());
        assert!(auto.shard_count().is_power_of_two());
    }

    #[test]
    fn sharded_sweep_restamps_across_all_shards() {
        let c = ShardedCache::new(4, 64, 1.0, 1);
        for id in 0..24 {
            c.put(
                &q(id, 8),
                CachedAnswer {
                    answer: id as u32,
                    score: 0.5,
                    model: Some(id as usize % 3),
                    plan_version: 0,
                },
            );
        }
        let kept = c.retain_and_restamp(1, |a| a.model == Some(1));
        assert_eq!(kept, 8, "ids ≡ 1 (mod 3) survive regardless of shard");
        assert_eq!(c.len(), 8);
        for id in (0..24).filter(|i| i % 3 == 1) {
            let hit = c.get(&q(id, 8), 1).expect("survivor serves new generation");
            assert_eq!(hit.plan_version, 1);
        }
        assert_eq!(c.stats().invalidations, 16);
    }
}
