//! Per-query contextual routing: a learned meta-router stage.
//!
//! FrugalGPT's optimizer learns ONE global (L, τ) cascade, but the paper's
//! own framing — "which combinations of LLMs to use for *different*
//! queries" — points at per-query routing (FORC's meta-model router and
//! budget-conditioned contextual cascades, see PAPERS.md). This module is
//! that idea as one more [`Strategy`](crate::strategies::pipeline::Strategy)
//! stage: a cheap linear meta-model reads per-query features (token
//! length, an optional tiny probe-model score, an optional cache-signal)
//! and picks a **route** — the global plan, a suffix of it (skip a
//! cascade prefix the probe says is doomed or unnecessary), or a
//! different frontier point entirely.
//!
//! §Snapshot discipline — routes ride the exact same publish machinery as
//! plans: an immutable [`RouterBundle`] (model + compiled route cascades)
//! behind a wait-free [`SnapshotCell`] in a [`RouterHandle`]. The stage
//! loads ONE bundle per query; the bundle records the plan version it was
//! compiled against, and the stage *abstains* (routes nothing) whenever
//! that version differs from the query's [`PlanBundle`] snapshot — a plan
//! swap can therefore never mix route cascades from one generation with a
//! plan from another. Router swaps are recorded as [`RouterSwapEvent`]s,
//! mirroring the plan swap history.
//!
//! §Degenerate identity — a zero-weight model routes every query to
//! route 0 (the global plan) at zero extra cost, and the stage then
//! passes without touching the context at all: the pipeline is
//! **bit-identical** to one without the router stage (pinned by
//! `prop_degenerate_router_reproduces_global_plan_bitwise`). Features
//! that no route weights read (the probe call, the cache peek) are never
//! computed, so the degenerate router also never *spends* anything.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::coordinator::cascade::{argmax, Cascade, CascadePlan};
use crate::coordinator::optimizer::FrontierPoint;
use crate::coordinator::scorer::Scorer;
use crate::data::DatasetMeta;
use crate::marketplace::CostModel;
use crate::runtime::EngineHandle;
use crate::server::batcher::{Batcher, BatcherConfig, BatcherHandle};
use crate::strategies::cache::ShardedCache;
use crate::strategies::concat;
use crate::strategies::pipeline::{Decision, QueryCtx, Strategy};
use crate::util::json::Value;
use crate::util::sync::SnapshotCell;

/// Number of per-query features the router reads.
pub const N_FEATURES: usize = 4;
/// Feature index: constant bias term (always 1.0).
pub const FEAT_BIAS: usize = 0;
/// Feature index: log-scaled billable input length.
pub const FEAT_LEN: usize = 1;
/// Feature index: probe-model reliability score (0.0 when no probe).
pub const FEAT_PROBE: usize = 2;
/// Feature index: completion-cache similarity signal (0.0 when no cache).
pub const FEAT_CACHE: usize = 3;

/// Log-scaled billable-input-length feature. The fixed normalizer keeps
/// the feature O(1) for realistic prompt sizes without a stored
/// per-dataset scale (so a degenerate model needs no statistics).
pub fn length_feature(billed_input: u32) -> f32 {
    (1.0 + billed_input as f32).ln() / 8.0
}

/// Assemble the feature vector the router model scores.
pub fn features(billed_input: u32, probe_score: f32, cache_signal: f32) -> [f32; N_FEATURES] {
    [1.0, length_feature(billed_input), probe_score, cache_signal]
}

/// Router configuration (`--router on` on the serve CLIs).
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Max frontier points offered as routes beyond the global plan and
    /// its prefix-skips (`--router-grid`).
    pub grid: usize,
    /// Marketplace model name scored as the probe feature (`--probe-model`;
    /// `None` = the probe feature stays 0.0 and costs nothing).
    pub probe_model: Option<String>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { grid: 4, probe_model: None }
    }
}

/// The learned meta-model: one linear scorer per route over the
/// [`features`] vector; `decide` picks the argmax (ties → the lowest
/// route index, so the all-zero model always picks route 0 — the global
/// plan).
#[derive(Debug, Clone, PartialEq)]
pub struct RouterModel {
    /// Per-route feature weights (`n_routes × N_FEATURES`).
    pub weights: Vec<[f32; N_FEATURES]>,
}

impl RouterModel {
    /// The zero-weight model over `n_routes` routes: routes everything to
    /// route 0 and reads no paid feature — the bit-identity fallback.
    pub fn degenerate(n_routes: usize) -> RouterModel {
        RouterModel { weights: vec![[0.0; N_FEATURES]; n_routes] }
    }

    /// Number of routes this model scores.
    pub fn n_routes(&self) -> usize {
        self.weights.len()
    }

    /// Whether every weight is exactly zero (the identity router).
    pub fn is_degenerate(&self) -> bool {
        self.weights.iter().all(|w| w.iter().all(|&x| x == 0.0))
    }

    /// Whether any route reads feature `feat` — gates paid feature
    /// extraction (probe calls, cache peeks) so the degenerate model
    /// never spends.
    pub fn uses_feature(&self, feat: usize) -> bool {
        self.weights.iter().any(|w| w[feat] != 0.0)
    }

    /// Linear score of route `r` on a feature vector.
    pub fn score(&self, r: usize, f: &[f32; N_FEATURES]) -> f32 {
        self.weights[r].iter().zip(f.iter()).map(|(w, x)| w * x).sum()
    }

    /// Pick the route: argmax of the per-route linear scores, ties
    /// resolved to the lowest index.
    pub fn decide(&self, f: &[f32; N_FEATURES]) -> usize {
        let scores: Vec<f32> = (0..self.n_routes()).map(|r| self.score(r, f)).collect();
        argmax(&scores)
    }

    /// JSON form (row-major weights), bit-lossless through the
    /// shortest-printing serializer.
    pub fn to_value(&self) -> Value {
        Value::Arr(
            self.weights
                .iter()
                .map(|w| Value::Arr(w.iter().map(|&x| Value::Num(x as f64)).collect()))
                .collect(),
        )
    }

    /// Parse the [`RouterModel::to_value`] form.
    pub fn from_value(v: &Value) -> Result<RouterModel> {
        let rows = v.as_arr().context("router model must be an array of weight rows")?;
        let mut weights = Vec::with_capacity(rows.len());
        for row in rows {
            let xs = row.as_arr().context("router weight row must be an array")?;
            if xs.len() != N_FEATURES {
                anyhow::bail!("router weight row has {} features, want {N_FEATURES}", xs.len());
            }
            let mut w = [0.0f32; N_FEATURES];
            for (i, x) in xs.iter().enumerate() {
                w[i] = x.as_f64().context("router weight must be a number")? as f32;
            }
            weights.push(w);
        }
        Ok(RouterModel { weights })
    }
}

/// One route the router may pick: a cascade plan plus how many stages of
/// the *global* plan it skips (so `stopped_at` can be reported in global
/// stage coordinates).
pub struct RouteTarget {
    /// The plan this route executes.
    pub plan: CascadePlan,
    /// Stages of the global plan this route skips (`plan` is then the
    /// global plan's suffix `stages[skip..]`); 0 for the global plan
    /// itself and for frontier-point routes.
    pub skip: usize,
    /// Compiled cascade; `None` for route 0 — the global plan — which
    /// executes the query's own [`PlanBundle`] cascade (this is what
    /// makes the degenerate router bit-identical: no second compile).
    pub cascade: Option<Arc<Cascade>>,
    /// Short label for reports (`global`, `skip1`, `frontier2`, ...).
    pub label: String,
}

/// Enumerate the route *plans* for a global plan and a served frontier:
/// route 0 is the global plan itself, then one prefix-skip route per
/// non-trivial suffix, then up to `grid` frontier points (evenly
/// subsampled across the frontier, deduplicated against the routes
/// already present). Pure — compilation to cascades happens in the
/// service, which owns engine/health wiring.
pub fn route_plans(
    global: &CascadePlan,
    frontier: &[FrontierPoint],
    grid: usize,
) -> Vec<(CascadePlan, usize, String)> {
    let mut out = vec![(global.clone(), 0usize, "global".to_string())];
    for j in 1..global.stages.len() {
        out.push((
            CascadePlan::new(global.stages[j..].to_vec()),
            j,
            format!("skip{j}"),
        ));
    }
    if grid > 0 && !frontier.is_empty() {
        let picks = grid.min(frontier.len());
        for k in 0..picks {
            // Even subsample across the frontier ordering (cheapest to
            // most accurate), endpoints included when picks > 1.
            let idx = if picks == 1 { 0 } else { k * (frontier.len() - 1) / (picks - 1) };
            let plan = &frontier[idx].plan;
            if out.iter().any(|(p, _, _)| p == plan) {
                continue;
            }
            out.push((plan.clone(), 0, format!("frontier{idx}")));
        }
    }
    out
}

/// One immutable router generation: the learned model plus the compiled
/// route cascades, stamped with the plan version it was compiled against.
/// Never mutated after build — router swaps replace the whole bundle.
pub struct RouterBundle {
    /// Monotone router version assigned at publish time.
    pub version: u64,
    /// The plan-bundle version the routes were compiled against. The
    /// stage abstains when this differs from the query's plan snapshot.
    pub plan_version: u64,
    /// The learned meta-model (`n_routes` must equal `routes.len()`).
    pub model: RouterModel,
    /// The routes, index-aligned with the model's route scores.
    pub routes: Vec<RouteTarget>,
}

impl RouterBundle {
    /// Assemble a bundle, checking the model/route alignment.
    pub fn new(
        version: u64,
        plan_version: u64,
        model: RouterModel,
        routes: Vec<RouteTarget>,
    ) -> Result<RouterBundle> {
        if routes.is_empty() {
            anyhow::bail!("a router bundle needs at least the global route");
        }
        if model.n_routes() != routes.len() {
            anyhow::bail!(
                "router model scores {} routes but the bundle compiled {}",
                model.n_routes(),
                routes.len()
            );
        }
        if routes[0].skip != 0 || routes[0].cascade.is_some() {
            anyhow::bail!("route 0 must be the global plan (skip 0, no compiled cascade)");
        }
        Ok(RouterBundle { version, plan_version, model, routes })
    }
}

/// One published router swap, kept for the `report swaps` history.
#[derive(Debug, Clone)]
pub struct RouterSwapEvent {
    /// Router version this publish installed.
    pub version: u64,
    /// Plan version the new bundle was compiled against.
    pub plan_version: u64,
    /// `metrics.queries` at publish time.
    pub at_query: u64,
    /// Human-readable cause (reoptimizer retrain, plan-swap rebuild, ...).
    pub reason: String,
    /// Routes offered by the new bundle.
    pub n_routes: usize,
    /// Whether the installed model is the zero-weight identity.
    pub degenerate: bool,
    /// Window accuracy of the routed policy at publish time (retrains).
    pub window_accuracy: Option<f64>,
    /// Window avg cost of the routed policy at publish time (retrains).
    pub window_avg_cost: Option<f64>,
}

impl RouterSwapEvent {
    /// JSON form for the swap log.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("version".to_string(), Value::Num(self.version as f64));
        m.insert("plan_version".to_string(), Value::Num(self.plan_version as f64));
        m.insert("at_query".to_string(), Value::Num(self.at_query as f64));
        m.insert("reason".to_string(), Value::Str(self.reason.clone()));
        m.insert("n_routes".to_string(), Value::Num(self.n_routes as f64));
        m.insert("degenerate".to_string(), Value::Bool(self.degenerate));
        m.insert(
            "window_accuracy".to_string(),
            self.window_accuracy.map(Value::Num).unwrap_or(Value::Null),
        );
        m.insert(
            "window_avg_cost".to_string(),
            self.window_avg_cost.map(Value::Num).unwrap_or(Value::Null),
        );
        Value::Obj(m)
    }

    /// Parse an event serialized by [`RouterSwapEvent::to_value`].
    pub fn from_value(v: &Value) -> Result<RouterSwapEvent> {
        Ok(RouterSwapEvent {
            version: v.get("version").as_f64().context("router swap missing `version`")? as u64,
            plan_version: v
                .get("plan_version")
                .as_f64()
                .context("router swap missing `plan_version`")? as u64,
            at_query: v.get("at_query").as_f64().context("router swap missing `at_query`")?
                as u64,
            reason: v
                .get("reason")
                .as_str()
                .context("router swap missing `reason`")?
                .to_string(),
            n_routes: v.get("n_routes").as_usize().context("router swap missing `n_routes`")?,
            degenerate: v
                .get("degenerate")
                .as_bool()
                .context("router swap missing `degenerate`")?,
            window_accuracy: v.get("window_accuracy").as_f64(),
            window_avg_cost: v.get("window_avg_cost").as_f64(),
        })
    }
}

/// Point-in-time router stage counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterStats {
    /// Queries routed off route 0 (or charged a probe call).
    pub routed: u64,
    /// Queries the stage abstained on because the router bundle was
    /// compiled against a different plan version than the query's
    /// snapshot.
    pub abstained: u64,
}

/// Shared, atomically swappable handle to the current [`RouterBundle`] —
/// the same wait-free publish discipline as the plan handle (readers are
/// two atomics + an `Arc` clone; publishers serialize on the history
/// mutex, which keeps the recorded events strictly version-ordered).
pub struct RouterHandle {
    current: SnapshotCell<RouterBundle>,
    next_version: AtomicU64,
    history: Mutex<Vec<RouterSwapEvent>>,
    routed: AtomicU64,
    abstained: AtomicU64,
}

impl RouterHandle {
    /// Wrap an initial bundle (its install is not a history event).
    pub fn new(initial: RouterBundle) -> RouterHandle {
        let v0 = initial.version;
        RouterHandle {
            current: SnapshotCell::new(Arc::new(initial)),
            next_version: AtomicU64::new(v0 + 1),
            history: Mutex::new(Vec::new()),
            routed: AtomicU64::new(0),
            abstained: AtomicU64::new(0),
        }
    }

    /// The current bundle (wait-free).
    pub fn snapshot(&self) -> Arc<RouterBundle> {
        self.current.load()
    }

    /// Version of the currently served bundle.
    pub fn version(&self) -> u64 {
        self.snapshot().version
    }

    /// Reserve the version number for a bundle about to be built.
    pub fn reserve_version(&self) -> u64 {
        self.next_version.fetch_add(1, Ordering::Relaxed)
    }

    /// Install `bundle` if its version is still the newest; a publish
    /// that lost the version race is dropped (no history entry — it never
    /// served traffic). Mirrors `PlanHandle::publish`.
    pub fn publish(&self, bundle: RouterBundle, event: RouterSwapEvent) -> bool {
        let version = bundle.version;
        let mut history = self.history.lock().unwrap();
        if !self
            .current
            .store_if(Arc::new(bundle), |cur| cur.version < version)
        {
            return false;
        }
        history.push(event);
        true
    }

    /// All router swaps published so far (oldest first).
    pub fn history(&self) -> Vec<RouterSwapEvent> {
        self.history.lock().unwrap().clone()
    }

    /// Point-in-time stage counters.
    pub fn stats(&self) -> RouterStats {
        RouterStats {
            routed: self.routed.load(Ordering::Relaxed),
            abstained: self.abstained.load(Ordering::Relaxed),
        }
    }
}

/// Result of one probe-model call.
#[derive(Debug, Clone, Copy)]
pub struct ProbeResult {
    /// Reliability score of the probe's answer (the scorer's `g(q, a)`).
    pub score: f32,
    /// The probe's predicted class.
    pub pred: u32,
    /// Marketplace cost of the probe call (billed onto the answer).
    pub cost_usd: f64,
}

/// The tiny probe model behind the router's [`FEAT_PROBE`] feature: one
/// cheap marketplace model executed through its own batcher (submissions
/// from concurrent answer threads coalesce), scored by the shared
/// reliability scorer. Prices are frozen at spawn time (same documented
/// approximation as the shadow worker).
pub struct ProbeScorer {
    // Keeps the batcher worker alive for the service's lifetime.
    _batcher: Batcher,
    handle: BatcherHandle,
    model_index: usize,
    scorer: Scorer,
    costs: CostModel,
}

impl ProbeScorer {
    /// Spawn the probe batcher for marketplace model `model_name`.
    pub fn spawn(
        engine: EngineHandle,
        costs: CostModel,
        meta: DatasetMeta,
        model_name: &str,
    ) -> Result<ProbeScorer> {
        let model_index = costs
            .model_index(model_name)
            .with_context(|| format!("probe model `{model_name}` is not in the marketplace"))?;
        let batcher = Batcher::spawn(
            engine.clone(),
            costs.dataset.clone(),
            model_name.to_string(),
            BatcherConfig::default(),
        );
        let handle = batcher.handle();
        Ok(ProbeScorer {
            _batcher: batcher,
            handle,
            model_index,
            scorer: Scorer::new(engine, meta),
            costs,
        })
    }

    /// Marketplace index of the probe model.
    pub fn model_index(&self) -> usize {
        self.model_index
    }

    /// Run the probe on one query row: model call (batched) → predicted
    /// class → reliability score of that prediction. `billed_input` is
    /// the query's amortized billable input size.
    pub fn probe(&self, tokens: &[i32], billed_input: u32) -> Result<ProbeResult> {
        let logits = self.handle.submit(tokens.to_vec())?;
        let pred = argmax(&logits) as u32;
        let score = self.scorer.score(tokens, pred)?;
        let cost_usd = self.costs.call_cost(self.model_index, billed_input, pred);
        Ok(ProbeResult { score, pred, cost_usd })
    }
}

/// What the router stage attached to the query context: which cascade the
/// terminal stage should execute instead of the bundle default, plus the
/// bookkeeping to report it honestly.
pub struct RouteDecision {
    /// Index of the picked route in the router bundle.
    pub route: usize,
    /// Compiled cascade to execute; `None` = the global plan (the
    /// query's own [`PlanBundle`] cascade — identical code path to no
    /// router at all).
    pub cascade: Option<Arc<Cascade>>,
    /// Global-plan stages skipped (added to the reported `stopped_at` /
    /// `skipped_stages` so they stay in global coordinates).
    pub skip: usize,
    /// Probe spend to add to the answer's metered cost (0.0 when the
    /// model reads no probe feature).
    pub probe_cost_usd: f64,
    /// Version of the router bundle that made this decision.
    pub router_version: u64,
}

/// The router as a pipeline stage: loads ONE router bundle snapshot,
/// extracts only the features the model actually reads, and attaches a
/// [`RouteDecision`] for the cascade executor. Never answers; never
/// transforms the tokens.
pub struct RouterStage {
    /// The swappable router bundle handle.
    pub router: Arc<RouterHandle>,
    /// Completion cache peeked (non-mutating) for [`FEAT_CACHE`].
    pub cache: Option<Arc<ShardedCache>>,
    /// Probe model behind [`FEAT_PROBE`] (`None` = feature stays 0.0).
    pub probe: Option<Arc<ProbeScorer>>,
}

impl Strategy for RouterStage {
    fn name(&self) -> &'static str {
        "router"
    }

    fn on_query(&self, ctx: &mut QueryCtx) -> Result<Decision> {
        let bundle = self.router.snapshot();
        // One-snapshot discipline: the routes were compiled against one
        // plan generation. If the query's plan snapshot is a different
        // generation (the tiny window between a plan publish and its
        // router rebuild), abstain — serve the plain global plan rather
        // than mix generations.
        if bundle.plan_version != ctx.bundle.version() {
            self.router.abstained.fetch_add(1, Ordering::Relaxed);
            return Ok(Decision::Pass);
        }
        let model = &bundle.model;
        let (prompt_toks, query_toks) = concat::split_row_tokens(&ctx.tokens, ctx.meta);
        let billed = concat::amortized_input(prompt_toks, query_toks, ctx.concat_group);
        let mut probe_cost = 0.0;
        let mut probe_score = 0.0;
        // Paid features are extracted only when some route weights them —
        // the degenerate model must not spend a cent.
        if model.uses_feature(FEAT_PROBE) {
            if let Some(probe) = &self.probe {
                let r = probe.probe(&ctx.tokens, billed)?;
                probe_score = r.score;
                probe_cost = r.cost_usd;
            }
        }
        let mut cache_signal = 0.0;
        if model.uses_feature(FEAT_CACHE) {
            if let Some(cache) = &self.cache {
                cache_signal = cache.peek_similarity(ctx.original, ctx.bundle.version()) as f32;
            }
        }
        let route = model
            .decide(&features(billed, probe_score, cache_signal))
            .min(bundle.routes.len() - 1);
        if route == 0 && probe_cost == 0.0 {
            // The global plan at no extra cost: leave the context
            // untouched so the cascade executor takes the exact code path
            // it takes without a router stage (bit-parity fast path).
            return Ok(Decision::Pass);
        }
        let target = &bundle.routes[route];
        self.router.routed.fetch_add(1, Ordering::Relaxed);
        ctx.route = Some(RouteDecision {
            route,
            cascade: target.cascade.clone(),
            skip: target.skip,
            probe_cost_usd: probe_cost,
            router_version: bundle.version,
        });
        Ok(Decision::Pass)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::Stage;

    fn plan3() -> CascadePlan {
        CascadePlan::new(vec![
            Stage { model: 0, threshold: 0.6 },
            Stage { model: 1, threshold: 0.4 },
            Stage { model: 2, threshold: 0.0 },
        ])
    }

    #[test]
    fn degenerate_model_always_picks_route_zero_and_reads_no_paid_feature() {
        let m = RouterModel::degenerate(5);
        assert!(m.is_degenerate());
        assert!(!m.uses_feature(FEAT_PROBE));
        assert!(!m.uses_feature(FEAT_CACHE));
        for f in [
            features(0, 0.0, 0.0),
            features(10_000, 1.0, 1.0),
            features(37, 0.2, 0.9),
        ] {
            assert_eq!(m.decide(&f), 0);
        }
    }

    #[test]
    fn decide_is_argmax_with_ties_to_lowest_index() {
        let mut m = RouterModel::degenerate(3);
        m.weights[2][FEAT_LEN] = 2.0;
        m.weights[1][FEAT_LEN] = 2.0; // exact tie with route 2 → route 1
        let f = features(100, 0.0, 0.0);
        assert_eq!(m.decide(&f), 1);
        m.weights[2][FEAT_BIAS] = 0.1; // break the tie upward
        assert_eq!(m.decide(&f), 2);
        assert!(m.uses_feature(FEAT_LEN));
        assert!(!m.uses_feature(FEAT_PROBE));
    }

    #[test]
    fn length_feature_is_monotone_and_bounded_for_real_prompts() {
        assert!(length_feature(10) < length_feature(100));
        assert!(length_feature(100) < length_feature(10_000));
        assert!(length_feature(100_000) < 1.6);
    }

    #[test]
    fn route_plans_prefix_skips_and_skip_zero_is_identity() {
        let global = plan3();
        let routes = route_plans(&global, &[], 4);
        // Route 0 IS the global plan — prefix-skip with skip=0 is the
        // identity cascade.
        assert_eq!(routes[0].0, global);
        assert_eq!(routes[0].1, 0);
        assert_eq!(routes.len(), 3);
        // skip j executes the suffix stages[j..].
        assert_eq!(routes[1].1, 1);
        assert_eq!(routes[1].0.stages, global.stages[1..].to_vec());
        assert_eq!(routes[2].1, 2);
        assert_eq!(routes[2].0.stages, global.stages[2..].to_vec());
    }

    #[test]
    fn route_plans_subsamples_and_dedupes_frontier_points() {
        let global = plan3();
        let mk = |m: usize| FrontierPoint {
            plan: CascadePlan::single(m),
            accuracy: 0.5 + m as f64 / 10.0,
            avg_cost: m as f64,
        };
        // frontier of 5 single-model plans; plan single(2) duplicates the
        // skip2 route and must be deduped.
        let frontier: Vec<FrontierPoint> = (0..5).map(mk).collect();
        let routes = route_plans(&global, &frontier, 3);
        let labels: Vec<&str> = routes.iter().map(|(_, _, l)| l.as_str()).collect();
        assert_eq!(&labels[..3], &["global", "skip1", "skip2"]);
        // grid=3 over 5 points picks indices 0, 2, 4; single(2) ≡ skip2
        // is deduped, leaving frontier0 and frontier4.
        assert_eq!(&labels[3..], &["frontier0", "frontier4"]);
        let n_before = routes.len();
        // grid=0 disables frontier routes entirely.
        assert_eq!(route_plans(&global, &frontier, 0).len(), 3);
        assert!(n_before > 3);
    }

    #[test]
    fn router_bundle_checks_alignment_and_route_zero_shape() {
        let mk_routes = || {
            vec![RouteTarget {
                plan: plan3(),
                skip: 0,
                cascade: None,
                label: "global".into(),
            }]
        };
        assert!(RouterBundle::new(1, 0, RouterModel::degenerate(1), mk_routes()).is_ok());
        // model/route count mismatch
        assert!(RouterBundle::new(1, 0, RouterModel::degenerate(2), mk_routes()).is_err());
        // empty routes
        assert!(RouterBundle::new(1, 0, RouterModel::degenerate(0), vec![]).is_err());
        // route 0 must be the global plan shape
        let bad = vec![RouteTarget {
            plan: plan3(),
            skip: 1,
            cascade: None,
            label: "bad".into(),
        }];
        assert!(RouterBundle::new(1, 0, RouterModel::degenerate(1), bad).is_err());
    }

    #[test]
    fn router_handle_publish_is_monotone_and_recorded() {
        let routes = || {
            vec![RouteTarget {
                plan: plan3(),
                skip: 0,
                cascade: None,
                label: "global".into(),
            }]
        };
        let h = RouterHandle::new(
            RouterBundle::new(0, 0, RouterModel::degenerate(1), routes()).unwrap(),
        );
        let ev = |version| RouterSwapEvent {
            version,
            plan_version: 0,
            at_query: 0,
            reason: "test".into(),
            n_routes: 1,
            degenerate: true,
            window_accuracy: None,
            window_avg_cost: None,
        };
        let v1 = h.reserve_version();
        let v2 = h.reserve_version();
        assert!(v2 > v1);
        // Install v2 first; the stale v1 publish must be dropped.
        assert!(h.publish(
            RouterBundle::new(v2, 0, RouterModel::degenerate(1), routes()).unwrap(),
            ev(v2)
        ));
        assert!(!h.publish(
            RouterBundle::new(v1, 0, RouterModel::degenerate(1), routes()).unwrap(),
            ev(v1)
        ));
        assert_eq!(h.version(), v2);
        let hist = h.history();
        assert_eq!(hist.len(), 1);
        assert_eq!(hist[0].version, v2);
    }

    #[test]
    fn router_swap_event_json_roundtrip() {
        let ev = RouterSwapEvent {
            version: 7,
            plan_version: 3,
            at_query: 512,
            reason: "retrain on window of 256 obs".into(),
            n_routes: 5,
            degenerate: false,
            window_accuracy: Some(0.9375),
            window_avg_cost: Some(0.00042),
        };
        let json = ev.to_value().to_json();
        let back = RouterSwapEvent::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.version, ev.version);
        assert_eq!(back.plan_version, ev.plan_version);
        assert_eq!(back.at_query, ev.at_query);
        assert_eq!(back.reason, ev.reason);
        assert_eq!(back.n_routes, ev.n_routes);
        assert_eq!(back.degenerate, ev.degenerate);
        assert_eq!(back.window_accuracy, ev.window_accuracy);
        assert_eq!(back.window_avg_cost, ev.window_avg_cost);
    }

    #[test]
    fn router_model_json_roundtrip_is_bit_exact() {
        let mut m = RouterModel::degenerate(3);
        m.weights[1] = [0.1, -2.5, 3.75, 1e-6];
        m.weights[2] = [f32::MIN_POSITIVE, 0.0, -0.0, 42.0];
        let json = m.to_value().to_json();
        let back = RouterModel::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.n_routes(), 3);
        for (a, b) in back.weights.iter().zip(m.weights.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
