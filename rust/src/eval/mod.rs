//! Evaluation utilities behind the paper's tables and figures.

pub mod mpi;
pub mod router_ablation;
pub mod simulate;
pub mod speculate_ablation;
pub mod table;

use crate::coordinator::optimizer::FrontierPoint;
use crate::coordinator::responses::SplitTable;

/// Accuracy and average cost of always using one model (a Fig. 5 scatter
/// point for an individual API).
#[derive(Debug, Clone)]
pub struct IndividualPoint {
    /// Marketplace model name.
    pub model: String,
    /// Split accuracy of always answering with this model.
    pub accuracy: f64,
    /// Average USD per query of always calling it.
    pub avg_cost: f64,
}

/// Compute the individual-API scatter (accuracy, cost) for every model.
pub fn individual_points(
    table: &SplitTable,
    costs: &crate::marketplace::CostModel,
    input_tokens: &[u32],
) -> Vec<IndividualPoint> {
    let n = table.len();
    (0..table.n_models())
        .map(|m| {
            let mut c = 0.0;
            for i in 0..n {
                c += costs.call_cost(m, input_tokens[i], table.pred(m, i));
            }
            IndividualPoint {
                model: table.model_names[m].clone(),
                accuracy: table.accuracy(m),
                avg_cost: c / n.max(1) as f64,
            }
        })
        .collect()
}

/// The best individual API by accuracy (ties → cheaper).
pub fn best_individual(points: &[IndividualPoint]) -> &IndividualPoint {
    points
        .iter()
        .max_by(|a, b| {
            a.accuracy
                .partial_cmp(&b.accuracy)
                .unwrap()
                .then(b.avg_cost.partial_cmp(&a.avg_cost).unwrap())
        })
        .expect("non-empty marketplace")
}

/// Interpolate the max accuracy achievable on a frontier at cost ≤ c.
pub fn frontier_accuracy_at(frontier: &[FrontierPoint], cost: f64) -> Option<f64> {
    frontier
        .iter()
        .filter(|p| p.avg_cost <= cost + 1e-15)
        .map(|p| p.accuracy)
        .fold(None, |acc, a| Some(acc.map_or(a, |b: f64| b.max(a))))
}

/// Smallest frontier cost that reaches accuracy ≥ `target` (None if the
/// frontier never gets there).
pub fn frontier_cost_to_reach(frontier: &[FrontierPoint], target: f64) -> Option<f64> {
    frontier
        .iter()
        .filter(|p| p.accuracy + 1e-12 >= target)
        .map(|p| p.avg_cost)
        .fold(None, |acc, c| Some(acc.map_or(c, |b: f64| b.min(c))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::CascadePlan;
    use crate::coordinator::responses::synthetic_table;
    use crate::marketplace::CostModel;

    #[test]
    fn individual_points_match_table_accuracy() {
        let t = synthetic_table(4, 500, 4, 0.9, 5);
        let cm = CostModel::from_table1("x", vec![1; 4]);
        let cm = CostModel {
            model_names: t.model_names.clone(),
            pricing: cm.pricing[..4].to_vec(),
            latency: cm.latency[..4].to_vec(),
            ..cm
        };
        let pts = individual_points(&t, &cm, &vec![100; t.len()]);
        for (m, p) in pts.iter().enumerate() {
            assert!((p.accuracy - t.accuracy(m)).abs() < 1e-12);
            assert!(p.avg_cost > 0.0);
        }
        let best = best_individual(&pts);
        assert!((best.accuracy - t.accuracy(3)).abs() < 0.05);
    }

    #[test]
    fn frontier_queries() {
        let f: Vec<FrontierPoint> = [(1.0, 0.5), (2.0, 0.7), (4.0, 0.9)]
            .iter()
            .map(|&(c, a)| FrontierPoint {
                plan: CascadePlan::single(0),
                accuracy: a,
                avg_cost: c,
            })
            .collect();
        assert_eq!(frontier_accuracy_at(&f, 0.5), None);
        assert_eq!(frontier_accuracy_at(&f, 2.5), Some(0.7));
        assert_eq!(frontier_cost_to_reach(&f, 0.8), Some(4.0));
        assert_eq!(frontier_cost_to_reach(&f, 0.95), None);
    }
}
