//! Maximum Performance Improvement (paper §4, Fig. 4).
//!
//! `MPI[a][b] = P[model a correct ∧ model b wrong]` — the probability that
//! invoking A *in addition to* B could fix B's mistakes; the paper's
//! measure of marketplace diversity. Note the paper phrases the matrix as
//! "the LLM on its row is wrong but the LLM on its column gives the right
//! answer", i.e. entry (row=b, col=a) = MPI of a w.r.t. b; we expose both
//! orientations.

use crate::coordinator::responses::SplitTable;

/// Popcount of `correct_a ∧ ¬correct_b` over two packed rows — the count
/// of items a gets right and b gets wrong, word-at-a-time. The table's
/// tail bits are zero, so `a & !b` is tail-safe without masking (`a`'s
/// tail contributes zeros through the AND).
fn count_right_wrong(a_words: &[u64], b_words: &[u64]) -> u64 {
    a_words
        .iter()
        .zip(b_words)
        .map(|(&a, &b)| u64::from((a & !b).count_ones()))
        .sum()
}

/// Full MPI matrix: `m[row][col] = P[row wrong ∧ col right]` (the paper's
/// Fig. 4 orientation). Word-at-a-time over the packed correctness rows.
pub fn mpi_matrix(table: &SplitTable) -> Vec<Vec<f64>> {
    let k = table.n_models();
    let n = table.len();
    let mut m = vec![vec![0.0; k]; k];
    for row in 0..k {
        let row_correct = table.correct_words_row(row);
        for col in 0..k {
            if row == col {
                continue;
            }
            let cnt = count_right_wrong(table.correct_words_row(col), row_correct);
            m[row][col] = cnt as f64 / n.max(1) as f64;
        }
    }
    m
}

/// MPI of model `a` with respect to model `b`: P[a right ∧ b wrong].
pub fn mpi(table: &SplitTable, a: usize, b: usize) -> f64 {
    let cnt =
        count_right_wrong(table.correct_words_row(a), table.correct_words_row(b));
    cnt as f64 / table.len().max(1) as f64
}

/// Best improver of `b`: the model with the largest MPI w.r.t. `b`.
pub fn best_improver(table: &SplitTable, b: usize) -> (usize, f64) {
    let mut best = (b, 0.0);
    for a in 0..table.n_models() {
        if a == b {
            continue;
        }
        let v = mpi(table, a, b);
        if v > best.1 {
            best = (a, v);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::responses::synthetic_table;

    #[test]
    fn mpi_consistency_identity_and_bounds() {
        let t = synthetic_table(5, 2000, 4, 0.9, 11);
        let m = mpi_matrix(&t);
        for a in 0..5 {
            assert_eq!(m[a][a], 0.0);
            for b in 0..5 {
                assert!(m[a][b] >= 0.0 && m[a][b] <= 1.0);
                if a != b {
                    // matrix entry (row, col) == mpi(col w.r.t. row)
                    assert!((m[a][b] - mpi(&t, b, a)).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn identity_decomposition() {
        // P[a right] - P[b right] = MPI(a|b) - MPI(b|a).
        let t = synthetic_table(4, 3000, 4, 0.9, 12);
        for a in 0..4 {
            for b in 0..4 {
                let lhs = t.accuracy(a) - t.accuracy(b);
                let rhs = mpi(&t, a, b) - mpi(&t, b, a);
                assert!((lhs - rhs).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn word_at_a_time_counts_match_scalar_recount() {
        // n = 100 leaves 28 tail bits in the second word of each packed
        // row — the case the tail-safety argument in count_right_wrong
        // must cover.
        let t = synthetic_table(4, 100, 4, 0.9, 21);
        for a in 0..4 {
            for b in 0..4 {
                if a == b {
                    continue;
                }
                let scalar = (0..t.len())
                    .filter(|&i| t.is_correct(a, i) && !t.is_correct(b, i))
                    .count() as f64
                    / t.len() as f64;
                assert_eq!(mpi(&t, a, b), scalar, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn weak_models_still_improve_strong_ones() {
        // The marketplace-diversity effect the paper leans on: even the
        // weakest API fixes some of the strongest API's mistakes.
        let t = synthetic_table(6, 5000, 4, 0.9, 13);
        let strongest = 5;
        let (_, v) = best_improver(&t, strongest);
        assert!(v > 0.0);
        assert!(mpi(&t, 0, strongest) > 0.0);
    }
}
