//! The speculate-vs-cascade ablation behind `report strategies`: on a
//! [`SimWorld::correlated`] marketplace the reliability scorer hedges on
//! a fraction of *correct* cheap answers, so a threshold cascade must
//! escalate them to the pricey terminal stage — while the two cheapest
//! models, fired concurrently, *agree* exactly when both are right
//! (independent errors land on model-distinct classes). The calibrated
//! accept rule (`server::calibrate`) turns that agreement into an early
//! accept, skipping the escalation spend; the same replay shows the rule
//! *refusing* to enable when the correlated-error knob makes agreement
//! uninformative — the SMART-style guarantee doing its job.
//!
//! The replay mirrors the serving stack's economics exactly: both probes
//! are billed on every speculated query, an escalated query re-uses the
//! probe answers as seeds (the cascade never re-bills an already-answered
//! stage, `cascade::answer_billed_seeded`), and a disabled calibration
//! reproduces the plain cascade bit-for-bit (the safety identity).

use anyhow::{Context, Result};

use crate::coordinator::cascade::{replay, CascadePlan};
use crate::coordinator::optimizer::{CascadeOptimizer, OptimizerOptions};
use crate::eval::simulate::SimWorld;
use crate::server::calibrate::{CalibratorBundle, SpeculateConfig};
use crate::strategies::speculate::cheapest_pair;

/// Everything `report strategies` renders about the ablation.
#[derive(Debug, Clone)]
pub struct SpeculateAblation {
    /// Marketplace model names (for plan rendering).
    pub model_names: Vec<String>,
    /// The global plan both pipelines serve (the frontier's best point).
    pub global_plan: CascadePlan,
    /// The probe pair (plan's two cheapest distinct models).
    pub pair: (usize, usize),
    /// Whether the calibrated agreement rule came up enabled.
    pub enabled: bool,
    /// The `P(correct | agree)` estimate behind that decision.
    pub p_correct_given_agree: f64,
    /// Replay accuracy of the plain global cascade.
    pub cascade_accuracy: f64,
    /// Replay average USD/query of the plain global cascade.
    pub cascade_avg_cost: f64,
    /// Replay accuracy of the speculative pipeline (probes + accept rule
    /// + seeded escalation).
    pub speculate_accuracy: f64,
    /// Replay average USD/query of the speculative pipeline.
    pub speculate_avg_cost: f64,
    /// Queries accepted on probe agreement (no cascade consulted).
    pub accepts: u64,
    /// Queries escalated to the (seeded) cascade.
    pub escalations: u64,
}

impl SpeculateAblation {
    /// Fractional spend saving of speculation over the plain cascade
    /// (negative = speculation costs more).
    pub fn saving_frac(&self) -> f64 {
        1.0 - self.speculate_avg_cost / self.cascade_avg_cost
    }

    /// Speculative accuracy minus cascade accuracy.
    pub fn accuracy_delta(&self) -> f64 {
        self.speculate_accuracy - self.cascade_accuracy
    }
}

/// Replay both pipelines over one correlated-error world. `rho` is the
/// error-correlation knob: 0.0 = independent errors (agreement is
/// informative, the rule enables and wins), 1.0 = lockstep errors (the
/// rule must refuse and the speculative replay degenerates to the plain
/// cascade). Calibration and evaluation share the table on purpose — the
/// serving loop calibrates on the observation window it is about to
/// serve.
pub fn speculate_vs_cascade(n: usize, seed: u64, rho: f64) -> Result<SpeculateAblation> {
    let w = SimWorld::correlated(3, n, seed, rho);
    let tokens = w.input_tokens();
    let opt = CascadeOptimizer::new(
        &w.table,
        &w.costs,
        tokens.clone(),
        OptimizerOptions::default(),
    )?;
    let frontier = opt.frontier();
    let global = frontier.last().context("empty frontier")?;
    let g = replay::replay(&global.plan, &w.table, &w.costs, &tokens);
    let pair = cheapest_pair(&global.plan, &w.costs)
        .context("global plan has fewer than two distinct models — no probe pair")?;
    let bundle =
        CalibratorBundle::from_table(1, 0, pair, SpeculateConfig::default(), &w.table)?;

    // With no accept rule live, the serving stage passes every query
    // untouched — the speculative pipeline IS the cascade (bit-for-bit).
    if !bundle.accepts_anything() {
        return Ok(SpeculateAblation {
            model_names: w.costs.model_names.clone(),
            global_plan: global.plan.clone(),
            pair,
            enabled: bundle.enabled,
            p_correct_given_agree: bundle.calibration.p_correct_given_agree,
            cascade_accuracy: g.accuracy,
            cascade_avg_cost: g.avg_cost,
            speculate_accuracy: g.accuracy,
            speculate_avg_cost: g.avg_cost,
            accepts: 0,
            escalations: 0,
        });
    }

    let plan = &global.plan;
    let (mut correct, mut spend) = (0u64, 0.0f64);
    let (mut accepts, mut escalations) = (0u64, 0u64);
    for i in 0..w.len() {
        let (pa, sa) = (w.table.pred(pair.0, i), w.table.score(pair.0, i));
        let (pb, sb) = (w.table.pred(pair.1, i), w.table.score(pair.1, i));
        // Both probes are always billed — speculation buys concurrency
        // and early accepts, not free calls.
        let mut cost = w.costs.call_cost(pair.0, tokens[i], pa)
            + w.costs.call_cost(pair.1, tokens[i], pb);
        let answer = if let Some((ans, _score, _lane)) = bundle.accept(pa, sa, pb, sb) {
            accepts += 1;
            ans
        } else {
            escalations += 1;
            // Seeded cascade walk: a stage whose model already answered
            // as a probe is re-used, not re-billed (multiplicity-aware,
            // exactly like `take_seed` on the serving path).
            let mut unclaimed = vec![pair.0, pair.1];
            let last = plan.stages.len() - 1;
            let mut ans = 0u32;
            for (s, stage) in plan.stages.iter().enumerate() {
                let m = stage.model;
                if let Some(p) = unclaimed.iter().position(|&u| u == m) {
                    unclaimed.swap_remove(p);
                } else {
                    cost += w.costs.call_cost(m, tokens[i], w.table.pred(m, i));
                }
                ans = w.table.pred(m, i);
                if s == last || w.table.score(m, i) > stage.threshold {
                    break;
                }
            }
            ans
        };
        correct += (answer == w.table.labels[i]) as u64;
        spend += cost;
    }
    let denom = w.len().max(1) as f64;
    Ok(SpeculateAblation {
        model_names: w.costs.model_names.clone(),
        global_plan: global.plan.clone(),
        pair,
        enabled: bundle.enabled,
        p_correct_given_agree: bundle.calibration.p_correct_given_agree,
        cascade_accuracy: g.accuracy,
        cascade_avg_cost: g.avg_cost,
        speculate_accuracy: correct as f64 / denom,
        speculate_avg_cost: spend / denom,
        accepts,
        escalations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tentpole acceptance bar: with independent errors the
    /// calibrated agreement rule enables and the speculative pipeline
    /// lands at strictly lower spend than the global cascade, within one
    /// accuracy point — and it gets there by actually accepting (not by
    /// a degenerate no-op).
    #[test]
    fn speculation_beats_the_global_cascade_when_agreement_is_informative() {
        let r = speculate_vs_cascade(600, 11, 0.0).unwrap();
        assert!(
            r.global_plan.stages.len() >= 2,
            "the global plan must be a real cascade (got {})",
            r.global_plan.describe(&r.model_names)
        );
        assert!(r.enabled, "P(correct|agree) = {}", r.p_correct_given_agree);
        assert!(
            r.p_correct_given_agree >= 0.99,
            "independent errors never collide, got {}",
            r.p_correct_given_agree
        );
        assert!(r.accepts > 0, "the rule must actually accept");
        assert!(r.escalations > 0, "disagreements must still escalate");
        assert!(
            r.speculate_avg_cost < r.cascade_avg_cost,
            "speculation must be strictly cheaper: ${:.6} vs ${:.6}",
            r.speculate_avg_cost,
            r.cascade_avg_cost
        );
        assert!(
            r.accuracy_delta().abs() <= 0.01,
            "accuracy moved {:.4} (cascade {:.4} speculate {:.4})",
            r.accuracy_delta(),
            r.cascade_accuracy,
            r.speculate_accuracy
        );
    }

    /// The SMART-style guarantee: lockstep errors make agreement
    /// uninformative, the estimate lands under the target, the rule
    /// refuses to enable, and the speculative replay IS the cascade.
    #[test]
    fn calibration_refuses_when_errors_correlate() {
        let r = speculate_vs_cascade(600, 11, 1.0).unwrap();
        assert!(!r.enabled, "P(correct|agree) = {}", r.p_correct_given_agree);
        assert!(r.p_correct_given_agree < 0.9);
        assert_eq!((r.accepts, r.escalations), (0, 0));
        assert_eq!(
            r.speculate_avg_cost.to_bits(),
            r.cascade_avg_cost.to_bits(),
            "disabled rule must reproduce the cascade bit-for-bit"
        );
        assert_eq!(r.speculate_accuracy.to_bits(), r.cascade_accuracy.to_bits());
    }

    #[test]
    fn ablation_is_deterministic() {
        let a = speculate_vs_cascade(300, 5, 0.0).unwrap();
        let b = speculate_vs_cascade(300, 5, 0.0).unwrap();
        assert_eq!(a.global_plan, b.global_plan);
        assert_eq!(a.pair, b.pair);
        assert_eq!((a.accepts, a.escalations), (b.accepts, b.escalations));
        assert_eq!(a.speculate_avg_cost.to_bits(), b.speculate_avg_cost.to_bits());
        assert_eq!(a.cascade_avg_cost.to_bits(), b.cascade_avg_cost.to_bits());
    }
}
