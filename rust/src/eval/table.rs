//! Plain-text table rendering for the report binaries (fixed-width,
//! newline-terminated — easy to diff against EXPERIMENTS.md).

/// Render rows as a fixed-width table with a header and separator.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for r in rows {
        assert_eq!(r.len(), ncol, "row arity mismatch");
        for (c, cell) in r.iter().enumerate() {
            widths[c] = widths[c].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut s = String::new();
        for (c, cell) in cells.iter().enumerate() {
            if c > 0 {
                s.push_str("  ");
            }
            s.push_str(&format!("{:<width$}", cell, width = widths[c]));
        }
        s.trim_end().to_string()
    };
    let hdr: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(r, &widths));
        out.push('\n');
    }
    out
}

/// Format a USD amount with sensible precision for tiny per-query values.
pub fn usd(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.1}")
    } else if v >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// Format a probability/accuracy as a percentage.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render(
            &["api", "acc"],
            &[
                vec!["gpt4".into(), "0.95".into()],
                vec!["gpt_j".into(), "0.88".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("api"));
        assert!(lines[2].starts_with("gpt4"));
    }

    #[test]
    fn usd_precision_scales() {
        assert_eq!(usd(123.456), "123.5");
        assert_eq!(usd(3.14159), "3.14");
        assert_eq!(usd(0.00123), "0.0012");
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.983), "98.3%");
    }
}
