//! Table-backed serving simulation: drive the *real* serving stack
//! (`FrugalService`, the strategy pipeline, the live `Cascade`) from an
//! offline [`SplitTable`] instead of PJRT artifacts.
//!
//! [`table_backed_engine`] wraps a response table as an
//! [`EngineHandle::simulated`] actor: executing model `m` on an item's
//! token row returns one-hot logits at `table.pred(m, i)`, and executing
//! the scorer on a `[query; answer]` row returns the logit whose sigmoid
//! is the table's score for that (item, answer). Rows are recognized by
//! their *query segment*, which is invariant under prompt adaptation —
//! so a truncated prompt still resolves to its item, exactly like the
//! real artifacts (whose simulated models degrade gracefully instead; the
//! table-backed engine holds accuracy constant under truncation, making
//! it the *billing-side* simulation).
//!
//! Two users:
//! * `report strategies` — ablates pipeline stacks over the real
//!   response-table artifacts, deterministically and PJRT-free;
//! * [`SimWorld`] — a fully synthetic marketplace (table, prices, token
//!   layout, engine) for the examples' `--sim` mode and hermetic CI
//!   smoke runs: the whole serving stack end-to-end with zero artifacts.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::coordinator::responses::{synthetic_table, SplitTable};
use crate::data::{layout, prompt, DatasetMeta};
use crate::marketplace::{CostModel, LatencyModel, Pricing};
use crate::runtime::EngineHandle;

/// Wrap `table` as a simulated engine actor. `rows[i]` must be item i's
/// full token row in `meta`'s layout; models are resolved by name against
/// `table.model_names`, plus the reliability `"scorer"`.
pub fn table_backed_engine(
    table: SplitTable,
    rows: &[Vec<i32>],
    meta: DatasetMeta,
) -> Result<EngineHandle> {
    if rows.len() != table.len() {
        bail!("{} rows for a table of {} items", rows.len(), table.len());
    }
    let qlen = meta.query_len();
    let mut by_segment: HashMap<Vec<i32>, usize> = HashMap::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        if row.len() < meta.q_offset + qlen {
            bail!("row {i} shorter than the query segment");
        }
        by_segment.insert(prompt::query_segment(row, &meta).to_vec(), i);
    }
    Ok(EngineHandle::simulated(move |_ds, model, batch| {
        let mut out = Vec::with_capacity(batch.len());
        for r in batch {
            if model == "scorer" {
                // Scorer rows carry the query segment (qlen = meta.qlen+2
                // tokens) at the front and the answer token right after
                // it, at index meta.qlen + 2 == qlen (see
                // prompt::scorer_input) — so the row must be at least
                // qlen + 1 long for both reads below.
                if r.len() < qlen + 1 {
                    bail!("scorer row shorter than query segment + answer token");
                }
                let Some(&item) = by_segment.get(&r[..qlen]) else {
                    bail!("scorer row does not match any item's query segment");
                };
                let answer = (r[meta.qlen + 2] - layout::LABEL_BASE) as u32;
                // g(q, a) depends only on the (query, answer) pair, so any
                // model that gave this answer carries its table score.
                let score = (0..table.n_models())
                    .find(|&m| table.pred(m, item) == answer)
                    .map(|m| f64::from(table.score(m, item)))
                    .unwrap_or(0.05)
                    .clamp(1e-6, 1.0 - 1e-6);
                out.push(vec![(score / (1.0 - score)).ln() as f32]);
            } else {
                let Some(m) = table.model_names.iter().position(|n| n == model) else {
                    bail!("unknown table-backed model {model}");
                };
                if r.len() < meta.q_offset + qlen {
                    bail!("model row shorter than the query segment");
                }
                let Some(&item) = by_segment.get(prompt::query_segment(r, &meta)) else {
                    bail!("model row does not match any item's query segment");
                };
                let mut logits = vec![0.0f32; meta.n_classes];
                logits[table.pred(m, item) as usize] = 1.0;
                out.push(logits);
            }
        }
        Ok(out)
    }))
}

/// A self-consistent synthetic marketplace: K APIs with rising accuracy
/// ([`synthetic_table`]) and rising Table-1-style prices (two orders of
/// magnitude input-price spread, like the paper's testbed), one token
/// layout with a real few-shot prompt segment (so prompt adaptation and
/// concatenation have something to save), and a [`table_backed_engine`]
/// that answers exactly per the table. Everything the serving stack
/// needs, no artifacts.
pub struct SimWorld {
    /// Dataset geometry of the generated rows.
    pub meta: DatasetMeta,
    /// Marketplace pricing aligned with the table's model order.
    pub costs: CostModel,
    /// The response table the engine answers from (labels included).
    pub table: SplitTable,
    rows: Vec<Vec<i32>>,
}

/// Answer classes of the sim world (fixed small, like the paper's tasks).
const SIM_CLASSES: u32 = 4;

impl SimWorld {
    /// A world of `k` APIs over `n` items, deterministic in `seed`.
    pub fn new(k: usize, n: usize, seed: u64) -> SimWorld {
        let meta = DatasetMeta {
            name: "sim".into(),
            seq: 20,
            n_classes: SIM_CLASSES as usize,
            n_examples: 4,
            qlen: 6,
            block_len: 3,
            q_offset: 12,
            scorer_seq: 20,
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let table = synthetic_table(k, n, SIM_CLASSES, 0.9, seed);
        let span = (k.max(2) - 1) as f64;
        let costs = CostModel {
            dataset: "sim".into(),
            model_names: table.model_names.clone(),
            // Smooth two-orders-of-magnitude price ladder: api_0 at $2 /
            // 10M tokens up to $200 for the priciest, mirroring Table 1's
            // spread.
            pricing: (0..k)
                .map(|m| {
                    let usd = 2.0 * 100f64.powf(m as f64 / span);
                    Pricing::new(usd, usd, 0.0)
                })
                .collect(),
            latency: (0..k)
                .map(|m| LatencyModel {
                    base_ms: 30.0 + m as f64,
                    per_1k_tokens_ms: 30.0,
                })
                .collect(),
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let rows = (0..n).map(|i| sim_row(&meta, i)).collect();
        SimWorld { meta, costs, table, rows }
    }

    /// Items in the world.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the world holds no items.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Item i's full token row.
    pub fn row(&self, i: usize) -> &[i32] {
        &self.rows[i]
    }

    /// All token rows (item order).
    pub fn rows(&self) -> &[Vec<i32>] {
        &self.rows
    }

    /// Ground-truth labels (item order).
    pub fn labels(&self) -> &[u32] {
        &self.table.labels
    }

    /// Billable input tokens per item (uniform layout).
    pub fn input_tokens(&self) -> Vec<u32> {
        self.rows.iter().map(|r| prompt::input_tokens(r)).collect()
    }

    /// Spawn a [`table_backed_engine`] actor for this world.
    pub fn engine(&self) -> Result<EngineHandle> {
        table_backed_engine(self.table.clone(), &self.rows, self.meta.clone())
    }
}

/// Item i's token row: 4 dense example blocks, then `[CLS] body [QSEP]`
/// with the item id in the body (each item's query segment is unique, so
/// the table-backed engine can resolve it).
fn sim_row(meta: &DatasetMeta, i: usize) -> Vec<i32> {
    let mut row = vec![layout::PAD; meta.seq];
    for j in 0..meta.n_examples {
        row[j * meta.block_len] = layout::SEP_EX;
        row[j * meta.block_len + 1] = 20 + j as i32;
        row[j * meta.block_len + 2] = layout::LABEL_BASE + (j % meta.n_classes) as i32;
    }
    row[meta.q_offset] = layout::CLS;
    row[meta.q_offset + 1] = 100 + i as i32;
    for p in 1..meta.qlen {
        row[meta.q_offset + 1 + p] = 30 + p as i32;
    }
    row[meta.q_offset + 1 + meta.qlen] = layout::QSEP;
    row
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::argmax;
    use crate::coordinator::scorer::sigmoid;

    #[test]
    fn engine_answers_exactly_per_table() {
        let w = SimWorld::new(3, 24, 11);
        let h = w.engine().unwrap();
        for i in [0usize, 7, 23] {
            for m in 0..3 {
                let logits = h
                    .execute("sim", &w.table.model_names[m], w.row(i).to_vec())
                    .unwrap();
                assert_eq!(argmax(&logits) as u32, w.table.pred(m, i), "item {i} model {m}");
            }
        }
    }

    #[test]
    fn prompt_truncated_rows_still_resolve() {
        let w = SimWorld::new(3, 8, 5);
        let h = w.engine().unwrap();
        let cut = prompt::truncate_examples(w.row(3), &w.meta, 1);
        let logits = h.execute("sim", &w.table.model_names[2], cut).unwrap();
        assert_eq!(argmax(&logits) as u32, w.table.pred(2, 3));
    }

    #[test]
    fn scorer_logit_recovers_table_score() {
        let w = SimWorld::new(3, 16, 9);
        let h = w.engine().unwrap();
        let (i, m) = (5usize, 1usize);
        let answer = w.table.pred(m, i);
        let row = prompt::scorer_input(w.row(i), &w.meta, answer);
        let logits = h.execute("sim", "scorer", row).unwrap();
        let got = sigmoid(logits[0]);
        assert!(
            (f64::from(got) - f64::from(w.table.score(m, i))).abs() < 1e-3,
            "score {} vs table {}",
            got,
            w.table.score(m, i)
        );
    }

    #[test]
    fn unknown_rows_error_instead_of_misattributing() {
        let w = SimWorld::new(2, 4, 3);
        let h = w.engine().unwrap();
        let mut bogus = w.row(0).to_vec();
        bogus[w.meta.q_offset + 1] = 9999; // unknown query segment
        assert!(h.execute("sim", &w.table.model_names[0], bogus).is_err());
        assert!(h
            .execute("sim", "nonexistent_model", w.row(0).to_vec())
            .is_err());
    }

    #[test]
    fn world_is_deterministic_in_seed() {
        let a = SimWorld::new(4, 32, 42);
        let b = SimWorld::new(4, 32, 42);
        assert_eq!(a.labels(), b.labels());
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.table.pred(2, 9), b.table.pred(2, 9));
        assert_eq!(a.input_tokens(), b.input_tokens());
        assert_eq!(a.input_tokens()[0], 20, "12 prompt + 8 query tokens");
    }
}
