//! Table-backed serving simulation: drive the *real* serving stack
//! (`FrugalService`, the strategy pipeline, the live `Cascade`) from an
//! offline [`SplitTable`] instead of PJRT artifacts.
//!
//! [`table_backed_engine`] wraps a response table as an
//! [`EngineHandle::simulated`] actor: executing model `m` on an item's
//! token row returns one-hot logits at `table.pred(m, i)`, and executing
//! the scorer on a `[query; answer]` row returns the logit whose sigmoid
//! is the table's score for that (item, answer). Rows are recognized by
//! their *query segment*, which is invariant under prompt adaptation —
//! so a truncated prompt still resolves to its item, exactly like the
//! real artifacts (whose simulated models degrade gracefully instead; the
//! table-backed engine holds accuracy constant under truncation, making
//! it the *billing-side* simulation).
//!
//! Two users:
//! * `report strategies` — ablates pipeline stacks over the real
//!   response-table artifacts, deterministically and PJRT-free;
//! * [`SimWorld`] — a fully synthetic marketplace (table, prices, token
//!   layout, engine) for the examples' `--sim` mode and hermetic CI
//!   smoke runs: the whole serving stack end-to-end with zero artifacts.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::responses::{synthetic_table, SplitTable, TableBuilder};
use crate::data::{layout, prompt, DatasetMeta};
use crate::marketplace::{CostModel, LatencyModel, Pricing};
use crate::runtime::EngineHandle;
use crate::util::json::Value;
use crate::util::rng::splitmix64_mix;

/// Wrap `table` as a simulated engine actor. `rows[i]` must be item i's
/// full token row in `meta`'s layout; models are resolved by name against
/// `table.model_names`, plus the reliability `"scorer"`.
pub fn table_backed_engine(
    table: SplitTable,
    rows: &[Vec<i32>],
    meta: DatasetMeta,
) -> Result<EngineHandle> {
    if rows.len() != table.len() {
        bail!("{} rows for a table of {} items", rows.len(), table.len());
    }
    let qlen = meta.query_len();
    let mut by_segment: HashMap<Vec<i32>, usize> = HashMap::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        if row.len() < meta.q_offset + qlen {
            bail!("row {i} shorter than the query segment");
        }
        by_segment.insert(prompt::query_segment(row, &meta).to_vec(), i);
    }
    Ok(EngineHandle::simulated(move |_ds, model, batch| {
        let mut out = Vec::with_capacity(batch.len());
        for r in batch {
            if model == "scorer" {
                // Scorer rows carry the query segment (qlen = meta.qlen+2
                // tokens) at the front and the answer token right after
                // it, at index meta.qlen + 2 == qlen (see
                // prompt::scorer_input) — so the row must be at least
                // qlen + 1 long for both reads below.
                if r.len() < qlen + 1 {
                    bail!("scorer row shorter than query segment + answer token");
                }
                let Some(&item) = by_segment.get(&r[..qlen]) else {
                    bail!("scorer row does not match any item's query segment");
                };
                let answer = (r[meta.qlen + 2] - layout::LABEL_BASE) as u32;
                // g(q, a) depends only on the (query, answer) pair, so any
                // model that gave this answer carries its table score.
                let score = (0..table.n_models())
                    .find(|&m| table.pred(m, item) == answer)
                    .map(|m| f64::from(table.score(m, item)))
                    .unwrap_or(0.05)
                    .clamp(1e-6, 1.0 - 1e-6);
                out.push(vec![(score / (1.0 - score)).ln() as f32]);
            } else {
                let Some(m) = table.model_names.iter().position(|n| n == model) else {
                    bail!("unknown table-backed model {model}");
                };
                if r.len() < meta.q_offset + qlen {
                    bail!("model row shorter than the query segment");
                }
                let Some(&item) = by_segment.get(prompt::query_segment(r, &meta)) else {
                    bail!("model row does not match any item's query segment");
                };
                let mut logits = vec![0.0f32; meta.n_classes];
                logits[table.pred(m, item) as usize] = 1.0;
                out.push(logits);
            }
        }
        Ok(out)
    }))
}

/// A self-consistent synthetic marketplace: K APIs with rising accuracy
/// ([`synthetic_table`]) and rising Table-1-style prices (two orders of
/// magnitude input-price spread, like the paper's testbed), one token
/// layout with a real few-shot prompt segment (so prompt adaptation and
/// concatenation have something to save), and a [`table_backed_engine`]
/// that answers exactly per the table. Everything the serving stack
/// needs, no artifacts.
pub struct SimWorld {
    /// Dataset geometry of the generated rows.
    pub meta: DatasetMeta,
    /// Marketplace pricing aligned with the table's model order.
    pub costs: CostModel,
    /// The response table the engine answers from (labels included).
    pub table: SplitTable,
    rows: Vec<Vec<i32>>,
}

/// Answer classes of the sim world (fixed small, like the paper's tasks).
const SIM_CLASSES: u32 = 4;

/// Billable input tokens of the heterogeneous world's short population.
pub const HET_SHORT_TOKENS: usize = 50;
/// Billable input tokens of the heterogeneous world's long population.
pub const HET_LONG_TOKENS: usize = 350;
/// Fraction denominators of the heterogeneous mix: item `i` is long iff
/// `i % HET_MIX == HET_MIX - 1` (so 1 in 4 queries is long/hard).
pub const HET_MIX: usize = 4;

/// Marginal error rate of the *cheapest* model in a
/// [`SimWorld::correlated`] world (pricier models err linearly less,
/// down to 0 for the priciest).
pub const CORR_BASE_ERR: f64 = 0.30;

/// Probability a *correct* answer in a [`SimWorld::correlated`] world
/// scores confidently ([`CORR_CONF_SCORE`]); the rest hedge at
/// [`CORR_HEDGE_SCORE`] — the underconfident-but-right queries a cascade
/// must escalate and probe agreement can rescue.
pub const CORR_CONF: f64 = 0.6;
/// Reliability score of a confident (always correct) answer.
pub const CORR_CONF_SCORE: f32 = 0.92;
/// Reliability score of a hedged answer (right or wrong alike).
pub const CORR_HEDGE_SCORE: f32 = 0.55;

impl SimWorld {
    /// A world of `k` APIs over `n` items, deterministic in `seed`.
    pub fn new(k: usize, n: usize, seed: u64) -> SimWorld {
        let meta = DatasetMeta {
            name: "sim".into(),
            seq: 20,
            n_classes: SIM_CLASSES as usize,
            n_examples: 4,
            qlen: 6,
            block_len: 3,
            q_offset: 12,
            scorer_seq: 20,
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let table = synthetic_table(k, n, SIM_CLASSES, 0.9, seed);
        let span = (k.max(2) - 1) as f64;
        let costs = CostModel {
            dataset: "sim".into(),
            model_names: table.model_names.clone(),
            // Smooth two-orders-of-magnitude price ladder: api_0 at $2 /
            // 10M tokens up to $200 for the priciest, mirroring Table 1's
            // spread.
            pricing: (0..k)
                .map(|m| {
                    let usd = 2.0 * 100f64.powf(m as f64 / span);
                    Pricing::new(usd, usd, 0.0)
                })
                .collect(),
            latency: (0..k)
                .map(|m| LatencyModel {
                    base_ms: 30.0 + m as f64,
                    per_1k_tokens_ms: 30.0,
                })
                .collect(),
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let rows = (0..n).map(|i| sim_row(&meta, i)).collect();
        SimWorld { meta, costs, table, rows }
    }

    /// A heterogeneous-difficulty marketplace where no single `(L, τ)`
    /// cascade is per-query optimal — the testbed of the router-vs-global
    /// ablation (`report strategies`) and the router pipeline tests.
    ///
    /// Three APIs at a $2 / $4 / $8 per-10M price ladder over two query
    /// populations (3 short+easy : 1 long+hard, [`HET_MIX`]):
    ///
    /// * short/easy ([`HET_SHORT_TOKENS`] billable tokens): the cheap API
    ///   is right with a confident 0.95 score — stopping at stage 0 is
    ///   ideal;
    /// * long/hard ([`HET_LONG_TOKENS`] billable tokens): the cheap API
    ///   is *wrong* yet scores an overconfident 0.80, the pricey API is
    ///   right (0.97) — every global cascade wastes the cheap call before
    ///   escalating, so skipping straight to the pricey stage is ideal.
    ///
    /// The mid API answers like the cheap one at twice the price (score
    /// 0.50), so it is Pareto-dominated and never clutters the frontier.
    /// The best single plan is `cheap(τ≈0.87) → pricey` (a midpoint
    /// threshold between the 0.80 and 0.95 score bands, so live sigmoid
    /// roundtrips sit far from the boundary); a contextual router that
    /// reads query length beats it by ~18% cost at identical accuracy.
    pub fn heterogeneous(n: usize, seed: u64) -> SimWorld {
        let meta = DatasetMeta {
            name: "sim-het".into(),
            seq: HET_LONG_TOKENS,
            n_classes: SIM_CLASSES as usize,
            n_examples: 4,
            qlen: 6,
            block_len: 3,
            q_offset: 12,
            scorer_seq: 20,
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let names: Vec<String> =
            ["api_cheap", "api_mid", "api_pricey"].map(String::from).to_vec();
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut b = TableBuilder::new("sim-het", names.clone());
        for i in 0..n {
            let label = rng.below(SIM_CLASSES as u64) as u32;
            let wrong = (label + 1) % SIM_CLASSES;
            let long = i % HET_MIX == HET_MIX - 1;
            let (cheap_pred, cheap_score) =
                if long { (wrong, 0.80f32) } else { (label, 0.95f32) };
            let mid_pred = if long { wrong } else { label };
            let preds = [cheap_pred, mid_pred, label];
            let scores = [cheap_score, 0.50, 0.97];
            let correct = [preds[0] == label, preds[1] == label, true];
            b.push_item(label, &preds, &scores, &correct)
                .expect("aligned per-model triples");
        }
        let table = b.finish().expect("well-formed synthetic rows");
        let costs = CostModel {
            dataset: "sim-het".into(),
            model_names: names,
            pricing: [2.0, 4.0, 8.0]
                .iter()
                .map(|&usd| Pricing::new(usd, usd, 0.0))
                .collect(),
            latency: (0..3)
                .map(|m| LatencyModel {
                    base_ms: 30.0 + m as f64,
                    per_1k_tokens_ms: 30.0,
                })
                .collect(),
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let rows = (0..n)
            .map(|i| {
                let billable = if i % HET_MIX == HET_MIX - 1 {
                    HET_LONG_TOKENS
                } else {
                    HET_SHORT_TOKENS
                };
                hetero_row(&meta, i, billable)
            })
            .collect();
        SimWorld { meta, costs, table, rows }
    }

    /// A marketplace with a tunable *correlated-error* knob — the
    /// testbed of speculative agreement serving. Each of the `k` APIs
    /// has a fixed marginal error rate falling from [`CORR_BASE_ERR`]
    /// (cheapest) to 0 (priciest), and the reliability scorer is *noisy*:
    /// a correct answer is confident ([`CORR_CONF_SCORE`]) only with
    /// probability [`CORR_CONF`], hedging at [`CORR_HEDGE_SCORE`]
    /// otherwise (wrong answers always hedge) — so a threshold cascade
    /// must escalate every hedged query even when the cheap answer was
    /// right. Cross-model *agreement* is the signal that rescues those:
    ///
    /// * `rho = 0` (independent): erring models pick *model-distinct*
    ///   wrong classes, so the two cheapest APIs agree only when both
    ///   are right — `P(correct | agree) = 1` and an agreement-based
    ///   accept rule soundly skips the escalation the hedged scores
    ///   would have forced;
    /// * `rho = 1` (lockstep): every item is judged against one shared
    ///   coin and erring models agree on one shared wrong class —
    ///   `P(correct | agree)` collapses toward the marginal accuracy and
    ///   a *calibrated* accept rule must notice and disable itself.
    ///
    /// Marginal per-model accuracy is identical at every `rho` (both
    /// branches draw from the same uniform); only the joint law moves.
    /// Same token layout and Table-1 price ladder as [`SimWorld::new`].
    pub fn correlated(k: usize, n: usize, seed: u64, rho: f64) -> SimWorld {
        assert!(
            (0.0..=1.0).contains(&rho),
            "correlation rho must be in [0, 1], got {rho}"
        );
        let meta = DatasetMeta {
            name: "sim-corr".into(),
            seq: 20,
            n_classes: SIM_CLASSES as usize,
            n_examples: 4,
            qlen: 6,
            block_len: 3,
            q_offset: 12,
            scorer_seq: 20,
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let names: Vec<String> = (0..k).map(|m| format!("api_{m}")).collect();
        let span = (k.max(2) - 1) as f64;
        // err_m falls linearly to 0 at the priciest model, so cascades
        // still have a real frontier to climb.
        let err = |m: usize| CORR_BASE_ERR * (1.0 - m as f64 / span);
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut b = TableBuilder::new("sim-corr", names.clone());
        for _ in 0..n {
            let label = rng.below(SIM_CLASSES as u64) as u32;
            // The shared wrong class of the correlated branch: when
            // errors coincide, the erring models AGREE on it (that is
            // the whole point of the knob).
            let shared_wrong = (label + 1) % SIM_CLASSES;
            let correlated = rng.f64() < rho;
            let shared_coin = rng.f64();
            let mut preds = Vec::with_capacity(k);
            let mut scores = Vec::with_capacity(k);
            let mut right = Vec::with_capacity(k);
            for m in 0..k {
                let coin = if correlated { shared_coin } else { rng.f64() };
                let is_err = coin < err(m);
                // Independent errors land on model-DISTINCT wrong
                // classes (never a spurious agreement); correlated
                // errors land on the shared one. The +1..C-1 offset can
                // never wrap back onto the label.
                let wrong = if correlated {
                    shared_wrong
                } else {
                    (label + 1 + (m as u32 % (SIM_CLASSES - 1))) % SIM_CLASSES
                };
                let confident = !is_err && rng.f64() < CORR_CONF;
                preds.push(if is_err { wrong } else { label });
                scores.push(if confident { CORR_CONF_SCORE } else { CORR_HEDGE_SCORE });
                right.push(!is_err);
            }
            b.push_item(label, &preds, &scores, &right)
                .expect("aligned per-model triples");
        }
        let table = b.finish().expect("well-formed synthetic rows");
        let costs = CostModel {
            dataset: "sim-corr".into(),
            model_names: names,
            pricing: (0..k)
                .map(|m| {
                    let usd = 2.0 * 100f64.powf(m as f64 / span);
                    Pricing::new(usd, usd, 0.0)
                })
                .collect(),
            latency: (0..k)
                .map(|m| LatencyModel {
                    base_ms: 30.0 + m as f64,
                    per_1k_tokens_ms: 30.0,
                })
                .collect(),
            answer_lens: vec![1; SIM_CLASSES as usize],
        };
        let rows = (0..n).map(|i| sim_row(&meta, i)).collect();
        SimWorld { meta, costs, table, rows }
    }

    /// Whether item `i` belongs to the long/hard population of a
    /// [`SimWorld::heterogeneous`] world (always false for uniform-length
    /// worlds from [`SimWorld::new`]).
    pub fn is_long(&self, i: usize) -> bool {
        prompt::input_tokens(&self.rows[i]) as usize > HET_SHORT_TOKENS
    }

    /// Items in the world.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the world holds no items.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Item i's full token row.
    pub fn row(&self, i: usize) -> &[i32] {
        &self.rows[i]
    }

    /// All token rows (item order).
    pub fn rows(&self) -> &[Vec<i32>] {
        &self.rows
    }

    /// Ground-truth labels (item order).
    pub fn labels(&self) -> &[u32] {
        &self.table.labels
    }

    /// Billable input tokens per item (uniform layout).
    pub fn input_tokens(&self) -> Vec<u32> {
        self.rows.iter().map(|r| prompt::input_tokens(r)).collect()
    }

    /// Spawn a [`table_backed_engine`] actor for this world.
    pub fn engine(&self) -> Result<EngineHandle> {
        table_backed_engine(self.table.clone(), &self.rows, self.meta.clone())
    }

    /// Spawn this world's engine behind a [`fault_injected_engine`]
    /// wrapper scripted by `timeline`. The returned handle is the SAME
    /// production `EngineHandle` type the service executes on — injected
    /// faults surface as real `Err`s/latencies on the serving code path.
    pub fn engine_with(&self, timeline: ScenarioTimeline) -> Result<EngineHandle> {
        Ok(fault_injected_engine(
            self.engine()?,
            &self.costs.model_names,
            timeline,
        ))
    }
}

/// Item i's token row: 4 dense example blocks, then `[CLS] body [QSEP]`
/// with the item id in the body (each item's query segment is unique, so
/// the table-backed engine can resolve it).
fn sim_row(meta: &DatasetMeta, i: usize) -> Vec<i32> {
    let mut row = vec![layout::PAD; meta.seq];
    for j in 0..meta.n_examples {
        row[j * meta.block_len] = layout::SEP_EX;
        row[j * meta.block_len + 1] = 20 + j as i32;
        row[j * meta.block_len + 2] = layout::LABEL_BASE + (j % meta.n_classes) as i32;
    }
    row[meta.q_offset] = layout::CLS;
    row[meta.q_offset + 1] = 100 + i as i32;
    for p in 1..meta.qlen {
        row[meta.q_offset + 1 + p] = 30 + p as i32;
    }
    row[meta.q_offset + 1 + meta.qlen] = layout::QSEP;
    row
}

/// A [`sim_row`] padded out to `billable` non-PAD tokens with filler
/// *after* the query segment — the segment itself stays byte-identical to
/// the uniform layout, so the table-backed engine (and the cache, and the
/// scorer) resolve long rows exactly like short ones; only billing and
/// the router's length feature see the difference.
fn hetero_row(meta: &DatasetMeta, i: usize, billable: usize) -> Vec<i32> {
    let mut row = sim_row(meta, i);
    debug_assert!(billable <= row.len());
    for p in meta.q_offset + meta.query_len()..billable {
        row[p] = 40 + (p % 29) as i32;
    }
    row
}

// ---------------------------------------------------------------------------
// Scripted fault timelines
// ---------------------------------------------------------------------------

/// JSON schema tag of a persisted scenario file.
pub const SCENARIO_FORMAT: &str = "frugalgpt-scenario/v1";

/// Sentinel duration meaning "until the end of the run".
pub const FOREVER: u64 = u64::MAX;

/// One marketplace fault, pure data. Timing lives in [`TimedEvent`];
/// durations are in *queries* (the timeline clock is query-indexed, never
/// wall-clock — hermetic tests advance it explicitly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioEvent {
    /// Model `model` answers a deterministic fraction `rate` of calls
    /// with a 429-style error for `dur` queries.
    RateLimitStorm {
        /// Marketplace model index.
        model: usize,
        /// Fraction of calls rejected (1.0 = every call).
        rate: f64,
        /// Storm length in queries.
        dur: u64,
    },
    /// Model `model`'s calls take `factor`× longer for `dur` queries.
    LatencySpike {
        /// Marketplace model index.
        model: usize,
        /// Multiplier on the injected per-call delay (1.0 = none).
        factor: f64,
        /// Spike length in queries.
        dur: u64,
    },
    /// Model `model`'s pricing is scaled by `mult`, once, at the event's
    /// time. Billing lives in the driver's `CostModel`, not the engine —
    /// drivers apply these via `ScenarioTimeline::price_steps_at` +
    /// `FrugalService::reprice`.
    PriceStep {
        /// Marketplace model index.
        model: usize,
        /// Price multiplier (0.5 = half price, 3.0 = tripled).
        mult: f64,
    },
    /// From the event's time on, a deterministic fraction `|acc_delta|`
    /// of model `model`'s answers are silently rotated to a wrong class —
    /// the un-announced model-version bump that only shadow scoring can
    /// catch.
    SilentDrift {
        /// Marketplace model index.
        model: usize,
        /// Fraction of answers corrupted (sign ignored; 1.0 = all).
        acc_delta: f64,
    },
    /// Model `model` errors on every call for `dur` queries
    /// ([`FOREVER`] = the rest of the run).
    Outage {
        /// Marketplace model index.
        model: usize,
        /// Outage length in queries.
        dur: u64,
    },
}

/// A [`ScenarioEvent`] armed at query-index `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedEvent {
    /// Query index the event fires at (timeline clock value).
    pub at: u64,
    /// The fault.
    pub event: ScenarioEvent,
}

impl TimedEvent {
    /// JSON form: `{"at": t, "kind": ..., "model": m, ...}`.
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("at".to_string(), Value::Num(self.at as f64));
        let (kind, model) = match self.event {
            ScenarioEvent::RateLimitStorm { model, rate, dur } => {
                m.insert("rate".to_string(), Value::Num(rate));
                m.insert("dur".to_string(), Value::Num(dur as f64));
                ("rate_limit_storm", model)
            }
            ScenarioEvent::LatencySpike { model, factor, dur } => {
                m.insert("factor".to_string(), Value::Num(factor));
                m.insert("dur".to_string(), Value::Num(dur as f64));
                ("latency_spike", model)
            }
            ScenarioEvent::PriceStep { model, mult } => {
                m.insert("mult".to_string(), Value::Num(mult));
                ("price_step", model)
            }
            ScenarioEvent::SilentDrift { model, acc_delta } => {
                m.insert("acc_delta".to_string(), Value::Num(acc_delta));
                ("silent_drift", model)
            }
            ScenarioEvent::Outage { model, dur } => {
                if dur != FOREVER {
                    m.insert("dur".to_string(), Value::Num(dur as f64));
                }
                ("outage", model)
            }
        };
        m.insert("kind".to_string(), Value::Str(kind.to_string()));
        m.insert("model".to_string(), Value::Num(model as f64));
        Value::Obj(m)
    }

    /// Parse an event serialized by [`TimedEvent::to_value`].
    pub fn from_value(v: &Value) -> Result<TimedEvent> {
        let at = v.get("at").as_f64().context("event missing `at`")? as u64;
        let kind = v.get("kind").as_str().context("event missing `kind`")?;
        let model = v.get("model").as_usize().context("event missing `model`")?;
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .as_f64()
                .with_context(|| format!("`{kind}` event missing `{key}`"))
        };
        let event = match kind {
            "rate_limit_storm" => ScenarioEvent::RateLimitStorm {
                model,
                rate: num("rate")?,
                dur: num("dur")? as u64,
            },
            "latency_spike" => ScenarioEvent::LatencySpike {
                model,
                factor: num("factor")?,
                dur: num("dur")? as u64,
            },
            "price_step" => ScenarioEvent::PriceStep { model, mult: num("mult")? },
            "silent_drift" => {
                ScenarioEvent::SilentDrift { model, acc_delta: num("acc_delta")? }
            }
            "outage" => ScenarioEvent::Outage {
                model,
                dur: v.get("dur").as_f64().map(|d| d as u64).unwrap_or(FOREVER),
            },
            other => bail!(
                "unknown scenario event kind `{other}` (want rate_limit_storm|\
                 latency_spike|price_step|silent_drift|outage)"
            ),
        };
        Ok(TimedEvent { at, event })
    }
}

/// A scripted marketplace timeline: pure-literal [`TimedEvent`]s indexed
/// by a shared query-count clock. The driver owns the clock
/// ([`ScenarioTimeline::set_now`] / [`ScenarioTimeline::advance`] once
/// per query); the [`fault_injected_engine`] closure only reads it — so a
/// scenario replays bit-identically regardless of wall-clock, thread
/// scheduling, or retry counts. `Clone` shares the clock (engine wrapper
/// and driver see the same time).
#[derive(Debug, Clone)]
pub struct ScenarioTimeline {
    events: Arc<Vec<TimedEvent>>,
    clock: Arc<AtomicU64>,
}

impl ScenarioTimeline {
    /// Timeline over a literal event list, clock at 0.
    pub fn new(events: Vec<TimedEvent>) -> ScenarioTimeline {
        ScenarioTimeline { events: Arc::new(events), clock: Arc::new(AtomicU64::new(0)) }
    }

    /// The scripted events (time order not required; queries scan all).
    pub fn events(&self) -> &[TimedEvent] {
        &self.events
    }

    /// Current clock value (the query index faults are judged against).
    pub fn now(&self) -> u64 {
        self.clock.load(Ordering::Relaxed)
    }

    /// Pin the clock to query index `t` (hermetic tests; serve drivers).
    pub fn set_now(&self, t: u64) {
        self.clock.store(t, Ordering::Relaxed);
    }

    /// Tick the clock by one query; returns the *previous* value (the
    /// index of the query about to be served).
    pub fn advance(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Whether model `m` is in a scripted outage at time `t`.
    pub fn outage(&self, m: usize, t: u64) -> bool {
        self.events.iter().any(|e| match e.event {
            ScenarioEvent::Outage { model, dur } => {
                model == m && t >= e.at && t - e.at < dur
            }
            _ => false,
        })
    }

    /// Combined 429 rejection rate for model `m` at time `t` (max over
    /// active storms; 0.0 = calm).
    pub fn storm_rate(&self, m: usize, t: u64) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e.event {
                ScenarioEvent::RateLimitStorm { model, rate, dur }
                    if model == m && t >= e.at && t - e.at < dur =>
                {
                    Some(rate)
                }
                _ => None,
            })
            .fold(0.0, f64::max)
    }

    /// Latency multiplier for model `m` at time `t` (max over active
    /// spikes; 1.0 = no spike).
    pub fn latency_factor(&self, m: usize, t: u64) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e.event {
                ScenarioEvent::LatencySpike { model, factor, dur }
                    if model == m && t >= e.at && t - e.at < dur =>
                {
                    Some(factor)
                }
                _ => None,
            })
            .fold(1.0, f64::max)
    }

    /// Fraction of model `m`'s answers silently corrupted at time `t`
    /// (max over active drifts; drift is persistent from `at` on).
    pub fn drift_rate(&self, m: usize, t: u64) -> f64 {
        self.events
            .iter()
            .filter_map(|e| match e.event {
                ScenarioEvent::SilentDrift { model, acc_delta }
                    if model == m && t >= e.at =>
                {
                    Some(acc_delta.abs())
                }
                _ => None,
            })
            .fold(0.0, f64::max)
            .min(1.0)
    }

    /// The price steps that fire exactly at time `t`, as
    /// `(model, multiplier)` pairs — the driver applies each ONCE (e.g.
    /// via `FrugalService::reprice`) when its query index comes up.
    pub fn price_steps_at(&self, t: u64) -> Vec<(usize, f64)> {
        self.events
            .iter()
            .filter_map(|e| match e.event {
                ScenarioEvent::PriceStep { model, mult } if e.at == t => {
                    Some((model, mult))
                }
                _ => None,
            })
            .collect()
    }

    /// JSON form (`{"format": "frugalgpt-scenario/v1", "events": [...]}`).
    pub fn to_value(&self) -> Value {
        let mut m = std::collections::HashMap::new();
        m.insert("format".to_string(), Value::Str(SCENARIO_FORMAT.to_string()));
        m.insert(
            "events".to_string(),
            Value::Arr(self.events.iter().map(TimedEvent::to_value).collect()),
        );
        Value::Obj(m)
    }

    /// Parse the [`ScenarioTimeline::to_value`] form (fresh clock at 0).
    pub fn from_value(v: &Value) -> Result<ScenarioTimeline> {
        match v.get("format").as_str() {
            Some(SCENARIO_FORMAT) => {}
            Some(other) => bail!(
                "unsupported scenario format `{other}` (want {SCENARIO_FORMAT})"
            ),
            None => bail!("not a scenario file (missing `format`)"),
        }
        let events = v
            .get("events")
            .as_arr()
            .context("scenario missing `events`")?
            .iter()
            .map(TimedEvent::from_value)
            .collect::<Result<Vec<_>>>()?;
        Ok(ScenarioTimeline::new(events))
    }

    /// Load a scenario file written in the [`ScenarioTimeline::to_value`]
    /// JSON form.
    pub fn load(path: &std::path::Path) -> Result<ScenarioTimeline> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        let v = Value::parse(&text)
            .with_context(|| format!("parsing scenario {}", path.display()))?;
        ScenarioTimeline::from_value(&v)
    }

    /// Named built-in scenarios (`serve --scenario storm` without a
    /// file): `storm` = a full 429 storm on the cheapest model for
    /// queries 40..120. `None` for unknown names.
    pub fn builtin(name: &str) -> Option<ScenarioTimeline> {
        match name {
            "storm" => Some(ScenarioTimeline::new(vec![TimedEvent {
                at: 40,
                event: ScenarioEvent::RateLimitStorm { model: 0, rate: 1.0, dur: 80 },
            }])),
            _ => None,
        }
    }
}

/// Deterministic per-call coin in `[0, 1)`: a pure function of
/// `(time, model, row contents)`, so storms reject the *same* calls on
/// every run — and a retry of the same row in the same query window hits
/// the same verdict (retries cannot wish a scripted storm away).
fn fault_coin(t: u64, m: usize, row: &[i32]) -> f64 {
    let mut h = t
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(m as u64)
        .wrapping_add(1);
    for &tok in row.iter().take(8) {
        h = splitmix64_mix(h ^ (tok as u64));
    }
    (splitmix64_mix(h) >> 11) as f64 / (1u64 << 53) as f64
}

/// Wrap `inner` so calls to the named marketplace models pass through the
/// scripted faults of `timeline` first: outages and 429 storms surface as
/// real `Err`s, latency spikes as real added latency, silent drift as
/// deterministically corrupted answers. The `"scorer"` artifact and any
/// name outside `model_names` pass through untouched. Composable over
/// any engine — `SimWorld::engine_with` for the synthetic marketplace,
/// or a real table-backed engine in `report`/serve drivers.
pub fn fault_injected_engine(
    inner: EngineHandle,
    model_names: &[String],
    timeline: ScenarioTimeline,
) -> EngineHandle {
    let names = model_names.to_vec();
    EngineHandle::simulated(move |ds, model, batch| {
        let Some(m) = names.iter().position(|n| n == model) else {
            return inner.execute_batch(ds, model, batch.to_vec());
        };
        let t = timeline.now();
        if timeline.outage(m, t) {
            bail!("injected outage: {model} is down (t={t})");
        }
        let rate = timeline.storm_rate(m, t);
        if rate > 0.0 {
            // Reject the whole batch if ANY member draws a 429 — real
            // batched API calls fail together, and per-row partial
            // failure would silently shrink batches instead of surfacing
            // the storm.
            if batch.iter().any(|r| fault_coin(t, m, r) < rate) {
                bail!("429 rate limited: {model} is storming (t={t})");
            }
        }
        let factor = timeline.latency_factor(m, t);
        if factor > 1.0 {
            // Injected real latency: 1ms of extra queueing per spike
            // factor unit. Kept small so CI smoke runs stay fast.
            let extra_us = ((factor - 1.0) * 1_000.0).min(50_000.0) as u64;
            std::thread::sleep(std::time::Duration::from_micros(extra_us));
        }
        let mut out = inner.execute_batch(ds, model, batch.to_vec())?;
        let drift = timeline.drift_rate(m, t);
        if drift > 0.0 {
            for (r, logits) in out.iter_mut().enumerate() {
                // Key the coin off the row, salted per-effect so a storm
                // and a drift at the same (t, m) draw independently.
                if fault_coin(t.wrapping_add(0xD1F7), m, &batch[r]) < drift
                    && logits.len() > 1
                {
                    // Rotate the logits one class: the answer silently
                    // moves to a wrong class, scores stay plausible.
                    logits.rotate_right(1);
                }
            }
        }
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cascade::argmax;
    use crate::coordinator::scorer::sigmoid;

    #[test]
    fn engine_answers_exactly_per_table() {
        let w = SimWorld::new(3, 24, 11);
        let h = w.engine().unwrap();
        for i in [0usize, 7, 23] {
            for m in 0..3 {
                let logits = h
                    .execute("sim", &w.table.model_names[m], w.row(i).to_vec())
                    .unwrap();
                assert_eq!(argmax(&logits) as u32, w.table.pred(m, i), "item {i} model {m}");
            }
        }
    }

    #[test]
    fn prompt_truncated_rows_still_resolve() {
        let w = SimWorld::new(3, 8, 5);
        let h = w.engine().unwrap();
        let cut = prompt::truncate_examples(w.row(3), &w.meta, 1);
        let logits = h.execute("sim", &w.table.model_names[2], cut).unwrap();
        assert_eq!(argmax(&logits) as u32, w.table.pred(2, 3));
    }

    #[test]
    fn scorer_logit_recovers_table_score() {
        let w = SimWorld::new(3, 16, 9);
        let h = w.engine().unwrap();
        let (i, m) = (5usize, 1usize);
        let answer = w.table.pred(m, i);
        let row = prompt::scorer_input(w.row(i), &w.meta, answer);
        let logits = h.execute("sim", "scorer", row).unwrap();
        let got = sigmoid(logits[0]);
        assert!(
            (f64::from(got) - f64::from(w.table.score(m, i))).abs() < 1e-3,
            "score {} vs table {}",
            got,
            w.table.score(m, i)
        );
    }

    #[test]
    fn unknown_rows_error_instead_of_misattributing() {
        let w = SimWorld::new(2, 4, 3);
        let h = w.engine().unwrap();
        let mut bogus = w.row(0).to_vec();
        bogus[w.meta.q_offset + 1] = 9999; // unknown query segment
        assert!(h.execute("sim", &w.table.model_names[0], bogus).is_err());
        assert!(h
            .execute("sim", "nonexistent_model", w.row(0).to_vec())
            .is_err());
    }

    #[test]
    fn world_is_deterministic_in_seed() {
        let a = SimWorld::new(4, 32, 42);
        let b = SimWorld::new(4, 32, 42);
        assert_eq!(a.labels(), b.labels());
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.table.pred(2, 9), b.table.pred(2, 9));
        assert_eq!(a.input_tokens(), b.input_tokens());
        assert_eq!(a.input_tokens()[0], 20, "12 prompt + 8 query tokens");
    }

    #[test]
    fn heterogeneous_world_splits_populations_by_length_and_skill() {
        let w = SimWorld::heterogeneous(32, 5);
        let tokens = w.input_tokens();
        for i in 0..w.len() {
            let long = i % HET_MIX == HET_MIX - 1;
            assert_eq!(w.is_long(i), long, "item {i}");
            assert_eq!(
                tokens[i] as usize,
                if long { HET_LONG_TOKENS } else { HET_SHORT_TOKENS },
                "item {i} billable tokens"
            );
            assert_eq!(w.table.is_correct(0, i), !long, "cheap is right iff short");
            assert_eq!(w.table.is_correct(1, i), !long, "mid mirrors cheap");
            assert!(w.table.is_correct(2, i), "pricey is always right");
            let expect = if long { 0.80 } else { 0.95 };
            assert!((w.table.score(0, i) - expect).abs() < 1e-6, "item {i} cheap score");
        }
        // The engine resolves both populations by their (identical-layout)
        // query segments.
        let h = w.engine().unwrap();
        for i in [0usize, 3] {
            let logits = h
                .execute("sim-het", &w.table.model_names[2], w.row(i).to_vec())
                .unwrap();
            assert_eq!(argmax(&logits) as u32, w.table.pred(2, i), "item {i}");
        }
        let b = SimWorld::heterogeneous(32, 5);
        assert_eq!(w.labels(), b.labels());
        assert_eq!(w.rows(), b.rows());
    }

    #[test]
    fn correlated_world_moves_joint_errors_not_marginals() {
        let n = 600usize;
        let indep = SimWorld::correlated(3, n, 17, 0.0);
        let locked = SimWorld::correlated(3, n, 17, 1.0);

        // Marginal per-model accuracy is rho-invariant (same coin law in
        // both branches): each world's accuracy sits near 1 - err_m.
        for w in [&indep, &locked] {
            for m in 0..3 {
                let acc = (0..n).filter(|&i| w.table.is_correct(m, i)).count() as f64
                    / n as f64;
                let expect = 1.0 - CORR_BASE_ERR * (1.0 - m as f64 / 2.0);
                assert!(
                    (acc - expect).abs() < 0.08,
                    "model {m}: accuracy {acc} far from {expect}"
                );
            }
            assert!(
                (0..n).all(|i| w.table.is_correct(2, i)),
                "the priciest model never errs"
            );
        }

        // The JOINT law is what moves: under independence erring models
        // pick model-distinct wrong classes, so the two cheapest APIs
        // NEVER agree on a wrong answer; under lockstep they err to one
        // shared class together (≈ err_1 = 15% of items).
        let agree_wrong = |w: &SimWorld| {
            (0..n)
                .filter(|&i| {
                    w.table.pred(0, i) == w.table.pred(1, i) && !w.table.is_correct(0, i)
                })
                .count()
        };
        assert_eq!(agree_wrong(&indep), 0, "independent errors never collide");
        assert!(
            agree_wrong(&locked) as f64 > 0.08 * n as f64,
            "lockstep must make agree-wrong events common: {}",
            agree_wrong(&locked)
        );
        // Lockstep erring models agree on the SAME wrong class; scores
        // are two-valued and confidence implies correctness.
        for w in [&indep, &locked] {
            for i in 0..n {
                for m in 0..3 {
                    let s = w.table.score(m, i);
                    assert!(s == CORR_CONF_SCORE || s == CORR_HEDGE_SCORE);
                    if s == CORR_CONF_SCORE {
                        assert!(w.table.is_correct(m, i), "confident implies correct");
                    }
                    if !w.table.is_correct(m, i) {
                        assert_eq!(s, CORR_HEDGE_SCORE, "wrong answers always hedge");
                    }
                }
            }
        }
        for i in 0..n {
            for m in 0..3 {
                if !locked.table.is_correct(m, i) {
                    assert_eq!(
                        locked.table.pred(m, i),
                        (locked.table.labels[i] + 1) % SIM_CLASSES
                    );
                }
            }
        }

        // Deterministic in seed, and the engine serves the table.
        let again = SimWorld::correlated(3, n, 17, 1.0);
        assert_eq!(locked.labels(), again.labels());
        assert_eq!(locked.rows(), again.rows());
        let h = locked.engine().unwrap();
        let logits = h
            .execute("sim-corr", &locked.table.model_names[0], locked.row(4).to_vec())
            .unwrap();
        assert_eq!(argmax(&logits) as u32, locked.table.pred(0, 4));
    }

    #[test]
    fn storm_rejects_exactly_in_its_window_and_only_its_model() {
        let w = SimWorld::new(3, 16, 7);
        let tl = ScenarioTimeline::new(vec![TimedEvent {
            at: 5,
            event: ScenarioEvent::RateLimitStorm { model: 0, rate: 1.0, dur: 10 },
        }]);
        let h = w.engine_with(tl.clone()).unwrap();
        let call = |m: usize| h.execute("sim", &w.table.model_names[m], w.row(2).to_vec());

        assert!(call(0).is_ok(), "before the storm");
        tl.set_now(5);
        let err = call(0).unwrap_err();
        assert!(format!("{err:#}").contains("429"), "{err:#}");
        assert!(call(1).is_ok(), "other models are untouched by the storm");
        // scorer passes through untouched
        let srow = prompt::scorer_input(w.row(2), &w.meta, w.table.pred(1, 2));
        assert!(h.execute("sim", "scorer", srow).is_ok());
        tl.set_now(14);
        assert!(call(0).is_err(), "last storm query");
        tl.set_now(15);
        assert!(call(0).is_ok(), "storm is over");
    }

    #[test]
    fn outage_and_drift_inject_on_the_real_call_path() {
        let w = SimWorld::new(3, 12, 21);
        let tl = ScenarioTimeline::new(vec![
            TimedEvent { at: 2, event: ScenarioEvent::Outage { model: 1, dur: 3 } },
            TimedEvent {
                at: 4,
                event: ScenarioEvent::SilentDrift { model: 2, acc_delta: -1.0 },
            },
        ]);
        let h = w.engine_with(tl.clone()).unwrap();
        tl.set_now(2);
        let err = h
            .execute("sim", &w.table.model_names[1], w.row(0).to_vec())
            .unwrap_err();
        assert!(format!("{err:#}").contains("down"), "{err:#}");
        tl.set_now(5); // outage over (2..5), drift on
        assert!(h.execute("sim", &w.table.model_names[1], w.row(0).to_vec()).is_ok());
        for i in 0..4 {
            let logits = h
                .execute("sim", &w.table.model_names[2], w.row(i).to_vec())
                .unwrap();
            let honest = w.table.pred(2, i);
            assert_eq!(
                argmax(&logits) as u32,
                (honest + 1) % SIM_CLASSES,
                "full drift rotates every answer one class"
            );
        }
    }

    #[test]
    fn storm_verdicts_are_deterministic_per_call() {
        let w = SimWorld::new(2, 8, 3);
        let mk = || {
            ScenarioTimeline::new(vec![TimedEvent {
                at: 0,
                event: ScenarioEvent::RateLimitStorm { model: 0, rate: 0.5, dur: 100 },
            }])
        };
        let (ta, tb) = (mk(), mk());
        let ha = w.engine_with(ta.clone()).unwrap();
        let hb = w.engine_with(tb.clone()).unwrap();
        for t in 0..20u64 {
            ta.set_now(t);
            tb.set_now(t);
            let a = ha.execute("sim", &w.table.model_names[0], w.row(1).to_vec());
            let b = hb.execute("sim", &w.table.model_names[0], w.row(1).to_vec());
            assert_eq!(a.is_ok(), b.is_ok(), "verdict must replay at t={t}");
        }
    }

    #[test]
    fn timeline_json_roundtrip_and_corrupt_files() {
        let tl = ScenarioTimeline::new(vec![
            TimedEvent {
                at: 10,
                event: ScenarioEvent::RateLimitStorm { model: 0, rate: 0.9, dur: 40 },
            },
            TimedEvent {
                at: 15,
                event: ScenarioEvent::LatencySpike { model: 1, factor: 4.0, dur: 5 },
            },
            TimedEvent { at: 20, event: ScenarioEvent::PriceStep { model: 2, mult: 0.25 } },
            TimedEvent {
                at: 25,
                event: ScenarioEvent::SilentDrift { model: 0, acc_delta: -0.3 },
            },
            TimedEvent { at: 30, event: ScenarioEvent::Outage { model: 3, dur: FOREVER } },
        ]);
        let json = tl.to_value().to_json();
        let back = ScenarioTimeline::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back.events(), tl.events());

        for (bad, msg) in [
            (r#"{"events": []}"#, "missing `format`"),
            (r#"{"format": "frugalgpt-scenario/v0", "events": []}"#, "unsupported"),
            (r#"{"format": "frugalgpt-scenario/v1"}"#, "missing `events`"),
            (
                r#"{"format": "frugalgpt-scenario/v1",
                    "events": [{"at": 1, "kind": "teleport", "model": 0}]}"#,
                "unknown scenario event kind",
            ),
        ] {
            let err = ScenarioTimeline::from_value(&Value::parse(bad).unwrap()).unwrap_err();
            assert!(format!("{err:#}").contains(msg), "{bad} → {err:#}");
        }
    }

    #[test]
    fn price_steps_fire_exactly_once_at_their_index() {
        let tl = ScenarioTimeline::new(vec![
            TimedEvent { at: 8, event: ScenarioEvent::PriceStep { model: 1, mult: 3.0 } },
            TimedEvent { at: 8, event: ScenarioEvent::PriceStep { model: 0, mult: 0.5 } },
        ]);
        assert!(tl.price_steps_at(7).is_empty());
        assert_eq!(tl.price_steps_at(8), vec![(1, 3.0), (0, 0.5)]);
        assert!(tl.price_steps_at(9).is_empty());
    }

    #[test]
    fn builtin_storm_targets_the_cheap_model() {
        let tl = ScenarioTimeline::builtin("storm").expect("storm is built in");
        assert!(tl.storm_rate(0, 40) >= 1.0);
        assert!(tl.storm_rate(0, 119) >= 1.0);
        assert_eq!(tl.storm_rate(0, 120), 0.0);
        assert_eq!(tl.storm_rate(1, 60), 0.0, "only the cheap model storms");
        assert!(ScenarioTimeline::builtin("nope").is_none());
        // the clock is shared across clones (engine wrapper + driver)
        let c = tl.clone();
        c.set_now(99);
        assert_eq!(tl.now(), 99);
        assert_eq!(tl.advance(), 99);
        assert_eq!(c.now(), 100);
    }
}
