//! The router-vs-global ablation behind `report strategies`: on a
//! heterogeneous-difficulty [`SimWorld`] no single `(L, τ)` cascade is
//! per-query optimal, so a trained contextual router — same trainer the
//! serving reoptimizer runs ([`train_router`]) — beats the best global
//! frontier plan on cost at matched accuracy. The short population stays
//! on the global route (stop-at-cheap is already ideal there); the long
//! population skips the cascade prefix straight to the pricey stage,
//! saving the wasted cheap call.

use anyhow::{Context, Result};

use crate::coordinator::cascade::{replay, CascadePlan};
use crate::coordinator::optimizer::{CascadeOptimizer, OptimizerOptions};
use crate::eval::simulate::SimWorld;
use crate::server::router_train::{
    evaluate_router, train_router, RouteSpec, RouterTrainConfig,
};
use crate::strategies::router::{features, route_plans, RouterModel};

/// Everything `report strategies` renders about the ablation.
#[derive(Debug, Clone)]
pub struct RouterAblation {
    /// Marketplace model names (for plan rendering).
    pub model_names: Vec<String>,
    /// The global plan the router is pinned to (the frontier's best point).
    pub global_plan: CascadePlan,
    /// Replay accuracy of serving the global plan to every query.
    pub global_accuracy: f64,
    /// Replay average USD/query of the global plan.
    pub global_avg_cost: f64,
    /// Replay accuracy of the trained per-query router.
    pub router_accuracy: f64,
    /// Replay average USD/query of the trained router.
    pub router_avg_cost: f64,
    /// Fraction of the short population the router keeps on route 0.
    pub short_on_global: f64,
    /// Fraction of the long population the router sends down a prefix skip.
    pub long_on_skip: f64,
    /// Route labels (`global`, `skip1`, `frontierN`, ...).
    pub route_labels: Vec<String>,
    /// Items per route under the trained router (label order).
    pub route_counts: Vec<u64>,
    /// The trained router weights.
    pub router: RouterModel,
}

impl RouterAblation {
    /// Fractional cost saving of the router over the global plan.
    pub fn saving_frac(&self) -> f64 {
        1.0 - self.router_avg_cost / self.global_avg_cost
    }

    /// Router accuracy minus global accuracy (negative = router loses).
    pub fn accuracy_delta(&self) -> f64 {
        self.router_accuracy - self.global_accuracy
    }
}

/// Train a router against the best global plan of a heterogeneous world
/// and replay both policies over the same table. Training and evaluation
/// share the table on purpose: this mirrors the serving loop, where the
/// reoptimizer trains on the observation window it is about to serve.
/// The ablation runs probe-free (the probe would re-bill the stage-0
/// model), so the router reads only the free length feature — exactly
/// the signal that separates the two populations.
pub fn router_vs_global(n: usize, seed: u64, grid: usize) -> Result<RouterAblation> {
    let w = SimWorld::heterogeneous(n, seed);
    let tokens = w.input_tokens();
    let opt =
        CascadeOptimizer::new(&w.table, &w.costs, tokens.clone(), OptimizerOptions::default())?;
    let frontier = opt.frontier();
    // The served global plan: the frontier's most accurate point (the
    // frontier is cost-ascending and Pareto, so that is the last one).
    let global = frontier.last().context("empty frontier")?;
    let labelled = route_plans(&global.plan, &frontier, grid);
    let specs: Vec<RouteSpec> = labelled.iter().map(|(p, s, _)| (p.clone(), *s)).collect();
    let trained =
        train_router(&w.table, &tokens, &specs, None, &w.costs, &RouterTrainConfig::default())?;
    let eval = evaluate_router(&trained.model, &w.table, &tokens, &specs, None, &w.costs)?;
    let g = replay::replay(&global.plan, &w.table, &w.costs, &tokens);

    let (mut short, mut short_on_global) = (0u64, 0u64);
    let (mut long, mut long_on_skip) = (0u64, 0u64);
    for i in 0..w.len() {
        // Probe-free serving features: length only (matches evaluate_router).
        let route = trained
            .model
            .decide(&features(tokens[i], 0.0, 0.0))
            .min(specs.len() - 1);
        if w.is_long(i) {
            long += 1;
            long_on_skip += (specs[route].1 > 0) as u64;
        } else {
            short += 1;
            short_on_global += (route == 0) as u64;
        }
    }

    Ok(RouterAblation {
        model_names: w.costs.model_names.clone(),
        global_plan: global.plan.clone(),
        global_accuracy: g.accuracy,
        global_avg_cost: g.avg_cost,
        router_accuracy: eval.accuracy,
        router_avg_cost: eval.avg_cost,
        short_on_global: short_on_global as f64 / short.max(1) as f64,
        long_on_skip: long_on_skip as f64 / long.max(1) as f64,
        route_labels: labelled.iter().map(|(_, _, l)| l.clone()).collect(),
        route_counts: eval.route_counts,
        router: trained.model,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tentpole acceptance bar: on the heterogeneous mix the router
    /// must cut cost by ≥15% while staying within 1 accuracy point of the
    /// global plan — and it must do so by actually routing (short stays
    /// global, long skips the prefix), not by some pricing accident.
    #[test]
    fn router_beats_the_best_global_plan_on_the_heterogeneous_mix() {
        let r = router_vs_global(256, 7, 4).unwrap();
        assert!(
            r.global_plan.stages.len() >= 2,
            "the best global plan must be a real cascade (got {})",
            r.global_plan.describe(&r.model_names)
        );
        assert!(
            r.saving_frac() >= 0.15,
            "router saves {:.1}% (global ${:.6} vs router ${:.6})",
            r.saving_frac() * 100.0,
            r.global_avg_cost,
            r.router_avg_cost
        );
        assert!(
            r.accuracy_delta().abs() <= 0.01,
            "accuracy moved {:.4} (global {:.4} router {:.4})",
            r.accuracy_delta(),
            r.global_accuracy,
            r.router_accuracy
        );
        assert!(
            r.short_on_global >= 0.8,
            "only {:.2} of short queries stayed on the global route",
            r.short_on_global
        );
        assert!(
            r.long_on_skip >= 0.8,
            "only {:.2} of long queries skipped the prefix",
            r.long_on_skip
        );
        assert_eq!(r.route_labels[0], "global");
        assert_eq!(
            r.route_counts.iter().sum::<u64>(),
            256,
            "every query is routed exactly once"
        );
    }

    #[test]
    fn ablation_is_deterministic() {
        let a = router_vs_global(128, 3, 4).unwrap();
        let b = router_vs_global(128, 3, 4).unwrap();
        assert_eq!(a.router, b.router);
        assert_eq!(a.route_counts, b.route_counts);
        assert_eq!(a.global_avg_cost.to_bits(), b.global_avg_cost.to_bits());
        assert_eq!(a.router_avg_cost.to_bits(), b.router_avg_cost.to_bits());
    }
}
