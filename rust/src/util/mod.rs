//! In-tree substrates that would normally come from crates.io — this
//! build environment is offline, so the repo carries its own:
//!
//! * [`json`] — a complete JSON parser/serializer (serde_json stand-in),
//! * [`rng`] — a small deterministic PRNG (rand stand-in),
//! * [`args`] — CLI flag parsing (clap stand-in),
//! * [`bench`] — a measurement harness (criterion stand-in),
//! * [`hist`] — a log-bucketed latency histogram (hdrhistogram stand-in),
//! * [`prop`] — randomized property testing (proptest stand-in),
//! * [`sync`] — a wait-free snapshot cell (arc-swap stand-in).

pub mod args;
pub mod bench;
pub mod hist;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sync;
