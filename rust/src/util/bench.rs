//! Benchmark harness (criterion stand-in): warmup, timed iterations,
//! mean / p50 / p95 / max, throughput, a stable one-line report that the
//! §Perf logs in EXPERIMENTS.md quote verbatim, and a machine-readable
//! JSON emitter so bench binaries can append to the committed perf
//! trajectory (`BENCH_optimizer.json` et al. — see `make bench`).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (suite/variant).
    pub name: String,
    /// Timed iterations measured.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: Duration,
    /// Median iteration time.
    pub p50: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// 99th-percentile iteration time (tail — the serve-path contention
    /// suite gates swap-storm tails on this).
    pub p99: Duration,
    /// Slowest iteration.
    pub max: Duration,
}

impl BenchResult {
    /// The stable one-line human-readable report.
    pub fn report(&self) -> String {
        format!(
            "{:<32} iters={:<6} mean={:>10.3?} p50={:>10.3?} p95={:>10.3?} p99={:>10.3?} max={:>10.3?} ({:.1}/s)",
            self.name,
            self.iters,
            self.mean,
            self.p50,
            self.p95,
            self.p99,
            self.max,
            1.0 / self.mean.as_secs_f64().max(1e-12),
        )
    }

    /// One result as a JSON object (stable key order, ns-resolution).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":{},\"iters\":{},\"mean_ns\":{},\"p50_ns\":{},\"p95_ns\":{},\"p99_ns\":{},\"max_ns\":{},\"per_sec\":{:.3}}}",
            json_string(&self.name),
            self.iters,
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p95.as_nanos(),
            self.p99.as_nanos(),
            self.max.as_nanos(),
            1.0 / self.mean.as_secs_f64().max(1e-12),
        )
    }
}

/// A whole suite as one JSON document: `{"suite": ..., "meta": {...},
/// "results": [...]}`. `meta` entries land as string values.
/// `raw_sections` are appended as additional top-level keys whose values
/// are spliced in verbatim (already-serialized JSON) — used to carry a
/// preserved `history` array across regenerations of a committed file.
pub fn suite_json(
    suite: &str,
    meta: &[(&str, String)],
    results: &[BenchResult],
    raw_sections: &[(&str, String)],
) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"suite\": {},\n", json_string(suite)));
    out.push_str("  \"meta\": {");
    for (j, (k, v)) in meta.iter().enumerate() {
        if j > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("{}: {}", json_string(k), json_string(v)));
    }
    out.push_str("},\n  \"results\": [\n");
    for (j, r) in results.iter().enumerate() {
        out.push_str("    ");
        out.push_str(&r.to_json());
        if j + 1 < results.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("  ]");
    for (k, raw) in raw_sections {
        out.push_str(&format!(",\n  {}: {}", json_string(k), raw));
    }
    out.push_str("\n}\n");
    out
}

/// Write a suite document to `path`, preserving the `history` array of
/// any committed document already there — the one history-preserving
/// writer behind `BENCH_optimizer.json`, `BENCH_serve.json` and
/// `BENCH_front_door.json` (`make bench-*` regenerates `meta`/`results`;
/// the cross-PR `history` survives every regeneration).
///
/// Returns `true` when a prior history was found and carried over.
/// Refuses to clobber an existing file that does not parse as JSON —
/// that is how a trajectory (and its history) gets silently orphaned.
pub fn write_suite_json(
    path: &str,
    suite: &str,
    meta: &[(&str, String)],
    results: &[BenchResult],
) -> anyhow::Result<bool> {
    use anyhow::{bail, Context};
    let history = match std::fs::read_to_string(path) {
        Ok(raw) => match crate::util::json::Value::parse(&raw) {
            Ok(v) => {
                let h = v.get("history").clone();
                h.as_arr().is_some().then(|| h.to_json())
            }
            Err(e) => bail!(
                "refusing to overwrite {path}: existing file does not parse ({e}); \
                 move it aside first"
            ),
        },
        Err(_) => None,
    };
    let raw_sections: Vec<(&str, String)> = match &history {
        Some(h) => vec![("history", h.clone())],
        None => vec![],
    };
    let doc = suite_json(suite, meta, results, &raw_sections);
    std::fs::write(path, doc).with_context(|| format!("writing bench json {path}"))?;
    Ok(history.is_some())
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `min_time` has elapsed (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_time: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    let min_iters = 10;
    while start.elapsed() < min_time || samples.len() < min_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

/// Benchmark with a fixed iteration count (for expensive bodies).
pub fn bench_n<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        p99: samples[(n * 99 / 100).min(n - 1)],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from eliminating a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_n("spin", 2, 50, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert_eq!(r.iters, 50);
        assert!(r.p50 <= r.p95);
        assert!(r.p95 <= r.p99);
        assert!(r.p99 <= r.max);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn json_emitters_produce_valid_json() {
        let r = bench_n("opt/\"tricky\" name", 0, 3, || {
            black_box(1 + 1);
        });
        let doc = suite_json(
            "optimizer",
            &[("k", "12".to_string()), ("n", "8000".to_string())],
            &[r.clone(), r],
            &[("history", "[{\"pr\": 1}]".to_string())],
        );
        let v = crate::util::json::Value::parse(&doc).expect("suite_json must parse");
        assert_eq!(v.get("suite").as_str(), Some("optimizer"));
        assert_eq!(v.get("meta").get("k").as_str(), Some("12"));
        let results = v.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            v.get("history").as_arr().unwrap()[0].get("pr").as_f64(),
            Some(1.0)
        );
        assert_eq!(
            results[0].get("name").as_str(),
            Some("opt/\"tricky\" name")
        );
        assert!(results[0].get("iters").as_f64().unwrap() == 3.0);
        assert!(results[0].get("mean_ns").as_f64().unwrap() > 0.0);
        assert!(results[0].get("p99_ns").as_f64().is_some());
    }

    #[test]
    fn suite_writer_preserves_history_and_refuses_garbage() {
        let path = std::env::temp_dir().join(format!(
            "frugal_bench_writer_test_{}.json",
            std::process::id()
        ));
        let path_s = path.to_str().unwrap();
        let _ = std::fs::remove_file(&path);
        let r = bench_n("w", 0, 3, || {
            black_box(1 + 1);
        });

        // Fresh file: no history to preserve.
        assert!(!write_suite_json(path_s, "s", &[], std::slice::from_ref(&r)).unwrap());
        // Splice a history in (what a committed trajectory carries).
        let doc = std::fs::read_to_string(&path).unwrap();
        let spliced = doc.replacen("  \"results\":", "  \"history\": [{\"pr\": 8}],\n  \"results\":", 1);
        std::fs::write(&path, spliced).unwrap();
        // Regenerating keeps it.
        assert!(write_suite_json(path_s, "s", &[], std::slice::from_ref(&r)).unwrap());
        let v = crate::util::json::Value::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("history").as_arr().unwrap()[0].get("pr").as_f64(), Some(8.0));
        // An unparsable existing file is never clobbered.
        std::fs::write(&path, "not json").unwrap();
        assert!(write_suite_json(path_s, "s", &[], &[r]).is_err());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "not json");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn timed_mode_reaches_min_iters() {
        let r = bench("fast", 1, Duration::from_millis(5), || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
    }
}
