//! Benchmark harness (criterion stand-in): warmup, timed iterations,
//! mean / p50 / p95 / max, throughput, and a stable one-line report that
//! the §Perf logs in EXPERIMENTS.md quote verbatim.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<32} iters={:<6} mean={:>10.3?} p50={:>10.3?} p95={:>10.3?} max={:>10.3?} ({:.1}/s)",
            self.name,
            self.iters,
            self.mean,
            self.p50,
            self.p95,
            self.max,
            1.0 / self.mean.as_secs_f64().max(1e-12),
        )
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations, then timed iterations
/// until `min_time` has elapsed (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, min_time: Duration, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    let min_iters = 10;
    while start.elapsed() < min_time || samples.len() < min_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    summarize(name, samples)
}

/// Benchmark with a fixed iteration count (for expensive bodies).
pub fn bench_n<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, samples)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_unstable();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: samples[n / 2],
        p95: samples[(n * 95 / 100).min(n - 1)],
        max: samples[n - 1],
    }
}

/// Prevent the optimizer from eliminating a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_n("spin", 2, 50, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert_eq!(r.iters, 50);
        assert!(r.p50 <= r.p95);
        assert!(r.p95 <= r.max);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn timed_mode_reaches_min_iters() {
        let r = bench("fast", 1, Duration::from_millis(5), || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
    }
}
