//! Tiny CLI argument parser: `--flag value`, `--switch`, positionals.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Arguments that were not `--flag`s or their values, in order.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args().skip(1)` or any iterator.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .is_some_and(|n| !n.starts_with("--"))
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process command line (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Value of `--name value` / `--name=value`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// [`Args::get`] with a default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// [`Args::get`] parsed as f64 (None if absent or unparsable).
    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    /// [`Args::get`] parsed as usize (None if absent or unparsable).
    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    /// Whether `--name` appeared (as a switch or with a value).
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn flags_switches_positionals() {
        let a = parse("table3 --budget 6.5 --verbose --out=x.json data");
        assert_eq!(a.positional, vec!["table3", "data"]);
        assert_eq!(a.get_f64("budget"), Some(6.5));
        assert!(a.has("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert!(!a.has("missing"));
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn switch_before_positional() {
        // `--verbose data` — "data" doesn't start with --, so it binds as
        // the flag value; callers use `--verbose` last or `--verbose=true`.
        let a = parse("--flag --other x");
        assert!(a.has("flag"));
        assert_eq!(a.get("other"), Some("x"));
    }
}
