//! HdrHistogram-style log-bucketed latency histogram (hdrhistogram
//! crate stand-in, same vendored-substrate discipline as the rest of
//! [`crate::util`]).
//!
//! Values are `u64` (the load generator records nanoseconds). The first
//! `2^SUB_BITS` values are exact unit-width buckets; above that each
//! power-of-two octave is split into `2^(SUB_BITS-1)` sub-buckets, so
//! the relative quantile error is bounded by `2^-(SUB_BITS-1)` (~3.2%
//! at the default `SUB_BITS = 6`) across the full `u64` range — the
//! property that lets a load generator record millions of latencies
//! into a few KB without presorting.

/// Sub-bucket resolution: `2^SUB_BITS` exact low values, then
/// `2^(SUB_BITS-1)` sub-buckets per octave.
const SUB_BITS: u32 = 6;
const SUB: u64 = 1 << SUB_BITS; // 64
const HALF: usize = (SUB / 2) as usize; // 32 sub-buckets per octave
/// Linear range + one half-resolution row per remaining octave.
const BUCKETS: usize = SUB as usize + (64 - SUB_BITS as usize) * HALF;

/// Log-bucketed histogram of `u64` samples with bounded relative error.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    /// An empty histogram.
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn index(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let top = 63 - v.leading_zeros(); // >= SUB_BITS
        let shift = top - (SUB_BITS - 1); // >= 1
        let mantissa = ((v >> shift) - SUB / 2) as usize; // in [0, HALF)
        SUB as usize + (top - SUB_BITS) as usize * HALF + mantissa
    }

    /// Inclusive upper bound of the values mapping to bucket `i`.
    fn upper_bound(i: usize) -> u64 {
        if i < SUB as usize {
            return i as u64;
        }
        let octave = (i - SUB as usize) / HALF;
        let pos = ((i - SUB as usize) % HALF) as u64;
        let shift = octave as u32 + 1;
        let lower = (SUB / 2 + pos) << shift;
        lower + (1u64 << shift) - 1
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one (per-thread histograms merge
    /// without locks on the record path).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of the recorded samples (exact — tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]`: the upper bound of the bucket
    /// holding the q-th sample, clamped to the recorded max (so the
    /// reported value is within the bucket's ~3.2% relative width of the
    /// true order statistic, and `quantile(1.0) == max()`).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::upper_bound(i).min(self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn buckets_are_contiguous_and_ordered() {
        // Every value maps into a bucket whose bounds contain it, and
        // bucket indices are monotone in the value.
        let mut prev = 0usize;
        for shift in 0..64u32 {
            for &off in &[0u64, 1, 3] {
                let v = (1u64 << shift).saturating_add(off);
                let i = LogHistogram::index(v);
                assert!(v <= LogHistogram::upper_bound(i), "v={v} i={i}");
                assert!(i >= prev || v < (1u64 << shift), "indices monotone");
                prev = i;
            }
        }
        assert!(LogHistogram::index(u64::MAX) < BUCKETS);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in 0..SUB {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), SUB - 1);
        assert_eq!(h.count(), SUB);
        // Unit-width buckets below SUB: the median is exact.
        let q50 = h.quantile(0.5);
        assert_eq!(q50, SUB / 2 - 1);
    }

    #[test]
    fn quantile_relative_error_is_bounded() {
        let mut h = LogHistogram::new();
        let mut rng = Rng::new(7);
        let mut vals: Vec<u64> = (0..10_000).map(|_| 100 + rng.below(10_000_000)).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for &q in &[0.5, 0.95, 0.99, 0.999] {
            let exact = vals[(((q * vals.len() as f64).ceil() as usize).max(1) - 1).min(vals.len() - 1)];
            let got = h.quantile(q);
            let rel = (got as f64 - exact as f64).abs() / exact as f64;
            assert!(
                rel <= 1.0 / HALF as f64 + 1e-9,
                "q={q}: got {got}, exact {exact}, rel err {rel}"
            );
            assert!(got >= exact, "bucket upper bound never under-reports");
        }
        assert_eq!(h.quantile(1.0), *vals.last().unwrap());
    }

    #[test]
    fn merge_equals_recording_everything() {
        let mut rng = Rng::new(11);
        let vals: Vec<u64> = (0..5000).map(|_| rng.below(1 << 40)).collect();
        let mut whole = LogHistogram::new();
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for (i, &v) in vals.iter().enumerate() {
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.max(), whole.max());
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.mean(), whole.mean());
        for &q in &[0.25, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), whole.quantile(q));
        }
    }

    #[test]
    fn quantiles_monotone() {
        let mut h = LogHistogram::new();
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            h.record(rng.below(1 << 30));
        }
        let qs: Vec<u64> =
            [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0].iter().map(|&q| h.quantile(q)).collect();
        for w in qs.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
