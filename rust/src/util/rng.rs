//! Small deterministic PRNG (splitmix64 → xoshiro256**), used by the
//! synthetic workload generators, property tests and benches. Not for
//! cryptography.

/// The splitmix64 increment (golden-ratio constant).
pub const SPLITMIX64_GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// The splitmix64 output-mixing finalizer — the one canonical copy of
/// these magic constants (also used by the cache's MinHash permutations
/// and the shadow sampler; keep callers on this function).
#[inline]
pub fn splitmix64_mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator (any seed is fine, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(SPLITMIX64_GOLDEN);
            splitmix64_mix(sm)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Lemire's method without the rejection loop is fine for our use.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[0, n)` as usize.
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from a Zipf(s) distribution over `{0, .., n-1}` (used by the
    /// cache workload generator: few hot queries, long tail).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF over precomputable weights would be faster; n is
        // small in our workloads so a linear scan is fine.
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
        }
        let mut u = self.f64() * total;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.usize_below(10)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn zipf_is_head_heavy() {
        let mut r = Rng::new(3);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            counts[r.zipf(50, 1.1)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[49] * 5);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
