//! Read-mostly synchronization substrate: a hand-rolled arc-swap.
//!
//! The serving hot path reads the published plan (and the cost model) on
//! EVERY answer, while writes are rare (a reoptimizer swap every N
//! queries, a reprice on a scenario event). A `RwLock<Arc<T>>` makes
//! every one of those reads take a lock — under a swap storm the readers
//! convoy behind the writer and p99 answer latency spikes. This module
//! replaces that with [`SnapshotCell`], an epoch-style double-buffered
//! `Arc<T>` slot:
//!
//! * **Readers never block.** [`SnapshotCell::load`] is two atomic RMWs
//!   and an `Arc` clone on the active slot. A reader retries its slot
//!   acquisition only if a publish landed *between* its two atomic ops —
//!   at most once per concurrent publish, and a publish itself waits for
//!   the retired slot to drain, so the retry chain is bounded by the
//!   (rare) publish rate. There is no writer-held lock a reader can ever
//!   queue behind.
//! * **Writers are serialized** (a `Mutex` among themselves only) and
//!   reclamation is deferred: a publish writes the *inactive* slot, flips
//!   the active index, and the previous `Arc` stays alive until the slot
//!   is reused by the publish after next — readers that already entered
//!   the old slot finish their clone safely.
//!
//! Safety argument (the Dekker-style pairing that makes the `unsafe`
//! sound): a reader increments the slot's guard count and THEN re-checks
//! the active index; a writer flips the active index and THEN waits for
//! the retired slot's guard count to reach zero before overwriting it.
//! All four operations are `SeqCst`, so in any interleaving either the
//! reader's increment is visible to the writer's drain check (the writer
//! waits) or the writer's flip is visible to the reader's re-check (the
//! reader retries the other slot). The slot value is therefore never
//! overwritten while a reader is cloning it.
//!
//! [`SnapshotCell::new_rwlock_baseline`] builds the cell in a
//! `RwLock<Arc<T>>` compatibility mode — functionally identical, every
//! load takes the read lock. It exists so `benches/serve_hot_path.rs`
//! can measure the wait-free path against the exact serialization it
//! replaced, on the same service code path (see `BENCH_serve.json`).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Mutex, RwLock};

/// One buffered slot: an `Arc<T>` guarded by a reader count.
struct Slot<T> {
    readers: AtomicUsize,
    value: UnsafeCell<Arc<T>>,
}

/// The wait-free double-buffer (see module docs for the safety argument).
struct Epoch<T> {
    slots: [Slot<T>; 2],
    /// Index (0/1) of the slot `load` reads; flipped by `store`.
    active: AtomicUsize,
    /// Serializes writers only; never touched by `load`.
    writer: Mutex<()>,
}

enum Inner<T> {
    WaitFree(Epoch<T>),
    /// Bench-only baseline: the exact `RwLock<Arc<T>>` handle this cell
    /// replaced, kept so contention benches compare like with like.
    Baseline(RwLock<Arc<T>>),
}

/// A shared slot holding an `Arc<T>` snapshot: wait-free `load` for
/// readers, serialized `store` for writers. The hot-path replacement for
/// `RwLock<Arc<T>>` (plan handle, cost model).
pub struct SnapshotCell<T> {
    inner: Inner<T>,
}

// SAFETY: the cell hands out `Arc<T>` clones across threads (requires
// T: Send + Sync, same bound Arc itself imposes for sharing) and the
// UnsafeCell is only written under the writer mutex after the reader
// guard count on that slot has drained (module-level safety argument).
unsafe impl<T: Send + Sync> Send for SnapshotCell<T> {}
unsafe impl<T: Send + Sync> Sync for SnapshotCell<T> {}

impl<T> SnapshotCell<T> {
    /// A wait-free cell initially holding `value`.
    pub fn new(value: Arc<T>) -> Self {
        SnapshotCell {
            inner: Inner::WaitFree(Epoch {
                slots: [
                    Slot {
                        readers: AtomicUsize::new(0),
                        value: UnsafeCell::new(value.clone()),
                    },
                    Slot {
                        readers: AtomicUsize::new(0),
                        value: UnsafeCell::new(value),
                    },
                ],
                active: AtomicUsize::new(0),
                writer: Mutex::new(()),
            }),
        }
    }

    /// The `RwLock<Arc<T>>` compatibility mode (bench baseline only —
    /// every `load` takes the read lock, exactly the serialization the
    /// wait-free mode removes).
    pub fn new_rwlock_baseline(value: Arc<T>) -> Self {
        SnapshotCell { inner: Inner::Baseline(RwLock::new(value)) }
    }

    /// Whether this cell runs the bench-only `RwLock` baseline mode.
    pub fn is_rwlock_baseline(&self) -> bool {
        matches!(self.inner, Inner::Baseline(_))
    }

    /// Take a snapshot. Never blocks on a writer: two atomics plus an
    /// `Arc` clone, with at most one slot retry per concurrent publish.
    pub fn load(&self) -> Arc<T> {
        match &self.inner {
            Inner::Baseline(lock) => lock.read().unwrap().clone(),
            Inner::WaitFree(ep) => loop {
                let i = ep.active.load(SeqCst);
                ep.slots[i].readers.fetch_add(1, SeqCst);
                if ep.active.load(SeqCst) == i {
                    // SAFETY: the guard count on slot i is non-zero and
                    // the active index still names i, so any concurrent
                    // publish targets the OTHER slot and any publish that
                    // later retires this slot spins on our guard before
                    // overwriting (module-level pairing argument).
                    let out = unsafe { (*ep.slots[i].value.get()).clone() };
                    ep.slots[i].readers.fetch_sub(1, SeqCst);
                    return out;
                }
                // A publish flipped the active index between our two
                // atomics; back out and read the new active slot.
                ep.slots[i].readers.fetch_sub(1, SeqCst);
            },
        }
    }

    /// Publish a new snapshot unconditionally. Serialized against other
    /// writers; readers are never blocked (they keep loading the old
    /// snapshot until the flip, the new one after).
    pub fn store(&self, value: Arc<T>) {
        self.store_if(value, |_| true);
    }

    /// Publish `value` only if `accept(&current)` approves, atomically
    /// with respect to other writers (readers stay wait-free throughout).
    /// Returns whether the publish happened. This is the hook
    /// compare-and-publish callers (monotone plan versions) build on.
    pub fn store_if(&self, value: Arc<T>, accept: impl FnOnce(&T) -> bool) -> bool {
        match &self.inner {
            Inner::Baseline(lock) => {
                let mut cur = lock.write().unwrap();
                if !accept(&cur) {
                    return false;
                }
                *cur = value;
                true
            }
            Inner::WaitFree(ep) => {
                let _serialize = ep.writer.lock().unwrap();
                let cur = ep.active.load(SeqCst);
                // SAFETY: the writer mutex is held, so no publish is
                // concurrently overwriting either slot; readers only
                // clone from the active slot, never write it.
                if !accept(unsafe { &*ep.slots[cur].value.get() }) {
                    return false;
                }
                let next = 1 - cur;
                // Drain readers that entered the retired slot before the
                // PREVIOUS flip; they only ever clone, and each holds the
                // guard for an Arc-clone's worth of work, so this spin is
                // short and bounded.
                while ep.slots[next].readers.load(SeqCst) != 0 {
                    std::hint::spin_loop();
                }
                // SAFETY: guard count is zero and, with the active index
                // still pointing at `cur`, every future reader either
                // lands on `cur` or re-checks and retries — no reader can
                // be cloning `next` past the drain above.
                unsafe {
                    *ep.slots[next].value.get() = value;
                }
                ep.active.store(next, SeqCst);
                true
            }
        }
    }

    /// Serialized read-modify-write: clone the current value, let `f`
    /// rebuild it, publish the result. Readers stay wait-free and see
    /// either the old or the new snapshot, never a partial one. Returns
    /// `f`'s error without publishing.
    pub fn update<E>(
        &self,
        f: impl FnOnce(&T) -> Result<T, E>,
    ) -> Result<(), E> {
        match &self.inner {
            Inner::Baseline(lock) => {
                let mut cur = lock.write().unwrap();
                let next = f(&cur)?;
                *cur = Arc::new(next);
                Ok(())
            }
            Inner::WaitFree(ep) => {
                // `f` must run under the writer mutex: two racing updates
                // staged outside it would lose one of the writes.
                let _serialize = ep.writer.lock().unwrap();
                let cur = ep.active.load(SeqCst);
                // SAFETY: writer mutex held; see store_if.
                let next = f(unsafe { &*ep.slots[cur].value.get() })?;
                let next_slot = 1 - cur;
                while ep.slots[next_slot].readers.load(SeqCst) != 0 {
                    std::hint::spin_loop();
                }
                // SAFETY: same drain argument as store_if.
                unsafe {
                    *ep.slots[next_slot].value.get() = Arc::new(next);
                }
                ep.active.store(next_slot, SeqCst);
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::thread;

    #[test]
    fn load_store_roundtrip_both_modes() {
        for cell in [
            SnapshotCell::new(Arc::new(1u64)),
            SnapshotCell::new_rwlock_baseline(Arc::new(1u64)),
        ] {
            assert_eq!(*cell.load(), 1);
            cell.store(Arc::new(7));
            assert_eq!(*cell.load(), 7);
            cell.store(Arc::new(8));
            cell.store(Arc::new(9));
            assert_eq!(*cell.load(), 9);
        }
    }

    #[test]
    fn store_if_rejects_without_publishing() {
        for cell in [
            SnapshotCell::new(Arc::new(5u64)),
            SnapshotCell::new_rwlock_baseline(Arc::new(5u64)),
        ] {
            assert!(!cell.store_if(Arc::new(3), |cur| 3 > *cur));
            assert_eq!(*cell.load(), 5, "rejected publish must not land");
            assert!(cell.store_if(Arc::new(9), |cur| 9 > *cur));
            assert_eq!(*cell.load(), 9);
        }
    }

    #[test]
    fn update_is_read_modify_write() {
        let cell = SnapshotCell::new(Arc::new(10u64));
        cell.update::<()>(|v| Ok(v + 1)).unwrap();
        assert_eq!(*cell.load(), 11);
        let err = cell.update(|_| Err("no")).unwrap_err();
        assert_eq!(err, "no");
        assert_eq!(*cell.load(), 11, "failed update must not publish");
    }

    /// The core guarantee under a swap storm: every load observes a value
    /// that was genuinely published, loads are monotone per reader (the
    /// cell never travels back in time), and nothing tears or drops.
    #[test]
    fn concurrent_loads_see_monotone_published_values() {
        for baseline in [false, true] {
            let cell = Arc::new(if baseline {
                SnapshotCell::new_rwlock_baseline(Arc::new(0u64))
            } else {
                SnapshotCell::new(Arc::new(0u64))
            });
            let stop = Arc::new(AtomicBool::new(false));
            let readers: Vec<_> = (0..4)
                .map(|_| {
                    let cell = Arc::clone(&cell);
                    let stop = Arc::clone(&stop);
                    thread::spawn(move || {
                        let mut last = 0u64;
                        let mut n = 0u64;
                        while !stop.load(SeqCst) {
                            let v = *cell.load();
                            assert!(
                                v >= last,
                                "snapshot went backwards: {v} after {last}"
                            );
                            last = v;
                            n += 1;
                        }
                        n
                    })
                })
                .collect();
            // Writer: a storm of strictly increasing publishes. store_if
            // enforces monotonicity exactly like the plan handle does.
            for v in 1..=2000u64 {
                assert!(cell.store_if(Arc::new(v), |cur| v > *cur));
            }
            stop.store(true, SeqCst);
            let total: u64 = readers.into_iter().map(|h| h.join().unwrap()).sum();
            assert!(total > 0, "readers made no progress");
            assert_eq!(*cell.load(), 2000);
        }
    }
}
